import os
import sys

# smoke tests and benches must see ONE device — the 512-device flag is set
# only inside launch/dryrun.py (per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses                                            # noqa: E402

import numpy as np                                            # noqa: E402
import pytest                                                 # noqa: E402


# ---------------------------------------------------------------------------
# shared fixtures & helpers (deduped from the per-file copies: test_failures,
# test_api, test_topology_scenarios, test_engine_equiv all used private
# variants of these)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="session")
def mini_setup():
    """3 paper jobs on the paper fabric — small enough for CPU tests."""
    from repro.core import build_setup, paper_cluster, paper_jobs
    return build_setup(paper_jobs(seed=0, n_each=1), paper_cluster(),
                       split=2)


def with_failures(setup, sched):
    """A copy of ``setup`` carrying the given FailureSchedule."""
    return dataclasses.replace(setup, failures=sched)


def with_ctrl(setup, cfg):
    """A copy of ``setup`` carrying the given CtrlPlaneConfig."""
    return dataclasses.replace(setup, ctrl=cfg)


def with_degradation(setup, sched, spec_slots=None):
    """A copy of ``setup`` carrying the given DegradationSchedule (and
    optionally clone capacity for the speculation axis)."""
    kw = {"degradation": sched}
    if spec_slots is not None:
        kw["spec_slots"] = spec_slots
    return dataclasses.replace(setup, **kw)


def dims(setup):
    """-> (n_hosts, n_links) of the setup's topology (FailureSchedule
    constructor args)."""
    topo = setup.cluster.topo
    return topo.n_hosts, topo.n_links


def tiny_setups():
    """Two tiny heterogeneous scenarios for packed-sweep tests."""
    from repro.core.mapreduce import build_setup
    from repro.core.topology import canonical_tree, leaf_spine
    from repro.scenarios import make_cluster, uniform_workload, zipf_workload
    ls = build_setup(uniform_workload(n_jobs=2, seed=0),
                     make_cluster(leaf_spine(2, 2, 2)), k_max=4)
    ct = build_setup(zipf_workload(n_jobs=3, seed=1),
                     make_cluster(canonical_tree(2, 2, 2)), k_max=4)
    return [("leaf-spine", ls), ("canon-tree", ct)]


def assert_states_equal(a, b, label=""):
    """Leaf-by-leaf bit equality (NaN == NaN) between two SimStates."""
    for name in a._fields:
        la = np.asarray(getattr(a, name))
        lb = np.asarray(getattr(b, name))
        assert la.shape == lb.shape, \
            f"{label}: SimState.{name} shape {la.shape} != {lb.shape}"
        assert np.array_equal(la, lb, equal_nan=True), \
            f"{label}: SimState.{name} values differ"
