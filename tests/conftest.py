import os
import sys

# smoke tests and benches must see ONE device — the 512-device flag is set
# only inside launch/dryrun.py (per the assignment).
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
