"""Unified Experiment API (DESIGN.md §6): policy-field registry single
source of truth, compiled-runner cache (no retrace on equal SimMeta), and
bit-identical deprecated shims."""
import dataclasses
import re
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_states_equal as assert_states_identical
from conftest import tiny_setups as _tiny_setups
from repro.api import (Experiment, PolicyConfig, SimMeta, as_policy_arrays,
                       policy_field_names, runners)
from repro.core import (PLACE_RANDOM, ROUTE_LEGACY, ROUTE_SDN, paper_setup,
                        simulate, simulate_batch, simulate_scenarios)
from repro.core import policies as policy_mod
from repro.core.engine import make_consts
from repro.scenarios import pack_setups, policy_arrays, sweep_grid


# ---------------------------------------------------------------------------
# policy-field registry: ONE source of truth
# ---------------------------------------------------------------------------


def test_registry_matches_engine_consumed_keys():
    """The keys the engine actually reads (pol["..."]) must be exactly the
    registered policy fields — no hand-duplicated lists anywhere."""
    src = (Path(policy_mod.__file__).parent / "engine.py").read_text()
    consumed = set(re.findall(r'pol\[["\'](\w+)["\']\]', src))
    assert consumed == set(policy_field_names())


def test_policy_config_and_packers_derive_from_registry():
    names = policy_field_names()
    assert tuple(vars(PolicyConfig())) == names
    assert tuple(PolicyConfig().as_arrays()) == names
    assert tuple(as_policy_arrays(None)) == names
    assert tuple(policy_arrays([PolicyConfig()])) == names


def test_as_policy_arrays_fills_defaults_and_rejects_unknown():
    pol = as_policy_arrays({"routing": ROUTE_LEGACY})
    assert int(pol["routing"]) == ROUTE_LEGACY
    assert int(pol["job_concurrency"]) == 1_000_000
    assert pol["seed"].dtype == jnp.int32
    with pytest.raises(KeyError):
        as_policy_arrays({"no_such_axis": 1})


def test_register_policy_field_extends_config():
    """Adding a policy axis = one registration; PolicyConfig (the SAME
    import-time class), as_arrays and the sweep packers all pick it up with
    no further edits, and pre-existing instances stay usable."""
    old_instance = PolicyConfig(job_concurrency=3)
    try:
        policy_mod.register_policy_field("test_knob", 7, doc="test-only")
        cfg = PolicyConfig(test_knob=8)       # import-time binding, not stale
        assert cfg.test_knob == 8
        assert int(cfg.as_arrays()["test_knob"]) == 8
        assert int(PolicyConfig().as_arrays()["test_knob"]) == 7
        assert int(as_policy_arrays({"test_knob": 9})["test_knob"]) == 9
        assert "test_knob" in policy_arrays([PolicyConfig()])
        # instances born before the registration fall back to the default
        assert int(old_instance.as_arrays()["test_knob"]) == 7
        assert old_instance.replace(seed=1).seed == 1
        with pytest.raises(ValueError):
            policy_mod.register_policy_field("test_knob", 0)
        with pytest.raises(TypeError):
            PolicyConfig(not_an_axis=1)
    finally:
        policy_mod._REGISTRY.pop("test_knob", None)


# ---------------------------------------------------------------------------
# compiled-runner cache: second run with equal SimMeta never retraces
# ---------------------------------------------------------------------------


def test_cache_no_retrace_on_identical_meta():
    runners.cache_clear()
    scens = _tiny_setups()
    pol = PolicyConfig(placement=PLACE_RANDOM)

    r1 = Experiment(scenarios=scens[0], policies=pol).run()
    traces_after_first = runners.trace_count()
    assert traces_after_first == 1

    r2 = Experiment(scenarios=scens[0], policies=pol).run()
    assert runners.trace_count() == traces_after_first, \
        "second run with identical SimMeta must not retrace"
    assert_states_identical(r1.states, r2.states)

    # a different scenario => different SimMeta => a fresh trace
    Experiment(scenarios=scens[1], policies=pol).run()
    assert runners.trace_count() == traces_after_first + 1

    # and back to the first meta: still cached
    Experiment(scenarios=scens[0], policies=pol).run()
    assert runners.trace_count() == traces_after_first + 1


def test_fleet_no_retrace_on_identical_meta():
    """Experiment.run_fleet: the chunk programs (which bump the same
    trace counter at trace time) compile exactly once — a second
    same-meta, same-width invocation is trace-free."""
    runners.cache_clear()
    setup = _tiny_setups()[0]
    pols = [PolicyConfig(seed=i) for i in range(3)]

    exp = Experiment(scenarios=setup, policies=pols)
    r1 = exp.run_fleet(width=2, chunk_steps=8)
    n = runners.trace_count()
    assert n >= 1

    r2 = Experiment(scenarios=setup, policies=pols).run_fleet(
        width=2, chunk_steps=8)
    assert runners.trace_count() == n, \
        "second run_fleet with identical SimMeta must not retrace"
    assert_states_identical(r1.states, r2.states)


def test_stream_no_retrace_on_identical_meta():
    """Experiment.run_stream: chunk/refill programs compile exactly once —
    replaying the same arrival trace through an equal-meta ring is
    trace-free the second time."""
    from repro.scenarios import get_scenario
    from repro.scenarios.arrivals import PoissonArrivals

    runners.cache_clear()
    setup = get_scenario("leaf-spine", n_jobs=2).build()
    arrivals = PoissonArrivals(rate=0.05, seed=0)

    def one_run():
        exp = Experiment(scenarios=("leaf-spine", setup),
                         policies=PolicyConfig(job_concurrency=2))
        return exp.run_stream(arrivals, horizon=120.0, slots=4,
                              chunk_steps=32)

    r1 = one_run()
    n = runners.trace_count()
    assert n >= 1

    r2 = one_run()
    assert runners.trace_count() == n, \
        "second run_stream with identical SimMeta must not retrace"
    assert r1.jobs[0]["seq"].size == r2.jobs[0]["seq"].size


def test_cache_shared_by_shims():
    """simulate() reuses the same cache — repeated calls are trace-free."""
    runners.cache_clear()
    setup = _tiny_setups()[0][1]
    simulate(setup, PolicyConfig())
    n = runners.trace_count()
    simulate(setup, PolicyConfig())
    simulate(setup, {"routing": ROUTE_SDN})
    assert runners.trace_count() == n


def test_simmeta_hashable_and_dict_compatible():
    _, meta = make_consts(_tiny_setups()[0][1])
    assert isinstance(meta, SimMeta)
    assert hash(meta) == hash(SimMeta.coerce(meta))
    assert meta["n_vms"] == meta.n_vms          # legacy spelling
    with pytest.raises(KeyError):
        meta["not_a_field"]
    legacy = {f.name: getattr(meta, f.name)
              for f in dataclasses.fields(SimMeta)}
    assert SimMeta.coerce(legacy) == meta


# ---------------------------------------------------------------------------
# shim equivalence: old entry points == Experiment path, bit for bit
# ---------------------------------------------------------------------------


def test_simulate_shim_bit_identical_on_paper_fabric():
    setup = paper_setup(seed=0)
    pol = PolicyConfig(routing=ROUTE_SDN, job_concurrency=2)
    old = simulate(setup, pol)
    new = Experiment(scenarios=setup, policies=pol).run()
    assert_states_identical(old, new.state(), "simulate vs Experiment: ")


def test_simulate_batch_shim_bit_identical():
    setup = _tiny_setups()[0][1]
    pols = [PolicyConfig(routing=ROUTE_SDN, job_concurrency=2),
            PolicyConfig(routing=ROUTE_LEGACY, job_concurrency=2)]
    old = simulate_batch(setup, policy_arrays(pols))
    new = Experiment(scenarios=setup,
                     policies=[("sdn", pols[0]), ("legacy", pols[1])]).run()
    squeezed = jax.tree_util.tree_map(lambda a: a[0], new.states)
    assert_states_identical(old, squeezed, "simulate_batch vs Experiment: ")


def test_packed_two_scenario_batch_bit_identical():
    """sweep_grid (deprecated) vs Experiment on a packed heterogeneous
    two-scenario batch, plus the zipped simulate_scenarios diagonal."""
    scens = _tiny_setups()
    pols = [("a", PolicyConfig(job_concurrency=2)),
            ("b", PolicyConfig(placement=PLACE_RANDOM, job_concurrency=2))]
    res = Experiment(scenarios=scens, policies=pols).run()
    grid = sweep_grid(scens, pols)
    S, P = res.n_scenarios, res.n_policies
    regrid = jax.tree_util.tree_map(
        lambda a: a.reshape((S, P) + a.shape[1:]), grid.states)
    assert_states_identical(regrid, res.states, "sweep_grid vs Experiment: ")

    consts, meta = pack_setups([s for _, s in scens])
    zipped = simulate_scenarios(
        consts, meta,
        {k: jnp.asarray(v) for k, v in policy_arrays(
            [p for _, p in pols]).items()})
    diag = jax.tree_util.tree_map(
        lambda a: np.stack([np.asarray(a)[0, 0], np.asarray(a)[1, 1]]),
        res.states)
    assert_states_identical(zipped, diag, "simulate_scenarios vs diagonal: ")


# ---------------------------------------------------------------------------
# Experiment/Results surface
# ---------------------------------------------------------------------------


def test_experiment_seeds_cross_product():
    e = Experiment(scenarios=_tiny_setups()[0],
                   policies=[("p", PolicyConfig())], seeds=[0, 1, 2])
    assert e.policy_names == ["p/s0", "p/s1", "p/s2"]
    assert [p.seed for _, p in e.policies] == [0, 1, 2]
    with pytest.raises(ValueError):
        Experiment(scenarios=_tiny_setups()[0], seeds=[])


def test_experiment_accepts_named_registry_name_pairs():
    e = Experiment(scenarios=[("mine", "canonical-tree")])
    assert e.scenario_names == ["mine"]
    # a top-level (str, str) tuple reads as a sequence of two names
    e2 = Experiment(scenarios=("fat-tree", "canonical-tree"))
    assert len(e2.scenarios) == 2


def test_sweep_grid_shim_preserves_duplicate_labels():
    (name, setup), _ = _tiny_setups()
    res = sweep_grid([("x", setup), ("x", setup)],
                     [("p", PolicyConfig(job_concurrency=2))])
    assert res.scenario_names == ["x", "x"]
    assert res.policy_names == ["p", "p"]


def test_runner_cache_is_lru_bounded():
    runners.cache_clear()
    _, meta = make_consts(_tiny_setups()[0][1])
    for i in range(runners.CACHE_MAX + 5):
        runners.get_runner(meta.replace(max_steps=meta.max_steps + i),
                           "single")
    assert runners.cache_size() == runners.CACHE_MAX
    runners.cache_clear()


def test_results_masks_pad_jobs():
    """In a packed batch the smaller scenario's pad jobs must read NaN,
    and the valid-job numbers must match the scenario's own single run."""
    scens = _tiny_setups()     # 2 jobs vs 3 jobs -> one pad job slot
    res = Experiment(scenarios=scens, policies=PolicyConfig()).run()
    jr = res.job_report()
    assert jr["completion_measured"].shape == (2, 1, 3)
    assert np.all(np.isnan(jr["completion_measured"][0, 0, 2:]))
    assert np.all(np.isfinite(jr["completion_measured"][0, 0, :2]))

    single = Experiment(scenarios=scens[0], policies=PolicyConfig()).run()
    np.testing.assert_allclose(
        np.asarray(single.job_report()["completion_measured"])[0, 0],
        jr["completion_measured"][0, 0, :2], rtol=1e-5)

    rows = res.rows()
    assert len(rows) == 2
    assert {r["scenario"] for r in rows} == {"leaf-spine", "canon-tree"}
    for r in rows:
        assert np.isfinite(r["mean_completion_s"]) and not r["stalled"]


def test_results_summary_matches_summarize():
    from repro.core import summarize
    setup = _tiny_setups()[0][1]
    pol = PolicyConfig(job_concurrency=2)
    res = Experiment(scenarios=setup, policies=pol).run()
    legacy = summarize(setup, simulate(setup, pol))
    mine = res.summary()
    for key in ("transmission_time", "completion_measured", "makespan_s",
                "total_energy_j", "stalled", "steps"):
        np.testing.assert_allclose(np.asarray(mine[key]),
                                   np.asarray(legacy[key]), rtol=1e-6)


def test_experiment_accepts_registry_names():
    res = Experiment(scenarios="canonical-tree",
                     policies={"job_concurrency": 2}).run()
    assert res.scenario_names == ["canonical-tree-d3f2"]
    assert not res.rows()[0]["stalled"]
