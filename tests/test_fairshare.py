"""Fair-share bandwidth and control-plane properties (hypothesis; skipped
when the optional dev dependency is absent — see requirements-dev.txt)."""
import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import (CtrlPlaneConfig, INSTALL_PROACTIVE, INSTALL_REACTIVE,
                        PolicyConfig, simulate)
from repro.core.fairshare import eq3_rates, waterfill_rates
from repro.core.flows import Flow, flows_setup
from repro.core.topology import leaf_spine

INTRA = 1e12


def _random_instance(draw):
    n_links = draw(st.integers(2, 8))
    n_flows = draw(st.integers(1, 10))
    max_hops = draw(st.integers(1, 4))
    bw = np.array([draw(st.floats(0.5, 10.0)) for _ in range(n_links)],
                  np.float32)
    routes = np.full((n_flows, max_hops), -1, np.int32)
    for f in range(n_flows):
        hops = draw(st.integers(1, min(max_hops, n_links)))
        links = draw(st.lists(st.integers(0, n_links - 1), min_size=hops,
                              max_size=hops, unique=True))
        routes[f, :hops] = links
    active = np.array([draw(st.booleans()) for _ in range(n_flows)])
    return bw, routes, active


@st.composite
def instances(draw):
    return _random_instance(draw)


def link_loads(routes, rates, n_links):
    load = np.zeros(n_links)
    for f in range(routes.shape[0]):
        for li in routes[f]:
            if li >= 0:
                load[li] += rates[f]
    return load


@given(instances())
@settings(max_examples=60, deadline=None)
def test_eq3_never_oversubscribes(inst):
    bw, routes, active = inst
    rates = np.asarray(eq3_rates(jnp.asarray(routes), jnp.asarray(active),
                                 jnp.asarray(bw), INTRA))
    assert np.all(rates[~active] == 0)
    load = link_loads(routes, rates, bw.shape[0])
    assert np.all(load <= bw * (1 + 1e-4))


@given(instances())
@settings(max_examples=60, deadline=None)
def test_waterfill_no_oversubscribe_and_saturation(inst):
    bw, routes, active = inst
    rates = np.asarray(waterfill_rates(jnp.asarray(routes),
                                       jnp.asarray(active),
                                       jnp.asarray(bw), INTRA))
    load = link_loads(routes, rates, bw.shape[0])
    assert np.all(load <= bw * (1 + 1e-3))
    # max-min: every active flow crosses at least one (nearly) saturated
    # link — otherwise its rate could grow (Pareto violation)
    for f in range(routes.shape[0]):
        if not active[f] or routes[f].max() < 0:
            continue
        sat = False
        for li in routes[f]:
            if li >= 0 and load[li] >= bw[li] * (1 - 1e-2):
                sat = True
        assert sat, f"flow {f} not bottlenecked anywhere"


@given(instances())
@settings(max_examples=40, deadline=None)
def test_waterfill_total_throughput_geq_eq3(inst):
    bw, routes, active = inst
    r3 = np.asarray(eq3_rates(jnp.asarray(routes), jnp.asarray(active),
                              jnp.asarray(bw), INTRA))
    rw = np.asarray(waterfill_rates(jnp.asarray(routes),
                                    jnp.asarray(active),
                                    jnp.asarray(bw), INTRA))
    assert rw.sum() >= r3.sum() * (1 - 1e-3)


@given(instances(), st.integers(0, 3))
@settings(max_examples=60, deadline=None)
def test_capacity_invariant_both_policies_any_iter_cap(inst, n_iter):
    """The engine-facing invariant: under BOTH traffic policies the summed
    allocation on every link stays within its bandwidth — including when
    the water-fill iteration cap leaves flows unfrozen and the clamped
    fallback kicks in (the old Eq. 3 fallback stacked full-capacity rates
    on top of frozen allocations and oversubscribed shared links)."""
    bw, routes, active = inst
    for rates in (
            eq3_rates(jnp.asarray(routes), jnp.asarray(active),
                      jnp.asarray(bw), INTRA),
            waterfill_rates(jnp.asarray(routes), jnp.asarray(active),
                            jnp.asarray(bw), INTRA),
            # force the iteration-cap fallback path
            waterfill_rates(jnp.asarray(routes), jnp.asarray(active),
                            jnp.asarray(bw), INTRA, n_iter=n_iter)):
        load = link_loads(routes, np.asarray(rates), bw.shape[0])
        assert np.all(load <= bw * (1 + 1e-3)), (load, bw)


# ---------------------------------------------------------------------------
# control-plane properties (DESIGN.md §10)
# ---------------------------------------------------------------------------

# fixed topology + flow count: every draw reuses the same traced program
# (ctrl scalars live in consts; only table_slots changes the trace)
_CTRL_TOPO = leaf_spine(2, 2, 2)


def _ctrl_run(flows, cfg, install_mode=None):
    setup = flows_setup(_CTRL_TOPO, flows)
    if cfg.any_ctrl:
        setup = dataclasses.replace(setup, ctrl=cfg)
    pol = PolicyConfig() if install_mode is None else \
        PolicyConfig(install_mode=install_mode)
    return simulate(setup, pol)


@given(lat=st.floats(0.0, 0.6), rate=st.sampled_from([2.0, 10.0, 100.0]),
       slots=st.sampled_from([0, 2]),
       sizes=st.tuples(st.floats(1.0, 10.0), st.floats(1.0, 10.0)))
@settings(max_examples=15, deadline=None)
def test_controller_work_conservation(lat, rate, slots, sizes):
    """Flow-table conservation: every installed rule either still occupies
    a slot or was evicted — ``occupied == installs - evictions`` EXACTLY,
    for any (latency, rate, slots) config, including the table-less
    slots=0 degenerate."""
    cfg = CtrlPlaneConfig(install_latency=lat, ctrl_rate=rate,
                          table_slots=slots)
    s = _ctrl_run([Flow(0, 2, sizes[0]), Flow(1, 3, sizes[1])], cfg)
    assert not bool(s.stalled)
    installs = int(s.ctrl_installs)
    evictions = int(s.ctrl_evictions)
    occupied = int((np.asarray(s.ftab_pair) >= 0).sum())
    assert installs >= 0 and evictions >= 0
    assert occupied == installs - evictions
    if slots == 0:
        assert installs == evictions      # nothing can be retained


@given(lats=st.tuples(st.floats(0.0, 1.5), st.floats(0.0, 1.5)),
       rate=st.sampled_from([5.0, 50.0]),
       mode=st.sampled_from([INSTALL_REACTIVE, INSTALL_PROACTIVE]))
@settings(max_examples=15, deadline=None)
def test_install_latency_monotone(lats, rate, mode):
    """A slower controller can only delay a single flow: its completion
    time is non-decreasing in install latency under BOTH install modes
    (proactive pre-pins the route but still waits out the install)."""
    lo, hi = sorted(lats)
    t_lo = float(_ctrl_run(
        [Flow(0, 2, 8.0)], CtrlPlaneConfig(install_latency=lo,
                                           ctrl_rate=rate, table_slots=2),
        install_mode=mode).time)
    t_hi = float(_ctrl_run(
        [Flow(0, 2, 8.0)], CtrlPlaneConfig(install_latency=hi,
                                           ctrl_rate=rate, table_slots=2),
        install_mode=mode).time)
    assert t_hi >= t_lo - 1e-4
    # the latency is paid additively on an uncontended path
    assert t_hi - t_lo == pytest.approx(hi - lo, abs=1e-3)
