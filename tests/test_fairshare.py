"""Fair-share bandwidth properties (hypothesis; skipped when the optional
dev dependency is absent — see requirements-dev.txt)."""
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.fairshare import eq3_rates, waterfill_rates

INTRA = 1e12


def _random_instance(draw):
    n_links = draw(st.integers(2, 8))
    n_flows = draw(st.integers(1, 10))
    max_hops = draw(st.integers(1, 4))
    bw = np.array([draw(st.floats(0.5, 10.0)) for _ in range(n_links)],
                  np.float32)
    routes = np.full((n_flows, max_hops), -1, np.int32)
    for f in range(n_flows):
        hops = draw(st.integers(1, min(max_hops, n_links)))
        links = draw(st.lists(st.integers(0, n_links - 1), min_size=hops,
                              max_size=hops, unique=True))
        routes[f, :hops] = links
    active = np.array([draw(st.booleans()) for _ in range(n_flows)])
    return bw, routes, active


@st.composite
def instances(draw):
    return _random_instance(draw)


def link_loads(routes, rates, n_links):
    load = np.zeros(n_links)
    for f in range(routes.shape[0]):
        for li in routes[f]:
            if li >= 0:
                load[li] += rates[f]
    return load


@given(instances())
@settings(max_examples=60, deadline=None)
def test_eq3_never_oversubscribes(inst):
    bw, routes, active = inst
    rates = np.asarray(eq3_rates(jnp.asarray(routes), jnp.asarray(active),
                                 jnp.asarray(bw), INTRA))
    assert np.all(rates[~active] == 0)
    load = link_loads(routes, rates, bw.shape[0])
    assert np.all(load <= bw * (1 + 1e-4))


@given(instances())
@settings(max_examples=60, deadline=None)
def test_waterfill_no_oversubscribe_and_saturation(inst):
    bw, routes, active = inst
    rates = np.asarray(waterfill_rates(jnp.asarray(routes),
                                       jnp.asarray(active),
                                       jnp.asarray(bw), INTRA))
    load = link_loads(routes, rates, bw.shape[0])
    assert np.all(load <= bw * (1 + 1e-3))
    # max-min: every active flow crosses at least one (nearly) saturated
    # link — otherwise its rate could grow (Pareto violation)
    for f in range(routes.shape[0]):
        if not active[f] or routes[f].max() < 0:
            continue
        sat = False
        for li in routes[f]:
            if li >= 0 and load[li] >= bw[li] * (1 - 1e-2):
                sat = True
        assert sat, f"flow {f} not bottlenecked anywhere"


@given(instances())
@settings(max_examples=40, deadline=None)
def test_waterfill_total_throughput_geq_eq3(inst):
    bw, routes, active = inst
    r3 = np.asarray(eq3_rates(jnp.asarray(routes), jnp.asarray(active),
                              jnp.asarray(bw), INTRA))
    rw = np.asarray(waterfill_rates(jnp.asarray(routes),
                                    jnp.asarray(active),
                                    jnp.asarray(bw), INTRA))
    assert rw.sum() >= r3.sum() * (1 - 1e-3)


@given(instances(), st.integers(0, 3))
@settings(max_examples=60, deadline=None)
def test_capacity_invariant_both_policies_any_iter_cap(inst, n_iter):
    """The engine-facing invariant: under BOTH traffic policies the summed
    allocation on every link stays within its bandwidth — including when
    the water-fill iteration cap leaves flows unfrozen and the clamped
    fallback kicks in (the old Eq. 3 fallback stacked full-capacity rates
    on top of frozen allocations and oversubscribed shared links)."""
    bw, routes, active = inst
    for rates in (
            eq3_rates(jnp.asarray(routes), jnp.asarray(active),
                      jnp.asarray(bw), INTRA),
            waterfill_rates(jnp.asarray(routes), jnp.asarray(active),
                            jnp.asarray(bw), INTRA),
            # force the iteration-cap fallback path
            waterfill_rates(jnp.asarray(routes), jnp.asarray(active),
                            jnp.asarray(bw), INTRA, n_iter=n_iter)):
        load = link_loads(routes, np.asarray(rates), bw.shape[0])
        assert np.all(load <= bw * (1 + 1e-3)), (load, bw)
