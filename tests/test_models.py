"""Model zoo: per-arch smoke + serve-path consistency oracles."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import get_model
from repro.models.attention import chunked_attention, naive_attention

KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b, s):
    if cfg.family == "vlm":
        return {"embeds": jax.random.normal(KEY, (b, s, cfg.d_model),
                                            cfg.dtype),
                "pos3": jnp.tile(jnp.arange(s)[None, :, None], (b, 1, 3))}
    if cfg.family == "audio":
        return {"enc_embeds": jax.random.normal(
                    KEY, (b, cfg.enc_seq, cfg.d_model), cfg.dtype),
                "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_shapes_no_nans(arch):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(KEY)
    b, s = 2, 16
    out = jax.jit(lambda p, bb: api.apply(p, bb))(params, make_batch(cfg, b, s))
    assert out["logits"].shape == (b, s, cfg.vocab)
    assert not np.any(np.isnan(np.asarray(out["logits"], np.float32)))


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_instantiable(arch):
    """The EXACT published config builds abstract params (no allocation)."""
    cfg = get_config(arch)
    api = get_model(cfg)
    sds = jax.eval_shape(api.init, KEY)
    n = sum(int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(sds))
    assert n > 1e8  # every assigned arch is >100M params


@pytest.mark.parametrize("arch", ["qwen3-4b", "falcon-mamba-7b",
                                  "jamba-v0.1-52b", "whisper-base",
                                  "qwen3-moe-30b-a3b"])
def test_prefill_decode_matches_full_forward(arch):
    """Greedy digits: decode-with-cache must equal the full forward.

    MoE capacity dropping is batch-context-dependent, so give MoE configs
    enough capacity that no token drops (the equivalence precondition)."""
    import dataclasses
    cfg = get_smoke_config(arch)
    if cfg.is_moe_arch:
        cfg = dataclasses.replace(cfg, capacity_factor=8.0)
    api = get_model(cfg)
    params = api.init(KEY)
    b, s = 2, 12
    batch = make_batch(cfg, b, s)
    full = api.apply(params, batch, remat=False)["logits"]

    cache = api.init_cache(b, 32)
    logits_p, cache = api.prefill(params, batch, cache)
    # prefill returns last-position logits == full forward's last position
    np.testing.assert_allclose(np.asarray(logits_p[:, 0], np.float32),
                               np.asarray(full[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)

    # one decode step with token s must match forward over s+1 tokens
    tok = jnp.full((b, 1), 7, jnp.int32)
    if cfg.family == "vlm":
        extra = {"embeds": jax.random.normal(KEY, (b, 1, cfg.d_model),
                                             cfg.dtype),
                 "pos3": jnp.full((b, 1, 3), s, jnp.int32)}
        logits_d, _ = api.decode_step(params, None, cache,
                                      batch_extra=extra)
        return  # full-forward comparison needs embed concat; smoke only
    logits_d, _ = api.decode_step(params, tok, cache)
    batch2 = dict(batch)
    batch2["tokens"] = jnp.concatenate([batch["tokens"], tok], axis=1)
    full2 = api.apply(params, batch2, remat=False)["logits"]
    np.testing.assert_allclose(np.asarray(logits_d[:, 0], np.float32),
                               np.asarray(full2[:, -1], np.float32),
                               rtol=2e-2, atol=2e-2)


def test_chunked_attention_matches_naive():
    for (b, sq, h, kv, dh, blk) in [(2, 64, 4, 2, 32, 16),
                                    (1, 100, 4, 4, 16, 64),
                                    (2, 33, 8, 2, 16, 8)]:
        ks = jax.random.split(KEY, 3)
        q = jax.random.normal(ks[0], (b, sq, h, dh), jnp.float32)
        k = jax.random.normal(ks[1], (b, sq, kv, dh), jnp.float32)
        v = jax.random.normal(ks[2], (b, sq, kv, dh), jnp.float32)
        got = chunked_attention(q, k, v, causal=True, block_k=blk)
        want = naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-4, atol=1e-5)


def test_moe_capacity_drop_and_mixing():
    from repro.models.moe import moe_apply, moe_init, _capacity
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    p = moe_init(KEY, cfg)
    x = jax.random.normal(KEY, (2, 16, cfg.d_model), cfg.dtype)
    out, aux = moe_apply(p, x, cfg)
    assert out.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3   # Switch aux loss lower bound is 1
    # capacity is TPU-aligned
    assert _capacity(32, cfg) % 8 == 0


def test_mamba_chunked_scan_vs_sequential():
    from repro.kernels.selective_scan.ref import selective_scan_ref
    from repro.models.ssm import _scan_chunked
    b, s, d, n = 2, 37, 8, 4
    ks = jax.random.split(KEY, 3)
    a = jax.random.uniform(ks[0], (b, s, d, n), jnp.float32, 0.6, 0.99)
    bb = jax.random.normal(ks[1], (b, s, d, n), jnp.float32) * 0.1
    c = jax.random.normal(ks[2], (b, s, n), jnp.float32)
    h, h_last = _scan_chunked(a, bb, jnp.zeros((b, d, n)), chunk=8)
    y = jnp.einsum("bsdn,bsn->bsd", h, c)
    want = selective_scan_ref(a, bb, c)
    np.testing.assert_allclose(np.asarray(y), np.asarray(want),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(h[:, -1]),
                               rtol=1e-5)
