"""Docs integrity: README/DESIGN exist and every docstring section
reference into DESIGN.md resolves (same check CI runs via
tools/check_design_refs.py)."""
import importlib.util
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent


def _load_checker():
    spec = importlib.util.spec_from_file_location(
        "check_design_refs", ROOT / "tools" / "check_design_refs.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def test_docs_exist():
    assert (ROOT / "DESIGN.md").exists()
    assert (ROOT / "README.md").exists()
    readme = (ROOT / "README.md").read_text()
    # README must point at the tier-1 verify command and DESIGN.md
    assert "python -m pytest -x -q" in readme
    assert "DESIGN.md" in readme


def test_design_refs_resolve():
    checker = _load_checker()
    errors = checker.check(ROOT)
    assert not errors, "\n".join(errors)


def test_design_refs_checker_finds_refs():
    """The checker must actually see the §2/§3/§5 docstring references —
    guards against the scan regex silently matching nothing."""
    checker = _load_checker()
    tokens = {t for _, _, t in checker.collect_refs(ROOT)}
    assert {"2", "3", "5", "Beyond-paper"} <= tokens, tokens
