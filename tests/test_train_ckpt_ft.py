"""Training, optimizer, checkpoint and fault-tolerance integration."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import ckpt
from repro.configs import get_smoke_config
from repro.data import TokenPipeline, pipeline_jobs
from repro.ft import FailurePlan, StragglerMonitor, TrainDriver
from repro.models import get_model
from repro.train import AdamWConfig, lr_schedule, make_train_step
from repro.train import init as opt_init
from repro.train.optim import compress_grads

KEY = jax.random.PRNGKey(0)


def setup_train(arch="qwen3-4b", compress=False, microbatch=0):
    cfg = get_smoke_config(arch)
    api = get_model(cfg)
    params = api.init(KEY)
    ocfg = AdamWConfig(total_steps=50, warmup_steps=2, compress=compress)
    ostate = opt_init(ocfg, params)
    step = jax.jit(make_train_step(api, ocfg, microbatch=microbatch))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=4, seq=16)
    batch_fn = lambda s: {k: jnp.asarray(v)
                          for k, v in pipe.batch_at(s).items()}
    return api, params, ostate, step, batch_fn


def run_steps(step, params, ostate, batch_fn, n, start=0):
    losses = []
    for i in range(start, start + n):
        params, ostate, met = step(params, ostate, batch_fn(i))
        losses.append(float(met["loss"]))
    return params, ostate, losses


def test_loss_decreases():
    """Overfit ONE fixed batch (the hash-random stream itself is
    unlearnable — its only signal is the uniform marginal)."""
    _, params, ostate, step, batch_fn = setup_train()
    fixed = batch_fn(0)
    _, _, losses = run_steps(step, params, ostate, lambda s: fixed, 8)
    assert losses[-1] < losses[0] - 0.1


def test_microbatch_equivalence():
    """grad accumulation over 2 microbatches == full batch (same data)."""
    _, params, ostate, step1, batch_fn = setup_train(microbatch=0)
    _, params2, ostate2, step2, _ = setup_train(microbatch=2)
    p1, _, l1 = run_steps(step1, params, ostate, batch_fn, 3)
    p2, _, l2 = run_steps(step2, params2, ostate2, batch_fn, 3)
    np.testing.assert_allclose(l1[-1], l2[-1], rtol=2e-2)


def test_compressed_training_converges():
    _, params, ostate, step, batch_fn = setup_train(compress=True)
    fixed = batch_fn(0)
    _, _, losses = run_steps(step, params, ostate, lambda s: fixed, 8)
    assert losses[-1] < losses[0] - 0.1


def test_error_feedback_reduces_bias():
    g = {"w": jnp.asarray(np.random.RandomState(0).randn(64, 64), jnp.float32)}
    e = {"w": jnp.zeros((64, 64), jnp.float32)}
    acc = jnp.zeros((64, 64))
    acc_exact = jnp.zeros((64, 64))
    for _ in range(50):
        gq, e = compress_grads(g, e)
        acc = acc + gq["w"]
        acc_exact = acc_exact + g["w"]
    # with error feedback the accumulated quantized stream tracks the
    # exact sum to within one quantization step
    err = float(jnp.max(jnp.abs(acc - acc_exact)))
    scale = float(jnp.max(jnp.abs(g["w"]))) / 127
    assert err <= 2 * scale * 1.01


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr_peak=1e-3, lr_min=1e-5, warmup_steps=10,
                      total_steps=100)
    assert float(lr_schedule(cfg, jnp.int32(0))) == pytest.approx(0.0)
    assert float(lr_schedule(cfg, jnp.int32(10))) == pytest.approx(1e-3,
                                                                   rel=1e-3)
    assert float(lr_schedule(cfg, jnp.int32(100))) == pytest.approx(
        1e-5, rel=1e-2)


def test_checkpoint_roundtrip_exact():
    _, params, ostate, step, batch_fn = setup_train()
    params, ostate, _ = run_steps(step, params, ostate, batch_fn, 2)
    with tempfile.TemporaryDirectory() as d:
        ckpt.save(d, 2, (params, ostate), extra={"next_step": 2})
        (p2, o2), extra = ckpt.restore(d, (params, ostate))
        assert extra["next_step"] == 2
        for a, b in zip(jax.tree_util.tree_leaves(params),
                        jax.tree_util.tree_leaves(p2)):
            np.testing.assert_array_equal(np.asarray(a, np.float32),
                                          np.asarray(b, np.float32))


def test_crash_restart_bit_identical():
    """Training WITH a crash+restore == training without (determinism)."""
    _, params, ostate, step, batch_fn = setup_train()
    with tempfile.TemporaryDirectory() as d1:
        drv = TrainDriver(step_fn=step, batch_fn=batch_fn, ckpt_dir=d1,
                          ckpt_every=2)
        p_ref, _, info = drv.run(params, ostate, 6)
        assert info["restarts"] == 0
    with tempfile.TemporaryDirectory() as d2:
        drv = TrainDriver(step_fn=step, batch_fn=batch_fn, ckpt_dir=d2,
                          ckpt_every=2,
                          failure_plan=FailurePlan(at_steps={3: "crash"}))
        p_crash, _, info = drv.run(params, ostate, 6)
        assert info["restarts"] == 1
    for a, b in zip(jax.tree_util.tree_leaves(p_ref),
                    jax.tree_util.tree_leaves(p_crash)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_atomic_save_never_corrupts():
    with tempfile.TemporaryDirectory() as d:
        tree = {"w": jnp.ones((4,))}
        ckpt.save(d, 1, tree)
        # a .tmp dir left behind (simulated crash mid-save) is ignored
        os.makedirs(os.path.join(d, ".tmp-dead"), exist_ok=True)
        assert ckpt.latest_step(d) == 1


def test_straggler_monitor():
    mon = StragglerMonitor(n_hosts=4, factor=2.0, patience=2)
    assert mon.observe([1, 1, 1, 1]) == []
    assert mon.observe([1, 1, 5, 1]) == []       # one strike
    assert mon.observe([1, 1, 5, 1]) == [2]      # second strike -> flagged


def test_pipeline_determinism_and_elastic_reshard():
    p1 = TokenPipeline(vocab=1000, batch=8, seq=16, n_hosts=1, host_id=0)
    full = p1.batch_at(5)
    # two hosts, each half the batch: rows must partition the same stream
    a = TokenPipeline(vocab=1000, batch=4, seq=16, n_hosts=2, host_id=0)
    b = TokenPipeline(vocab=1000, batch=4, seq=16, n_hosts=2, host_id=1)
    ba, bb = a.batch_at(5), b.batch_at(5)
    # host 0 rows == rows [0:4) at the equivalent global step offsets
    assert ba["tokens"].shape == (4, 16)
    assert not np.array_equal(ba["tokens"], bb["tokens"])
    # determinism
    np.testing.assert_array_equal(full["tokens"], p1.batch_at(5)["tokens"])
    np.testing.assert_array_equal(full["labels"][:, :-1],
                                  full["tokens"][:, 1:])


def test_pipeline_jobs_render():
    jobs = pipeline_jobs(n_shards=4, shard_gbits=1.0, n_reducers=2)
    assert jobs[0].n_map == 4 and jobs[0].n_reduce == 2
    assert jobs[0].input_gbits == pytest.approx(4.0)
