"""Gray failures, straggler speculation & controller failover
(DESIGN.md §13).

Covers: degradation-schedule semantics (exact rate arithmetic at the
piecewise boundary), the straggler-speculation win path and its off-state
inertness, controller failover accounting, the ``FailureSchedule`` /
``DegradationSchedule`` validation rejections, ``check_finite``
falsifiability, and the full chaos composition (outages x degradation x
failover x speculation) streamed through ``run_stream``.  The §13
OFF-switch bit-identity against the reference kernel lives in
test_engine_equiv.py; the all-unity-factor hypothesis property in
test_chaos_property.py.
"""
import dataclasses

import numpy as np
import pytest

from conftest import (assert_states_equal, dims, with_ctrl, with_degradation,
                      with_failures)
from invariants import check_all, check_chaos, check_finite, check_stream
from repro.core import (DegradationSchedule, PolicyConfig, host_crash,
                        host_slowdown, link_brownout, no_degradation,
                        no_failures, simulate)
from repro.core.ctrlplane import CtrlPlaneConfig
from repro.core.engine import make_consts
from repro.core.flows import Flow, flows_setup
from repro.core.mapreduce import DONE, build_setup
from repro.core.policies import (PLACE_ROUND_ROBIN, ROUTE_SDN, SPEC_OFF,
                                 SPEC_ON)
from repro.core.topology import leaf_spine, torus_2d
from repro.scenarios import get_scenario, make_cluster, uniform_workload


# ---------------------------------------------------------------------------
# validation (the satellite bugfix + the new schedule's rejections)
# ---------------------------------------------------------------------------


def test_failure_validate_rejects_zero_length_window():
    """Regression: ``recover_t <= fail_t`` used to slip through validate
    silently (the window never fired); now it is a hard error."""
    sched = no_failures(4, 8)
    sched.host_fail_t[1] = 10.0
    sched.host_recover_t[1] = 10.0      # zero-length
    with pytest.raises(ValueError, match="recover_t <= fail_t"):
        sched.validate(4, 8)
    sched = no_failures(4, 8)
    sched.link_fail_t[3] = 5.0
    sched.link_recover_t[3] = 2.0       # negative-length
    with pytest.raises(ValueError, match="recover_t <= fail_t"):
        sched.validate(4, 8)


def test_degradation_validate_rejections():
    s = no_degradation(4, 8)
    s.host_slow_t[0] = 10.0
    s.host_restore_t[0] = 10.0
    s.host_factor[0] = 0.5
    with pytest.raises(ValueError, match="restore_t <= slow_t"):
        s.validate(4, 8)
    s = no_degradation(4, 8)
    s.link_slow_t[2] = 1.0
    s.link_factor[2] = 0.0              # a zero factor is an outage, not
    with pytest.raises(ValueError):     # a gray window
        s.validate(4, 8)
    s = no_degradation(4, 8)
    s.host_slow_t[1] = 1.0
    s.host_factor[1] = np.inf
    with pytest.raises(ValueError):
        s.validate(4, 8)
    with pytest.raises(AssertionError, match="shape"):
        no_degradation(4, 8).validate(5, 8)


# ---------------------------------------------------------------------------
# degradation semantics
# ---------------------------------------------------------------------------


def test_link_brownout_exact_piecewise_rate():
    """A factor-0.5 brownout from t=2 on the only cable: 2 s at full rate,
    the remaining 6 units at half rate -> done at 14.  The analytic dt-min
    must hit the t=2 boundary exactly (deg_breaks joins the min)."""
    topo = torus_2d(2, 1, bw=1e9)
    setup = flows_setup(topo, [Flow(0, 1, 8.0)])
    sched = link_brownout(topo.n_hosts, topo.n_links, [0, 1], at=2.0,
                          factor=0.5)
    s = simulate(with_degradation(setup, sched), PolicyConfig())
    assert not bool(s.stalled)
    assert float(s.time) == pytest.approx(14.0, rel=1e-3)
    assert float(s.degraded_time) == pytest.approx(12.0, rel=1e-3)
    # restoring at t=6 gives 2 full + 4*0.5=2 browned + 4 full -> 10
    sched2 = link_brownout(topo.n_hosts, topo.n_links, [0, 1], at=2.0,
                           factor=0.5, restore_at=6.0)
    s2 = simulate(with_degradation(setup, sched2), PolicyConfig())
    assert float(s2.time) == pytest.approx(10.0, rel=1e-3)
    assert float(s2.degraded_time) == pytest.approx(4.0, rel=1e-3)


def test_host_slowdown_stretches_compute(mini_setup):
    """Halving every host's MIPS from t=0 stretches the makespan and the
    whole run counts as degraded time."""
    n_h, n_l = dims(mini_setup)
    sched = no_degradation(n_h, n_l)
    sched.host_slow_t[:] = 0.0
    sched.host_factor[:] = 0.5
    base = simulate(mini_setup, PolicyConfig(job_concurrency=2))
    slow = simulate(with_degradation(mini_setup, sched.validate(n_h, n_l)),
                    PolicyConfig(job_concurrency=2))
    assert not bool(slow.stalled)
    assert float(slow.time) > float(base.time)
    assert float(slow.degraded_time) == pytest.approx(float(slow.time),
                                                      rel=1e-5)
    consts, meta = make_consts(
        with_degradation(mini_setup, sched.validate(n_h, n_l)))
    check_all(consts, meta, slow, label="host-slowdown")


def test_unity_factor_schedule_bit_identical(mini_setup):
    """An attached all-factor-1.0 schedule is structurally OFF: its
    windows are inert, ``has_degradation`` stays False, and the run is
    bitwise the no-schedule program."""
    n_h, n_l = dims(mini_setup)
    sched = no_degradation(n_h, n_l)
    sched.host_slow_t[:] = 3.0          # windows exist, but factor == 1.0
    sched.host_restore_t[:] = 9.0
    assert not sched.validate(n_h, n_l).any_degradation
    base = simulate(mini_setup, PolicyConfig(job_concurrency=2))
    unit = simulate(with_degradation(mini_setup, sched.validate(n_h, n_l)),
                    PolicyConfig(job_concurrency=2))
    assert_states_equal(base, unit, "unity-factor")


# ---------------------------------------------------------------------------
# straggler speculation
# ---------------------------------------------------------------------------


def _straggler_setup(spec_slots):
    """4-host leaf-spine, host 0 crawling at 5% MIPS from t=0: with
    round-robin placement and 8-wide map waves some tasks land on host 0
    and crawl while healthy peers expose them — the textbook straggler.
    (Detection is rate-vs-live-job-median, so the wide template matters:
    a straggler whose peers have all finished is undetectable.)"""
    from repro.scenarios.workloads import JobTemplate
    topo = leaf_spine(2, 2, 2)
    cluster = make_cluster(topo)
    sched = host_slowdown(topo.n_hosts, topo.n_links, host=0, at=0.0,
                          factor=0.05)
    # 6 maps round-robin over 4 VMs puts exactly 2 maps on the slow host
    # and the 2 reduces on healthy vm2/vm3 — the crawling maps are the
    # critical path AND keep healthy peers alive long enough to be seen
    template = JobTemplate(n_map=6, n_reduce=2)
    return build_setup(uniform_workload(n_jobs=1, seed=0, template=template),
                       cluster, degradation=sched, spec_slots=spec_slots)


def test_speculation_beats_straggler():
    setup = _straggler_setup(spec_slots=2)
    pol_off = PolicyConfig(placement=PLACE_ROUND_ROBIN, speculation=SPEC_OFF)
    pol_on = PolicyConfig(placement=PLACE_ROUND_ROBIN, speculation=SPEC_ON)
    off = simulate(setup, pol_off)
    on = simulate(setup, pol_on)
    assert not bool(off.stalled) and not bool(on.stalled)
    # the clone on a healthy host finishes first and wins
    assert int(on.spec_launches) >= 1
    assert int(on.spec_wins) >= 1
    assert float(on.time) < float(off.time)
    # the losing original's runtime is accounted as waste
    assert float(on.spec_wasted) > 0.0
    # speculation=off on the SAME armed setup keeps every counter at zero
    assert int(off.spec_launches) == 0 and int(off.spec_wins) == 0
    assert float(off.spec_wasted) == 0.0
    consts, meta = make_consts(setup)
    for label, s in (("spec-on", on), ("spec-off", off)):
        check_all(consts, meta, s, label=label)


def test_speculation_policy_inert_without_slots():
    """``speculation=on`` with zero clone capacity is bitwise the off
    program — capacity is the structural switch, the policy only picks
    within it."""
    setup = _straggler_setup(spec_slots=0)
    off = simulate(setup, PolicyConfig(placement=PLACE_ROUND_ROBIN,
                                       speculation=SPEC_OFF))
    on = simulate(setup, PolicyConfig(placement=PLACE_ROUND_ROBIN,
                                      speculation=SPEC_ON))
    assert_states_equal(off, on, "no-slots")


def test_clone_never_slower_tie_goes_to_original():
    """On a healthy cluster with clone slots armed, speculation may fire
    (rate noise) but can never lose time: first-finish-wins with ties to
    the original keeps the on-makespan <= off-makespan."""
    topo = leaf_spine(2, 2, 2)
    setup = build_setup(uniform_workload(n_jobs=2, seed=0),
                        make_cluster(topo), spec_slots=2)
    off = simulate(setup, PolicyConfig(speculation=SPEC_OFF))
    on = simulate(setup, PolicyConfig(speculation=SPEC_ON))
    assert float(on.time) <= float(off.time) + 1e-3


# ---------------------------------------------------------------------------
# controller failover
# ---------------------------------------------------------------------------


def test_failover_parks_requests_and_counts(mini_setup):
    base_cfg = CtrlPlaneConfig(install_latency=0.05, ctrl_rate=500.0,
                               table_slots=8)
    fo_cfg = dataclasses.replace(base_cfg, ctrl_fail_t=0.0,
                                 ctrl_recover_t=1e9, failover_delay=5.0,
                                 backup_rate=50.0, backup_latency=0.5)
    base = simulate(with_ctrl(mini_setup, base_cfg),
                    PolicyConfig(job_concurrency=2))
    fo = simulate(with_ctrl(mini_setup, fo_cfg),
                  PolicyConfig(job_concurrency=2))
    assert not bool(fo.stalled)
    # the primary died before the first request: exactly one failover, the
    # whole run served by the slower backup after the handover gap
    assert int(fo.ctrl_failovers) == 1
    assert float(fo.ctrl_failover_park) > 0.0
    assert float(fo.time) > float(base.time)
    # a finite-primary run never touching the outage keeps counters at 0
    assert int(base.ctrl_failovers) == 0
    assert float(base.ctrl_failover_park) == 0.0
    consts, meta = make_consts(with_ctrl(mini_setup, fo_cfg))
    check_all(consts, meta, fo, label="failover")


def test_failover_validate_rejections():
    with pytest.raises(ValueError):
        CtrlPlaneConfig(ctrl_fail_t=10.0, ctrl_recover_t=5.0).validate()
    with pytest.raises(ValueError):
        CtrlPlaneConfig(ctrl_fail_t=10.0, failover_delay=-1.0).validate()
    with pytest.raises(ValueError):
        CtrlPlaneConfig(ctrl_fail_t=10.0, backup_rate=0.0).validate()


# ---------------------------------------------------------------------------
# check_finite falsifiability + chaos accounting
# ---------------------------------------------------------------------------


def test_check_finite_catches_doctored_nan(mini_setup):
    consts, meta = make_consts(mini_setup)
    s = simulate(mini_setup, PolicyConfig(job_concurrency=2))
    check_finite(consts, meta, s)                       # clean state passes
    arr = np.asarray(s.task_rem).copy()
    arr[0] = np.nan
    with pytest.raises(AssertionError, match="task_rem"):
        check_finite(consts, meta, s._replace(task_rem=arr))
    arr = np.asarray(s.host_energy).copy()
    arr[0] = np.inf
    with pytest.raises(AssertionError, match="host_energy"):
        check_finite(consts, meta, s._replace(host_energy=arr))
    # the documented sentinels stay allowed: NaN timestamps, inf park
    bad_ts = np.asarray(s.task_start).copy()
    bad_ts[0] = np.inf                                  # inf is NOT allowed
    with pytest.raises(AssertionError, match="task_start"):
        check_finite(consts, meta, s._replace(task_start=bad_ts))


def test_check_chaos_catches_doctored_counters(mini_setup):
    consts, meta = make_consts(mini_setup)
    s = simulate(mini_setup, PolicyConfig(job_concurrency=2))
    check_chaos(consts, meta, s)
    with pytest.raises(AssertionError, match="without clone slots"):
        check_chaos(consts, meta,
                    s._replace(spec_launches=np.int32(3)))
    with pytest.raises(AssertionError, match="degradation schedule"):
        check_chaos(consts, meta,
                    s._replace(degraded_time=np.float32(1.0)))
    with pytest.raises(AssertionError, match="ctrl plane off"):
        check_chaos(consts, meta,
                    s._replace(ctrl_failovers=np.int32(1)))


def test_chaos_rows_metrics():
    """``Results.rows`` carries the six §13 metrics and they are exactly
    zero on a chaos-free scenario."""
    from repro.api import Experiment
    res = Experiment("leaf-spine", policies=[
        ("sdn", PolicyConfig(routing=ROUTE_SDN, job_concurrency=2))]).run()
    row = res.rows()[0]
    for key in ("spec_launches", "spec_wins", "wasted_spec_work_s",
                "degraded_time_s", "failover_count", "failover_park_s"):
        assert key in row
        assert row[key] == 0


# ---------------------------------------------------------------------------
# composition: everything at once, batch and streaming
# ---------------------------------------------------------------------------


def test_chaos_scenarios_registered():
    for name in ("paper-fabric-chaos", "leaf-spine-chaos"):
        sc = get_scenario(name)
        setup = sc.build()
        assert setup.degradation is not None
        assert setup.degradation.any_degradation
        assert setup.spec_slots > 0
    assert get_scenario("paper-fabric-chaos").build().ctrl is not None
    # link gray windows are drawn per cable: both directed slots agree
    deg = get_scenario("paper-fabric-chaos").build().degradation
    assert np.array_equal(deg.link_slow_t[0::2], deg.link_slow_t[1::2],
                          equal_nan=True)


def test_chaos_composition_through_run_stream():
    """Outages x degradation x controller failover x speculation, streamed
    through the slot-recycling ring: conservation holds, the run drains,
    and the chaos counters surface in ``StreamResults.summary``."""
    from repro.api import Experiment
    from repro.scenarios.arrivals import ServiceClass, TraceArrivals
    from repro.scenarios.workloads import JobTemplate

    setup = get_scenario("leaf-spine", n_jobs=2).build()
    topo = setup.cluster.topo
    n_h, n_l = topo.n_hosts, topo.n_links
    deg = host_slowdown(n_h, n_l, host=0, at=0.0, factor=0.1)
    fail = host_crash(n_h, n_l, host=1, at=20.0, recover_at=60.0)
    ctrl = CtrlPlaneConfig(install_latency=0.02, ctrl_rate=1000.0,
                           table_slots=8, ctrl_fail_t=10.0,
                           ctrl_recover_t=1e9, failover_delay=1.0,
                           backup_rate=200.0, backup_latency=0.1)
    chaos_setup = dataclasses.replace(setup, degradation=deg, failures=fail,
                                      ctrl=ctrl, spec_slots=2)
    times = tuple(4.0 * i for i in range(8))
    arrivals = TraceArrivals(
        times=times,
        classes=(ServiceClass("only", slo_s=500.0,
                              template=JobTemplate(n_map=2, n_reduce=1)),))
    exp = Experiment(
        scenarios=("chaos-stream", chaos_setup),
        policies=[("spec-on", PolicyConfig(
            routing=ROUTE_SDN, placement=PLACE_ROUND_ROBIN,
            speculation=SPEC_ON, job_concurrency=2))])
    res = exp.run_stream(arrivals, horizon=30.0, slots=4, chunk_steps=64)
    assert res.stats.refills > 0         # the ring actually recycled
    check_stream(res, label="chaos-stream")
    summ = res.summary(0)
    assert summ["failover_count"] >= 1
    assert summ["degraded_time_s"] > 0.0
    assert summ["spec_launches"] >= summ["spec_wins"] >= 0
