"""Hypothesis property for the §13 degradation off-switch (skipped when
the optional dev dependency is absent — see requirements-dev.txt).

The property: a ``DegradationSchedule`` whose every factor is 1.0 is
STRUCTURALLY inert — no matter where its windows sit, the run is bitwise
the no-schedule program.  This is stronger than the fixed-window unit
test in test_chaos.py: window placement must never leak into the trace
(inert windows are masked out of ``deg_breaks``), so there is no
"breakpoint at t but zero effect" drift either.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from conftest import assert_states_equal, with_degradation
from repro.core import (PolicyConfig, no_degradation, simulate)
from repro.core.flows import Flow, flows_setup
from repro.core.mapreduce import build_setup
from repro.core.topology import leaf_spine
from repro.scenarios import make_cluster, uniform_workload

_TOPO = leaf_spine(2, 2, 2)
_SETUP = build_setup(uniform_workload(n_jobs=2, seed=0),
                     make_cluster(_TOPO), k_max=4)
_BASE = None


def _base():
    global _BASE
    if _BASE is None:
        _BASE = simulate(_SETUP, PolicyConfig(job_concurrency=2))
    return _BASE


@st.composite
def unity_schedules(draw):
    """Arbitrary window times, every factor pinned at 1.0."""
    n_h, n_l = _TOPO.n_hosts, _TOPO.n_links
    sched = no_degradation(n_h, n_l)
    for i in range(n_h):
        if draw(st.booleans()):
            at = draw(st.floats(0.0, 500.0, allow_nan=False))
            sched.host_slow_t[i] = at
            sched.host_restore_t[i] = at + draw(
                st.floats(0.1, 500.0, allow_nan=False))
    for i in range(n_l):
        if draw(st.booleans()):
            at = draw(st.floats(0.0, 500.0, allow_nan=False))
            sched.link_slow_t[i] = at
            sched.link_restore_t[i] = at + draw(
                st.floats(0.1, 500.0, allow_nan=False))
    return sched.validate(n_h, n_l)


@settings(max_examples=20, deadline=None)
@given(sched=unity_schedules())
def test_unity_factor_schedule_is_structurally_off(sched):
    assert not sched.any_degradation
    run = simulate(with_degradation(_SETUP, sched),
                   PolicyConfig(job_concurrency=2))
    assert_states_equal(_base(), run, "unity-degradation")


@settings(max_examples=10, deadline=None)
@given(at=st.floats(0.5, 6.0, allow_nan=False),
       factor=st.floats(0.05, 0.95, allow_nan=False))
def test_brownout_rate_arithmetic_property(at, factor):
    """For a single flow on one cable: brownout at ``at`` with ``factor``
    gives done-time = at + (total - at)/factor exactly (the flow runs 1
    unit/s healthy) — the piecewise-constant integration is analytic, not
    stepped."""
    from repro.core.topology import torus_2d
    from repro.core import link_brownout
    topo = torus_2d(2, 1, bw=1e9)
    setup = flows_setup(topo, [Flow(0, 1, 8.0)])
    sched = link_brownout(topo.n_hosts, topo.n_links, [0, 1], at=at,
                          factor=factor)
    s = simulate(with_degradation(setup, sched), PolicyConfig())
    expect = at + (8.0 - at) / factor
    assert float(s.time) == pytest.approx(expect, rel=1e-3)
