"""Roofline HLO parser, hardware model, advisor, and sharding rules."""
import types

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.roofline import V5E, advise_allreduce, analytic_time
from repro.roofline.hlo import collective_stats
from repro.roofline.terms import count_active_params, count_params
from repro.sharding.rules import batch_specs, cache_specs_tree, param_specs

HLO = """
HloModule test
ENTRY main {
  %p = f32[128,256]{1,0} parameter(0)
  %ag = f32[128,4096]{1,0} all-gather(%p), replica_groups=[16,16]<=[256], dimensions={1}
  %ar = bf16[512,512]{1,0} all-reduce(%x), replica_groups={{0,1,2,3}}, to_apply=%add
  %rs = f32[32,256]{1,0} reduce-scatter(%y), replica_groups=[2,8]<=[16], dimensions={0}
  %cp = f32[64,64]{1,0} collective-permute(%z), source_target_pairs={{0,1}}
  %aa = bf16[16,1024]{1,0} all-to-all(%w), replica_groups=[4,4]<=[16]
}
"""


def test_collective_parser_bytes():
    st = collective_stats(HLO, num_partitions=256)
    assert st.counts == {"all-gather": 1, "all-reduce": 1,
                         "reduce-scatter": 1, "collective-permute": 1,
                         "all-to-all": 1}
    ag = 128 * 4096 * 4
    ar = 512 * 512 * 2
    rs = 32 * 256 * 4
    cp = 64 * 64 * 4
    aa = 16 * 1024 * 2
    want = (ag * 15 / 16          # all-gather: out x (n-1)/n, group 16
            + 2 * ar * 3 / 4      # all-reduce: 2 x size x (n-1)/n, group 4
            + rs * 8 * 7 / 8      # reduce-scatter: out x n x (n-1)/n
            + cp                  # permute: size
            + aa * 3 / 4)         # all-to-all
    assert st.wire_bytes == pytest.approx(want, rel=1e-6)


def test_parser_ignores_non_collectives():
    st = collective_stats("  %f = f32[8,8]{1,0} fusion(%a), kind=kLoop",
                          num_partitions=8)
    assert st.total_count == 0 and st.wire_bytes == 0


def test_analytic_ring_times():
    # 100 MB over 16 chips at 50 GB/s
    t = analytic_time("ring", 16, 100e6)
    assert t == pytest.approx(2 * 15 / 16 * 100e6 / 50e9, rel=1e-9)
    assert analytic_time("ring-bidir", 16, 100e6) == pytest.approx(t / 2)


def test_advisor_des_matches_analytic():
    for a in advise_allreduce(10e6, (2, 2)):
        an = analytic_time(a.schedule, 4, 10e6, V5E, (2, 2))
        assert a.predicted_s == pytest.approx(an, rel=1e-3), a.schedule
        assert a.source == "des"


def _mesh(shape, axes):
    dev = np.empty(shape, dtype=object)
    return types.SimpleNamespace(axis_names=axes, devices=dev)


def test_param_specs_rules():
    params = {
        "embed": {"tok": jax.ShapeDtypeStruct((1024, 64), jnp.bfloat16)},
        "layers": {"attn": {"wq": jax.ShapeDtypeStruct((4, 64, 128),
                                                       jnp.bfloat16)},
                   "moe": {"wi": jax.ShapeDtypeStruct((4, 16, 64, 32),
                                                      jnp.bfloat16)},
                   "ln1": {"scale": jax.ShapeDtypeStruct((64,),
                                                         jnp.bfloat16)}},
    }
    specs = param_specs(params)
    assert specs["embed"]["tok"] == P("model", None)
    assert specs["layers"]["attn"]["wq"] == P(None, None, "model")
    assert specs["layers"]["moe"]["wi"] == P(None, "model", None, None)
    assert specs["layers"]["ln1"]["scale"] == P(None)


def test_param_specs_divisibility_fallback():
    mesh = _mesh((2, 16), ("data", "model"))
    params = {"embed": {"tok": jax.ShapeDtypeStruct((51865, 512),
                                                    jnp.bfloat16)}}
    specs = param_specs(params, mesh)
    assert specs["embed"]["tok"] == P(None, None)  # 51865 % 16 != 0


def test_batch_specs_cascade():
    mesh = _mesh((2, 4, 8), ("pod", "data", "model"))
    b = {"tokens": jax.ShapeDtypeStruct((64, 128), jnp.int32),
         "one": jax.ShapeDtypeStruct((1, 128), jnp.int32),
         "mid": jax.ShapeDtypeStruct((8, 128), jnp.int32)}
    specs = batch_specs(b, mesh)
    assert specs["tokens"] == P(("pod", "data", "model"), None)
    assert specs["one"] == P(None, None)
    assert specs["mid"] == P(("pod", "data"), None)


def test_cache_specs():
    mesh = _mesh((16, 16), ("data", "model"))
    cache = {"k": jax.ShapeDtypeStruct((36, 128, 32768, 8, 128),
                                       jnp.bfloat16),
             "len": jax.ShapeDtypeStruct((128,), jnp.int32)}
    specs = cache_specs_tree(cache, mesh)
    assert specs["k"] == P(None, "data", None, None, "model")
    assert specs["len"] == P()


def test_active_params_moe():
    from repro.configs import get_smoke_config
    from repro.models import get_model
    cfg = get_smoke_config("qwen3-moe-30b-a3b")
    api = get_model(cfg)
    sds = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    total = count_params(sds)
    active = count_active_params(sds, cfg)
    assert active < total
    # top-2 of 8 experts: expert params scale by 1/4
    assert active > total * 0.2
