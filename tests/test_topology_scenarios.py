"""Topology-builder invariants, workload generators, registry, and the
packed multi-topology sweep (single-run equivalence + smoke)."""
import numpy as np
import pytest

from conftest import tiny_setups as _tiny_setups
from repro.core import (PLACE_LEAST_USED, PLACE_RANDOM, PolicyConfig,
                        simulate)
from repro.core.routing import build_route_table, hop_distances_np
from repro.core.topology import GBPS, canonical_tree, fat_tree, leaf_spine
from repro.scenarios import (get_scenario, list_scenarios, sweep_grid,
                             uniform_workload, zipf_workload,
                             bursty_workload)

# ---------------------------------------------------------------------------
# builder invariants
# ---------------------------------------------------------------------------


def test_fat_tree_counts_and_full_bisection():
    k = 4
    topo = fat_tree(k)
    half = k // 2
    assert topo.n_hosts == k * half * half
    assert topo.n_switches == half * half + 2 * k * half
    # undirected cables: 3 layers of k*(k/2)^2 links + 1 SAN uplink
    assert topo.n_links == 2 * (3 * k * half * half + 1)
    # full (1:1) bisection: agg->core capacity equals total host capacity
    core_lo, core_hi = topo.n_hosts, topo.n_hosts + half * half
    is_core = lambda v: (core_lo <= v) & (v < core_hi)
    up = is_core(topo.link_dst) & ~is_core(topo.link_src) \
        & (topo.link_src != topo.storage(0))
    assert np.isclose(topo.link_bw[up].sum(), topo.n_hosts * GBPS)


def test_leaf_spine_counts_and_bisection_bw():
    s, l, h = 4, 4, 2
    topo = leaf_spine(n_spine=s, n_leaf=l, hosts_per_leaf=h)
    assert topo.n_hosts == l * h
    assert topo.n_switches == s + l
    assert topo.n_links == 2 * (s * l + l * h + 1)
    # bisection across a leaf split: every A->B host path crosses an
    # A-leaf -> spine link; cut capacity = (l/2) * s * fabric_bw
    leaf0 = topo.n_hosts + s
    a_leaves = np.arange(leaf0, leaf0 + l // 2)
    spines = np.arange(topo.n_hosts, topo.n_hosts + s)
    cut = np.isin(topo.link_src, a_leaves) & np.isin(topo.link_dst, spines)
    assert np.isclose(topo.link_bw[cut].sum(), (l // 2) * s * GBPS)


def test_canonical_tree_structure_and_unique_routes():
    topo = canonical_tree(depth=3, fanout=2, hosts_per_edge=2)
    assert topo.n_switches == 1 + 2 + 4
    assert topo.n_hosts == 4 * 2
    # a tree has exactly one route between any two nodes
    rt = build_route_table(topo, k_max=4)
    nc = rt.n_cand.reshape(topo.n_nodes, topo.n_nodes)
    off = ~np.eye(topo.n_nodes, dtype=bool)
    assert np.all(nc[off] == 1)


@pytest.mark.parametrize("topo_fn", [
    lambda: fat_tree(4),
    lambda: leaf_spine(3, 4, 2),
    lambda: canonical_tree(2, 3, 2),
])
def test_all_nodes_reachable_and_candidates_symmetric(topo_fn):
    topo = topo_fn()
    dist = hop_distances_np(topo.hop_matrix())
    assert np.all(np.isfinite(dist)), "fabric must be connected"
    rt = build_route_table(topo, k_max=16)
    nc = rt.n_cand.reshape(topo.n_nodes, topo.n_nodes)
    # these fabrics are symmetric graphs: equal-hop route count must be too
    assert np.array_equal(nc, nc.T)


def test_leaf_spine_route_diversity_equals_spine_count():
    s = 3
    topo = leaf_spine(n_spine=s, n_leaf=2, hosts_per_leaf=2)
    rt = build_route_table(topo, k_max=8)
    nc = rt.n_cand.reshape(topo.n_nodes, topo.n_nodes)
    # inter-leaf host pair: one equal-hop route per spine
    assert nc[0, topo.n_hosts - 1] == s
    # same-leaf host pair: single route via the shared leaf
    assert nc[0, 1] == 1


# ---------------------------------------------------------------------------
# workload generators
# ---------------------------------------------------------------------------


def test_workloads_deterministic_and_well_formed():
    for gen in (uniform_workload, zipf_workload, bursty_workload):
        a, b = gen(n_jobs=5, seed=3), gen(n_jobs=5, seed=3)
        assert a == b, f"{gen.__name__} not deterministic"
        assert len(a) == 5
        for j in a:
            assert j.n_map >= 1 and j.n_reduce >= 1
            assert j.total_mi > 0 and j.input_gbits > 0
        assert all(x.submit_time <= y.submit_time for x, y in zip(a, a[1:]))
    assert uniform_workload(n_jobs=4, seed=0) != uniform_workload(n_jobs=4,
                                                                  seed=1)


def test_bursty_workload_gaps():
    jobs = bursty_workload(n_jobs=6, burst_size=3, burst_gap_s=100.0,
                           intra_gap_s=0.5)
    t = [j.submit_time for j in jobs]
    assert t[0] == 0.0 and t[2] == pytest.approx(1.0)
    assert t[3] == pytest.approx(100.0)  # second burst


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


def test_registry_contents_and_overrides():
    names = list_scenarios()
    for required in ("paper-fabric", "fat-tree", "leaf-spine",
                     "canonical-tree"):
        assert required in names
    sc = get_scenario("leaf-spine", n_spine=2, n_leaf=2, hosts_per_leaf=2,
                      n_jobs=2)
    setup = sc.build()
    assert setup.cluster.topo.n_hosts == 4
    assert setup.n_jobs == 2
    with pytest.raises(KeyError):
        get_scenario("no-such-fabric")


# ---------------------------------------------------------------------------
# packed sweep: equivalence + smoke
# ---------------------------------------------------------------------------




def test_packed_sweep_matches_single_runs():
    """Padding/renumbering must not change any scenario's outcome."""
    scens = _tiny_setups()
    pols = [("least", PolicyConfig(placement=PLACE_LEAST_USED)),
            ("rand", PolicyConfig(placement=PLACE_RANDOM))]
    res = sweep_grid(scens, pols)
    t = np.asarray(res.states.time)
    assert t.shape == (4,)
    for si, (_, setup) in enumerate(scens):
        for pi, (_, pol) in enumerate(pols):
            single = simulate(setup, pol)
            assert not bool(single.stalled)
            packed_t = float(t[si * len(pols) + pi])
            assert packed_t == pytest.approx(float(single.time), rel=1e-5)


def test_simulate_scenarios_zipped_semantics():
    """Replica i of the zipped API runs consts[i] under pols[i]."""
    import jax.numpy as jnp
    from repro.core import simulate_scenarios
    from repro.scenarios import pack_setups, policy_arrays

    scens = _tiny_setups()
    consts, meta = pack_setups([s for _, s in scens])
    pols = {k: jnp.asarray(v) for k, v in policy_arrays(
        [PolicyConfig(placement=PLACE_LEAST_USED),
         PolicyConfig(placement=PLACE_RANDOM)]).items()}
    s = simulate_scenarios(consts, meta, pols)
    assert float(s.time[0]) == pytest.approx(float(simulate(
        scens[0][1], PolicyConfig(placement=PLACE_LEAST_USED)).time), rel=1e-5)
    assert float(s.time[1]) == pytest.approx(float(simulate(
        scens[1][1], PolicyConfig(placement=PLACE_RANDOM)).time), rel=1e-5)


def test_paper_fabric_scenario_matches_paper_setup():
    """The registered paper scenario must be the calibrated repro config."""
    from repro.core import paper_setup

    built = get_scenario("paper-fabric", seed=0, n_each=1).build()
    ref = paper_setup(seed=0, jobs=list(built.jobs))
    assert built.n_packets == ref.n_packets        # same split
    assert built.route_table.k_max == ref.route_table.k_max
    np.testing.assert_array_equal(built.route_table.n_cand,
                                  ref.route_table.n_cand)
    np.testing.assert_array_equal(built.pkt_bits, ref.pkt_bits)


def test_scenario_sweep_smoke():
    res = sweep_grid(_tiny_setups(),
                     [("least", PolicyConfig(placement=PLACE_LEAST_USED))])
    for row in res.rows():
        assert not row["stalled"], row
        assert np.isfinite(row["mean_completion_s"]), row
        assert row["mean_completion_s"] > 0
        assert row["energy_kwh"] > 0
        assert row["makespan_s"] >= row["mean_completion_s"] * 0.5
