"""Bit-identity of the vectorized step kernel (DESIGN.md §8) against the
pre-vectorization scalar event loop.

The reference implementation below is a self-contained copy of the engine
as it stood before the batched-admission / compacted-activation / fused
network-pass rewrite: admission is an O(n_jobs) argmin fori, placement an
O(n_tasks) ordered fori, packet activation an O(n_packets) fori, every
network tensor is recomputed per phase, and ``_finished`` is evaluated
twice per loop iteration.  The suite runs BOTH kernels over every registry
scenario x a policy grid covering all placement/routing/recovery branches
(with job-selection, traffic and concurrency cycling through their values)
x 3 seeds, and asserts every ``SimState`` field is bitwise equal
(NaN == NaN) — the vectorized kernel must preserve the sequential
tie-break order exactly.
"""
import dataclasses
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import assert_states_equal
from repro.core import fairshare
from repro.core.engine import (NODE_OFFSET, init_state_from_consts,
                               make_consts)
from repro.core.mapreduce import ACTIVE, DONE, WAITING
from repro.core.policies import (JOBSEL_FCFS, JOBSEL_PRIORITY, JOBSEL_SJF,
                                 PLACE_LEAST_USED, PLACE_RANDOM,
                                 PLACE_ROUND_ROBIN, PolicyConfig,
                                 RECOVERY_RESTART, RECOVERY_RESUME,
                                 ROUTE_LEGACY, ROUTE_SDN, TRAFFIC_FAIRSHARE,
                                 TRAFFIC_WATERFILL)
from repro.core.routing import choose_route, flow_hash_u32
from repro.core.simmeta import SimMeta
from repro.api import runners
from repro.scenarios import get_scenario, list_scenarios
from repro.scenarios.sweep import pack_setups, policy_arrays

_INF = jnp.float32(jnp.inf)

# ---------------------------------------------------------------------------
# reference kernel: the pre-PR scalar event loop, verbatim semantics
# ---------------------------------------------------------------------------


def _ref_link_bw(c, meta, s):
    if meta.has_failures:
        return jnp.where(s.link_dead, 0.0, c.link_bw)
    return c.link_bw


def _ref_route_links(c, s, mask):
    pair = jnp.maximum(s.pkt_pair, 0)
    cand = jnp.maximum(s.pkt_cand, 0)
    links = c.routes[pair, cand]
    return jnp.where(mask[:, None], links, -1)


def _ref_endpoints(c, s):
    n_tasks = s.task_vm.shape[0]

    def node_of(task_idx):
        t = jnp.clip(task_idx, 0, n_tasks - 1)
        vm = jnp.maximum(s.task_vm[t], 0)
        node = jnp.where(task_idx < 0, c.storage_node, c.vm_host[vm])
        return jnp.where(task_idx >= NODE_OFFSET,
                         task_idx - NODE_OFFSET, node).astype(jnp.int32)
    return node_of(c.pkt_src_task), node_of(c.pkt_dst_task)


def _ref_apply_failures(c, pol, s):
    t = s.time
    host_dead = (c.host_fail_t <= t) & (t < c.host_recover_t)
    link_dead = (c.link_fail_t <= t) & (t < c.link_recover_t)
    new_h = host_dead & ~s.host_dead
    new_l = link_dead & ~s.link_dead
    restart = pol["recovery"] == RECOVERY_RESTART

    n_hosts_pad = c.host_fail_t.shape[0]
    src_node, dst_node = _ref_endpoints(c, s)
    p_active = s.pkt_state == ACTIVE
    links = _ref_route_links(c, s, p_active)
    route_hit = p_active & jnp.any(
        (links >= 0) & new_l[jnp.maximum(links, 0)], axis=-1)

    def _endpoint_died(node):
        return (node < c.n_hosts) & new_h[jnp.clip(node, 0, n_hosts_pad - 1)]

    ep_hit = p_active & (_endpoint_died(src_node) | _endpoint_died(dst_node))
    hit_p = route_hit | ep_hit
    pkt_state = jnp.where(hit_p, WAITING, s.pkt_state)
    pkt_rem = jnp.where(ep_hit & restart, c.pkt_bits.astype(jnp.float32),
                        s.pkt_rem)
    pkt_pair = jnp.where(hit_p, -1, s.pkt_pair)
    pkt_cand = jnp.where(hit_p, -1, s.pkt_cand)
    pkt_reroutes = s.pkt_reroutes + hit_p.astype(jnp.int32)

    vm_safe = jnp.maximum(s.task_vm, 0)
    task_host = jnp.clip(c.vm_host[vm_safe], 0, n_hosts_pad - 1)
    hit_t = (c.task_valid & (s.task_vm >= 0) & new_h[task_host]
             & ((s.task_state == ACTIVE) | (s.task_state == WAITING)))
    task_state = jnp.where(hit_t, WAITING, s.task_state)
    task_rem = jnp.where(hit_t & restart, c.task_mi.astype(jnp.float32),
                         s.task_rem)
    task_start = jnp.where(hit_t, jnp.nan, s.task_start)
    vm_load = s.vm_load.at[vm_safe].add(-hit_t.astype(jnp.int32))
    task_vm = jnp.where(hit_t, -1, s.task_vm)
    task_restarts = s.task_restarts + hit_t.astype(jnp.int32)

    return s._replace(
        host_dead=host_dead, link_dead=link_dead,
        pkt_state=pkt_state, pkt_rem=pkt_rem, pkt_pair=pkt_pair,
        pkt_cand=pkt_cand, pkt_reroutes=pkt_reroutes,
        task_state=task_state, task_rem=task_rem, task_start=task_start,
        task_vm=task_vm, vm_load=vm_load, task_restarts=task_restarts)


def _ref_admit_and_place(c, meta, pol, s):
    n_vms = c.n_vms
    vm_slot_live = jnp.arange(meta.n_vms) < n_vms
    if meta.has_failures:
        vm_live = vm_slot_live & ~s.host_dead[
            jnp.clip(c.vm_host, 0, c.host_fail_t.shape[0] - 1)]
        n_live = jnp.sum(vm_live.astype(jnp.int32))
        live_pos = jnp.cumsum(vm_live.astype(jnp.int32)) - 1
    else:
        vm_live, n_live, live_pos = vm_slot_live, n_vms, None

    def pick_vm(vm_load, counter, h):
        masked_load = jnp.where(vm_live, vm_load, jnp.iinfo(jnp.int32).max)
        if meta.has_failures:
            def kth_live(k):
                return jnp.argmax(vm_live & (live_pos == k)).astype(jnp.int32)
            rr = kth_live(counter % jnp.maximum(n_live, 1))
            rnd = kth_live(h % jnp.maximum(n_live, 1))
        else:
            rr, rnd = counter % n_vms, h % n_vms
        pick = jnp.where(
            pol["placement"] == PLACE_ROUND_ROBIN, rr,
            jnp.where(pol["placement"] == PLACE_RANDOM, rnd,
                      jnp.argmin(masked_load).astype(jnp.int32)))
        return pick.astype(jnp.int32)

    def place_mask(s, mine):
        def place_one(t, carry):
            vm_load, task_vm, counter = carry
            is_mine = mine[t]
            h = flow_hash_u32(jnp.int32(t), c.task_job[t], pol["seed"])
            pick = pick_vm(vm_load, counter, h)
            vm_load = jnp.where(is_mine, vm_load.at[pick].add(1), vm_load)
            task_vm = jnp.where(is_mine, task_vm.at[t].set(pick), task_vm)
            counter = counter + jnp.where(is_mine, 1, 0)
            return vm_load, task_vm, counter

        vm_load, task_vm, counter = jax.lax.fori_loop(
            0, s.task_vm.shape[0], place_one,
            (s.vm_load, s.task_vm, s.place_counter))
        return s._replace(vm_load=vm_load, task_vm=task_vm,
                          place_counter=counter)

    def admit_one(_, s):
        released = (~s.job_admitted) & c.job_valid & (c.job_release <= s.time)
        running = s.job_admitted & (s.job_out_done < c.job_n_out) & c.job_valid
        free = jnp.sum(running.astype(jnp.int32)) < pol["job_concurrency"]
        any_wait = jnp.any(released)
        key = jnp.where(
            pol["job_selection"] == JOBSEL_SJF, c.job_total_mi,
            jnp.where(pol["job_selection"] == JOBSEL_PRIORITY,
                      -c.job_priority, c.job_release))
        key = jnp.where(released, key, _INF)
        j = jnp.argmin(key).astype(jnp.int32)
        do = free & any_wait
        if meta.has_failures:
            do = do & (n_live > 0)

        def place(s):
            s = place_mask(s, (c.task_job == j) & c.task_valid)
            return s._replace(
                job_admitted=s.job_admitted.at[j].set(True),
                job_admit_t=s.job_admit_t.at[j].set(s.time))

        return jax.lax.cond(do, place, lambda s: s, s)

    s = jax.lax.fori_loop(0, s.job_admitted.shape[0], admit_one, s)

    if meta.has_failures:
        orphaned = (c.task_valid & (s.task_vm < 0)
                    & (s.task_state == WAITING)
                    & s.job_admitted[jnp.maximum(c.task_job, 0)]
                    & (n_live > 0))
        s = jax.lax.cond(jnp.any(orphaned),
                         lambda s: place_mask(s, orphaned), lambda s: s, s)
    return s


def _ref_activate(c, meta, pol, s):
    t_ready = ((s.task_state == WAITING) & (s.task_got >= c.task_need)
               & (s.task_vm >= 0))
    task_state = jnp.where(t_ready, ACTIVE, s.task_state)
    task_start = jnp.where(t_ready, s.time, s.task_start)
    s = s._replace(task_state=task_state, task_start=task_start)

    gate = c.pkt_gate_task
    gate_ok = jnp.where(gate < 0, True,
                        s.task_state[jnp.maximum(gate, 0)] == DONE)
    admitted = s.job_admitted[jnp.maximum(c.pkt_job, 0)]
    p_ready = (s.pkt_state == WAITING) & admitted & gate_ok & c.pkt_valid
    src_node, dst_node = _ref_endpoints(c, s)
    n_nodes = meta.n_nodes
    pair_all = (src_node * n_nodes + dst_node).astype(jnp.int32)
    reachable = (c.n_cand[pair_all] > 0) | (src_node == dst_node)
    p_ready = p_ready & reachable
    if meta.has_failures:
        n_tasks = s.task_vm.shape[0]

        def _ep_placed(ref):
            is_task = (ref >= 0) & (ref < NODE_OFFSET)
            return jnp.where(is_task,
                             s.task_vm[jnp.clip(ref, 0, n_tasks - 1)] >= 0,
                             True)

        p_ready = (p_ready & _ep_placed(c.pkt_src_task)
                   & _ep_placed(c.pkt_dst_task))

    link_bw = _ref_link_bw(c, meta, s)
    ch0 = fairshare.channel_counts(
        _ref_route_links(c, s, s.pkt_state == ACTIVE),
        s.pkt_state == ACTIVE, meta.n_links)

    def act_one(i, carry):
        pkt_state, pkt_pair, pkt_cand, pkt_start, ch = carry
        ready = p_ready[i]
        pair = (src_node[i] * n_nodes + dst_node[i]).astype(jnp.int32)
        fh = flow_hash_u32(c.pkt_src_task[i] + 1, c.pkt_dst_task[i] + 1,
                           pol["seed"])
        cand = choose_route(pol["routing"], c.routes[pair], c.n_cand[pair],
                            link_bw, ch, fh)
        links = c.routes[pair, cand]
        valid = links >= 0
        ch_new = ch.at[jnp.maximum(links, 0)].add(valid.astype(jnp.int32))
        if meta.has_failures:
            start_val = jnp.where(jnp.isnan(pkt_start[i]), s.time,
                                  pkt_start[i])
        else:
            start_val = s.time
        return (
            jnp.where(ready, pkt_state.at[i].set(ACTIVE), pkt_state),
            jnp.where(ready, pkt_pair.at[i].set(pair), pkt_pair),
            jnp.where(ready, pkt_cand.at[i].set(cand), pkt_cand),
            jnp.where(ready, pkt_start.at[i].set(start_val), pkt_start),
            jnp.where(ready, ch_new, ch),
        )

    pkt_state, pkt_pair, pkt_cand, pkt_start, _ = jax.lax.fori_loop(
        0, s.pkt_state.shape[0], act_one,
        (s.pkt_state, s.pkt_pair, s.pkt_cand, s.pkt_start, ch0))
    return s._replace(pkt_state=pkt_state, pkt_pair=pkt_pair,
                      pkt_cand=pkt_cand, pkt_start=pkt_start)


def _ref_rates(c, meta, pol, s):
    p_active = s.pkt_state == ACTIVE
    links = _ref_route_links(c, s, p_active)
    pkt_rate = fairshare.rates(pol["traffic"], links, p_active,
                               _ref_link_bw(c, meta, s), meta.intra_bw)
    t_active = s.task_state == ACTIVE
    vm = jnp.maximum(s.task_vm, 0)
    n_on_vm = jnp.zeros_like(c.vm_total_mips, jnp.int32).at[vm].add(
        t_active.astype(jnp.int32))
    share = c.vm_total_mips[vm] / jnp.maximum(n_on_vm[vm],
                                              1).astype(jnp.float32)
    task_rate = jnp.where(t_active, jnp.minimum(c.vm_core_mips[vm], share),
                          0.0)
    if meta.has_failures:
        task_rate = jnp.where(
            s.host_dead[jnp.clip(c.vm_host[vm], 0,
                                 c.host_fail_t.shape[0] - 1)],
            0.0, task_rate)
    return pkt_rate, task_rate, links, p_active, t_active


def _ref_finished(c, meta, s):
    all_done = jnp.all(~c.job_valid | (s.job_out_done >= c.job_n_out))
    return all_done | s.stalled | (s.steps >= meta.max_steps)


def _ref_step(c, meta, pol, s):
    from repro.core.energy import host_power, switch_power
    if meta.has_failures:
        s = _ref_apply_failures(c, pol, s)
    s = _ref_admit_and_place(c, meta, pol, s)
    s = _ref_activate(c, meta, pol, s)
    pkt_rate, task_rate, links, p_active, t_active = _ref_rates(
        c, meta, pol, s)

    dt_p = jnp.min(jnp.where(p_active & (pkt_rate > 0),
                             s.pkt_rem / pkt_rate, _INF))
    dt_t = jnp.min(jnp.where(t_active & (task_rate > 0),
                             s.task_rem / task_rate, _INF))
    future = (~s.job_admitted) & c.job_valid & (c.job_release > s.time)
    dt_r = jnp.min(jnp.where(future, c.job_release - s.time, _INF))
    dt = jnp.minimum(jnp.minimum(dt_p, dt_t), dt_r)
    if meta.has_failures:
        def _next(ts):
            return jnp.min(jnp.where(ts > s.time, ts - s.time, _INF))

        dt_f = jnp.minimum(
            jnp.minimum(_next(c.host_fail_t), _next(c.host_recover_t)),
            jnp.minimum(_next(c.link_fail_t), _next(c.link_recover_t)))
        dt = jnp.minimum(dt, dt_f)
    stalled = jnp.isinf(dt)
    dt = jnp.where(stalled, 0.0, dt)

    vm_safe = jnp.maximum(s.task_vm, 0)
    host_of_task = c.vm_host[vm_safe]
    mips_used = jnp.zeros_like(c.host_total_mips).at[host_of_task].add(
        jnp.where(t_active, task_rate, 0.0))
    util = jnp.clip(mips_used / jnp.maximum(c.host_total_mips, 1e-9),
                    0.0, 1.0)
    if meta.has_failures:
        util = jnp.where(s.host_dead, 0.0, util)
    host_energy = s.host_energy + host_power(util, meta.energy) * dt
    host_busy = s.host_busy + jnp.where(util > 0, dt, 0.0)
    ch = fairshare.channel_counts(links, p_active, meta.n_links)
    live_link = (ch > 0).astype(jnp.int32)
    if meta.has_failures:
        live_link = jnp.where(s.link_dead, 0, live_link)
    node_ports = jnp.zeros(meta.n_nodes, jnp.int32)
    node_ports = node_ports.at[c.link_src].add(live_link)
    node_ports = node_ports.at[c.link_dst].add(live_link)
    sw_ports = jax.lax.dynamic_slice_in_dim(node_ports, meta.n_hosts,
                                            meta.n_switches)
    switch_energy = s.switch_energy + switch_power(sw_ports, meta.energy) * dt

    if meta.has_failures:
        n_j = s.job_downtime.shape[0]
        prog_t = ((t_active & (task_rate > 0) & c.task_valid)
                  .astype(jnp.int32))
        prog_p = ((p_active & (pkt_rate > 0) & c.pkt_valid)
                  .astype(jnp.int32))
        job_prog = jnp.zeros(n_j, jnp.int32)
        job_prog = job_prog.at[jnp.maximum(c.task_job, 0)].max(prog_t)
        job_prog = job_prog.at[jnp.maximum(c.pkt_job, 0)].max(prog_p)
        job_live = (s.job_admitted & (s.job_out_done < c.job_n_out)
                    & c.job_valid)
        job_downtime = s.job_downtime + jnp.where(
            job_live & (job_prog == 0), dt, 0.0)
    else:
        job_downtime = s.job_downtime

    time = s.time + dt
    pkt_rem = jnp.where(p_active, s.pkt_rem - pkt_rate * dt, s.pkt_rem)
    task_rem = jnp.where(t_active, s.task_rem - task_rate * dt, s.task_rem)
    pkt_tol = c.pkt_bits * 1e-6 + 1.0
    task_tol = c.task_mi * 1e-6 + 1e-6
    p_done_now = p_active & (pkt_rem <= pkt_tol)
    t_done_now = t_active & (task_rem <= task_tol)

    pkt_state = jnp.where(p_done_now, DONE, s.pkt_state)
    pkt_finish = jnp.where(p_done_now, time, s.pkt_finish)
    task_state = jnp.where(t_done_now, DONE, s.task_state)
    task_finish = jnp.where(t_done_now, time, s.task_finish)

    feeds = jnp.maximum(c.pkt_feeds_task, 0)
    task_got = s.task_got.at[feeds].add(
        (p_done_now & (c.pkt_feeds_task >= 0)).astype(jnp.int32))
    out_pkt = p_done_now & (c.pkt_feeds_task < 0)
    job_of = jnp.maximum(c.pkt_job, 0)
    job_out_done = s.job_out_done.at[job_of].add(out_pkt.astype(jnp.int32))
    newly_job_done = (job_out_done >= c.job_n_out) & \
        (s.job_out_done < c.job_n_out) & c.job_valid
    job_done_t = jnp.where(newly_job_done, time, s.job_done_t)
    vm_load = s.vm_load.at[vm_safe].add(-t_done_now.astype(jnp.int32))

    return s._replace(
        time=time, steps=s.steps + 1, stalled=stalled,
        job_out_done=job_out_done, job_done_t=job_done_t,
        task_state=task_state, task_rem=task_rem, task_got=task_got,
        task_finish=task_finish,
        pkt_state=pkt_state, pkt_rem=pkt_rem, pkt_finish=pkt_finish,
        vm_load=vm_load, host_energy=host_energy, host_busy=host_busy,
        switch_energy=switch_energy, job_downtime=job_downtime)


def ref_simulator(meta):
    """The pre-PR loop: ``_finished`` evaluated in cond AND body."""
    meta = SimMeta.coerce(meta)

    def run(consts, pol):
        s0 = init_state_from_consts(consts, meta.n_switches)

        def cond(s):
            return ~_ref_finished(consts, meta, s)

        def body(s):
            new = _ref_step(consts, meta, pol, s)
            live = ~_ref_finished(consts, meta, s)
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(live, n, o), new, s)

        return jax.lax.while_loop(cond, body, s0)

    return run


# ---------------------------------------------------------------------------
# the scenario x policy x seed grid
# ---------------------------------------------------------------------------

# every registered scenario, at reduced workload size: the REFERENCE
# kernel is O(n_packets) per event per replica, so the 36-policy grid only
# fits the test budget on small instances (the structures — topology
# family, workload shape, failure traces — are the registered ones; the
# slow-marked test below runs the full-size xl fabric)
NO_FAILURE_SCENARIOS = [
    ("paper-fabric", dict(split=1)),
    ("fat-tree", dict(n_jobs=4)),
    ("leaf-spine", dict(n_jobs=4)),
    ("canonical-tree", dict(n_jobs=4)),
    ("leaf-spine-xl", dict(n_spine=2, n_leaf=2, hosts_per_leaf=2, n_jobs=4,
                           max_scale=1.5)),
    # the streaming scenario's FINITE arrival preview (DESIGN.md §11) is an
    # ordinary workload, so it belongs in the bit-identity grid too
    ("leaf-spine-stream", dict(n_spine=2, n_leaf=2, hosts_per_leaf=2,
                               horizon=160.0, max_jobs=4)),
]
FAILURE_SCENARIOS = [
    ("paper-fabric-failures", dict(split=1)),
    ("leaf-spine-failures", dict(n_jobs=4)),
]
# ctrl scenarios enter the bit-identity suite with their CtrlPlaneConfig
# STRIPPED: has_ctrl=False must trace the exact pre-control-plane program
# (DESIGN.md §10) — the on-behavior is covered by tests/test_ctrlplane.py
CTRL_SCENARIOS = [
    ("paper-fabric-ctrl", dict(split=1)),
    ("leaf-spine-ctrl", dict(n_jobs=4)),
]
# chaos scenarios enter with degradation, ctrl AND spec_slots STRIPPED:
# the §13 off switch must trace the exact pre-chaos program (what remains
# is a plain failures / plain scenario the reference kernel handles) —
# the on-behavior is covered by tests/test_chaos.py
CHAOS_SCENARIOS = [
    ("paper-fabric-chaos", dict(split=1)),
    ("leaf-spine-chaos", dict(n_jobs=4)),
]


def policy_grid(seeds=(0, 1, 2)):
    """All placement x routing x recovery branches; job-selection, traffic
    and concurrency cycle through their values across the combos."""
    jobsels = [JOBSEL_FCFS, JOBSEL_SJF, JOBSEL_PRIORITY]
    traffics = [TRAFFIC_FAIRSHARE, TRAFFIC_WATERFILL]
    concs = [1, 2, 1_000_000]
    pols = []
    for seed in seeds:
        for i, (p, r, rec) in enumerate(itertools.product(
                (PLACE_LEAST_USED, PLACE_ROUND_ROBIN, PLACE_RANDOM),
                (ROUTE_SDN, ROUTE_LEGACY),
                (RECOVERY_RESTART, RECOVERY_RESUME))):
            pols.append(PolicyConfig(
                placement=p, routing=r, recovery=rec,
                job_selection=jobsels[i % 3], traffic=traffics[i % 2],
                job_concurrency=concs[i % 3], seed=seed))
    return pols


def _run_grid(scenarios, strip_ctrl=False, strip_chaos=False):
    setups = [get_scenario(name, **kw).build() for name, kw in scenarios]
    if strip_ctrl:
        setups = [dataclasses.replace(s, ctrl=None) for s in setups]
    if strip_chaos:
        setups = [dataclasses.replace(s, degradation=None, ctrl=None,
                                      spec_slots=0) for s in setups]
    consts, meta = pack_setups(setups)
    pols = {k: jnp.asarray(v) for k, v in policy_arrays(policy_grid()).items()}

    ref_run = ref_simulator(meta)
    ref_grid = jax.jit(lambda c, p: jax.vmap(
        lambda ci: jax.vmap(lambda pi: ref_run(ci, pi))(p))(c))
    ref_states = jax.block_until_ready(ref_grid(consts, pols))
    new_states = jax.block_until_ready(
        runners.get_runner(meta, "grid")(consts, pols))
    return ref_states, new_states, [n for n, _ in scenarios]


def test_all_scenarios_registered():
    """The grids below must cover every registered scenario."""
    covered = {n for n, _ in
               NO_FAILURE_SCENARIOS + FAILURE_SCENARIOS + CTRL_SCENARIOS
               + CHAOS_SCENARIOS}
    assert covered == set(list_scenarios())


def test_grid_bit_identity_no_failures():
    ref_states, new_states, names = _run_grid(NO_FAILURE_SCENARIOS)
    for si, name in enumerate(names):
        ref = jax.tree_util.tree_map(lambda a: a[si], ref_states)
        new = jax.tree_util.tree_map(lambda a: a[si], new_states)
        assert_states_equal(ref, new, name)


def test_grid_bit_identity_with_failures():
    ref_states, new_states, names = _run_grid(FAILURE_SCENARIOS)
    for si, name in enumerate(names):
        ref = jax.tree_util.tree_map(lambda a: a[si], ref_states)
        new = jax.tree_util.tree_map(lambda a: a[si], new_states)
        assert_states_equal(ref, new, name)


def test_grid_bit_identity_ctrl_stripped():
    """The §10 off switch: the ctrl scenarios with their CtrlPlaneConfig
    removed must be BITWISE the pre-control-plane engine across the whole
    policy x seed grid — every control-plane path sits behind trace-time
    ``meta.has_ctrl`` branches, so has_ctrl=False is the identical
    program, not a dynamically-disabled one."""
    ref_states, new_states, names = _run_grid(CTRL_SCENARIOS,
                                              strip_ctrl=True)
    for si, name in enumerate(names):
        ref = jax.tree_util.tree_map(lambda a: a[si], ref_states)
        new = jax.tree_util.tree_map(lambda a: a[si], new_states)
        assert_states_equal(ref, new, name)


def test_grid_bit_identity_chaos_stripped():
    """The §13 off switch: the chaos scenarios with degradation, ctrl and
    clone capacity removed must be BITWISE the pre-chaos engine across the
    whole policy x seed grid — gray failures, speculation and failover all
    sit behind trace-time ``meta`` switches, so off is the identical
    program, not a dynamically-disabled one."""
    ref_states, new_states, names = _run_grid(CHAOS_SCENARIOS,
                                              strip_chaos=True)
    for si, name in enumerate(names):
        ref = jax.tree_util.tree_map(lambda a: a[si], ref_states)
        new = jax.tree_util.tree_map(lambda a: a[si], new_states)
        assert_states_equal(ref, new, name)


def test_single_run_bit_identity_unpacked():
    """The unpacked single-scenario path (no pad slots) also matches."""
    setup = get_scenario("leaf-spine").build()
    consts, meta = make_consts(setup)
    for pol_cfg in (PolicyConfig(job_concurrency=2),
                    PolicyConfig(routing=ROUTE_LEGACY,
                                 placement=PLACE_ROUND_ROBIN, seed=3)):
        pol = {k: jnp.asarray(v)
               for k, v in pol_cfg.as_arrays().items()}
        ref = jax.block_until_ready(
            jax.jit(ref_simulator(meta))(consts, pol))
        new = jax.block_until_ready(
            runners.get_runner(meta, "single")(consts, pol))
        assert_states_equal(ref, new, f"leaf-spine/{pol_cfg!r}")


@pytest.mark.slow
def test_full_size_xl_bit_identity():
    """Full leaf-spine-xl (128 hosts, >=1k tasks, >=4k packets): the
    reference kernel needs minutes here — slow-marked, one policy."""
    setup = get_scenario("leaf-spine-xl").build()
    consts, meta = make_consts(setup)
    pol = {k: jnp.asarray(v)
           for k, v in PolicyConfig(job_concurrency=4).as_arrays().items()}
    ref = jax.block_until_ready(jax.jit(ref_simulator(meta))(consts, pol))
    new = jax.block_until_ready(
        runners.get_runner(meta, "single")(consts, pol))
    assert_states_equal(ref, new, "leaf-spine-xl")
