"""Control-plane resource model (DESIGN.md §10): off-switch identity,
install-latency breakpoints, controller queueing, LRU flow tables,
proactive install overlap, and migrate-on-congestion."""
import numpy as np
import pytest

from conftest import with_ctrl, with_failures, dims, assert_states_equal
from invariants import check_all
from repro.core import (CtrlPlaneConfig, INSTALL_PROACTIVE, MIG_CONGESTION,
                        PolicyConfig, ROUTE_LEGACY, ROUTE_SDN, host_crash,
                        no_ctrl, simulate)
from repro.core.engine import make_consts
from repro.core.flows import Flow, flows_setup
from repro.core.topology import leaf_spine

CTRL = CtrlPlaneConfig(install_latency=0.05, ctrl_rate=500.0, table_slots=8)


@pytest.fixture(scope="module")
def ls_flow_setup():
    """One 8-second flow crossing 3 switches (leaf, spine, leaf)."""
    return flows_setup(leaf_spine(2, 2, 2), [Flow(0, 2, 8.0)])


def test_config_validation_and_any_ctrl():
    assert not no_ctrl().any_ctrl
    assert not CtrlPlaneConfig().any_ctrl
    for cfg in (CtrlPlaneConfig(install_latency=0.1),
                CtrlPlaneConfig(ctrl_rate=100.0),
                CtrlPlaneConfig(table_slots=4),
                CtrlPlaneConfig(mig_threshold=8.0)):
        assert cfg.any_ctrl
    with pytest.raises(ValueError):
        CtrlPlaneConfig(install_latency=-1.0).validate()
    with pytest.raises(ValueError):
        CtrlPlaneConfig(ctrl_rate=0.0).validate()
    with pytest.raises(ValueError):
        CtrlPlaneConfig(table_slots=-1).validate()


def test_identity_config_is_the_off_switch(ls_flow_setup):
    """ctrl=no_ctrl() and ctrl=None build the same meta (has_ctrl=False)
    and the same bitwise run — the off switch is trace-time."""
    _, meta_none = make_consts(ls_flow_setup)
    _, meta_id = make_consts(with_ctrl(ls_flow_setup, no_ctrl()))
    assert not meta_none.has_ctrl and not meta_id.has_ctrl
    assert meta_none == meta_id
    a = simulate(ls_flow_setup, PolicyConfig())
    b = simulate(with_ctrl(ls_flow_setup, no_ctrl()), PolicyConfig())
    assert_states_equal(a, b, "no_ctrl off switch")


def test_install_latency_delays_exactly(ls_flow_setup):
    """Reactive install with an unconstrained controller: one flow pays
    exactly one install latency before transmitting."""
    base = simulate(ls_flow_setup, PolicyConfig())
    assert float(base.time) == pytest.approx(8.0, rel=1e-4)
    for lat in (0.25, 1.5):
        s = simulate(with_ctrl(ls_flow_setup,
                               CtrlPlaneConfig(install_latency=lat)),
                     PolicyConfig())
        assert not bool(s.stalled)
        assert float(s.time) == pytest.approx(8.0 + lat, rel=1e-4)
        assert float(np.asarray(s.pkt_install_wait).sum()) == pytest.approx(
            lat, rel=1e-4)


def test_legacy_routing_bypasses_controller(ls_flow_setup):
    """Legacy forwarding needs no flow-mod round trip: zero installs, and
    the makespan matches the ctrl-free legacy run exactly."""
    cfg = CtrlPlaneConfig(install_latency=0.5, ctrl_rate=50.0, table_slots=2)
    base = simulate(ls_flow_setup, PolicyConfig(routing=ROUTE_LEGACY))
    s = simulate(with_ctrl(ls_flow_setup, cfg),
                 PolicyConfig(routing=ROUTE_LEGACY))
    assert int(s.ctrl_installs) == 0
    assert float(s.time) == float(base.time)
    assert float(np.asarray(s.pkt_install_wait).sum()) == 0.0


def test_rate_limited_controller_serializes_installs():
    """A finite-rate controller is a FIFO queue: concurrent flow setups
    wait on each other and the queue wait is accounted."""
    setup = flows_setup(leaf_spine(2, 2, 2),
                        [Flow(0, 2, 8.0), Flow(1, 3, 8.0)])
    fast = simulate(with_ctrl(setup, CtrlPlaneConfig(install_latency=0.01)),
                    PolicyConfig())
    slow = simulate(with_ctrl(setup, CtrlPlaneConfig(install_latency=0.01,
                                                     ctrl_rate=2.0)),
                    PolicyConfig())
    assert not bool(slow.stalled)
    assert float(slow.ctrl_queue_wait) > 0.0
    assert float(fast.ctrl_queue_wait) == 0.0
    assert float(slow.time) > float(fast.time)


def test_lru_table_evicts_and_conserves():
    """With one slot per switch, a second flow through the same spine
    displaces the first flow's rule — and the conservation identity
    ``occupied == installs - evictions`` holds exactly."""
    setup = flows_setup(leaf_spine(1, 2, 2),
                        [Flow(0, 2, 4.0), Flow(1, 3, 4.0)])
    s = simulate(with_ctrl(setup, CtrlPlaneConfig(install_latency=0.01,
                                                  table_slots=1)),
                 PolicyConfig())
    assert not bool(s.stalled)
    assert int(s.ctrl_evictions) >= 1
    occupied = int((np.asarray(s.ftab_pair) >= 0).sum())
    assert occupied == int(s.ctrl_installs) - int(s.ctrl_evictions)


def test_tableless_conservation():
    """table_slots=0 models install latency with no caching: every install
    is immediately 'evicted' and the identity still balances."""
    setup = flows_setup(leaf_spine(2, 2, 2), [Flow(0, 2, 8.0)])
    s = simulate(with_ctrl(setup, CtrlPlaneConfig(install_latency=0.1)),
                 PolicyConfig())
    assert int(s.ctrl_installs) > 0
    assert int(s.ctrl_installs) == int(s.ctrl_evictions)
    assert np.asarray(s.ftab_pair).size == 0


def test_proactive_overlaps_install_latency(mini_setup):
    """Proactive install pre-pins routes at admission, overlapping the
    install round trip with job queueing: on the paper fabric it recovers
    (nearly all of) the reactive makespan penalty."""
    setup = with_ctrl(mini_setup, CTRL)
    react = simulate(setup, PolicyConfig(job_concurrency=2))
    pro = simulate(setup, PolicyConfig(job_concurrency=2,
                                       install_mode=INSTALL_PROACTIVE))
    assert not bool(react.stalled) and not bool(pro.stalled)
    assert float(pro.time) < float(react.time)
    # churn-evicted pins fall back to reactive install and are counted
    assert int(pro.ctrl_reinstalls) >= 0
    c, meta = make_consts(setup)
    check_all(c, meta, pro, label="paper-fabric/proactive")
    check_all(c, meta, react, label="paper-fabric/reactive")


def test_legacy_beats_sdn_under_priced_controller(mini_setup):
    """The headline regime (the acceptance bar for DESIGN.md §10): with
    the controller priced in, legacy's zero-install static hash finishes
    the paper-fabric mix FASTER than reactive SDN — the comparison the
    instant-oracle model could never produce."""
    setup = with_ctrl(mini_setup, CTRL)
    sdn = simulate(setup, PolicyConfig(routing=ROUTE_SDN, job_concurrency=2))
    legacy = simulate(setup, PolicyConfig(routing=ROUTE_LEGACY,
                                          job_concurrency=2))
    assert not bool(sdn.stalled) and not bool(legacy.stalled)
    assert float(legacy.time) < float(sdn.time)
    # and WITHOUT the controller priced, SDN wins the same comparison
    sdn0 = simulate(mini_setup, PolicyConfig(routing=ROUTE_SDN,
                                             job_concurrency=2))
    legacy0 = simulate(mini_setup, PolicyConfig(routing=ROUTE_LEGACY,
                                                job_concurrency=2))
    assert float(sdn0.time) < float(legacy0.time)


def test_migration_rehomes_and_completes():
    """Migrate-on-congestion (S-CORE): with a finite threshold the
    controller re-homes hot VMs — runs migrate, packets re-route, the
    workload still completes; under migration=static nothing moves."""
    from repro.scenarios import get_scenario
    setup = get_scenario("leaf-spine-ctrl").build()
    mig = simulate(setup, PolicyConfig(routing=ROUTE_SDN,
                                       migration=MIG_CONGESTION))
    static = simulate(setup, PolicyConfig(routing=ROUTE_SDN))
    assert not bool(mig.stalled) and not bool(static.stalled)
    assert int(np.asarray(mig.vm_migrations).sum()) > 0
    assert int(np.asarray(static.vm_migrations).sum()) == 0
    c, meta = make_consts(setup)
    assert not np.array_equal(np.asarray(mig.vm_host), np.asarray(c.vm_host))
    assert np.array_equal(np.asarray(static.vm_host), np.asarray(c.vm_host))
    check_all(c, meta, mig, label="leaf-spine-ctrl/mig")


def test_ctrl_composes_with_failures(mini_setup):
    """§7 x §10: a host crash under a priced controller still recovers,
    and both subsystems' invariants hold on the same run."""
    sched = host_crash(*dims(mini_setup), host=0, at=30.0, recover_at=300.0)
    setup = with_ctrl(with_failures(mini_setup, sched), CTRL)
    s = simulate(setup, PolicyConfig(job_concurrency=2))
    assert not bool(s.stalled)
    assert int(np.asarray(s.task_restarts).sum()) >= 1
    c, meta = make_consts(setup)
    assert meta.has_ctrl and meta.has_failures
    check_all(c, meta, s, label="paper-fabric/failures+ctrl")


def test_ctrl_metrics_reported(mini_setup):
    """rows() carries the §10 columns, zeroed without a ctrl config."""
    from repro.api import Experiment
    res = Experiment(
        scenarios=[("plain", mini_setup), ("priced", with_ctrl(mini_setup,
                                                               CTRL))],
        policies=[("sdn", PolicyConfig(routing=ROUTE_SDN,
                                       job_concurrency=2))]).run()
    rows = {r["scenario"]: r for r in res.rows()}
    keys = {"install_wait_s", "rule_installs", "rule_evictions",
            "rule_reinstalls", "ctrl_queue_wait_s", "vm_migrations"}
    assert keys <= set(rows["plain"])
    assert rows["plain"]["rule_installs"] == 0
    assert rows["plain"]["install_wait_s"] == 0.0
    assert rows["priced"]["rule_installs"] > 0
    assert rows["priced"]["install_wait_s"] > 0.0
    # the packed no-ctrl replica never moves a VM
    import jax
    c0 = jax.tree_util.tree_map(lambda a: a[0], res.consts)
    s0 = res.state(0, 0)
    assert np.array_equal(np.asarray(s0.vm_host)[:int(c0.n_vms)],
                          np.asarray(c0.vm_host)[:int(c0.n_vms)])
