"""Dry-run machinery on a miniature mesh in a subprocess (the 512-device
flag must not leak into this test process)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import dataclasses
import jax
from repro.configs import SHAPES, get_smoke_config
from repro.launch.dryrun import lower_one
from repro.launch.mesh import make_mesh
from repro.roofline.terms import raw_counts

results = {}
mesh = make_mesh((2, 4), ("data", "model"))
shape = dataclasses.replace(SHAPES["train_4k"], seq_len=64, global_batch=8)
for arch in ["qwen3-4b", "qwen3-moe-30b-a3b", "falcon-mamba-7b",
             "jamba-v0.1-52b", "whisper-base", "qwen2-vl-72b"]:
    cfg = get_smoke_config(arch)
    compiled = lower_one(cfg, shape, mesh, backend="chunked", remat=True,
                         microbatch=0)
    rc = raw_counts(compiled, chips=8)
    mem = compiled.memory_analysis()
    results[arch] = {"flops": rc["flops"], "wire": rc["wire_bytes"],
                     "temp": getattr(mem, "temp_size_in_bytes", 0)}
# decode shape too (TP path)
dshape = dataclasses.replace(SHAPES["decode_32k"], seq_len=64,
                             global_batch=8)
cfg = get_smoke_config("qwen3-4b")
compiled = lower_one(cfg, dshape, mesh, backend="chunked", remat=True,
                     microbatch=0)
results["qwen3-4b-decode"] = {"ok": True}
print("RESULT " + json.dumps(results))
"""


@pytest.mark.slow
def test_mini_dryrun_all_families():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    results = json.loads(line[len("RESULT "):])
    assert len(results) == 7
    for arch, r in results.items():
        if "flops" in r:
            assert r["flops"] > 0, arch
