"""Failure & recovery subsystem (DESIGN.md §7): §4 invariants under
failures, no-failure bit-identity, SDN-reroute vs legacy-pin semantics.

``mini_setup`` / ``with_failures`` / ``dims`` live in conftest.py (shared
with the invariant and control-plane suites)."""
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import dims, with_failures
from repro.core import (PolicyConfig, RECOVERY_RESUME, ROUTE_LEGACY,
                        ROUTE_SDN, host_crash, link_cut, no_failures,
                        simulate, simulate_batch, summarize)
from repro.core.flows import Flow, flows_setup
from repro.core.mapreduce import DONE
from repro.core.topology import leaf_spine, torus_2d


def test_all_inf_schedule_bit_identical(mini_setup):
    """The no-failure schedule IS the pre-failure engine, bitwise."""
    base = simulate(mini_setup, PolicyConfig(job_concurrency=2))
    inf = simulate(with_failures(mini_setup, no_failures(*dims(mini_setup))),
                   PolicyConfig(job_concurrency=2))
    for name in base._fields:
        a, b = np.asarray(getattr(base, name)), np.asarray(getattr(inf, name))
        assert np.array_equal(a, b, equal_nan=True), name


def test_conservation_and_clock_after_reexecution(mini_setup):
    """§4 invariants survive a host outage: every valid task/packet still
    completes, the clock stays monotone (finish >= start)."""
    sched = host_crash(*dims(mini_setup), host=0, at=30.0, recover_at=300.0)
    s = simulate(with_failures(mini_setup, sched),
                 PolicyConfig(job_concurrency=2))
    assert not bool(s.stalled)
    valid_t = np.asarray(mini_setup.task_valid)
    valid_p = np.asarray(mini_setup.pkt_valid)
    assert np.all(np.asarray(s.task_state)[valid_t] == DONE)
    assert np.all(np.asarray(s.pkt_state)[valid_p] == DONE)
    assert np.all(np.asarray(s.pkt_finish - s.pkt_start)[valid_p] >= -1e-5)
    assert float(s.time) > 0
    assert int(np.asarray(s.task_restarts).sum()) >= 1


def test_dead_host_draws_zero_power(mini_setup):
    """A permanently-dead host re-executes its tasks elsewhere and stops
    accumulating energy."""
    sched = host_crash(*dims(mini_setup), host=0, at=1.0)
    s = simulate(with_failures(mini_setup, sched),
                 PolicyConfig(job_concurrency=2))
    assert not bool(s.stalled)  # 15 other hosts absorb the work
    base = simulate(mini_setup, PolicyConfig(job_concurrency=2))
    # host 0 runs (almost) nothing after t=1 -> far below its healthy draw
    assert float(s.host_energy[0]) < float(base.host_energy[0])
    assert np.all(np.asarray(s.task_state)[np.asarray(mini_setup.task_valid)]
                  == DONE)


def test_stall_on_permanent_disconnect():
    """Cutting the only cable forever must stall, not free-transfer."""
    topo = torus_2d(2, 1, bw=1e9)
    setup = flows_setup(topo, [Flow(0, 1, 8.0)])
    sched = link_cut(topo.n_hosts, topo.n_links, [0, 1], at=2.0)
    s = simulate(with_failures(setup, sched), PolicyConfig())
    assert bool(s.stalled)
    assert float(s.time) == pytest.approx(2.0, rel=1e-5)


def test_transient_link_cut_resumes():
    """Same cut with a recovery instant: the flow finishes after repair."""
    topo = torus_2d(2, 1, bw=1e9)
    setup = flows_setup(topo, [Flow(0, 1, 8.0)])
    sched = link_cut(topo.n_hosts, topo.n_links, [0, 1], at=2.0,
                     recover_at=10.0)
    s = simulate(with_failures(setup, sched), PolicyConfig())
    assert not bool(s.stalled)
    # 2 s transferred, 8 s outage, 6 s remaining -> done at 16
    assert float(s.time) == pytest.approx(16.0, rel=1e-3)
    assert float(np.asarray(s.job_downtime).sum()) == pytest.approx(
        8.0, rel=1e-3)


def test_sdn_reroutes_legacy_pins():
    """The headline (DESIGN.md §7): on a path-diverse fabric SDN's global
    view routes around a cut; the legacy static hash can keep forwarding
    into it and waits out the outage."""
    topo = leaf_spine(2, 2, 2)
    setup = flows_setup(topo, [Flow(0, 2, 8.0)])
    times = {}
    for spine in (0, 1):
        cut = topo.links_touching(topo.switch(spine))
        sched = link_cut(topo.n_hosts, topo.n_links, cut, at=2.0,
                         recover_at=500.0)
        sf = with_failures(setup, sched)
        for name, pol in (("sdn", ROUTE_SDN), ("legacy", ROUTE_LEGACY)):
            s = simulate(sf, PolicyConfig(routing=pol))
            assert not bool(s.stalled)
            times[(name, spine)] = float(s.time)
    # whichever spine it was using, SDN finishes as if nothing happened
    assert min(times[("sdn", 0)], times[("sdn", 1)]) == pytest.approx(
        8.0, rel=1e-3)
    assert max(times[("sdn", 0)], times[("sdn", 1)]) == pytest.approx(
        8.0, rel=1e-3)
    # the legacy flow is pinned to exactly one spine: cutting THAT spine
    # parks it until recovery
    assert max(times[("legacy", 0)], times[("legacy", 1)]) > 100.0


def test_recovery_resume_not_slower_than_restart(mini_setup):
    """Checkpoint resume (beyond-paper) keeps task progress a restart
    would redo."""
    sched = host_crash(*dims(mini_setup), host=0, at=50.0, recover_at=400.0)
    sf = with_failures(mini_setup, sched)
    restart = simulate(sf, PolicyConfig(job_concurrency=2))
    resume = simulate(sf, PolicyConfig(job_concurrency=2,
                                       recovery=RECOVERY_RESUME))
    assert not bool(restart.stalled) and not bool(resume.stalled)
    assert float(resume.time) <= float(restart.time) + 1e-3


def test_batch_single_bit_equality_with_failures(mini_setup):
    """§4: a vmapped policy batch equals the corresponding single runs,
    failures included."""
    sched = host_crash(*dims(mini_setup), host=2, at=40.0, recover_at=200.0)
    sf = with_failures(mini_setup, sched)
    pols = {"routing": jnp.asarray([ROUTE_SDN, ROUTE_LEGACY]),
            "job_concurrency": jnp.asarray([2, 2])}
    sb = simulate_batch(sf, pols)
    for i, routing in enumerate((ROUTE_SDN, ROUTE_LEGACY)):
        si = simulate(sf, PolicyConfig(routing=routing, job_concurrency=2))
        assert float(sb.time[i]) == float(si.time)
        assert np.array_equal(np.asarray(sb.task_restarts[i]),
                              np.asarray(si.task_restarts))


def test_total_outage_defers_admission(mini_setup):
    """With EVERY host dead at release time the ResourceManager has
    nowhere to place: admission waits for the first recovery breakpoint
    instead of piling tasks onto a dead VM slot."""
    n_h, n_l = dims(mini_setup)
    sched = no_failures(n_h, n_l)
    sched.host_fail_t[:] = 0.0
    sched.host_recover_t[:] = 50.0
    s = simulate(with_failures(mini_setup, sched),
                 PolicyConfig(job_concurrency=2))
    assert not bool(s.stalled)
    admit = np.asarray(s.job_admit_t)
    assert np.nanmin(admit) >= 50.0  # nothing admitted while all-dead
    assert np.all(np.asarray(s.task_state)[np.asarray(mini_setup.task_valid)]
                  == DONE)


def test_recovery_metrics_in_report(mini_setup):
    sched = host_crash(*dims(mini_setup), host=0, at=30.0, recover_at=300.0)
    s = simulate(with_failures(mini_setup, sched),
                 PolicyConfig(job_concurrency=2))
    rep = summarize(mini_setup, s)
    for key in ("task_reexecs", "pkt_reroutes", "downtime_s"):
        assert key in rep and rep[key].shape == (mini_setup.n_jobs,)
    assert int(rep["task_reexecs"].sum()) == \
        int(np.asarray(s.task_restarts).sum())


def test_experiment_failure_axis(mini_setup):
    """Experiment(failures=...) replicates scenarios per schedule and the
    whole grid runs as one program with recovery metrics in rows()."""
    from repro.api import Experiment
    from repro.scenarios.failures import failure_injector
    res = Experiment(
        scenarios=("mini", mini_setup),
        policies=[("sdn", PolicyConfig(routing=ROUTE_SDN,
                                       job_concurrency=2)),
                  ("legacy", PolicyConfig(routing=ROUTE_LEGACY,
                                          job_concurrency=2))],
        failures=[("none", no_failures(*dims(mini_setup))),
                  ("r1", failure_injector(host_rate=3e-4, link_rate=3e-4,
                                          mttr=120.0, horizon=2000.0,
                                          seed=1))],
    ).run()
    assert res.n_scenarios == 2 and res.n_policies == 2
    rows = res.rows()
    assert len(rows) == 4
    for row in rows:
        assert not row["stalled"]
        assert {"task_reexecs", "pkt_reroutes", "downtime_s"} <= set(row)
    # the all-inf cell reports zero recovery activity
    none_rows = [r for r in rows if r["scenario"].endswith("none")]
    assert none_rows and all(r["task_reexecs"] == 0 and r["pkt_reroutes"] == 0
                             for r in none_rows)


def test_failed_scenario_registry_entries():
    from repro.scenarios import get_scenario
    sc = get_scenario("paper-fabric-failures", n_each=1)
    setup = sc.build()
    assert setup.failures is not None and setup.failures.any_failures
    sc2 = get_scenario("leaf-spine-failures")
    setup2 = sc2.build()
    assert setup2.failures is not None
    # link cuts are drawn per CABLE: both directed slots agree
    lf = setup2.failures.link_fail_t
    assert np.array_equal(lf[0::2], lf[1::2], equal_nan=True)
