"""Arrival-process property tests (DESIGN.md §11).

Every generator must be seed-deterministic (same seed ⇒ bit-identical
trace — the replay property the streaming ring's shared-trace design
rests on), statistically honest (empirical Poisson rate within tolerance,
diurnal modulation with the requested period/phase), and the trace path
must round-trip literal ``JobSpec`` lists unchanged.
"""
import math

import numpy as np
import pytest

from repro.scenarios.arrivals import (DEFAULT_CLASSES, DiurnalArrivals,
                                      PoissonArrivals, ServiceClass,
                                      TraceArrivals, as_workload)
from repro.scenarios.workloads import JobTemplate, uniform_workload


def _trace(proc, horizon):
    return list(proc.events(horizon))


# ---------------------------------------------------------------------------
# determinism / replay
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("make", [
    lambda s: PoissonArrivals(rate=0.8, seed=s),
    lambda s: DiurnalArrivals(base_rate=0.8, amplitude=0.6, period=50.0,
                              phase=7.0, seed=s),
])
def test_seed_determinism_and_replay(make):
    a = _trace(make(3), 200.0)
    b = _trace(make(3), 200.0)          # fresh events() call: replays
    c = _trace(make(4), 200.0)
    assert len(a) == len(b) > 10
    for x, y in zip(a, b):
        assert x.t == y.t and x.cls == y.cls and x.job == y.job
    assert [x.t for x in a] != [x.t for x in c]   # seed actually matters
    # strictly increasing, below the horizon
    ts = np.asarray([x.t for x in a])
    assert np.all(np.diff(ts) > 0) and ts[-1] < 200.0
    # a longer horizon extends the SAME trace (lazy prefix property)
    d = _trace(make(3), 400.0)
    assert [x.t for x in d[:len(a)]] == [x.t for x in a]


def test_empirical_poisson_rate():
    rate, horizon = 2.0, 4000.0
    n = len(_trace(PoissonArrivals(rate=rate, seed=0), horizon))
    # n ~ Poisson(rate*horizon): 5 sigma ≈ 5*sqrt(8000) ≈ 447 on 8000
    mean = rate * horizon
    assert abs(n - mean) < 5.0 * math.sqrt(mean)


def test_diurnal_rate_modulation_period_and_phase():
    """Arrivals thin to the sinusoid: the peak-quarter of the cycle must
    collect measurably more arrivals than the trough-quarter, with the
    quarters located by ``period``/``phase``."""
    p = DiurnalArrivals(base_rate=2.0, amplitude=0.8, period=100.0,
                        phase=10.0, seed=1)
    # rate_at honors phase: mean upcrossing at t=phase, peak a quarter later
    assert p.rate_at(10.0) == pytest.approx(2.0)
    assert p.rate_at(35.0) == pytest.approx(2.0 * 1.8)
    assert p.rate_at(85.0) == pytest.approx(2.0 * 0.2)
    ts = np.asarray([a.t for a in _trace(p, 4000.0)])
    phase_of = (ts - 10.0) % 100.0
    peak = np.sum((phase_of >= 12.5) & (phase_of < 37.5))     # sin in top arc
    trough = np.sum((phase_of >= 62.5) & (phase_of < 87.5))
    # expected ratio ≈ (1+0.8*avg_sin)/(1-0.8*avg_sin) ≈ 4.1 — demand >2
    assert peak > 2.0 * trough
    # overall mean stays at base_rate (the sinusoid integrates out)
    assert abs(len(ts) - 2.0 * 4000.0) < 5.0 * math.sqrt(2.0 * 4000.0)


def test_diurnal_amplitude_validation():
    with pytest.raises(ValueError, match="amplitude"):
        _trace(DiurnalArrivals(base_rate=1.0, amplitude=1.0), 10.0)


# ---------------------------------------------------------------------------
# trace replay / round trip
# ---------------------------------------------------------------------------


def test_trace_jobs_round_trip():
    """as_workload(TraceArrivals(jobs=…)) returns the jobs unchanged, in
    submit-time order — the bit-identity path."""
    jobs = uniform_workload(n_jobs=5, seed=2, interval_s=3.0)
    shuffled = tuple(jobs[i] for i in (3, 0, 4, 1, 2))
    out = as_workload(TraceArrivals(jobs=shuffled), horizon=1e9)
    assert out == sorted(jobs, key=lambda j: j.submit_time)
    # the horizon truncates by submit_time
    short = as_workload(TraceArrivals(jobs=shuffled), horizon=6.5)
    assert [j.submit_time for j in short] == [0.0, 3.0, 6.0]


def test_trace_times_lowers_from_class_template():
    cls = (ServiceClass("a", weight=2.0, template=JobTemplate(n_map=4)),
           ServiceClass("b", template=JobTemplate(n_map=2)))
    tr = TraceArrivals(times=(1.0, 2.0, 5.0), cls_ids=(0, 1, 0),
                       scales=(1.0, 1.0, 4.0), classes=cls)
    evs = _trace(tr, 10.0)
    assert [a.t for a in evs] == [1.0, 2.0, 5.0]
    assert [a.cls for a in evs] == [0, 1, 0]
    assert evs[0].job.n_map == 4 and evs[1].job.n_map == 2
    assert evs[2].job.n_map == 8          # par = sqrt(4) = 2
    assert evs[0].job.priority == 2.0 and evs[1].job.priority == 0.0
    with pytest.raises(ValueError, match="non-decreasing"):
        _trace(TraceArrivals(times=(2.0, 1.0)), 10.0)


# ---------------------------------------------------------------------------
# service classes
# ---------------------------------------------------------------------------


def test_class_shares_and_priority_threading():
    cls = (ServiceClass("batch", share=3.0, weight=0.0),
           ServiceClass("urgent", share=1.0, weight=5.0, slo_s=30.0))
    evs = _trace(PoissonArrivals(rate=2.0, classes=cls, seed=5), 2000.0)
    ci = np.asarray([a.cls for a in evs])
    frac_urgent = float(np.mean(ci == 1))
    assert abs(frac_urgent - 0.25) < 0.05       # share-proportional sampling
    pri = np.asarray([a.job.priority for a in evs])
    assert np.all(pri[ci == 1] == 5.0) and np.all(pri[ci == 0] == 0.0)


def test_class_share_validation():
    bad = (ServiceClass("x", share=-1.0),)
    with pytest.raises(ValueError, match="share"):
        _trace(PoissonArrivals(rate=1.0, classes=bad, seed=0), 10.0)
    assert DEFAULT_CLASSES[0].slo_s == math.inf


def test_as_workload_max_jobs():
    w = as_workload(PoissonArrivals(rate=1.0, seed=0), horizon=1e6,
                    max_jobs=7)
    assert len(w) == 7
    assert all(w[i].submit_time < w[i + 1].submit_time for i in range(6))
