"""Fleet execution engine (DESIGN.md §9): bit-identity to the serial
runners across the scenario registry, cohort retire/refill bookkeeping,
and the keyed consts cache."""
import jax
import numpy as np
import pytest

from repro.api import (CohortSchedule, Experiment, StepPredictor,
                       consts_build_count, consts_cache_clear, run_fleet,
                       runners)
from repro.scenarios import list_scenarios

# leaf-spine-xl runs for minutes serially; its fleet path is covered by the
# slow-marked test below and by benchmarks/engine_profile.py's large tier.
REGISTRY = [n for n in list_scenarios() if "xl" not in n]

# routing × placement coverage: both routings, all three placements, with
# one pair per static signature so cohort grouping is exercised too
POLICIES = [
    {"routing": 0, "placement": 0},
    {"routing": 0, "placement": 2},
    {"routing": 1, "placement": 0},
    {"routing": 1, "placement": 1},
]
SEEDS = (0, 1, 2)


def assert_results_identical(a, b, context=""):
    """Leaf-by-leaf bit equality (NaN == NaN) between two Results grids."""
    for name, la, lb in zip(a.states._fields, a.states, b.states):
        la, lb = np.asarray(la), np.asarray(lb)
        assert la.shape == lb.shape, \
            f"{context}{name}: shape {la.shape} != {lb.shape}"
        assert np.array_equal(la, lb, equal_nan=True), \
            f"{context}{name}: values differ"


# ---------------------------------------------------------------------------
# bit-identity to the serial runner
# ---------------------------------------------------------------------------


def test_fleet_identical_across_registry():
    """One packed grid over every (non-xl) registry scenario — including
    the *-failures ones, whose schedules flow through the packed consts —
    times routing/placement times 3 seeds, drained by the fleet with a
    width small enough to force retire/refill cycles."""
    exp = Experiment(scenarios=REGISTRY, policies=POLICIES, seeds=SEEDS)
    serial = exp.run()
    fleet, stats = exp.run_fleet(width=5, chunk_steps=16, return_stats=True)
    assert_results_identical(serial, fleet, "registry grid: ")
    assert stats.sims == len(REGISTRY) * len(POLICIES) * len(SEEDS)
    # width 5 over 3-member cohorts: every cohort fits one wave
    assert stats.cohorts == len(REGISTRY) * len(POLICIES)


def test_fleet_identical_single_scenario_with_refill():
    """S == 1 path (unpacked consts) with width << members so lanes retire
    and refill mid-cohort."""
    exp = Experiment(scenarios="paper-fabric", policies=POLICIES[:1],
                     seeds=range(9))
    serial = exp.run()
    fleet, stats = exp.run_fleet(width=2, chunk_steps=8, return_stats=True)
    assert_results_identical(serial, fleet, "single-scenario: ")
    assert stats.refills > 0


def test_fleet_identical_length_divergent_bucket():
    """A deliberately length-divergent cohort: job_concurrency 1 serializes
    the whole workload (many more events) but is NOT a static field, so the
    short and long sims share one cohort and the early-exit/refill path has
    to cope with the spread."""
    pols = [{"job_concurrency": c, "seed": s}
            for c in (1, 1_000_000) for s in SEEDS]
    exp = Experiment(scenarios="leaf-spine", policies=pols)
    serial = exp.run()
    steps = np.asarray(serial.states.steps)[0]
    assert steps.max() >= steps.min() + 16, "bucket not length-divergent"
    fleet = exp.run_fleet(width=4, chunk_steps=8)
    assert_results_identical(serial, fleet, "divergent bucket: ")


def test_fleet_sharded_matches_serial():
    """The shard_map path: with >1 visible device (the CI job forces 8 via
    XLA_FLAGS) the lane axis is split over the fleet mesh; on one device
    this degrades to the plain jitted chunk.  Either way: bit-identical."""
    n_dev = jax.local_device_count()
    exp = Experiment(scenarios="paper-fabric", policies=POLICIES, seeds=SEEDS)
    serial = exp.run()
    fleet, stats = exp.run_fleet(width=8, chunk_steps=16, devices=n_dev,
                                 return_stats=True)
    assert_results_identical(serial, fleet, f"sharded x{n_dev}: ")
    assert stats.devices == n_dev


@pytest.mark.slow
def test_fleet_identical_xl():
    """leaf-spine-xl (the 128-host tier) through the fleet batch path."""
    exp = Experiment(scenarios="leaf-spine-xl", policies=POLICIES[2:])
    assert_results_identical(exp.run(), exp.run_fleet(width=2, chunk_steps=64),
                             "xl: ")


# ---------------------------------------------------------------------------
# cohort bookkeeping
# ---------------------------------------------------------------------------


def test_cohort_schedule_retire_refill_and_pads():
    sched = CohortSchedule(["a", "b", "c", "d", "e"], width=3)
    assert sched.lane == ["a", "b", "c"]
    assert not sched.pad_mask().any()
    assert sched.active

    # lane 1 finishes: retired, refilled from the queue
    retire, refill = sched.step(np.array([False, True, False]))
    assert retire == [(1, "b")]
    assert refill.tolist() == [False, True, False]
    assert sched.lane == ["a", "d", "c"]

    # everything finishes: e takes a lane, the other two become pads
    retire, refill = sched.step(np.array([True, True, True]))
    assert sorted(m for _, m in retire) == ["a", "c", "d"]
    assert refill.sum() == 1 and sched.lane.count(None) == 2
    assert sched.pad_mask().sum() == 2
    assert sched.active

    # pad lanes stay done and must NOT retire again
    retire, refill = sched.step(np.array([True, True, True]))
    assert [m for _, m in retire] == ["e"] and not refill.any()
    assert not sched.active
    assert sorted(m for _, m in sched.retired) == list("abcde")


def test_cohort_schedule_width_wider_than_members():
    sched = CohortSchedule(["a"], width=4)
    assert sched.pad_mask().tolist() == [False, True, True, True]
    retire, refill = sched.step(np.array([True] * 4))
    assert retire == [(0, "a")] and not refill.any()
    assert not sched.active


def test_step_predictor_orders_by_observation():
    pred = StepPredictor()
    # unobserved: the group estimate (or size prior) ties everything
    assert pred.predict("m1", "g", 10, 20) == pred.predict("m2", "g", 10, 20)
    pred.observe("m1", 100.0)
    pred.observe("m2", 10.0)
    assert pred.predict("m2", "g", 10, 20) < pred.predict("m1", "g", 10, 20)
    # EWMA moves toward new observations without forgetting everything
    pred.observe("m2", 100.0)
    assert 10.0 < pred.predict("m2", "g", 10, 20) < 100.0


def test_fleet_bucket_order_does_not_change_results():
    """Predictor-driven admission order is a pure scheduling choice: a
    calibrated predictor (second fleet) must reproduce the cold-start
    results bit-for-bit."""
    exp = Experiment(scenarios="paper-fabric", policies=POLICIES[:1],
                     seeds=range(6))
    pred = StepPredictor()
    first = run_fleet(exp, width=2, chunk_steps=8, predictor=pred)
    second = run_fleet(exp, width=2, chunk_steps=8, predictor=pred)
    assert_results_identical(first, second, "calibrated reorder: ")


# ---------------------------------------------------------------------------
# keyed consts cache
# ---------------------------------------------------------------------------


def test_consts_built_once_per_scenario_set():
    """Experiment.run/get_runner used to rebuild packed EngineConsts every
    call; registry-name scenario sets now build once per process."""
    consts_cache_clear()
    names = ["paper-fabric", "leaf-spine"]
    e1 = Experiment(scenarios=names, policies=POLICIES[:1])
    e1.build()
    e1.build()                                  # instance memo
    assert consts_build_count() == 1
    Experiment(scenarios=names, policies=POLICIES[:2]).build()
    assert consts_build_count() == 1            # cross-Experiment cache
    Experiment(scenarios="paper-fabric").build()
    assert consts_build_count() == 2            # different key -> new build

    # a consts-cache hit must also hit the compiled-runner cache: same
    # consts identity, same SimMeta -> zero extra traces
    runners.cache_clear()
    Experiment(scenarios=names, policies=POLICIES[:1]).run()
    t = runners.trace_count()
    Experiment(scenarios=names, policies=POLICIES[:1]).run()
    assert runners.trace_count() == t


def test_consts_cache_skips_failure_crosses():
    """Failure crosses mutate the setups after build — never cached."""
    from repro.scenarios.failures import failure_injector
    consts_cache_clear()
    for _ in range(2):
        Experiment(scenarios="paper-fabric",
                   failures=failure_injector(host_rate=0.05)).build()
    assert consts_build_count() == 2
