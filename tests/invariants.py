"""Reusable engine invariants (DESIGN.md §4, §7, §10).

Factored out of the ad-hoc per-test assertions in test_failures.py /
test_engine_equiv.py so every suite — and especially the registry x policy
grid in test_invariants.py — checks the SAME properties.  Each checker
takes numpy views of one UNBATCHED replica (consts leaves ``[...]``, final
``SimState`` leaves ``[...]``) plus its ``SimMeta`` and raises
``AssertionError`` with a labelled message on violation.

The invariants:

- ``check_terminal``     — a non-stalled run finishes everything: every
  valid task/packet DONE, every valid job's outputs accounted, vm_load
  drained to zero.
- ``check_clock``        — the clock is monotone and finite: time >= 0,
  finish >= start for completed work, release <= admit <= done per job.
- ``check_pad_inert``    — pad slots of a packed sweep never leave VOID /
  never acquire a VM, a route, or a timestamp (DESIGN.md §5).
- ``check_energy``       — energy accumulators are non-negative and busy
  time never exceeds the makespan.
- ``check_ctrl``         — control-plane accounting (DESIGN.md §10):
  ``occupied == installs - evictions`` exactly (flow-table conservation),
  counters non-negative, nothing left parked INSTALLING at the end, and
  with the ctrl plane off every ctrl counter is zero and placement never
  moved.
- ``check_finite``       — every float leaf of the final state is finite,
  except the documented sentinels: NaN for never-set timestamps
  (``task_start``/``task_finish``/``pkt_start``/``pkt_finish``/
  ``job_admit_t``/``job_done_t``) and +inf for the ``pkt_ready_t``
  not-INSTALLING marker.  No other NaN/inf may ever escape the loop.
- ``check_chaos``        — chaos accounting (DESIGN.md §13): speculation
  counters/slots are zero/idle without clone capacity, ``degraded_time``
  is zero without a degradation schedule, failover counters are zero
  without a ctrl plane, and live clone slots always reference valid
  ACTIVE originals with non-negative remaining work.
- ``check_slots``        — slot conservation (DESIGN.md §11): admitted ==
  completed + in-flight over valid jobs, ``vm_load`` is EXACTLY the live
  placed-task count per VM, and unadmitted jobs' slots are untouched —
  the per-ring ledger the streaming refill relies on.
- ``check_stream``       — streaming-run conservation + clock monotonicity
  on a ``StreamResults``: every arrival loads and retires exactly once per
  lane, boundary clocks and cumulative energy/busy never go backwards, and
  per-job stamps are ordered.
"""
import numpy as np

from repro.core.mapreduce import ACTIVE, DONE, INSTALLING, VOID, WAITING

_TOL = 1e-4


def _np(tree_leaf):
    return np.asarray(tree_leaf)


def check_terminal(c, meta, s, label=""):
    stalled = bool(_np(s.stalled))
    assert not stalled, f"{label}: run stalled at t={float(_np(s.time))}"
    task_valid = _np(c.task_valid)
    pkt_valid = _np(c.pkt_valid)
    job_valid = _np(c.job_valid)
    assert np.all(_np(s.task_state)[task_valid] == DONE), \
        f"{label}: valid tasks not DONE"
    assert np.all(_np(s.pkt_state)[pkt_valid] == DONE), \
        f"{label}: valid packets not DONE"
    assert np.all(_np(s.job_out_done)[job_valid]
                  >= _np(c.job_n_out)[job_valid]), \
        f"{label}: valid jobs missing output packets"
    assert np.all(_np(s.vm_load) == 0), \
        f"{label}: vm_load not drained (residual={_np(s.vm_load).max()})"


def check_clock(c, meta, s, label=""):
    t = float(_np(s.time))
    assert np.isfinite(t) and t >= 0.0, f"{label}: bad makespan {t}"
    pkt_done = _np(s.pkt_state) == DONE
    task_done = _np(s.task_state) == DONE
    pdur = (_np(s.pkt_finish) - _np(s.pkt_start))[pkt_done]
    tdur = (_np(s.task_finish) - _np(s.task_start))[task_done]
    assert np.all(pdur >= -_TOL), f"{label}: packet finish < start"
    assert np.all(tdur >= -_TOL), f"{label}: task finish < start"
    assert np.all(_np(s.pkt_finish)[pkt_done] <= t + _TOL), \
        f"{label}: packet finished after the clock"
    job_valid = _np(c.job_valid)
    admit = _np(s.job_admit_t)[job_valid]
    done = _np(s.job_done_t)[job_valid]
    release = _np(c.job_release)[job_valid]
    fin = np.isfinite(admit)
    assert np.all(admit[fin] >= release[fin] - _TOL), \
        f"{label}: job admitted before release"
    both = fin & np.isfinite(done)
    assert np.all(done[both] >= admit[both] - _TOL), \
        f"{label}: job done before admission"


def check_pad_inert(c, meta, s, label=""):
    pad_t = ~_np(c.task_valid)
    pad_p = ~_np(c.pkt_valid)
    assert np.all(_np(s.task_state)[pad_t] == VOID), \
        f"{label}: pad task left VOID"
    assert np.all(_np(s.pkt_state)[pad_p] == VOID), \
        f"{label}: pad packet left VOID"
    assert np.all(_np(s.task_vm)[pad_t] == -1), \
        f"{label}: pad task acquired a VM"
    assert np.all(_np(s.pkt_pair)[pad_p] == -1), \
        f"{label}: pad packet acquired a route"
    assert np.all(np.isnan(_np(s.task_start)[pad_t])), \
        f"{label}: pad task has a start time"
    assert np.all(np.isnan(_np(s.pkt_finish)[pad_p])), \
        f"{label}: pad packet has a finish time"


def check_energy(c, meta, s, label=""):
    t = float(_np(s.time))
    assert np.all(_np(s.host_energy) >= 0), f"{label}: negative host energy"
    assert np.all(_np(s.switch_energy) >= 0), \
        f"{label}: negative switch energy"
    assert np.all(_np(s.host_busy) <= t * (1 + 1e-5) + _TOL), \
        f"{label}: host busy time exceeds makespan"


def check_ctrl(c, meta, s, label=""):
    installs = int(_np(s.ctrl_installs))
    evictions = int(_np(s.ctrl_evictions))
    reinstalls = int(_np(s.ctrl_reinstalls))
    qwait = float(_np(s.ctrl_queue_wait))
    migs = int(_np(s.vm_migrations).sum())
    if not meta.has_ctrl:
        assert installs == evictions == reinstalls == 0 and migs == 0, \
            f"{label}: ctrl counters nonzero with the control plane off"
        assert qwait == 0.0, f"{label}: queue wait nonzero with ctrl off"
        assert np.array_equal(_np(s.vm_host), _np(c.vm_host)), \
            f"{label}: placement moved with the control plane off"
        return
    assert installs >= 0 and evictions >= 0 and reinstalls >= 0, \
        f"{label}: negative ctrl counter"
    assert qwait >= 0.0, f"{label}: negative controller queue wait"
    assert reinstalls <= installs, f"{label}: reinstalls exceed installs"
    # flow-table conservation: every install either still occupies a slot
    # or was evicted — exact, for every (latency, rate, slots) config
    occupied = int((_np(s.ftab_pair) >= 0).sum())
    assert occupied == installs - evictions, \
        f"{label}: table conservation broken " \
        f"(occupied={occupied}, installs={installs}, evictions={evictions})"
    # nothing may end the run parked on the controller
    pkt_valid = _np(c.pkt_valid)
    assert not np.any(_np(s.pkt_state)[pkt_valid] == INSTALLING), \
        f"{label}: packet left INSTALLING at the end"
    assert np.all(_np(s.pkt_install_wait) >= 0), \
        f"{label}: negative install wait"
    # live placement stays on real hosts
    n_real_vms = int(_np(c.n_vms))
    vm_host = _np(s.vm_host)[:n_real_vms]
    assert np.all((vm_host >= 0) & (vm_host < int(_np(c.n_hosts)))), \
        f"{label}: migrated VM left the host range"


# float state leaves where NaN is the documented "never set" sentinel
_NAN_OK = {"task_start", "task_finish", "pkt_start", "pkt_finish",
           "job_admit_t", "job_done_t"}
# float state leaves where +inf is the documented "not parked" sentinel
_INF_OK = {"pkt_ready_t"}


def check_finite(c, meta, s, label=""):
    """No undocumented NaN/inf escapes the event loop (DESIGN.md §13):
    every float leaf is finite except the known sentinels, and even those
    never mix sentinel kinds (a timestamp may be NaN but never inf; the
    install-park marker may be inf but never NaN)."""
    for name, leaf in zip(type(s)._fields, s):
        a = _np(leaf)
        if not np.issubdtype(a.dtype, np.floating):
            continue
        if name in _NAN_OK:
            assert not np.any(np.isinf(a)), f"{label}: inf in {name}"
        elif name in _INF_OK:
            assert not np.any(np.isnan(a)), f"{label}: NaN in {name}"
        else:
            bad = ~np.isfinite(a)
            assert not np.any(bad), \
                f"{label}: non-finite {name} " \
                f"({int(bad.sum())} of {a.size} entries)"


def check_chaos(c, meta, s, label=""):
    """Gray-failure / speculation / failover accounting (DESIGN.md §13)."""
    launches = int(_np(s.spec_launches))
    wins = int(_np(s.spec_wins))
    wasted = float(_np(s.spec_wasted))
    degraded = float(_np(s.degraded_time))
    failovers = int(_np(s.ctrl_failovers))
    park = float(_np(s.ctrl_failover_park))
    if int(meta.spec_slots) == 0:
        assert launches == wins == 0 and wasted == 0.0, \
            f"{label}: speculation counters nonzero without clone slots"
    assert launches >= 0 and wins >= 0 and wasted >= -_TOL, \
        f"{label}: negative speculation counter"
    assert wins <= launches, f"{label}: clone wins exceed launches"
    if not meta.has_degradation:
        assert degraded == 0.0, \
            f"{label}: degraded_time nonzero without a degradation schedule"
    assert 0.0 <= degraded <= float(_np(s.time)) * (1 + 1e-5) + _TOL, \
        f"{label}: degraded_time outside [0, makespan]"
    if not meta.has_ctrl:
        assert failovers == 0 and park == 0.0, \
            f"{label}: failover counters nonzero with the ctrl plane off"
    assert failovers >= 0 and park >= -_TOL, \
        f"{label}: negative failover counter"
    # live clone slots reference valid, still-ACTIVE originals
    spec_of = _np(s.spec_of)
    live = spec_of >= 0
    if np.any(live):
        orig = spec_of[live]
        n_t = _np(s.task_state).shape[0]
        assert np.all(orig < n_t), f"{label}: clone references bad task"
        assert np.all(_np(c.task_valid)[orig]), \
            f"{label}: clone of a pad task"
        assert np.all(_np(s.task_state)[orig] == ACTIVE), \
            f"{label}: clone outlived its original"
        assert np.all(_np(s.spec_rem)[live] >= -_TOL), \
            f"{label}: negative clone remaining work"
        n_vms = _np(s.vm_load).shape[0]
        svm = _np(s.spec_vm)[live]
        assert np.all((svm >= 0) & (svm < n_vms)), \
            f"{label}: clone on a bad VM"


def check_slots(c, meta, s, label=""):
    """Slot conservation (DESIGN.md §11), valid on ANY state — final or a
    streaming chunk boundary: the job ledger balances, ``vm_load`` equals
    the live placed-task census, and unadmitted jobs' slots are pristine
    (exactly what a ring refill resets them to)."""
    job_valid = _np(c.job_valid)
    admitted = _np(s.job_admitted)
    out_done = _np(s.job_out_done)
    n_out = _np(c.job_n_out)
    assert not np.any(admitted & ~job_valid), f"{label}: pad job admitted"
    assert np.all(out_done[job_valid] <= n_out[job_valid]), \
        f"{label}: job over-completed (out_done > n_out)"
    assert np.all(out_done[~job_valid] == 0), \
        f"{label}: pad job produced outputs"
    done_j = job_valid & (out_done >= n_out)
    assert np.all(admitted[done_j]), f"{label}: job completed unadmitted"
    in_flight = admitted & ~done_j
    assert int(admitted.sum()) == int(done_j.sum()) + int(in_flight.sum()), \
        f"{label}: admission ledger broken"
    # vm_load is exactly the live (placed, not-DONE) valid-task census
    task_valid = _np(c.task_valid)
    st = _np(s.task_state)
    vm = _np(s.task_vm)
    vm_load = _np(s.vm_load)
    live = task_valid & ((st == WAITING) | (st == ACTIVE)) & (vm >= 0)
    census = np.bincount(vm[live], minlength=vm_load.shape[0])
    assert np.array_equal(vm_load, census[:vm_load.shape[0]]), \
        f"{label}: vm_load != live placed-task census " \
        f"(load={vm_load.sum()}, census={census.sum()})"
    # unadmitted valid jobs: their slots look freshly (re)loaded
    tj = _np(c.task_job)
    waiting_job = job_valid & ~admitted
    tw = task_valid & waiting_job[np.clip(tj, 0, waiting_job.shape[0] - 1)]
    assert np.all(st[tw] == WAITING), f"{label}: unadmitted job task moved"
    assert np.all(vm[tw] == -1), f"{label}: unadmitted job task placed"
    assert np.all(_np(s.task_got)[tw] == 0), \
        f"{label}: unadmitted job task received input"


def check_stream(res, label=""):
    """Streaming conservation + clock monotonicity over a completed
    ``repro.api.StreamResults`` (DESIGN.md §11)."""
    st = res.stats
    assert st.loads == st.retired, \
        f"{label}: loads ({st.loads}) != retired ({st.retired})"
    assert st.loads == st.trace_len * st.lanes, \
        f"{label}: arrivals lost (loads={st.loads}, " \
        f"trace={st.trace_len} x {st.lanes} lanes)"
    assert st.refills == st.loads - min(st.slots, st.trace_len) * st.lanes, \
        f"{label}: refill ledger broken"
    for pi in range(res.n_policies):
        lab = f"{label}/{res.policy_names[pi]}"
        j = res.jobs[pi]
        assert np.array_equal(np.sort(j["seq"]), np.arange(st.trace_len)), \
            f"{lab}: arrivals not retired exactly once"
        assert np.all(np.isfinite(j["t_done"])), f"{lab}: unfinished job row"
        assert np.all(j["t_admit"] >= j["t_arr"] - _TOL), \
            f"{lab}: job admitted before arrival"
        assert np.all(j["t_done"] >= j["t_admit"] - _TOL), \
            f"{lab}: job done before admission"
        smp = res.samples[pi]
        assert np.all(np.diff(smp[:, 0]) >= -_TOL), \
            f"{lab}: boundary clock went backwards"
        assert np.all(np.diff(smp[:, 1:], axis=0) >= -1e-3), \
            f"{lab}: cumulative energy/busy went backwards"


ALL_INVARIANTS = (check_terminal, check_clock, check_pad_inert,
                  check_energy, check_ctrl, check_slots, check_finite,
                  check_chaos)


def check_all(c, meta, s, label="", expect_stalled=False):
    """Run every invariant on one unbatched replica's final state."""
    for fn in ALL_INVARIANTS:
        if expect_stalled and fn in (check_terminal,):
            continue
        fn(c, meta, s, label=label)


def grid_check_all(consts, meta, states, scenario_names, policy_names):
    """Apply ``check_all`` to every cell of an ``[S, P]`` result grid.

    ``consts`` leaves are ``[S, ...]``, ``states`` leaves ``[S, P, ...]`` —
    the ``repro.api.Results`` layout."""
    import jax
    for si, sn in enumerate(scenario_names):
        ci = jax.tree_util.tree_map(lambda a: a[si], consts)
        for pi, pn in enumerate(policy_names):
            cell = jax.tree_util.tree_map(lambda a: a[si, pi], states)
            check_all(ci, meta, cell, label=f"{sn}/{pn}")
