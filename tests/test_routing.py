"""Routing oracles: APSP vs Floyd-Warshall, candidate-route validity."""
import numpy as np
import pytest

from repro.core.routing import build_route_table, hop_distances_np
from repro.core.topology import fat_tree, paper_fat_tree, torus_2d


def floyd_warshall(adj):
    d = adj.astype(np.float64).copy()
    n = d.shape[0]
    for k in range(n):
        d = np.minimum(d, d[:, k:k + 1] + d[k:k + 1, :])
    return d


def random_graph(n, m, seed):
    rng = np.random.RandomState(seed)
    adj = np.full((n, n), np.inf)
    np.fill_diagonal(adj, 0.0)
    for _ in range(m):
        i, j = rng.randint(0, n, 2)
        if i != j:
            adj[i, j] = 1.0
    return adj


@pytest.mark.parametrize("seed", range(5))
def test_hop_distances_vs_floyd_warshall(seed):
    adj = random_graph(24, 80, seed)
    got = hop_distances_np(adj.astype(np.float32))
    want = floyd_warshall(adj)
    finite = np.isfinite(want)
    assert np.array_equal(np.isfinite(got), finite)
    assert np.allclose(got[finite], want[finite])


@pytest.mark.parametrize("topo_fn", [paper_fat_tree,
                                     lambda: fat_tree(4),
                                     lambda: torus_2d(4, 4)])
def test_route_table_paths_are_valid(topo_fn):
    topo = topo_fn()
    rt = build_route_table(topo, k_max=8)
    dist = hop_distances_np(topo.hop_matrix())
    n = topo.n_nodes
    src_l, dst_l = topo.link_src, topo.link_dst
    checked = 0
    for src in range(0, n, max(1, n // 8)):
        for dst in range(0, n, max(1, n // 8)):
            p = src * n + dst
            for k in range(int(rt.n_cand[p])):
                hops = int(rt.route_len[p, k])
                assert hops == int(dist[src, dst])   # shortest
                node = src
                for h in range(hops):
                    li = int(rt.routes[p, k, h])
                    assert li >= 0
                    assert int(src_l[li]) == node    # contiguous
                    node = int(dst_l[li])
                assert node == dst                   # reaches dst
                checked += 1
    assert checked > 0


def test_paper_topology_counts():
    topo = paper_fat_tree()
    assert topo.n_hosts == 16
    assert topo.n_switches == 20
    assert topo.n_storage == 1
    rt = build_route_table(topo, k_max=16)
    nc = rt.n_cand.reshape(topo.n_nodes, topo.n_nodes)
    # SAN -> host: 2 parallel core-agg cables => 2 equal-hop routes
    assert nc[topo.storage(0), 0] == 2
    # inter-pod host pair: 2 agg x 2 core x 2 parallel x 2 parallel = 16
    assert nc[0, 4] == 16
    # same-edge pair: single route via the edge switch
    assert nc[0, 1] == 1


def test_candidates_distinct():
    topo = paper_fat_tree()
    rt = build_route_table(topo, k_max=16)
    n = topo.n_nodes
    p = 0 * n + 4
    routes = [tuple(rt.routes[p, k, :rt.route_len[p, k]])
              for k in range(int(rt.n_cand[p]))]
    assert len(set(routes)) == len(routes)
