"""Deterministic water-fill iteration-cap fallback regression (no
hypothesis dependency — the property-test version lives in
test_fairshare.py)."""
import jax.numpy as jnp
import numpy as np

from repro.core.fairshare import eq3_rates, waterfill_rates

INTRA = 1e12

# three bottleneck levels -> three freezing rounds:
#   L0 (bw 0.2): f1, f2            freeze round 1 at 0.1
#   L1 (bw 2.0): f0, f1, f3        f3 freezes round 2 (via L2), f0 round 3
#   L2 (bw 0.9): f3, f4, f5        freeze round 2 at 0.3
BW = np.asarray([0.2, 2.0, 0.9], np.float32)
ROUTES = np.asarray([
    [1, -1],   # f0
    [0, 1],    # f1
    [0, -1],   # f2
    [1, 2],    # f3
    [2, -1],   # f4
    [2, -1],   # f5
], np.int32)
ACTIVE = np.ones(6, bool)


def loads(rates):
    out = np.zeros(BW.shape[0])
    for f in range(ROUTES.shape[0]):
        for li in ROUTES[f]:
            if li >= 0:
                out[li] += float(rates[f])
    return out


def wf(n_iter=None):
    return np.asarray(waterfill_rates(jnp.asarray(ROUTES),
                                      jnp.asarray(ACTIVE), jnp.asarray(BW),
                                      INTRA, n_iter=n_iter))


def test_capacity_held_at_every_iteration_cap():
    full = wf()
    for n_iter in range(0, 5):
        rates = wf(n_iter)
        assert np.all(rates > 0)
        assert np.all(loads(rates) <= BW * (1 + 1e-4)), (n_iter, rates)
    # enough iterations -> the cap path vanishes
    assert np.allclose(wf(3), full)


def test_zero_iterations_degenerates_to_eq3():
    """With nothing frozen the clamped fallback level IS Eq. 3."""
    r3 = np.asarray(eq3_rates(jnp.asarray(ROUTES), jnp.asarray(ACTIVE),
                              jnp.asarray(BW), INTRA))
    assert np.allclose(wf(0), r3)
