"""Streaming engine tests (DESIGN.md §11).

The load-bearing guarantee: a finite trace that fits the ring's slot
capacity runs BIT-IDENTICALLY to ``Experiment.run`` on the equivalent
``ring_setup`` — the streaming layer adds refills around the compiled
chunk program, it never changes what the engine computes.  Plus: the
refill path conserves every arrival, and a large open-arrival run at
fixed slot capacity completes in bounded memory (slow-marked).
"""
import numpy as np
import pytest

from conftest import assert_states_equal
from invariants import check_stream
from repro.api import Experiment
from repro.core.policies import (PLACE_ROUND_ROBIN, PolicyConfig,
                                 ROUTE_LEGACY, ROUTE_SDN, TRAFFIC_WATERFILL)
from repro.core.streaming import RingSpec, ring_setup
from repro.scenarios import get_scenario
from repro.scenarios.arrivals import (PoissonArrivals, ServiceClass,
                                      TraceArrivals)
from repro.scenarios.workloads import JobTemplate

POLICIES = [
    ("sdn", PolicyConfig(routing=ROUTE_SDN, job_concurrency=2)),
    ("legacy", PolicyConfig(routing=ROUTE_LEGACY, job_concurrency=2,
                            placement=PLACE_ROUND_ROBIN)),
    ("wfill", PolicyConfig(routing=ROUTE_SDN, traffic=TRAFFIC_WATERFILL,
                           seed=1)),
]


@pytest.mark.parametrize("scen,seed", [
    ("leaf-spine", 0), ("leaf-spine", 1),
    ("paper-fabric-ctrl", 0), ("leaf-spine-failures", 1),
])
def test_finite_trace_bit_identity(scen, seed):
    """A trace that fits the slots (zero refills) reproduces
    ``Experiment.run`` on the same ring setup BITWISE, for every policy —
    across plain / ctrl / failure scenarios x workload seeds."""
    kw = dict(split=1) if scen.startswith("paper") else dict(n_jobs=3)
    setup = get_scenario(scen, seed=seed, **kw).build()
    horizon = 1e9
    arrivals = TraceArrivals(jobs=tuple(setup.jobs))
    jobs = [a.job for a in arrivals.events(horizon)]   # submit-time order
    spec = RingSpec.for_jobs(jobs, slots=len(jobs))

    exp = Experiment(scenarios=(scen, setup), policies=POLICIES)
    res = exp.run_stream(arrivals, horizon, slots=len(jobs),
                         return_states=True)
    assert res.stats.refills == 0          # the trace fit the ring

    rs = ring_setup(jobs, setup.cluster, spec, route_table=setup.route_table,
                    failures=setup.failures, ctrl=setup.ctrl)
    ref = Experiment(scenarios=("ring", rs), policies=POLICIES).run()
    for pi, (pname, _) in enumerate(POLICIES):
        assert_states_equal(ref.state(0, pi), res.final_states[pi],
                            f"{scen}/seed{seed}/{pname}")


def test_refill_conserves_arrivals():
    """A trace LONGER than the ring recycles slots; every arrival is loaded
    and retired exactly once per lane and sojourns are sane."""
    setup = get_scenario("leaf-spine", n_jobs=2).build()
    times = tuple(3.0 * i for i in range(12))
    arrivals = TraceArrivals(
        times=times,
        classes=(ServiceClass("only", slo_s=500.0,
                              template=JobTemplate(n_map=2, n_reduce=1)),))
    exp = Experiment(scenarios=("leaf-spine", setup), policies=POLICIES[:2])
    res = exp.run_stream(arrivals, horizon=40.0, slots=4, chunk_steps=64)
    assert res.stats.trace_len == sum(1 for t in times if t < 40.0)
    assert res.stats.refills > 0
    check_stream(res, label="refill")
    for pi in range(res.n_policies):
        j = res.jobs[pi]
        assert np.all(j["sojourn"] > 0)
        # arrival order is preserved in the per-lane load order: job k
        # cannot be admitted before it arrived
        assert np.all(j["t_admit"] >= j["t_arr"] - 1e-4)


def test_windowed_metrics_shape_and_nan_masking():
    """Windows cover every completion; empty windows are NaN (not 0) for
    percentile metrics and SLO attainment, 0 for counts."""
    setup = get_scenario("leaf-spine", n_jobs=2).build()
    arrivals = PoissonArrivals(
        rate=0.12, seed=4,
        classes=(ServiceClass("a", slo_s=100.0, share=0.5),
                 ServiceClass("b", slo_s=30.0, share=0.5, weight=1.0)))
    exp = Experiment(scenarios=("leaf-spine", setup), policies=POLICIES[:1])
    res = exp.run_stream(arrivals, horizon=150.0, warmup=30.0, window=25.0,
                         slots=4)
    wd = res.windows(0)
    n_w = wd["t0"].size
    assert wd["slo_attainment"].shape == (2, n_w)
    assert wd["t1"][-1] >= max(res.horizon, float(res.jobs[0]["t_done"].max()))
    empty = wd["n_done"] == 0
    assert np.all(np.isnan(wd["p99_sojourn_s"][empty]))
    assert np.all(wd["throughput_jobs_s"][empty] == 0.0)
    done = wd["n_done"] > 0
    assert np.all(wd["p50_sojourn_s"][done] <= wd["p99_sojourn_s"][done])
    att = wd["slo_attainment"]
    assert np.all((att[np.isfinite(att)] >= 0) & (att[np.isfinite(att)] <= 1))
    # summary excludes the warmup
    sm = res.summary(0)
    n_after = int((res.jobs[0]["t_done"] >= 30.0).sum())
    assert sm["jobs_done"] == n_after
    assert set(sm["classes"]) == {"a", "b"}
    # rows() is the flat export of the same windows
    rows = [r for r in res.rows() if r["policy"] == res.policy_names[0]]
    assert len(rows) == n_w and "slo_a" in rows[0] and "slo_b" in rows[0]


def test_ring_spec_rejects_oversize_job():
    setup = get_scenario("leaf-spine", n_jobs=2).build()
    big = TraceArrivals(
        times=(1.0,),
        classes=(ServiceClass("big",
                              template=JobTemplate(n_map=9, n_reduce=3)),))
    spec = RingSpec(slots=2, n_map_max=2, n_reduce_max=1)
    exp = Experiment(scenarios=("leaf-spine", setup), policies=POLICIES[:1])
    with pytest.raises(ValueError, match="slot geometry"):
        exp.run_stream(big, horizon=10.0, spec=spec)


@pytest.mark.slow
def test_large_open_arrival_bounded_memory():
    """Acceptance: a >=100k-job open-arrival run at FIXED slot capacity
    completes — tensor shapes never grow with the trace — and produces
    warmup-excluded windowed metrics."""
    setup = get_scenario("leaf-spine", n_spine=2, n_leaf=2, hosts_per_leaf=2,
                         n_jobs=2).build()
    tiny = JobTemplate(n_map=1, n_reduce=1, map_mi=300.0, reduce_mi=300.0,
                       input_gbits=0.02, shuffle_gbits=0.01,
                       output_gbits=0.01)
    arrivals = PoissonArrivals(
        rate=120.0, seed=7,
        classes=(ServiceClass("t", slo_s=20.0, template=tiny,
                              scale_lo=1.0, scale_hi=1.0),))
    horizon = 100_000 / 120.0 * 1.05        # ~105k expected arrivals
    exp = Experiment(scenarios=("leaf-spine", setup),
                     policies=[("sdn", PolicyConfig(routing=ROUTE_SDN,
                                                    job_concurrency=64))])
    res = exp.run_stream(arrivals, horizon, warmup=60.0, window=60.0,
                         slots=64, chunk_steps=512)
    assert res.stats.trace_len >= 100_000
    check_stream(res, label="100k")
    sm = res.summary(0)
    assert sm["jobs_done"] > 90_000
    assert np.isfinite(sm["p99_sojourn_s"])
    assert np.isfinite(sm["throughput_jobs_s"])
