"""The registry-wide invariant harness (DESIGN.md §4/§10): every
registered scenario x a policy grid spanning every axis — including the
new install_mode / migration control-plane axes — runs as ONE packed grid
and every cell must satisfy every invariant in tests/invariants.py."""
import jax
import jax.numpy as jnp

from invariants import (ALL_INVARIANTS, check_all, check_slots, check_stream,
                        grid_check_all)
from repro.api import runners
from repro.core.policies import (INSTALL_PROACTIVE, MIG_CONGESTION,
                                 PLACE_ROUND_ROBIN, PolicyConfig,
                                 RECOVERY_RESUME, ROUTE_LEGACY, ROUTE_SDN,
                                 SPEC_ON, TRAFFIC_WATERFILL)
from repro.scenarios import get_scenario, list_scenarios
from repro.scenarios.sweep import pack_setups, policy_arrays

# every registered scenario at CPU-test size (structures intact: topology
# family, workload shape, failure trace, ctrl config)
SCENARIOS = [
    ("paper-fabric", dict(split=1)),
    ("fat-tree", dict(n_jobs=4)),
    ("leaf-spine", dict(n_jobs=4)),
    ("canonical-tree", dict(n_jobs=4)),
    ("leaf-spine-xl", dict(n_spine=2, n_leaf=2, hosts_per_leaf=2, n_jobs=4,
                           max_scale=1.5)),
    ("paper-fabric-failures", dict(split=1)),
    ("leaf-spine-failures", dict(n_jobs=4)),
    ("paper-fabric-ctrl", dict(split=1)),
    ("leaf-spine-ctrl", dict(n_jobs=4)),
    ("leaf-spine-stream", dict(horizon=160.0, max_jobs=4)),
    ("paper-fabric-chaos", dict(split=1)),
    ("leaf-spine-chaos", dict(n_jobs=4)),
]

# one policy per branch family, cycling the secondary axes — including
# both §10 axes, so ctrl scenarios exercise proactive install and
# congestion migration inside the same packed grid
POLICIES = [
    ("sdn", PolicyConfig(routing=ROUTE_SDN, job_concurrency=2)),
    ("legacy", PolicyConfig(routing=ROUTE_LEGACY, job_concurrency=2,
                            placement=PLACE_ROUND_ROBIN)),
    ("sdn-pro", PolicyConfig(routing=ROUTE_SDN,
                             install_mode=INSTALL_PROACTIVE,
                             traffic=TRAFFIC_WATERFILL, seed=1)),
    ("sdn-mig", PolicyConfig(routing=ROUTE_SDN, migration=MIG_CONGESTION,
                             recovery=RECOVERY_RESUME, job_concurrency=2)),
    ("sdn-spec", PolicyConfig(routing=ROUTE_SDN, speculation=SPEC_ON,
                              placement=PLACE_ROUND_ROBIN,
                              job_concurrency=2, seed=2)),
]


def test_scenario_list_covers_registry():
    """This harness must grow with the registry — a newly registered
    scenario that is not invariant-checked fails here."""
    covered = {name for name, _ in SCENARIOS}
    assert covered == set(list_scenarios())


def test_policy_grid_covers_ctrl_axes():
    pols = [p for _, p in POLICIES]
    assert any(p.install_mode == INSTALL_PROACTIVE for p in pols)
    assert any(p.migration == MIG_CONGESTION for p in pols)
    assert any(p.routing == ROUTE_LEGACY for p in pols)


def test_registry_policy_grid_invariants():
    """The whole registry x policy grid in one vmapped program; every
    final state passes every invariant."""
    setups = [get_scenario(name, **kw).build() for name, kw in SCENARIOS]
    consts, meta = pack_setups(setups)
    assert meta.has_ctrl and meta.has_failures   # both subsystems traced in
    pols = {k: jnp.asarray(v) for k, v in
            policy_arrays([p for _, p in POLICIES]).items()}
    states = jax.block_until_ready(
        runners.get_runner(meta, "grid")(consts, pols))
    grid_check_all(consts, meta, states,
                   [name for name, _ in SCENARIOS],
                   [name for name, _ in POLICIES])


def test_invariants_catch_violations():
    """The harness itself must be falsifiable: a doctored final state
    trips the matching checker."""
    import numpy as np
    import pytest
    setup = get_scenario("leaf-spine", n_jobs=2).build()
    from repro.core.engine import make_consts
    from repro.core import simulate
    c, meta = make_consts(setup)
    s = simulate(setup, PolicyConfig(job_concurrency=2))
    check_all(c, meta, s, label="healthy")
    assert len(ALL_INVARIANTS) >= 5
    bad = s._replace(vm_load=np.asarray(s.vm_load) + 1)
    with pytest.raises(AssertionError, match="vm_load"):
        check_all(c, meta, bad, label="doctored")
    bad2 = s._replace(ctrl_installs=np.int32(3))
    with pytest.raises(AssertionError):
        check_all(c, meta, bad2, label="doctored-ctrl")
    # slot conservation must be falsifiable too: resurrect one DONE task
    # without a matching vm_load entry
    ts = np.asarray(s.task_state).copy()
    ts[np.flatnonzero(ts == 2)[0]] = 1   # DONE -> ACTIVE
    with pytest.raises(AssertionError, match="census"):
        check_slots(c, meta, s._replace(task_state=ts), label="doctored")


def test_streaming_registry_invariants():
    """Drive the streaming engine over registry scenarios and check the
    streaming ledger (check_stream) plus every per-state invariant —
    including slot conservation — on the drained final states against the
    consts of each lane's LAST ring generation."""
    from repro.api import Experiment
    from repro.scenarios.registry import stream_arrivals

    for scen, arrivals, horizon in [
            ("leaf-spine", stream_arrivals(rate=0.08, seed=2), 120.0),
            ("canonical-tree", stream_arrivals(rate=0.06, seed=3), 150.0)]:
        exp = Experiment(scenarios=get_scenario(scen, n_jobs=2),
                         policies=POLICIES[:2])
        res = exp.run_stream(arrivals, horizon, slots=3, chunk_steps=48,
                             return_states=True)
        assert res.stats.refills > 0     # the ring actually recycled
        check_stream(res, label=scen)
        for pi in range(res.n_policies):
            check_all(res.final_consts[pi], res.meta, res.final_states[pi],
                      label=f"{scen}/{res.policy_names[pi]}")
