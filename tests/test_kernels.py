"""Pallas kernels vs pure-jnp oracles (interpret mode), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.flash_attention.ref import naive_attention
from repro.kernels.selective_scan.ops import selective_scan
from repro.kernels.selective_scan.ref import selective_scan_ref
from repro.kernels.tropical_apsp.kernel import minplus_matmul
from repro.kernels.tropical_apsp.ops import apsp
from repro.kernels.tropical_apsp.ref import apsp_ref, minplus_matmul_ref

KEY = jax.random.PRNGKey(7)


@pytest.mark.parametrize("m,k,n,block", [
    (8, 8, 8, 8), (32, 16, 24, 16), (100, 64, 50, 32), (130, 130, 130, 64)])
def test_minplus_matmul(m, k, n, block):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.uniform(k1, (m, k), jnp.float32, 0, 10)
    y = jax.random.uniform(k2, (k, n), jnp.float32, 0, 10)
    got = minplus_matmul(x, y, bm=block, bn=block, bk=block, interpret=True)
    want = minplus_matmul_ref(x, y)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-6)


@pytest.mark.parametrize("n,density,block", [(17, 0.2, 8), (64, 0.1, 32),
                                             (90, 0.05, 64)])
def test_apsp_vs_ref(n, density, block):
    rng = np.random.RandomState(n)
    adj = np.full((n, n), np.inf, np.float32)
    np.fill_diagonal(adj, 0)
    mask = rng.rand(n, n) < density
    adj[mask] = rng.uniform(0.1, 5.0, mask.sum()).astype(np.float32)
    np.fill_diagonal(adj, 0)
    got = np.asarray(apsp(jnp.asarray(adj), interpret=True, block=block))
    want = np.asarray(apsp_ref(jnp.asarray(adj)))
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-5)
    assert np.all(got[~finite] > 1e30)


@pytest.mark.parametrize("b,sq,skv,h,kv,dh,causal,dtype", [
    (2, 64, 64, 4, 2, 32, True, jnp.float32),
    (1, 100, 100, 4, 4, 16, True, jnp.float32),
    (2, 1, 40, 4, 2, 16, False, jnp.float32),
    (1, 128, 256, 8, 2, 64, True, jnp.float32),
    (2, 64, 64, 4, 1, 128, True, jnp.bfloat16),
    (1, 48, 48, 2, 2, 64, False, jnp.bfloat16),
])
def test_flash_attention_sweep(b, sq, skv, h, kv, dh, causal, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = jax.random.normal(k1, (b, sq, h, dh), dtype)
    k = jax.random.normal(k2, (b, skv, kv, dh), dtype)
    v = jax.random.normal(k3, (b, skv, kv, dh), dtype)
    off = skv - sq if causal else 0
    got = flash_attention(q, k, v, causal=causal, q_offset=off,
                          bq=32, bk=32, interpret=True)
    want = naive_attention(q, k, v, causal=causal, q_offset=off)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               rtol=tol, atol=tol)


@pytest.mark.parametrize("b,s,d,n,chunk,bd", [
    (2, 16, 8, 4, 8, 8), (1, 100, 32, 16, 32, 16), (2, 64, 300, 16, 16, 64),
    (1, 33, 24, 8, 16, 24),
])
def test_selective_scan_sweep(b, s, d, n, chunk, bd):
    k1, k2, k3 = jax.random.split(KEY, 3)
    a = jax.random.uniform(k1, (b, s, d, n), jnp.float32, 0.5, 0.999)
    bb = jax.random.normal(k2, (b, s, d, n), jnp.float32) * 0.1
    c = jax.random.normal(k3, (b, s, n), jnp.float32)
    got = selective_scan(a, bb, c, chunk=chunk, bd=bd, interpret=True)
    want = selective_scan_ref(a, bb, c)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-5)


def test_apsp_on_paper_topology():
    """Kernel APSP == host-side routing distances on the paper's fat-tree."""
    from repro.core.routing import hop_distances_np
    from repro.core.topology import paper_fat_tree
    topo = paper_fat_tree()
    adj = topo.hop_matrix()
    got = np.asarray(apsp(jnp.asarray(adj), interpret=True, block=64))
    want = hop_distances_np(adj)
    finite = np.isfinite(want)
    np.testing.assert_allclose(got[finite], want[finite], rtol=1e-6)
