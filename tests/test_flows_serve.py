"""Flow frontend oracles + serve loop correctness."""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core import PolicyConfig, simulate
from repro.core.flows import Flow, flows_setup
from repro.core.topology import torus_2d
from repro.models import get_model
from repro.serve import Request, ServeLoop


def test_ring_allreduce_closed_form():
    """2(n-1) rounds of B/n on an n-ring at bw == analytic ring time."""
    n, bw, gbits = 4, 1e9, 4.0
    topo = torus_2d(n, 1, bw=bw)
    flows = [Flow(i, (i + 1) % n, gbits / n, round=r)
             for r in range(2 * (n - 1)) for i in range(n)]
    s = simulate(flows_setup(topo, flows), PolicyConfig())
    want = 2 * (n - 1) * (gbits / n) * 1e9 / bw
    assert float(s.time) == pytest.approx(want, rel=1e-4)


def test_flows_contention_vs_diverse():
    """4 flows onto one link vs 4 disjoint neighbor flows: 4x slower."""
    topo = torus_2d(4, 4, bw=1e9)
    idx = lambda x, y: x * 4 + y
    same = [Flow(idx(0, 0), idx(1, 0), 1.0) for _ in range(4)]
    t_same = float(simulate(flows_setup(topo, same), PolicyConfig()).time)
    disjoint = [Flow(idx(x, 0), idx(x, 1), 1.0) for x in range(4)]
    t_dis = float(simulate(flows_setup(topo, disjoint),
                           PolicyConfig()).time)
    assert t_same == pytest.approx(4 * t_dis, rel=1e-3)


def test_serve_loop_matches_uninterrupted_decode():
    """ServeLoop (admission + slots) must produce the same greedy tokens
    as a hand-rolled prefill+decode for each request."""
    cfg = get_smoke_config("qwen3-4b")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    rng = np.random.RandomState(1)
    prompts = [rng.randint(1, cfg.vocab, 8).astype(np.int32)
               for _ in range(3)]
    max_new = 5

    # oracle: one request at a time, batch 1
    import jax.numpy as jnp
    want = []
    for pr in prompts:
        cache = api.init_cache(1, 64)
        pad = np.zeros((32,), np.int32)
        pad[-len(pr):] = pr
        logits, cache = api.prefill(params, {"tokens": jnp.asarray(pad[None])},
                                    cache)
        toks = [int(jnp.argmax(logits[0, -1]))]
        for _ in range(max_new):
            lg, cache = api.decode_step(
                params, jnp.asarray([[toks[-1]]], jnp.int32), cache)
            toks.append(int(jnp.argmax(lg[0, 0])))
        want.append(toks[:max_new + 1])

    loop = ServeLoop(api, params, slots=2, max_len=64, bucket=32)
    for i, pr in enumerate(prompts):
        loop.submit(Request(rid=i, prompt=pr, max_new=max_new))
    results = {r.rid: r.tokens for r in loop.run()}
    for i in range(3):
        assert results[i] == want[i], (i, results[i], want[i])
