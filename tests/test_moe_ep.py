"""Expert-parallel MoE (shard_map all-to-all) vs dense oracle, incl. grads.

Runs in a subprocess with 8 forced host devices (same isolation pattern
as test_dryrun_mini)."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import dataclasses, json
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.configs import get_smoke_config
from repro.models.moe import moe_apply, moe_init

cfg = dataclasses.replace(get_smoke_config("qwen3-moe-30b-a3b"),
                          capacity_factor=8.0)   # no drops -> exact match
mesh = jax.make_mesh((2, 4), ("data", "model"))
key = jax.random.PRNGKey(0)
p = moe_init(key, cfg)
x = jax.random.normal(key, (8, 16, cfg.d_model), jnp.float32).astype(cfg.dtype)

ref_out, ref_aux = moe_apply(p, x, cfg)          # no mesh -> dense path

def loss(p_, x_):
    o, aux = moe_apply(p_, x_, cfg)
    return jnp.sum(o.astype(jnp.float32) ** 2) + aux

g_ref = jax.grad(loss)(p, x)
w_spec = {"router": P(None, None), "wi": P("model", None, None),
          "wg": P("model", None, None), "wo": P("model", None, None)}
p_sh = {k: NamedSharding(mesh, v) for k, v in w_spec.items()}
x_sh = NamedSharding(mesh, P(("data", "model"), None, None))
with jax.set_mesh(mesh):
    out_ep, _ = jax.jit(lambda p_, x_: moe_apply(p_, x_, cfg),
                        in_shardings=(p_sh, x_sh))(p, x)
    g_ep = jax.jit(jax.grad(loss), in_shardings=(p_sh, x_sh))(p, x)
fwd_err = float(np.max(np.abs(np.asarray(out_ep, np.float32)
                              - np.asarray(ref_out, np.float32))))
grad_errs = {}
for kk in ("wi", "wg", "wo", "router"):
    a = np.asarray(g_ep[kk], np.float32); b = np.asarray(g_ref[kk], np.float32)
    grad_errs[kk] = float(np.max(np.abs(a - b)) / (np.max(np.abs(b)) + 1e-9))
print("RESULT " + json.dumps({"fwd_err": fwd_err, "grad_errs": grad_errs}))
"""


@pytest.mark.slow
def test_ep_moe_matches_dense_including_grads():
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    env.pop("JAX_PLATFORMS", None)
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stderr[-3000:]
    line = [ln for ln in out.stdout.splitlines()
            if ln.startswith("RESULT ")][0]
    r = json.loads(line[len("RESULT "):])
    assert r["fwd_err"] < 2e-2, r
    for kk, v in r["grad_errs"].items():
        assert v < 5e-2, (kk, r)
