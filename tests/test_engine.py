"""DES engine: closed-form scenarios, invariants, paper-claim direction."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PolicyConfig, ROUTE_LEGACY, ROUTE_SDN,
                        TRAFFIC_WATERFILL, paper_setup, simulate,
                        simulate_batch, summarize)
from repro.core.flows import Flow, flows_setup
from repro.core.mapreduce import DONE
from repro.core.topology import torus_2d


@pytest.fixture(scope="module")
def two_hosts():
    return torus_2d(2, 1, bw=1e9)


def t(topo, flows, **pol):
    s = simulate(flows_setup(topo, flows), PolicyConfig(**pol))
    assert not bool(s.stalled)
    return float(s.time)


def test_single_flow_closed_form(two_hosts):
    assert t(two_hosts, [Flow(0, 1, 8.0)]) == pytest.approx(8.0, rel=1e-4)


def test_two_flows_share_link(two_hosts):
    assert t(two_hosts, [Flow(0, 1, 8.0)] * 2) == pytest.approx(16.0,
                                                                rel=1e-3)


def test_full_duplex(two_hosts):
    assert t(two_hosts, [Flow(0, 1, 8.0), Flow(1, 0, 8.0)]) == \
        pytest.approx(8.0, rel=1e-3)


def test_rounds_serialize(two_hosts):
    fl = [Flow(0, 1, 8.0, round=0), Flow(0, 1, 8.0, round=1)]
    assert t(two_hosts, fl) == pytest.approx(16.0, rel=1e-3)


def test_unequal_finish_releases_bandwidth(two_hosts):
    # 2 Gb and 6 Gb share 1 Gbps: both at 0.5 until t=4 (2Gb done),
    # then 4 Gb remain at full rate -> total 8 s
    fl = [Flow(0, 1, 2.0), Flow(0, 1, 6.0)]
    assert t(two_hosts, fl) == pytest.approx(8.0, rel=1e-3)


def test_conservation_and_clock():
    setup = paper_setup(seed=0)
    s = simulate(setup, PolicyConfig())
    assert not bool(s.stalled)
    # every valid packet fully delivered, every valid task fully executed
    valid_p = np.asarray(setup.pkt_valid)
    assert np.all(np.asarray(s.pkt_state)[valid_p] == DONE)
    assert np.all(np.asarray(s.pkt_rem)[valid_p] <=
                  np.asarray(setup.pkt_bits)[valid_p] * 1e-5 + 1.0)
    assert np.all(np.asarray(s.task_state)[np.asarray(setup.task_valid)]
                  == DONE)
    # finish times are within [start, end] and non-negative durations
    dur = np.asarray(s.pkt_finish - s.pkt_start)[valid_p]
    assert np.all(dur >= -1e-5)
    assert float(s.time) > 0


def test_energy_positive_and_bounded():
    setup = paper_setup(seed=0)
    s = simulate(setup, PolicyConfig())
    host_e = np.asarray(s.host_energy)
    sw_e = np.asarray(s.switch_energy)
    assert np.all(host_e >= 0) and np.all(sw_e >= 0)
    # no device can exceed peak power x makespan
    T = float(s.time)
    assert np.all(host_e <= 250.0 * T + 1)
    # switches: static + all ports (generous bound)
    assert np.all(sw_e <= (100.0 + 64 * 10.0) * T + 1)


def test_sdn_beats_legacy_on_paper_usecase():
    """The paper's qualitative claim (§5.3): SDN >= legacy on all three."""
    setup = paper_setup(seed=0)
    rs = summarize(setup, simulate(setup, PolicyConfig(
        routing=ROUTE_SDN, job_concurrency=2)))
    rl = summarize(setup, simulate(setup, PolicyConfig(
        routing=ROUTE_LEGACY, job_concurrency=2)))
    assert np.nanmean(rs["transmission_time"]) < \
        np.nanmean(rl["transmission_time"])
    assert np.nanmean(rs["completion_measured"]) < \
        np.nanmean(rl["completion_measured"])
    assert rs["total_energy_j"] < rl["total_energy_j"]


def test_waterfill_not_slower():
    setup = paper_setup(seed=0)
    base = summarize(setup, simulate(setup, PolicyConfig()))
    wf = summarize(setup, simulate(setup, PolicyConfig(
        traffic=TRAFFIC_WATERFILL)))
    assert wf["makespan_s"] <= base["makespan_s"] * 1.05


def test_vmapped_policy_sweep():
    setup = paper_setup(seed=0)
    pols = {
        "routing": jnp.asarray([ROUTE_SDN, ROUTE_LEGACY]),
        "traffic": jnp.asarray([0, 0]),
        "placement": jnp.asarray([0, 0]),
        "job_selection": jnp.asarray([0, 0]),
        "job_concurrency": jnp.asarray([2, 2]),
        "seed": jnp.asarray([0, 0]),
    }
    s = simulate_batch(setup, pols)
    assert s.time.shape == (2,)
    single = simulate(setup, PolicyConfig(routing=ROUTE_SDN,
                                          job_concurrency=2))
    assert float(s.time[0]) == pytest.approx(float(single.time), rel=1e-5)


def test_stall_detected_on_disconnected():
    # two 2-node islands: 0-1 connected, 2-3 connected, no bridge.
    from repro.core.topology import Topology
    import numpy as np_
    iso = Topology(n_hosts=4, n_switches=0, n_storage=0,
                   link_src=np_.asarray([0, 1, 2, 3], np_.int32),
                   link_dst=np_.asarray([1, 0, 3, 2], np_.int32),
                   link_bw=np_.full(4, 1e9, np_.float32))
    setup = flows_setup(iso, [Flow(0, 2, 1.0)])   # unreachable pair
    s = simulate(setup, PolicyConfig())
    assert bool(s.stalled)
