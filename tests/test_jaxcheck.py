"""Static analyzer falsifiability + budget-gate tests (DESIGN.md §12).

A checker that cannot be tripped is not checking anything: every jaxpr
checker gets a doctored program that MUST flag and the clean twin that
MUST pass; every AST rule gets a doctored source string and a clean one.
Plus: budget-diff semantics (increase fails, cond-decrease fails,
allowlist waives, jax-version demotes), the end-to-end sweep over the
registry, and the CLI's nonzero exit on a seeded regression.
"""
import importlib.util
import json
from pathlib import Path

import jax
import pytest

from repro.analysis import (analyze, build_ledger, clean_trace, diff_ledger,
                            doctored_trace, iter_traces, lint_source,
                            lint_tree, load_ledger, refresh_ledger,
                            static_sigs)
from repro.analysis.checkers import ProgramTrace, check_donation_policy
from repro.analysis.rules import AST_RULES, JAXPR_RULES, RULES
from repro.api import runners

ROOT = Path(__file__).resolve().parent.parent


def _load_tool(name):
    spec = importlib.util.spec_from_file_location(
        name, ROOT / "tools" / f"{name}.py")
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


def _rules_of(findings):
    return {f.rule for f in findings}


# ---------------------------------------------------------------------------
# falsifiability: each jaxpr checker trips on its doctored program
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("rule", ["sort-in-loop", "scatter-in-loop",
                                  "dtype-drift", "batched-cond", "donation"])
def test_doctored_program_trips_checker(rule):
    findings, _ = analyze([doctored_trace(rule)])
    assert rule in _rules_of(findings), \
        f"doctored program for {rule} did not trip it"
    # and the finding names the doctored program, not something else
    assert any(f.rule == rule and "doctored" in f.where for f in findings)


def test_carry_stability_trips_on_divergent_same_meta_carries():
    """Two programs sharing (meta, kind) but carrying different widths."""
    findings, _ = analyze([clean_trace(), clean_trace(n_packets=96)])
    assert "carry-stability" in _rules_of(findings)


def test_missing_engine_loop_is_flagged():
    closed = jax.make_jaxpr(lambda x: x + 1.0)(
        jax.ShapeDtypeStruct((8,), "float32"))
    trace = ProgramTrace(key="t/loopless", kind="serial", scenario="t",
                         meta="m", closed=closed, axes={"packets": 8})
    findings, _ = analyze([trace])
    assert any("no-loop" in f.key for f in findings)


def test_clean_program_passes_every_checker():
    findings, programs = analyze([clean_trace()])
    assert findings == []
    row = programs["doctored/clean"]
    assert row["loop"]["cond"] == 1 and row["loop"]["sort"] == 0


def test_donation_policy_checker_and_falsifiability():
    assert check_donation_policy(runners.donation_argnums) == []
    # a policy that donates on cpu must be flagged
    bad = lambda backend=None: (2,)                     # noqa: E731
    assert any(f.rule == "donation"
               for f in check_donation_policy(bad))


# ---------------------------------------------------------------------------
# AST rules: doctored source flags, clean source passes, disable suppresses
# ---------------------------------------------------------------------------

ENGINE_PATH = "src/repro/core/fake.py"
BENCH_PATH = "benchmarks/fake.py"

AST_CASES = {
    "tracer-cast": (
        "def step(s):\n    return float(s.time)\n",
        "def step(s):\n    import jax.numpy as jnp\n"
        "    return jnp.float32(s.time)\n",
        ENGINE_PATH),
    "item-call": (
        "def step(s):\n    return s.time.item()\n",
        "def step(s):\n    return s.time\n",
        ENGINE_PATH),
    "unseeded-random": (
        "import numpy as np\nx = np.random.rand(3)\n",
        "import numpy as np\nx = np.random.default_rng(0).random(3)\n",
        ENGINE_PATH),
    "random-module": (
        "import random\n",
        "import numpy as np\n",
        ENGINE_PATH),
    "naked-timer": (
        "import time\n\ndef bench(f):\n    t0 = time.perf_counter()\n"
        "    f()\n    return time.perf_counter() - t0\n",
        "import time\nimport jax\n\ndef bench(f):\n"
        "    t0 = time.perf_counter()\n    jax.block_until_ready(f())\n"
        "    return time.perf_counter() - t0\n",
        BENCH_PATH),
    "meta-subscript": (
        "def f(meta):\n    return meta['n_links']\n",
        "def f(meta):\n    return meta.n_links\n",
        ENGINE_PATH),
    "frozen-mutation": (
        "def f(meta):\n    meta.n_links = 3\n",
        "import dataclasses\n\ndef f(meta):\n"
        "    return dataclasses.replace(meta, n_links=3)\n",
        ENGINE_PATH),
    "f64-literal": (
        "import jax.numpy as jnp\nx = jnp.zeros(3, jnp.float64)\n",
        "import numpy as np\nx = np.zeros(3, np.float64)\n",
        ENGINE_PATH),
}


@pytest.mark.parametrize("rule", sorted(AST_CASES))
def test_ast_rule_falsifiability(rule):
    doctored, clean, relpath = AST_CASES[rule]
    assert rule in _rules_of(lint_source(doctored, relpath)), \
        f"doctored source for {rule} did not flag"
    assert rule not in _rules_of(lint_source(clean, relpath)), \
        f"clean source for {rule} flagged"


def test_ast_disable_comment_suppresses():
    doctored, _, relpath = AST_CASES["meta-subscript"]
    line = doctored.splitlines()[1] + "  # jaxcheck: disable=meta-subscript"
    text = doctored.splitlines()[0] + "\n" + line + "\n"
    assert lint_source(text, relpath) == []


def test_ast_rules_scope_outside_engine_is_quiet():
    """Engine-only rules must not fire on e.g. results-extraction code."""
    doctored, _, _ = AST_CASES["tracer-cast"]
    assert lint_source(doctored, "src/repro/api/results_fake_doc.py") != []
    assert lint_source(doctored, "examples/whatever.py") == []


# ---------------------------------------------------------------------------
# budget-diff semantics
# ---------------------------------------------------------------------------


def _fake_programs():
    return {"scn/serial": {
        "loop": {"sort": 2, "scatter": 1, "cond": 3, "select_n": 10},
        "eqns": 100,
        "carry": {"leaves": 5, "bytes": 128, "sig": "abc"}}}


def _bump(programs, prim, delta):
    out = json.loads(json.dumps(programs))
    out["scn/serial"]["loop"][prim] += delta
    return out


def test_budget_watched_increase_fails_decrease_ok():
    base = build_ledger(_fake_programs())
    up, _ = diff_ledger(_bump(_fake_programs(), "sort", +1), base)
    assert any(f.key == "scn/serial:sort" and f.severity == "error"
               for f in up)
    down, _ = diff_ledger(_bump(_fake_programs(), "sort", -1), base)
    assert down == []


def test_budget_cond_is_inverted():
    base = build_ledger(_fake_programs())
    down, _ = diff_ledger(_bump(_fake_programs(), "cond", -1), base)
    assert any(f.key == "scn/serial:cond" for f in down)
    up, _ = diff_ledger(_bump(_fake_programs(), "cond", +1), base)
    assert up == []


def test_budget_carry_change_fails_and_allowlist_waives():
    cur = _fake_programs()
    cur["scn/serial"]["carry"]["sig"] = "zzz"
    base = build_ledger(_fake_programs())
    findings, _ = diff_ledger(cur, base)
    assert any(f.key == "scn/serial:carry" for f in findings)
    waived = build_ledger(_fake_programs(),
                          allowlist={"scn/serial:carry": "reviewed"})
    findings, _ = diff_ledger(cur, waived)
    assert findings == []


def test_budget_membership_drift_full_sweep_only():
    base = build_ledger(_fake_programs())
    extra = dict(_fake_programs(), **{"scn/other": {"loop": {}, "eqns": 1}})
    full, _ = diff_ledger(extra, base, full_sweep=True)
    assert any(f.key == "scn/other:new" for f in full)
    partial, _ = diff_ledger(extra, base, full_sweep=False)
    assert partial == []
    gone, _ = diff_ledger({}, base, full_sweep=True)
    assert any(f.key == "scn/serial:gone" for f in gone)


def test_budget_jax_version_mismatch_demotes_to_warning():
    base = build_ledger(_fake_programs())
    base["jax"] = "0.0.0-not-this-one"
    findings, notes = diff_ledger(_bump(_fake_programs(), "sort", +1), base)
    assert findings and all(f.severity == "warning" for f in findings)
    assert notes


def test_refresh_preserves_allowlist():
    old = build_ledger(_fake_programs(), allowlist={"k": "why"})
    new = refresh_ledger(_fake_programs(), old)
    assert new["allowlist"] == {"k": "why"}


# ---------------------------------------------------------------------------
# end-to-end over the registry + the committed ledger + the clean tree
# ---------------------------------------------------------------------------


def test_quick_sweep_and_committed_budget_clean():
    """paper-fabric x all kinds x one signature: zero findings, and the
    derived rows match the committed PRIM_BUDGET.json exactly."""
    traces = list(iter_traces(["paper-fabric"], sigs=static_sigs()[:1]))
    findings, programs = analyze(traces)
    findings += check_donation_policy(runners.donation_argnums)
    assert [f.render() for f in findings] == []
    baseline = load_ledger(ROOT / "experiments" / "PRIM_BUDGET.json")
    assert baseline is not None, "committed PRIM_BUDGET.json missing"
    diff, _ = diff_ledger(programs, baseline, full_sweep=False)
    errors = [f for f in diff if f.severity == "error"]
    assert [f.render() for f in errors] == []


def test_ast_pass_clean_on_tree():
    findings = lint_tree(ROOT)
    assert [f.render() for f in findings] == []


@pytest.mark.slow
def test_full_registry_sweep_zero_unallowlisted_findings():
    """Every registry scenario x kind x static signature against the
    committed ledger: nothing unallowlisted may fire."""
    findings, programs = analyze(list(iter_traces()))
    findings += check_donation_policy(runners.donation_argnums)
    baseline = load_ledger(ROOT / "experiments" / "PRIM_BUDGET.json")
    diff, _ = diff_ledger(programs, baseline, full_sweep=True)
    errors = [f for f in findings + diff if f.severity == "error"]
    assert [f.render() for f in errors] == []


# ---------------------------------------------------------------------------
# the CLI: seeded regression goes red, quick clean run goes green
# ---------------------------------------------------------------------------


def test_cli_seeded_regression_exits_nonzero(capsys):
    jaxcheck = _load_tool("jaxcheck")
    rc = jaxcheck.main(["--quick", "--quiet", "--no-ast",
                        "--seed", "sort-in-loop"])
    out = capsys.readouterr().out
    assert rc != 0
    assert "sort-in-loop" in out


def test_cli_quick_clean_exits_zero():
    jaxcheck = _load_tool("jaxcheck")
    assert jaxcheck.main(["--quick", "--quiet", "--no-ast"]) == 0


def test_cli_refuses_partial_baseline_update(tmp_path):
    jaxcheck = _load_tool("jaxcheck")
    rc = jaxcheck.main(["--quick", "--quiet", "--no-ast",
                        "--update-baseline",
                        "--baseline", str(tmp_path / "b.json")])
    assert rc == 2
    assert not (tmp_path / "b.json").exists()


# ---------------------------------------------------------------------------
# docs contract: every rule documented, every token resolvable
# ---------------------------------------------------------------------------


def test_every_rule_documented_in_design_md():
    checker = _load_tool("check_design_refs")
    documented = checker.documented_rules(ROOT / "DESIGN.md")
    assert set(RULES) <= documented, \
        f"rules missing from DESIGN.md §12: {set(RULES) - documented}"
    assert set(RULES) == set(JAXPR_RULES) | set(AST_RULES)


def test_unknown_rule_token_fails_design_refs(tmp_path):
    checker = _load_tool("check_design_refs")
    root = tmp_path
    (root / "src").mkdir()
    # build the token at runtime so the real-tree scan never sees it here
    (root / "src" / "x.py").write_text(
        "# see " + "jaxcheck" + ":not-a-real-rule\n")
    (root / "DESIGN.md").write_text("# §1 heading\njaxcheck:sort-in-loop\n")
    errors = checker.check(root)
    assert any("not-a-real-rule" in e for e in errors)
