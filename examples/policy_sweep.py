"""Beyond-paper capability demo: a vmapped policy sweep — hundreds of
(routing x traffic x placement x job-selection x seed) scenarios as ONE
tensor program.  The Java original runs one scenario per JVM invocation.

  PYTHONPATH=src python examples/policy_sweep.py --width 64
"""
import argparse
import itertools
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (JOBSEL_FCFS, JOBSEL_SJF, PLACE_LEAST_USED,
                        PLACE_RANDOM, ROUTE_LEGACY, ROUTE_SDN,
                        TRAFFIC_FAIRSHARE, TRAFFIC_WATERFILL, paper_setup,
                        simulate_batch)
from repro.core.report import energy_report, job_report


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=32)
    args = ap.parse_args()

    setup = paper_setup(seed=0, split=2)
    combos = list(itertools.product(
        (ROUTE_SDN, ROUTE_LEGACY),
        (TRAFFIC_FAIRSHARE, TRAFFIC_WATERFILL),
        (PLACE_LEAST_USED, PLACE_RANDOM),
        (JOBSEL_FCFS, JOBSEL_SJF)))
    reps = max(1, args.width // len(combos))
    rows = [c + (s,) for s in range(reps) for c in combos][:args.width]
    pols = {
        "routing": jnp.asarray([r[0] for r in rows], jnp.int32),
        "traffic": jnp.asarray([r[1] for r in rows], jnp.int32),
        "placement": jnp.asarray([r[2] for r in rows], jnp.int32),
        "job_selection": jnp.asarray([r[3] for r in rows], jnp.int32),
        "job_concurrency": jnp.full(len(rows), 2, jnp.int32),
        "seed": jnp.asarray([r[4] for r in rows], jnp.int32),
    }
    t0 = time.time()
    states = simulate_batch(setup, pols)
    jax.block_until_ready(states.time)
    dt = time.time() - t0
    rep = jax.vmap(lambda s: job_report(setup, s))(states)
    en = jax.vmap(energy_report)(states)
    mean_ct = np.nanmean(np.asarray(rep["completion_measured"]), axis=1)
    print(f"{len(rows)} simulations in {dt:.1f}s "
          f"({len(rows) / dt:.1f} sims/s, one tensor program)")
    names = {ROUTE_SDN: "sdn", ROUTE_LEGACY: "legacy"}
    tn = {TRAFFIC_FAIRSHARE: "eq3", TRAFFIC_WATERFILL: "waterfill"}
    pn = {PLACE_LEAST_USED: "least-used", PLACE_RANDOM: "random"}
    jn = {JOBSEL_FCFS: "fcfs", JOBSEL_SJF: "sjf"}
    print(f"{'routing':8} {'traffic':10} {'placement':11} {'jobsel':5} "
          f"{'mean-ct(s)':>10} {'energy(kWh)':>11}")
    best = np.argsort(mean_ct)
    for i in best[:8]:
        r = rows[i]
        print(f"{names[r[0]]:8} {tn[r[1]]:10} {pn[r[2]]:11} {jn[r[3]]:5} "
              f"{mean_ct[i]:10.1f} "
              f"{float(en['total_energy_j'][i]) / 3.6e6:11.2f}")


if __name__ == "__main__":
    main()
