"""Beyond-paper capability demo: a vmapped policy sweep — hundreds of
(routing x traffic x placement x job-selection x seed) scenarios as ONE
tensor program via ``repro.api.Experiment`` (DESIGN.md §6).  The Java
original runs one scenario per JVM invocation.

  PYTHONPATH=src python examples/policy_sweep.py --width 64
"""
import argparse
import itertools
import time

import jax
import numpy as np

from repro.api import Experiment, PolicyConfig
from repro.core import (JOBSEL_FCFS, JOBSEL_SJF, PLACE_LEAST_USED,
                        PLACE_RANDOM, ROUTE_LEGACY, ROUTE_SDN,
                        TRAFFIC_FAIRSHARE, TRAFFIC_WATERFILL, paper_setup)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--width", type=int, default=32)
    args = ap.parse_args()

    setup = paper_setup(seed=0, split=2)
    combos = list(itertools.product(
        (ROUTE_SDN, ROUTE_LEGACY),
        (TRAFFIC_FAIRSHARE, TRAFFIC_WATERFILL),
        (PLACE_LEAST_USED, PLACE_RANDOM),
        (JOBSEL_FCFS, JOBSEL_SJF)))
    reps = max(1, args.width // len(combos))
    rows = [c + (s,) for s in range(reps) for c in combos][:args.width]
    pols = [PolicyConfig(routing=r, traffic=t, placement=p, job_selection=j,
                         job_concurrency=2, seed=s)
            for r, t, p, j, s in rows]
    exp = Experiment(scenarios=setup, policies=pols)

    t0 = time.time()
    res = exp.run()
    jax.block_until_ready(res.states.time)
    dt = time.time() - t0
    rep = res.job_report()
    en = res.energy_report()
    mean_ct = np.nanmean(rep["completion_measured"][0], axis=1)
    print(f"{len(pols)} simulations in {dt:.1f}s "
          f"({len(pols) / dt:.1f} sims/s, one tensor program)")
    names = {ROUTE_SDN: "sdn", ROUTE_LEGACY: "legacy"}
    tn = {TRAFFIC_FAIRSHARE: "eq3", TRAFFIC_WATERFILL: "waterfill"}
    pn = {PLACE_LEAST_USED: "least-used", PLACE_RANDOM: "random"}
    jn = {JOBSEL_FCFS: "fcfs", JOBSEL_SJF: "sjf"}
    print(f"{'routing':8} {'traffic':10} {'placement':11} {'jobsel':5} "
          f"{'mean-ct(s)':>10} {'energy(kWh)':>11}")
    best = np.argsort(mean_ct)
    for i in best[:8]:
        r = rows[i]
        print(f"{names[r[0]]:8} {tn[r[1]]:10} {pn[r[2]]:11} {jn[r[3]]:5} "
              f"{mean_ct[i]:10.1f} "
              f"{float(en['total_energy_j'][0, i]) / 3.6e6:11.2f}")


if __name__ == "__main__":
    main()
