"""End-to-end training driver: ~100M-param LM, few hundred steps, with
checkpointing + fault tolerance + deterministic data.

  PYTHONPATH=src python examples/train_lm.py --preset small --steps 100
  PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300

The 100m preset is the deliverable configuration (run it on real
hardware); `small` (~13M) finishes in minutes on this CPU container and
exercises the identical code path.  Use --crash-at to demo restart.
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import TokenPipeline
from repro.ft import FailurePlan, TrainDriver
from repro.models import get_model
from repro.models.layers import ModelConfig
from repro.train import AdamWConfig, make_train_step
from repro.train import init as opt_init

PRESETS = {
    "tiny": ModelConfig(name="tiny-2m", n_layers=2, d_model=128, n_heads=4,
                        n_kv=2, d_head=32, d_ff=512, vocab=4096),
    "small": ModelConfig(name="small-13m", n_layers=6, d_model=384,
                         n_heads=6, n_kv=2, d_head=64, d_ff=1536,
                         vocab=8192),
    "100m": ModelConfig(name="lm-100m", n_layers=12, d_model=768,
                        n_heads=12, n_kv=4, d_head=64, d_ff=3072,
                        vocab=32768, qk_norm=True),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=PRESETS, default="small")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--ckpt-dir", default="experiments/train_lm_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--crash-at", type=int, default=-1,
                    help="inject a crash at this step (restart demo)")
    args = ap.parse_args()

    cfg = PRESETS[args.preset]
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(l.shape))
                   for l in jax.tree_util.tree_leaves(params))
    print(f"model {cfg.name}: {n_params / 1e6:.1f}M params, "
          f"batch {args.batch}x{args.seq}")

    ocfg = AdamWConfig(total_steps=args.steps, warmup_steps=args.steps // 20)
    opt = opt_init(ocfg, params)
    step = jax.jit(make_train_step(api, ocfg), donate_argnums=(0, 1))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq)

    plan = FailurePlan(at_steps={args.crash_at: "crash"}
                       if args.crash_at >= 0 else {})
    drv = TrainDriver(
        step_fn=step,
        batch_fn=lambda s: {k: jnp.asarray(v)
                            for k, v in pipe.batch_at(s).items()},
        ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
        failure_plan=plan)
    t0 = time.time()
    params, opt, info = drv.run(params, opt, args.steps)
    dt = time.time() - t0
    hist = info["history"]
    tok_s = args.batch * args.seq * len(hist) / dt
    print(f"done: {len(hist)} steps in {dt:.0f}s ({tok_s:.0f} tok/s), "
          f"restarts={info['restarts']}")
    print(f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}")
    assert hist[-1]["loss"] < hist[0]["loss"], "loss did not improve"


if __name__ == "__main__":
    main()
