"""Full reproduction of the paper's §5 use-case (Figs. 11a/b, 12a/b, 13).

  PYTHONPATH=src python examples/sdn_vs_legacy.py [--full]

Prints per-job tables for both network modes and the three headline
deltas, plus the calibration grid over the paper's under-specified
parameters (packet split, AM admission width).
"""
import argparse
import sys

import numpy as np

sys.path.insert(0, ".")
from benchmarks.fig11_13_usecase import main as bench_main  # noqa: E402


def run(full: bool):
    report = bench_main(quick=not full)
    fd = report["fig_data"]
    print("\nPer-job detail (best-match calibration, jobs sorted by size):")
    order = np.argsort(fd["sdn_completion"])
    print(f"{'job':>4} {'tr SDN':>9} {'tr LEG':>9} {'ct SDN':>9} "
          f"{'ct LEG':>9} {'map SDN':>9} {'map LEG':>9}")
    for j in order:
        print(f"{j:4d} {fd['sdn_transmission'][j]:9.1f} "
              f"{fd['legacy_transmission'][j]:9.1f} "
              f"{fd['sdn_completion'][j]:9.1f} "
              f"{fd['legacy_completion'][j]:9.1f} "
              f"{fd['sdn_map_exec'][j]:9.1f} "
              f"{fd['legacy_map_exec'][j]:9.1f}")
    he, se = fd["sdn_energy"]
    hel, sel = fd["legacy_energy"]
    print(f"\nEnergy (Fig. 13): SDN hosts {he / 3.6e6:.2f} kWh + switches "
          f"{se / 3.6e6:.2f} kWh; legacy hosts {hel / 3.6e6:.2f} + "
          f"switches {sel / 3.6e6:.2f} kWh")
    print(f"\nHeadline deltas vs paper (41/24/22%): "
          f"{report['best_match_pct']}")


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    run(ap.parse_args().full)
