"""Quickstart: the paper's experiment in ~20 lines + a tiny LM train run.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (PolicyConfig, ROUTE_LEGACY, ROUTE_SDN, paper_setup,
                        simulate, summarize)

# --- 1. BigDataSDNSim: SDN vs legacy on the paper's fat-tree (Tables 2-3)
setup = paper_setup(seed=0)
for name, routing in (("SDN", ROUTE_SDN), ("legacy", ROUTE_LEGACY)):
    rep = summarize(setup, simulate(
        setup, PolicyConfig(routing=routing, job_concurrency=2)))
    print(f"{name:7s} mean job transmission {np.nanmean(rep['transmission_time']):7.1f} s   "
          f"completion {np.nanmean(rep['completion_measured']):7.1f} s   "
          f"energy {rep['total_energy_j'] / 3.6e6:6.2f} kWh")

# --- 2. Train a small LM with the same repo's training stack
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.models import get_model
from repro.train import AdamWConfig, make_train_step
from repro.train import init as opt_init

cfg = get_smoke_config("qwen3-4b")
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0))
ocfg = AdamWConfig(total_steps=30, warmup_steps=3)
opt = opt_init(ocfg, params)
step = jax.jit(make_train_step(api, ocfg))
pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq=32)
for i in range(30):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
    params, opt, met = step(params, opt, batch)
    if i % 10 == 0 or i == 29:
        print(f"step {i:3d}  loss {float(met['loss']):.3f}  "
              f"lr {float(met['lr']):.2e}")
print("quickstart OK")
