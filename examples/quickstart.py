"""Quickstart: the paper's experiment through the unified Experiment API
(DESIGN.md §6) + a tiny LM train run.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.api import Experiment, PolicyConfig
from repro.core import ROUTE_LEGACY, ROUTE_SDN
from repro.scenarios import get_scenario

# --- 1. BigDataSDNSim: SDN vs legacy on the paper's fat-tree (Tables 2-3).
# One declarative experiment; .run() compiles once and returns the grid.
res = Experiment(
    scenarios=get_scenario("paper-fabric", n_each=5),   # the 15-job mix
    policies=[("SDN", PolicyConfig(routing=ROUTE_SDN, job_concurrency=2)),
              ("legacy", PolicyConfig(routing=ROUTE_LEGACY,
                                      job_concurrency=2))]).run()
jr = res.job_report()
for pi, (name, row) in enumerate(zip(res.policy_names, res.rows())):
    print(f"{name:7s} mean job transmission "
          f"{np.nanmean(jr['transmission_time'][0, pi]):7.1f} s   "
          f"completion {row['mean_completion_s']:7.1f} s   "
          f"energy {row['energy_kwh']:6.2f} kWh")

# --- 2. Train a small LM with the same repo's training stack
import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data import TokenPipeline
from repro.models import get_model
from repro.train import AdamWConfig, make_train_step
from repro.train import init as opt_init

cfg = get_smoke_config("qwen3-4b")
api = get_model(cfg)
params = api.init(jax.random.PRNGKey(0))
ocfg = AdamWConfig(total_steps=30, warmup_steps=3)
opt = opt_init(ocfg, params)
step = jax.jit(make_train_step(api, ocfg))
pipe = TokenPipeline(vocab=cfg.vocab, batch=8, seq=32)
for i in range(30):
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(i).items()}
    params, opt, met = step(params, opt, batch)
    if i % 10 == 0 or i == 29:
        print(f"step {i:3d}  loss {float(met['loss']):.3f}  "
              f"lr {float(met['lr']):.2e}")
print("quickstart OK")
