"""Continuous-batching serving demo: batched requests through ServeLoop.

  PYTHONPATH=src python examples/serve_lm.py --requests 12 --slots 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs import get_smoke_config
from repro.models import get_model
from repro.serve import Request, ServeLoop


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--arch", default="qwen3-4b")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    loop = ServeLoop(api, params, slots=args.slots, max_len=128)

    rng = np.random.RandomState(0)
    for r in range(args.requests):
        plen = int(rng.randint(4, 24))
        loop.submit(Request(rid=r,
                            prompt=rng.randint(1, cfg.vocab, plen)
                            .astype(np.int32),
                            max_new=args.max_new))
    t0 = time.time()
    results = loop.run()
    dt = time.time() - t0
    tokens = sum(len(r.tokens) for r in results)
    print(f"served {len(results)} requests, {tokens} tokens in {dt:.1f}s "
          f"({tokens / dt:.1f} tok/s with {args.slots} slots)")
    for r in sorted(results, key=lambda x: x.rid)[:5]:
        print(f"  rid={r.rid} prefill={r.prefill_len} "
              f"decoded={r.decode_steps} first tokens {r.tokens[:6]}")
    assert len(results) == args.requests


if __name__ == "__main__":
    main()
