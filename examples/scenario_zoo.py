"""Tour of the scenario library: build each registered scenario, print its
fabric shape and route diversity, then race SDN vs legacy routing on every
topology in one packed ``repro.api.Experiment`` (DESIGN.md §5, §6).

  PYTHONPATH=src python examples/scenario_zoo.py                # all fabrics
  PYTHONPATH=src python examples/scenario_zoo.py fat-tree leaf-spine
"""
import sys

import numpy as np

from repro.api import Experiment
from repro.core import PolicyConfig, ROUTE_LEGACY, ROUTE_SDN
from repro.scenarios import get_scenario, list_scenarios

names = sys.argv[1:] or list_scenarios()
scens = []
for name in names:
    sc = get_scenario(name)
    setup = sc.build()
    topo = setup.cluster.topo
    nc = setup.route_table.n_cand.reshape(topo.n_nodes, topo.n_nodes)
    host_pairs = nc[: topo.n_hosts, : topo.n_hosts]
    off_diag = host_pairs[~np.eye(topo.n_hosts, dtype=bool)]
    print(f"{sc.name:22} {topo.n_hosts:3d} hosts {topo.n_switches:3d} switches "
          f"{topo.n_links:4d} links   host-pair route diversity: "
          f"min {off_diag.min()}  max {off_diag.max()}  "
          f"mean {off_diag.mean():.1f}   [{sc.description}]")
    scens.append((sc.name, setup))

res = Experiment(
    scenarios=scens,
    policies=[("sdn", PolicyConfig(routing=ROUTE_SDN, job_concurrency=2)),
              ("legacy", PolicyConfig(routing=ROUTE_LEGACY,
                                      job_concurrency=2))]).run()
print()
rows = res.rows()
for sdn, leg in zip(rows[::2], rows[1::2]):
    gain = (leg["mean_completion_s"] - sdn["mean_completion_s"]) \
        / leg["mean_completion_s"] * 100
    print(f"{sdn['scenario']:22} completion sdn {sdn['mean_completion_s']:7.1f}s "
          f"legacy {leg['mean_completion_s']:7.1f}s   sdn gain {gain:+5.1f}%")
print("\nscenario zoo OK")
