"""Scenario registry: named (topology × cluster × workload) bundles
(DESIGN.md §5).

A ``Scenario`` is host-side configuration only; ``Scenario.build()`` lowers
it to the fixed-shape ``SimSetup`` tensors the engine consumes.  Register a
factory with ``@register("name")`` and any sweep driver (or
``benchmarks/scenario_sweep.py``) can pick it up by name; factories accept
keyword overrides so one registered scenario covers a parameter family.
``repro.api.Experiment`` accepts registered names, ``Scenario`` objects and
raw ``SimSetup``s interchangeably (DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Dict, List, Optional, Sequence

import numpy as np

from ..core.ctrlplane import CtrlPlaneConfig
from ..core.energy import EnergyParams
from ..core.failures import DegradationSchedule, FailureSchedule
from ..core.mapreduce import ClusterSpec, JobSpec, SimSetup, build_setup
from ..core.topology import (Topology, canonical_tree, fat_tree, leaf_spine,
                             paper_fat_tree)
from ..core.usecase import (HOST_CORES, HOST_MIPS, VM_CORES, VM_CORE_MIPS,
                            paper_jobs)
from .failures import random_degradation, random_failures
from .workloads import (JobTemplate, bursty_workload, uniform_workload,
                        zipf_workload)


def make_cluster(topo: Topology, vms_per_host: int = 1,
                 vm_cores: int = VM_CORES, vm_core_mips: float = VM_CORE_MIPS,
                 host_mips: float = HOST_CORES * HOST_MIPS,
                 energy: EnergyParams = EnergyParams()) -> ClusterSpec:
    """Paper-Table-2 cluster defaults on an arbitrary topology: VMs spread
    round-robin over hosts, SAN = the topology's storage node 0."""
    n_vms = topo.n_hosts * vms_per_host
    return ClusterSpec(
        topo=topo,
        vm_host=(np.arange(n_vms, dtype=np.int32) % topo.n_hosts),
        vm_total_mips=np.full(n_vms, vm_cores * vm_core_mips, np.float32),
        vm_core_mips=np.full(n_vms, vm_core_mips, np.float32),
        host_total_mips=np.full(topo.n_hosts, host_mips, np.float32),
        storage_node=topo.storage(0),
        energy=energy,
    )


@dataclasses.dataclass(frozen=True)
class Scenario:
    """One named simulation configuration, lowered lazily by ``build()``."""

    name: str
    topology: Callable[[], Topology]
    workload: Callable[[], Sequence[JobSpec]]
    description: str = ""
    vms_per_host: int = 1
    split: int = 1
    k_max: int = 8
    # optional seeded outage trace (DESIGN.md §7), built against the
    # realized topology
    failures: Optional[Callable[[Topology], FailureSchedule]] = None
    # optional control-plane resource model (DESIGN.md §10); None = the
    # identity instant controller
    ctrl: Optional[CtrlPlaneConfig] = None
    # optional gray-failure trace (DESIGN.md §13), built against the
    # realized topology
    degradation: Optional[Callable[[Topology], DegradationSchedule]] = None
    # speculative-execution clone slots per job (DESIGN.md §13); 0 = the
    # ``speculation`` policy axis has no capacity and stays inert
    spec_slots: int = 0

    def build(self) -> SimSetup:
        topo = self.topology()
        return build_setup(list(self.workload()), make_cluster(
            topo, vms_per_host=self.vms_per_host),
            k_max=self.k_max, split=self.split,
            failures=self.failures(topo) if self.failures else None,
            ctrl=self.ctrl,
            degradation=(self.degradation(topo)
                         if self.degradation else None),
            spec_slots=self.spec_slots)


_REGISTRY: Dict[str, Callable[..., Scenario]] = {}


def register(name: str):
    """Decorator: ``@register("leaf-spine")`` on a ``(**kw) -> Scenario``
    factory."""

    def deco(fn: Callable[..., Scenario]):
        if name in _REGISTRY:
            raise ValueError(f"scenario {name!r} already registered")
        _REGISTRY[name] = fn
        return fn

    return deco


def get_scenario(name: str, **overrides) -> Scenario:
    if name not in _REGISTRY:
        raise KeyError(
            f"unknown scenario {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name](**overrides)


def list_scenarios() -> List[str]:
    return sorted(_REGISTRY)


# ---------------------------------------------------------------------------
# built-in scenarios
# ---------------------------------------------------------------------------


@register("paper-fabric")
def _paper_fabric(seed: int = 0, n_each: int = 1, split: int = 2,
                  k_max: int = 16) -> Scenario:
    """The paper's §5 Fig.-9 fabric with a Table-3 job mix (``n_each`` of
    each size class; the paper runs n_each=5).  split=2 and k_max=16 match
    ``usecase.paper_setup`` — the calibrated paper-reproduction path — so
    this scenario reports the same numbers as the repro benchmarks."""
    return Scenario(
        name="paper-fabric",
        topology=paper_fat_tree,
        workload=lambda: paper_jobs(seed=seed, n_each=n_each),
        description="paper §5 three-tier fabric, Table-3 job mix",
        split=split,
        k_max=k_max,
    )


@register("fat-tree")
def _fat_tree(k: int = 4, seed: int = 0, n_jobs: int = 6) -> Scenario:
    """k-ary fat-tree with a uniform workload."""
    return Scenario(
        name=f"fat-tree-k{k}",
        topology=lambda: fat_tree(k),
        workload=lambda: uniform_workload(n_jobs=n_jobs, seed=seed),
        description=f"{k}-ary fat-tree, uniform job sizes",
    )


@register("leaf-spine")
def _leaf_spine(n_spine: int = 4, n_leaf: int = 4, hosts_per_leaf: int = 4,
                seed: int = 0, n_jobs: int = 6) -> Scenario:
    """Leaf-spine Clos with a heavy-tailed (Zipf) workload."""
    return Scenario(
        name=f"leaf-spine-{n_spine}x{n_leaf}",
        topology=lambda: leaf_spine(n_spine, n_leaf, hosts_per_leaf),
        workload=lambda: zipf_workload(n_jobs=n_jobs, seed=seed),
        description=f"{n_spine}-spine/{n_leaf}-leaf Clos, Zipf job sizes",
    )


@register("paper-fabric-failures")
def _paper_fabric_failures(seed: int = 0, n_each: int = 1, split: int = 2,
                           k_max: int = 16, host_rate: float = 2e-4,
                           link_rate: float = 2e-4, mttr: float = 120.0,
                           horizon: float = 1500.0) -> Scenario:
    """The paper fabric under a seeded exponential outage trace
    (DESIGN.md §7) — the failure counterpart of ``paper-fabric``, where
    SDN's reroute-around-the-failure vs legacy's static hash becomes the
    headline comparison."""
    return Scenario(
        name="paper-fabric-failures",
        topology=paper_fat_tree,
        workload=lambda: paper_jobs(seed=seed, n_each=n_each),
        description="paper §5 fabric + seeded host/link outages",
        split=split,
        k_max=k_max,
        failures=lambda topo: random_failures(
            topo, host_rate=host_rate, link_rate=link_rate, mttr=mttr,
            horizon=horizon, seed=seed),
    )


@register("leaf-spine-failures")
def _leaf_spine_failures(n_spine: int = 4, n_leaf: int = 4,
                         hosts_per_leaf: int = 4, seed: int = 0,
                         n_jobs: int = 6, link_rate: float = 5e-4,
                         mttr: float = 60.0,
                         horizon: float = 2000.0) -> Scenario:
    """Leaf-spine Clos with link-only outages: with ``n_spine`` equal-hop
    routes per inter-leaf pair, every cut is SDN-routable-around."""
    return Scenario(
        name=f"leaf-spine-failures-{n_spine}x{n_leaf}",
        topology=lambda: leaf_spine(n_spine, n_leaf, hosts_per_leaf),
        workload=lambda: zipf_workload(n_jobs=n_jobs, seed=seed),
        description="leaf-spine Clos + seeded link cuts",
        failures=lambda topo: random_failures(
            topo, link_rate=link_rate, mttr=mttr, horizon=horizon,
            seed=seed),
    )


@register("leaf-spine-xl")
def _leaf_spine_xl(n_spine: int = 8, n_leaf: int = 16, hosts_per_leaf: int = 8,
                   seed: int = 0, n_jobs: int = 128, max_scale: float = 8.0,
                   k_max: int = 8) -> Scenario:
    """Data-center-scale leaf-spine Clos (the scale Kreutz et al. argue
    controller evaluation needs): 128 hosts, 24 switches, a 128-job Zipf
    mix lowering to >=1k tasks and >=4k packets.  The step-kernel scaling
    benchmark (``benchmarks/engine_profile.py``, DESIGN.md §8) — too big
    for the old sequential admission/activation loops, sized so the
    vectorized kernel's per-step cost is dominated by tensor ops."""
    template = JobTemplate(n_map=8, n_reduce=3)
    return Scenario(
        name=f"leaf-spine-xl-{n_spine}x{n_leaf}x{hosts_per_leaf}",
        topology=lambda: leaf_spine(n_spine, n_leaf, hosts_per_leaf),
        workload=lambda: zipf_workload(n_jobs=n_jobs, seed=seed,
                                       template=template,
                                       max_scale=max_scale),
        description="128-host leaf-spine Clos, 128-job Zipf mix "
                    "(engine_profile scaling tier)",
        k_max=k_max,
    )


@register("paper-fabric-ctrl")
def _paper_fabric_ctrl(seed: int = 0, n_each: int = 1, split: int = 2,
                       k_max: int = 16, install_latency: float = 0.05,
                       ctrl_rate: float = 500.0,
                       table_slots: int = 8) -> Scenario:
    """The paper fabric with the control plane as a REAL resource
    (DESIGN.md §10): finite rule-install latency, a rate-limited
    controller and LRU-bounded per-switch flow tables.  The honest
    counterpart of ``paper-fabric``'s instant-oracle controller — here
    legacy routing (which needs no flow-mod round trip) can beat SDN
    (``benchmarks/ctrl_sweep.py``)."""
    return Scenario(
        name="paper-fabric-ctrl",
        topology=paper_fat_tree,
        workload=lambda: paper_jobs(seed=seed, n_each=n_each),
        description="paper §5 fabric + rate-limited controller with "
                    "flow-rule install latency",
        split=split,
        k_max=k_max,
        ctrl=CtrlPlaneConfig(install_latency=install_latency,
                             ctrl_rate=ctrl_rate, table_slots=table_slots),
    )


@register("leaf-spine-ctrl")
def _leaf_spine_ctrl(n_spine: int = 4, n_leaf: int = 4,
                     hosts_per_leaf: int = 4, seed: int = 0, n_jobs: int = 6,
                     install_latency: float = 0.02, ctrl_rate: float = 1000.0,
                     table_slots: int = 8, mig_threshold: float = 12.0,
                     mig_cost: float = 0.5, mig_cooldown: float = 5.0
                     ) -> Scenario:
    """Leaf-spine Clos under a finite controller WITH migrate-on-congestion
    armed (DESIGN.md §10): a finite ``mig_threshold`` lets the
    ``migration=congestion`` policy re-home hot VMs (the S-CORE
    comparison); under ``migration=static`` the threshold is inert."""
    return Scenario(
        name=f"leaf-spine-ctrl-{n_spine}x{n_leaf}",
        topology=lambda: leaf_spine(n_spine, n_leaf, hosts_per_leaf),
        workload=lambda: zipf_workload(n_jobs=n_jobs, seed=seed),
        description="leaf-spine Clos + finite controller, migration armed",
        ctrl=CtrlPlaneConfig(install_latency=install_latency,
                             ctrl_rate=ctrl_rate, table_slots=table_slots,
                             mig_threshold=mig_threshold, mig_cost=mig_cost,
                             mig_cooldown=mig_cooldown),
    )


@register("paper-fabric-chaos")
def _paper_fabric_chaos(seed: int = 0, n_each: int = 1, split: int = 2,
                        k_max: int = 16, host_rate: float = 2e-4,
                        link_rate: float = 2e-4, mttr: float = 120.0,
                        deg_host_rate: float = 1e-3,
                        deg_link_rate: float = 1e-3,
                        mean_factor: float = 0.4, deg_mttr: float = 300.0,
                        horizon: float = 1500.0,
                        install_latency: float = 0.05,
                        ctrl_rate: float = 500.0, table_slots: int = 8,
                        ctrl_fail_t: float = 60.0,
                        ctrl_recover_t: float = 400.0,
                        failover_delay: float = 2.0,
                        backup_rate: float = 200.0,
                        backup_latency: float = 0.1,
                        spec_slots: int = 2) -> Scenario:
    """The paper fabric under the full chaos stack (DESIGN.md §13): hard
    outages AND gray slowdowns AND a finite controller whose primary dies
    mid-run and fails over to a slower backup, with speculative-execution
    clone capacity armed.  The ``speculation`` policy axis and
    ``benchmarks/chaos_sweep.py`` race on this scenario."""
    return Scenario(
        name="paper-fabric-chaos",
        topology=paper_fat_tree,
        workload=lambda: paper_jobs(seed=seed, n_each=n_each),
        description="paper §5 fabric + outages + gray degradation + "
                    "controller failover + speculation slots",
        split=split,
        k_max=k_max,
        failures=lambda topo: random_failures(
            topo, host_rate=host_rate, link_rate=link_rate, mttr=mttr,
            horizon=horizon, seed=seed),
        degradation=lambda topo: random_degradation(
            topo, host_rate=deg_host_rate, link_rate=deg_link_rate,
            mean_factor=mean_factor, mttr=deg_mttr, horizon=horizon,
            seed=seed + 1),
        ctrl=CtrlPlaneConfig(install_latency=install_latency,
                             ctrl_rate=ctrl_rate, table_slots=table_slots,
                             ctrl_fail_t=ctrl_fail_t,
                             ctrl_recover_t=ctrl_recover_t,
                             failover_delay=failover_delay,
                             backup_rate=backup_rate,
                             backup_latency=backup_latency),
        spec_slots=spec_slots,
    )


@register("leaf-spine-chaos")
def _leaf_spine_chaos(n_spine: int = 4, n_leaf: int = 4,
                      hosts_per_leaf: int = 4, seed: int = 0,
                      n_jobs: int = 6, deg_host_rate: float = 2e-3,
                      mean_factor: float = 0.3, deg_mttr: float = 400.0,
                      horizon: float = 2000.0,
                      spec_slots: int = 2) -> Scenario:
    """Leaf-spine Clos with gray host slowdowns only (no hard outages, no
    controller) — isolates the straggler-speculation effect: the
    ``speculation=on`` policy clones tasks stuck on degraded hosts onto
    healthy VMs (DESIGN.md §13)."""
    return Scenario(
        name=f"leaf-spine-chaos-{n_spine}x{n_leaf}",
        topology=lambda: leaf_spine(n_spine, n_leaf, hosts_per_leaf),
        workload=lambda: zipf_workload(n_jobs=n_jobs, seed=seed),
        description="leaf-spine Clos + gray host slowdowns, speculation "
                    "slots armed",
        degradation=lambda topo: random_degradation(
            topo, host_rate=deg_host_rate, mean_factor=mean_factor,
            mttr=deg_mttr, horizon=horizon, seed=seed + 1),
        spec_slots=spec_slots,
    )


@register("leaf-spine-stream")
def _leaf_spine_stream(n_spine: int = 4, n_leaf: int = 4,
                       hosts_per_leaf: int = 4, seed: int = 0,
                       rate: float = 0.05, horizon: float = 240.0,
                       urgent_share: float = 0.3, urgent_slo: float = 120.0,
                       batch_slo: float = 600.0,
                       max_jobs: Optional[int] = None) -> Scenario:
    """Leaf-spine Clos under a two-class Poisson open-arrival mix — the
    steady-state streaming scenario (DESIGN.md §11).  Registered with a
    FINITE arrival preview (the trace below ``horizon``) so it runs under
    ``Experiment.run`` like any scenario; ``Experiment.run_stream`` with
    the same ``stream_arrivals(...)`` process streams it unbounded through
    the slot-recycling ring.  The urgent class carries a priority weight
    the ``job_selection=priority`` axis consumes, plus the tighter SLO the
    windowed metrics grade."""
    from .arrivals import as_workload
    arrivals = stream_arrivals(rate=rate, seed=seed,
                               urgent_share=urgent_share,
                               urgent_slo=urgent_slo, batch_slo=batch_slo)
    return Scenario(
        name=f"leaf-spine-stream-{n_spine}x{n_leaf}",
        topology=lambda: leaf_spine(n_spine, n_leaf, hosts_per_leaf),
        workload=lambda: as_workload(arrivals, horizon, max_jobs=max_jobs),
        description="leaf-spine Clos, two-class Poisson open arrivals "
                    "(finite preview; stream via Experiment.run_stream)",
    )


def stream_arrivals(rate: float = 0.05, seed: int = 0,
                    urgent_share: float = 0.3, urgent_slo: float = 120.0,
                    batch_slo: float = 600.0):
    """The ``leaf-spine-stream`` scenario's arrival process — importable so
    ``run_stream`` users and the finite preview share one definition."""
    from .arrivals import PoissonArrivals, ServiceClass
    classes = (
        ServiceClass("batch", weight=0.0, slo_s=batch_slo,
                     share=1.0 - urgent_share),
        ServiceClass("urgent", weight=2.0, slo_s=urgent_slo,
                     share=urgent_share,
                     template=JobTemplate(n_map=2, n_reduce=1),
                     scale_lo=0.25, scale_hi=1.0),
    )
    return PoissonArrivals(rate=rate, classes=classes, seed=seed)


@register("canonical-tree")
def _canonical_tree(depth: int = 3, fanout: int = 2, hosts_per_edge: int = 4,
                    seed: int = 0, n_jobs: int = 6) -> Scenario:
    """Single-rooted tree (no path diversity) with a bursty workload — the
    degenerate baseline SDN routing cannot help."""
    return Scenario(
        name=f"canonical-tree-d{depth}f{fanout}",
        topology=lambda: canonical_tree(depth, fanout, hosts_per_edge,
                                        root_bw_mult=2.0),
        workload=lambda: bursty_workload(n_jobs=n_jobs, seed=seed),
        description=f"depth-{depth} canonical tree, bursty arrivals",
    )
