"""Synthetic MapReduce workload generators (DESIGN.md §5).

The paper's §5 use-case fixes one 15-job trace (Table 3).  These generators
produce parameterized ``JobSpec`` lists layered on ``core.mapreduce``:

  * ``uniform_workload``  — job sizes i.i.d. uniform around a base spec,
  * ``zipf_workload``     — heavy-tailed (Zipf) size distribution: many small
                            jobs, few elephants (the measured shape of
                            production MapReduce traces),
  * ``bursty_workload``   — arrivals clustered into bursts separated by idle
                            gaps (stress test for admission + SDN routing
                            under synchronized shuffles).

All are deterministic in ``seed`` (np.random.RandomState) so scenario sweeps
are reproducible replica-for-replica.
"""
from __future__ import annotations

import dataclasses
from typing import List

import numpy as np

from ..core.mapreduce import JobSpec


@dataclasses.dataclass(frozen=True)
class JobTemplate:
    """Base shape a generator scales; defaults ≈ the paper's 'medium' job
    scaled down ~20x so sweep smoke-runs stay cheap."""

    n_map: int = 2
    n_reduce: int = 1
    map_mi: float = 10_000.0
    reduce_mi: float = 8_000.0
    input_gbits: float = 20.0
    shuffle_gbits: float = 16.0
    output_gbits: float = 12.0


def _scaled_job(tmpl: JobTemplate, scale: float, submit: float,
                priority: float = 0.0) -> JobSpec:
    """Scale compute+data linearly; parallelism grows as sqrt(scale) so big
    jobs get more mappers instead of only fatter ones."""
    par = max(1, int(round(np.sqrt(scale))))
    return JobSpec(
        submit_time=float(submit),
        n_map=tmpl.n_map * par,
        n_reduce=max(1, tmpl.n_reduce * par),
        map_mi=tmpl.map_mi * scale / par,
        reduce_mi=tmpl.reduce_mi * scale / par,
        input_gbits=tmpl.input_gbits * scale,
        shuffle_gbits=tmpl.shuffle_gbits * scale,
        output_gbits=tmpl.output_gbits * scale,
        priority=priority,
    )


def uniform_workload(n_jobs: int = 6, seed: int = 0, interval_s: float = 1.0,
                     scale_lo: float = 0.5, scale_hi: float = 2.0,
                     template: JobTemplate = JobTemplate()) -> List[JobSpec]:
    """i.i.d. uniform job sizes, fixed submission interval."""
    rng = np.random.RandomState(seed)
    scales = rng.uniform(scale_lo, scale_hi, size=n_jobs)
    return [_scaled_job(template, s, i * interval_s)
            for i, s in enumerate(scales)]


def zipf_workload(n_jobs: int = 6, seed: int = 0, interval_s: float = 1.0,
                  alpha: float = 1.6, max_scale: float = 8.0,
                  template: JobTemplate = JobTemplate()) -> List[JobSpec]:
    """Zipf-distributed sizes clipped to ``max_scale`` — mostly rank-1
    (scale 1) jobs with an occasional elephant."""
    rng = np.random.RandomState(seed)
    scales = np.minimum(rng.zipf(alpha, size=n_jobs).astype(np.float64),
                        max_scale)
    return [_scaled_job(template, s, i * interval_s)
            for i, s in enumerate(scales)]


def bursty_workload(n_jobs: int = 6, seed: int = 0, burst_size: int = 3,
                    burst_gap_s: float = 60.0, intra_gap_s: float = 0.1,
                    scale_lo: float = 0.5, scale_hi: float = 2.0,
                    template: JobTemplate = JobTemplate()) -> List[JobSpec]:
    """Jobs arrive ``burst_size`` at a time, ``intra_gap_s`` apart inside a
    burst and ``burst_gap_s`` between bursts."""
    rng = np.random.RandomState(seed)
    jobs = []
    for i in range(n_jobs):
        burst, pos = divmod(i, burst_size)
        t = burst * burst_gap_s + pos * intra_gap_s
        jobs.append(_scaled_job(template, rng.uniform(scale_lo, scale_hi), t))
    return jobs
