"""Scenario & topology library: named topology×workload bundles plus a
packed multi-topology sweep driver (DESIGN.md §5)."""
from .arrivals import (Arrival, ArrivalProcess, DiurnalArrivals,
                       PoissonArrivals, ServiceClass, TraceArrivals,
                       as_workload)
from .failures import failure_injector, random_failures
from .registry import (Scenario, get_scenario, list_scenarios, make_cluster,
                       register)
from .sweep import (SweepResult, pack_setups, policy_arrays, sweep_grid)
from .workloads import (JobTemplate, bursty_workload, uniform_workload,
                        zipf_workload)

__all__ = [
    "Scenario", "get_scenario", "list_scenarios", "make_cluster", "register",
    "SweepResult", "pack_setups", "policy_arrays", "sweep_grid",
    "JobTemplate", "bursty_workload", "uniform_workload", "zipf_workload",
    "failure_injector", "random_failures",
    "Arrival", "ArrivalProcess", "PoissonArrivals", "DiurnalArrivals",
    "TraceArrivals", "ServiceClass", "as_workload",
]
