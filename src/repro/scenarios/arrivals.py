"""Open-arrival processes & service classes for streaming (DESIGN.md §11).

The finite workload generators in ``workloads.py`` answer "what jobs exist";
an ``ArrivalProcess`` answers "when does the NEXT job arrive" — a lazy,
seed-deterministic iterator the streaming ring (``core.streaming``) refills
from, so traces of any length run in bounded memory.

Generators (all deterministic in ``seed``; same seed ⇒ identical trace):

* ``PoissonArrivals``  — homogeneous Poisson: i.i.d. exponential gaps at
                         ``rate`` jobs/s.
* ``DiurnalArrivals``  — inhomogeneous Poisson with the day-cycle rate
                         ``base_rate * (1 + amplitude*sin(2π(t-phase)/period))``
                         realized by thinning against the peak rate.
* ``TraceArrivals``    — replay explicit arrival instants (or a literal
                         ``JobSpec`` list), for trace-driven studies and
                         the finite-trace bit-identity tests.

Service classes: each arrival samples a ``ServiceClass`` ∝ ``share``.  The
class ``weight`` lands in ``JobSpec.priority`` — the consts tensor the
policy-field registry's ``job_selection=priority`` axis already consumes —
so class-aware admission needs no new engine branch; ``slo_s`` is the
sojourn target the windowed metrics (``StreamResults``) grade attainment
against.  Job sizes come from the class's ``workloads.JobTemplate`` scaled
uniformly in ``[scale_lo, scale_hi]``.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Iterator, List, Optional, Sequence, Tuple

import numpy as np

from ..core.mapreduce import JobSpec
from .workloads import JobTemplate, _scaled_job


@dataclasses.dataclass(frozen=True)
class ServiceClass:
    """One tenant class: admission weight + SLO target + job shape."""

    name: str
    weight: float = 0.0        # job_priority under job_selection=priority
    slo_s: float = math.inf    # sojourn (arrival -> done) target
    share: float = 1.0         # relative arrival share
    template: JobTemplate = JobTemplate()
    scale_lo: float = 0.5
    scale_hi: float = 2.0


DEFAULT_CLASSES: Tuple[ServiceClass, ...] = (ServiceClass("default"),)


@dataclasses.dataclass(frozen=True)
class Arrival:
    """One materialized arrival: instant, class index, lowered job."""

    t: float
    cls: int
    job: JobSpec


class ArrivalProcess:
    """Base: ``events(horizon)`` lazily yields ``Arrival``s with strictly
    increasing ``t < horizon``.  Subclasses are frozen dataclasses, so one
    process can be replayed (every ``events`` call restarts the rng)."""

    classes: Tuple[ServiceClass, ...] = DEFAULT_CLASSES

    def events(self, horizon: float) -> Iterator[Arrival]:
        raise NotImplementedError

    def _shares(self) -> np.ndarray:
        s = np.asarray([c.share for c in self.classes], float)
        if not np.all(s >= 0) or s.sum() <= 0:
            raise ValueError("class shares must be non-negative, sum > 0")
        return s / s.sum()

    def _arrival(self, rng: np.random.Generator, t: float,
                 shares: np.ndarray) -> Arrival:
        ci = int(rng.choice(len(self.classes), p=shares))
        cl = self.classes[ci]
        scale = float(rng.uniform(cl.scale_lo, cl.scale_hi))
        return Arrival(float(t), ci,
                       _scaled_job(cl.template, scale, t,
                                   priority=cl.weight))


@dataclasses.dataclass(frozen=True)
class PoissonArrivals(ArrivalProcess):
    """Homogeneous Poisson arrivals at ``rate`` jobs/s."""

    rate: float = 1.0
    classes: Tuple[ServiceClass, ...] = DEFAULT_CLASSES
    seed: int = 0

    def events(self, horizon: float) -> Iterator[Arrival]:
        if self.rate <= 0:
            return
        rng = np.random.default_rng(self.seed)
        shares = self._shares()
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / self.rate))
            if t >= horizon:
                return
            yield self._arrival(rng, t, shares)


@dataclasses.dataclass(frozen=True)
class DiurnalArrivals(ArrivalProcess):
    """Inhomogeneous Poisson with a sinusoidal day cycle, by thinning:
    candidates arrive at the peak rate ``base_rate*(1+amplitude)`` and are
    accepted with probability ``rate_at(t)/peak`` — the standard exact
    construction (Lewis & Shedler)."""

    base_rate: float = 1.0
    amplitude: float = 0.5      # in [0, 1): rate stays positive
    period: float = 86400.0
    phase: float = 0.0          # instant of mean upcrossing (sin = 0, rising)
    classes: Tuple[ServiceClass, ...] = DEFAULT_CLASSES
    seed: int = 0

    def rate_at(self, t: float) -> float:
        return self.base_rate * (
            1.0 + self.amplitude
            * math.sin(2.0 * math.pi * (t - self.phase) / self.period))

    def events(self, horizon: float) -> Iterator[Arrival]:
        if self.base_rate <= 0:
            return
        if not 0.0 <= self.amplitude < 1.0:
            raise ValueError("amplitude must be in [0, 1)")
        rng = np.random.default_rng(self.seed)
        shares = self._shares()
        peak = self.base_rate * (1.0 + self.amplitude)
        t = 0.0
        while True:
            t += float(rng.exponential(1.0 / peak))
            if t >= horizon:
                return
            if float(rng.uniform()) * peak <= self.rate_at(t):
                yield self._arrival(rng, t, shares)


@dataclasses.dataclass(frozen=True)
class TraceArrivals(ArrivalProcess):
    """Replay explicit arrivals.  Either ``times`` (instants, with optional
    per-arrival ``cls_ids`` / ``scales``, jobs lowered from the class
    template) or ``jobs`` (literal ``JobSpec``s arriving at their own
    ``submit_time`` — the bit-identity test's path).  Fully deterministic:
    no rng is consumed."""

    times: Tuple[float, ...] = ()
    cls_ids: Optional[Tuple[int, ...]] = None
    scales: Optional[Tuple[float, ...]] = None
    jobs: Optional[Tuple[JobSpec, ...]] = None
    classes: Tuple[ServiceClass, ...] = DEFAULT_CLASSES

    def events(self, horizon: float) -> Iterator[Arrival]:
        if self.jobs is not None:
            seq = sorted(enumerate(self.jobs),
                         key=lambda kv: kv[1].submit_time)
            for i, job in seq:
                if job.submit_time < horizon:
                    ci = self.cls_ids[i] if self.cls_ids else 0
                    yield Arrival(float(job.submit_time), ci, job)
            return
        last = -math.inf
        for i, t in enumerate(self.times):
            if t < last:
                raise ValueError("trace times must be non-decreasing")
            last = t
            if t >= horizon:
                return
            ci = self.cls_ids[i] if self.cls_ids else 0
            cl = self.classes[ci]
            scale = self.scales[i] if self.scales else 1.0
            yield Arrival(float(t), ci,
                          _scaled_job(cl.template, scale, t,
                                      priority=cl.weight))


def as_workload(process: ArrivalProcess, horizon: float,
                max_jobs: Optional[int] = None) -> List[JobSpec]:
    """Materialize an arrival process into a finite ``JobSpec`` list — the
    bridge back to registry scenarios / ``Experiment.run`` (and the finite
    preview a streaming scenario registers)."""
    jobs: List[JobSpec] = []
    for a in process.events(horizon):
        jobs.append(a.job)
        if max_jobs is not None and len(jobs) >= max_jobs:
            break
    return jobs
