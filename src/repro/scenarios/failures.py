"""Seeded failure-trace generators (DESIGN.md §7).

Deterministic functions from ``(dimensions, rate parameters, seed)`` to a
``core.failures.FailureSchedule``: the whole failure-rate × seed grid of
``benchmarks/failure_sweep.py`` is generated host-side and swept through
the engine as consts data — one vmapped tensor program, no RNG inside the
event loop.

``random_failures`` draws at most ONE outage per device per run:
fail ~ Exp(1/rate) kept iff it lands inside the horizon, repair duration ~
Exp(mttr) (or permanent when ``mttr`` is None).  Link outages are drawn
per undirected CABLE (``Topology.cable_pairs``) and applied to both
directed slots, so a cut severs the full-duplex pair — what a failed
transceiver or pulled fiber does.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from ..core.failures import (DegradationSchedule, FailureSchedule,
                             no_degradation, no_failures)
from ..core.mapreduce import SimSetup
from ..core.topology import Topology


def random_failures(topo: Topology, *, host_rate: float = 0.0,
                    link_rate: float = 0.0, mttr: float | None = None,
                    horizon: float = np.inf,
                    seed: int = 0) -> FailureSchedule:
    """Exponential arrival / exponential repair outage trace.

    host_rate / link_rate : failures per second per device (0 = never)
    mttr                  : mean seconds to repair; None = permanent
    horizon               : failures drawn past this instant are dropped
                            (use roughly the expected makespan)
    """
    rng = np.random.default_rng(seed)
    sched = no_failures(topo.n_hosts, topo.n_links)

    def draw(fail_t, recover_t, idx, rate):
        if rate <= 0.0:
            return
        t = rng.exponential(1.0 / rate)
        if not (t < horizon):
            return
        fail_t[idx] = t
        recover_t[idx] = t + rng.exponential(mttr) if mttr is not None \
            else np.inf

    for h in range(topo.n_hosts):
        draw(sched.host_fail_t, sched.host_recover_t, h, host_rate)
    # one draw per undirected cable, applied to both directed slots
    for a, b in topo.cable_pairs():
        draw(sched.link_fail_t, sched.link_recover_t, a, link_rate)
        sched.link_fail_t[b] = sched.link_fail_t[a]
        sched.link_recover_t[b] = sched.link_recover_t[a]
    return sched.validate(topo.n_hosts, topo.n_links)


def failure_injector(**kw) -> Callable[[SimSetup], FailureSchedule]:
    """A ``(SimSetup) -> FailureSchedule`` closure over ``random_failures``
    parameters — the shape ``Experiment(failures=...)`` accepts, so one
    rate spec applies to scenarios of any topology."""

    def inject(setup: SimSetup) -> FailureSchedule:
        return random_failures(setup.cluster.topo, **kw)

    return inject


def random_degradation(topo: Topology, *, host_rate: float = 0.0,
                       link_rate: float = 0.0, mean_factor: float = 0.5,
                       mttr: float | None = None,
                       horizon: float = np.inf,
                       seed: int = 0) -> DegradationSchedule:
    """Seeded gray-failure trace (DESIGN.md §13): exponential window
    arrival / exponential restore, mirroring ``random_failures`` but
    producing rate MULTIPLIERS instead of outages.

    host_rate / link_rate : gray windows per second per device (0 = never)
    mean_factor           : mean of the in-window rate multiplier; each
                            window draws factor ~ U(mean_factor/2,
                            min(3*mean_factor/2, 0.95)) — always < 1 so a
                            window genuinely degrades, never a full outage
    mttr                  : mean seconds until the device restores; None =
                            degraded for the rest of the run
    horizon               : windows opening past this instant are dropped
    """
    rng = np.random.default_rng(seed)
    sched = no_degradation(topo.n_hosts, topo.n_links)
    lo = max(mean_factor / 2.0, 0.01)
    hi = min(1.5 * mean_factor, 0.95)
    hi = max(hi, lo + 1e-3)

    def draw(slow_t, restore_t, factor, idx, rate):
        if rate <= 0.0:
            return
        t = rng.exponential(1.0 / rate)
        if not (t < horizon):
            return
        slow_t[idx] = t
        restore_t[idx] = t + rng.exponential(mttr) if mttr is not None \
            else np.inf
        factor[idx] = rng.uniform(lo, hi)

    for h in range(topo.n_hosts):
        draw(sched.host_slow_t, sched.host_restore_t, sched.host_factor,
             h, host_rate)
    # one draw per undirected cable, applied to both directed slots
    for a, b in topo.cable_pairs():
        draw(sched.link_slow_t, sched.link_restore_t, sched.link_factor,
             a, link_rate)
        sched.link_slow_t[b] = sched.link_slow_t[a]
        sched.link_restore_t[b] = sched.link_restore_t[a]
        sched.link_factor[b] = sched.link_factor[a]
    return sched.validate(topo.n_hosts, topo.n_links)


def degradation_injector(**kw) -> Callable[[SimSetup], DegradationSchedule]:
    """A ``(SimSetup) -> DegradationSchedule`` closure over
    ``random_degradation`` parameters — the shape
    ``Experiment(degradation=...)`` accepts (DESIGN.md §13)."""

    def inject(setup: SimSetup) -> DegradationSchedule:
        return random_degradation(setup.cluster.topo, **kw)

    return inject
