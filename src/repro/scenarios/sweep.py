"""Pack heterogeneous scenarios into ONE vmapped tensor program
(DESIGN.md §5).

Different topologies produce different-shaped ``SimSetup`` tensors (node,
link, VM, job, task, packet counts all vary).  ``pack_setups`` pads every
scenario to the batch maxima and RENUMBERS nodes into a common layout

    hosts [0, H) | switches [H, H+SW) | storage [H+SW, H+SW+ST)

(H/SW/ST = padded maxima) so the engine's static host/switch tensor slices
hold for every replica.  Pad slots are inert by construction:

  * pad links have bw=0 and appear on no route,
  * pad jobs/tasks/packets carry valid=False (→ VOID at init),
  * pad VM slots are excluded from placement via ``EngineConsts.n_vms``,
  * pad hosts/switches idle at 0 W (the energy model zeroes idle devices).

``sweep_grid`` then crosses scenarios × policies and runs the whole grid
through the engine's packed simulator as a single nested jit(vmap(...))
call (scenarios outer, policies inner, so consts broadcast over policies).

Caveat: renumbering is outcome-invariant for MapReduce setups (packet
endpoints are task indices, which pad by appending), but a ``core.flows``
setup addresses nodes directly via NODE_OFFSET ids, and under
``ROUTE_LEGACY`` those ids feed the flow hash — renumbering then shifts
which of the equal-hop routes the legacy policy "randomly" pins, so exact
times can differ from a single run (same distribution, different draw).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import (EngineConsts, NODE_OFFSET, UNREACHABLE_HOPS,
                           default_max_steps, job_n_tasks_np,
                           job_valid_mask, task_rank_in_job_np)
from ..core.ctrlplane import no_ctrl
from ..core.failures import no_degradation, no_failures
from ..core.mapreduce import SimSetup
from ..core.policies import as_policy_arrays, policy_field_names
from ..core.report import energy_report, job_report_consts
from ..core.simmeta import SimMeta


def _pad1(a: np.ndarray, n: int, fill) -> np.ndarray:
    out = np.full((n,) + a.shape[1:], fill, dtype=a.dtype)
    out[: a.shape[0]] = a
    return out


def _pack_one(setup: SimSetup, dims: Dict[str, int]) -> Dict[str, np.ndarray]:
    """One scenario's EngineConsts fields, padded + renumbered to ``dims``."""
    topo = setup.cluster.topo
    rt = setup.route_table
    sched = setup.failures or no_failures(topo.n_hosts, topo.n_links)
    deg = setup.degradation or no_degradation(topo.n_hosts, topo.n_links)
    cfg = setup.ctrl or no_ctrl()
    H, SW = dims["n_hosts"], dims["n_switches"]
    Nn, L, K, HP = dims["n_nodes"], dims["n_links"], dims["k_max"], dims["max_hops"]
    n_h, n_sw = topo.n_hosts, topo.n_switches

    def node_map(ids):
        ids = np.asarray(ids, np.int64)
        return np.where(
            ids < n_h, ids,
            np.where(ids < n_h + n_sw, ids - n_h + H,
                     ids - (n_h + n_sw) + H + SW)).astype(np.int32)

    def task_ref_map(a):
        # -1 = SAN, >= NODE_OFFSET = direct node id (needs renumbering),
        # otherwise a task index (unchanged: tasks pad by appending).
        a = np.asarray(a, np.int64)
        return np.where(a >= NODE_OFFSET,
                        NODE_OFFSET + node_map(a - NODE_OFFSET),
                        a).astype(np.int32)

    # routes: scatter each (src, dst) pair into the renumbered pair index
    m_ids = node_map(np.arange(topo.n_nodes))
    new_pair = (m_ids[:, None].astype(np.int64) * Nn + m_ids[None, :]).reshape(-1)
    routes = np.full((Nn * Nn, K, HP), -1, np.int32)
    routes[new_pair, : rt.k_max, : rt.max_hops] = rt.routes
    n_cand = np.zeros((Nn * Nn,), np.int32)
    n_cand[new_pair] = rt.n_cand
    # candidate-0 hop counts at the padded pair layout (DESIGN.md §10):
    # pad pairs are unreachable, the padded diagonal stays 0
    pair_hops = np.full((Nn * Nn,), UNREACHABLE_HOPS, np.int32)
    pair_hops[new_pair] = np.where(rt.n_cand > 0, rt.route_len[:, 0],
                                   UNREACHABLE_HOPS).astype(np.int32)
    diag = np.arange(Nn, dtype=np.int64)
    pair_hops[diag * Nn + diag] = 0

    # failure schedule (DESIGN.md §7): pad hosts/links never fail; the
    # concatenated breakpoint tensor (DESIGN.md §8) is rebuilt from the
    # PADDED windows so its layout matches ``FailureSchedule.instants``
    # at the padded dims
    sched_pad = {
        "host_fail_t": _pad1(np.asarray(sched.host_fail_t, np.float32),
                             H, np.inf),
        "host_recover_t": _pad1(np.asarray(sched.host_recover_t, np.float32),
                                H, np.inf),
        "link_fail_t": _pad1(np.asarray(sched.link_fail_t, np.float32),
                             L, np.inf),
        "link_recover_t": _pad1(np.asarray(sched.link_recover_t, np.float32),
                                L, np.inf),
    }

    # degradation schedule (DESIGN.md §13): pad devices never degrade
    # (slow_t=inf, factor=1.0); the breakpoint tensor is rebuilt from the
    # PADDED windows so its layout matches ``DegradationSchedule.instants``
    # at the padded dims — inert windows masked to inf, like the unpacked
    # path
    deg_pad = {
        "host_slow_t": _pad1(np.asarray(deg.host_slow_t, np.float32),
                             H, np.inf),
        "host_restore_t": _pad1(np.asarray(deg.host_restore_t, np.float32),
                                H, np.inf),
        "host_deg_factor": _pad1(np.asarray(deg.host_factor, np.float32),
                                 H, 1.0),
        "link_slow_t": _pad1(np.asarray(deg.link_slow_t, np.float32),
                             L, np.inf),
        "link_restore_t": _pad1(np.asarray(deg.link_restore_t, np.float32),
                                L, np.inf),
        "link_deg_factor": _pad1(np.asarray(deg.link_factor, np.float32),
                                 L, 1.0),
    }
    lh = (np.isfinite(deg_pad["host_slow_t"])
          & (deg_pad["host_deg_factor"] != 1.0))
    ll = (np.isfinite(deg_pad["link_slow_t"])
          & (deg_pad["link_deg_factor"] != 1.0))
    deg_breaks = np.concatenate([
        np.where(lh, deg_pad["host_slow_t"], np.inf),
        np.where(lh, deg_pad["host_restore_t"], np.inf),
        np.where(ll, deg_pad["link_slow_t"], np.inf),
        np.where(ll, deg_pad["link_restore_t"], np.inf),
    ]).astype(np.float32)

    cl = setup.cluster
    return {
        "routes": routes,
        "n_cand": n_cand,
        "link_bw": _pad1(np.asarray(topo.link_bw, np.float32), L, 0.0),
        "link_src": _pad1(node_map(topo.link_src), L, 0),
        "link_dst": _pad1(node_map(topo.link_dst), L, 0),
        "vm_host": _pad1(np.asarray(cl.vm_host, np.int32), dims["n_vms"], 0),
        "vm_total_mips": _pad1(np.asarray(cl.vm_total_mips, np.float32),
                               dims["n_vms"], 0.0),
        "vm_core_mips": _pad1(np.asarray(cl.vm_core_mips, np.float32),
                              dims["n_vms"], 0.0),
        # pad hosts get 1 MIPS (not 0) so utilization never divides 0/0;
        # they run no tasks, so util=0 -> 0 W.
        "host_total_mips": _pad1(np.asarray(cl.host_total_mips, np.float32),
                                 H, 1.0),
        "job_release": _pad1(np.asarray(setup.job_release, np.float32),
                             dims["n_jobs"], 0.0),
        "job_total_mi": _pad1(np.asarray(setup.job_total_mi, np.float32),
                              dims["n_jobs"], 0.0),
        "job_priority": _pad1(np.asarray(setup.job_priority, np.float32),
                              dims["n_jobs"], 0.0),
        "job_n_out": _pad1(np.asarray(setup.job_n_out, np.int32),
                           dims["n_jobs"], 0),
        "job_valid": _pad1(np.asarray(job_valid_mask(setup.job_n_out)),
                           dims["n_jobs"], False),
        "task_job": _pad1(np.asarray(setup.task_job, np.int32),
                          dims["n_tasks"], -1),
        "task_kind": _pad1(np.asarray(setup.task_kind, np.int8),
                           dims["n_tasks"], 0),
        "task_mi": _pad1(np.asarray(setup.task_mi, np.float32),
                         dims["n_tasks"], 0.0),
        "task_need": _pad1(np.asarray(setup.task_need, np.int32),
                           dims["n_tasks"], 0),
        "task_valid": _pad1(np.asarray(setup.task_valid), dims["n_tasks"],
                            False),
        "task_rank_in_job": task_rank_in_job_np(
            _pad1(np.asarray(setup.task_job, np.int32), dims["n_tasks"], -1)),
        "job_n_tasks": job_n_tasks_np(setup.task_job, setup.task_valid,
                                      dims["n_jobs"]),
        "pkt_job": _pad1(np.asarray(setup.pkt_job, np.int32),
                         dims["n_packets"], -1),
        "pkt_phase": _pad1(np.asarray(setup.pkt_phase, np.int8),
                           dims["n_packets"], 0),
        "pkt_bits": _pad1(np.asarray(setup.pkt_bits, np.float32),
                          dims["n_packets"], 0.0),
        "pkt_gate_task": _pad1(np.asarray(setup.pkt_gate_task, np.int32),
                               dims["n_packets"], -1),
        "pkt_feeds_task": _pad1(np.asarray(setup.pkt_feeds_task, np.int32),
                                dims["n_packets"], -1),
        "pkt_src_task": _pad1(task_ref_map(setup.pkt_src_task),
                              dims["n_packets"], -1),
        "pkt_dst_task": _pad1(task_ref_map(setup.pkt_dst_task),
                              dims["n_packets"], -1),
        "pkt_valid": _pad1(np.asarray(setup.pkt_valid), dims["n_packets"],
                           False),
        "n_hosts": np.int32(n_h),
        "n_switches": np.int32(n_sw),
        "storage_node": node_map(cl.storage_node)[()],
        "n_vms": np.int32(cl.vm_host.shape[0]),
        **sched_pad,
        "fail_breaks": np.concatenate([
            sched_pad["host_fail_t"], sched_pad["host_recover_t"],
            sched_pad["link_fail_t"], sched_pad["link_recover_t"]]),
        **deg_pad,
        "deg_breaks": deg_breaks,
        # control plane (DESIGN.md §10): identity scalars when the replica
        # carries no config — its lanes behave like the oracle controller
        "ctrl_on": np.bool_(cfg.any_ctrl),
        "ctrl_latency": np.float32(cfg.install_latency),
        "ctrl_rate": np.float32(cfg.ctrl_rate),
        "mig_threshold": np.float32(cfg.mig_threshold),
        "mig_cost": np.float32(cfg.mig_cost),
        "mig_cooldown": np.float32(cfg.mig_cooldown),
        "mig_limit": np.int32(cfg.mig_limit),
        "pair_hops": pair_hops,
        # controller failover scalars (DESIGN.md §13); inert (inf fail_t)
        # for replicas without a failover window
        "ctrl_fail_t": np.float32(cfg.ctrl_fail_t),
        "ctrl_recover_t": np.float32(cfg.ctrl_recover_t),
        "ctrl_failover_delay": np.float32(cfg.failover_delay),
        "ctrl_backup_rate": np.float32(cfg.backup_rate),
        "ctrl_backup_latency": np.float32(cfg.backup_latency),
    }


def pack_setups(setups: Sequence[SimSetup]
                ) -> Tuple[EngineConsts, SimMeta]:
    """Pad + stack setups into batched EngineConsts (leading dim = scenario)
    and the shared static ``SimMeta`` for ``make_packed_simulator``."""
    assert len(setups) >= 1
    intra = {s.cluster.intra_bw for s in setups}
    energy = {s.cluster.energy for s in setups}
    assert len(intra) == 1, "scenarios must share intra_bw (engine scalar)"
    assert len(energy) == 1, "scenarios must share EnergyParams"

    dims = {
        "n_hosts": max(s.cluster.topo.n_hosts for s in setups),
        "n_switches": max(s.cluster.topo.n_switches for s in setups),
        "n_storage": max(s.cluster.topo.n_storage for s in setups),
        "n_links": max(s.cluster.topo.n_links for s in setups),
        "k_max": max(s.route_table.k_max for s in setups),
        "max_hops": max(s.route_table.max_hops for s in setups),
        "n_jobs": max(s.n_jobs for s in setups),
        "n_tasks": max(s.n_tasks for s in setups),
        "n_packets": max(s.n_packets for s in setups),
        "n_vms": max(int(s.cluster.vm_host.shape[0]) for s in setups),
    }
    dims["n_nodes"] = dims["n_hosts"] + dims["n_switches"] + dims["n_storage"]

    packed = [_pack_one(s, dims) for s in setups]
    consts = EngineConsts(**{
        f: jnp.asarray(np.stack([p[f] for p in packed]))
        for f in EngineConsts._fields})
    meta = SimMeta(
        n_nodes=dims["n_nodes"],
        n_links=dims["n_links"],
        n_hosts=dims["n_hosts"],
        n_switches=dims["n_switches"],
        n_vms=dims["n_vms"],
        intra_bw=next(iter(intra)),
        energy=next(iter(energy)),
        max_steps=max(default_max_steps(s) for s in setups),
        has_failures=any(s.failures is not None and s.failures.any_failures
                         for s in setups),
        has_ctrl=any(s.ctrl is not None and s.ctrl.any_ctrl
                     for s in setups),
        ctrl_slots=max((s.ctrl.table_slots for s in setups
                        if s.ctrl is not None and s.ctrl.any_ctrl),
                       default=0),
        has_degradation=any(
            s.degradation is not None and s.degradation.any_degradation
            for s in setups),
        spec_slots=max(int(s.spec_slots) for s in setups),
    )
    return consts, meta


def slice_packed(consts: EngineConsts, si: int) -> EngineConsts:
    """Scenario ``si``'s unbatched ``EngineConsts`` view of a packed batch.

    A plain leading-axis slice: every leaf keeps the PADDED dims, so the
    packed ``SimMeta`` stays valid for the slice and states computed from
    it stack back into the packed ``[S, P, ...]`` grid bit-exactly.  The
    fleet layer (``repro.api.fleet``, DESIGN.md §9) feeds these per-cohort
    consts to its chunk programs instead of vmapping the scenario axis."""
    return jax.tree_util.tree_map(lambda a: a[si], consts)


# ---------------------------------------------------------------------------
# scenario × policy grid
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class SweepResult:
    """Final states of a scenario×policy grid plus labels, replica-major
    ordering ``r = scenario_index * n_policies + policy_index``.  ``consts``
    stays un-replicated ([S] leading dim) — replica r's consts are
    ``consts[r // n_policies]``."""

    states: Any                # SimState, every leaf [S*P, ...]
    consts: EngineConsts       # packed consts, every leaf [S, ...]
    meta: SimMeta
    scenario_names: List[str]  # [S*P]
    policy_names: List[str]    # [S*P]
    n_policies: int

    def rows(self) -> List[Dict[str, Any]]:
        """Per-replica summary: completion/transmission means over VALID jobs,
        energy, makespan, stall flag."""
        P = self.n_policies
        S = len(self.scenario_names) // P
        grid = jax.tree_util.tree_map(
            lambda a: a.reshape((S, P) + a.shape[1:]), self.states)
        rep = jax.vmap(lambda c, ss: jax.vmap(
            lambda s: job_report_consts(c, s))(ss))(self.consts, grid)
        en = jax.vmap(jax.vmap(energy_report))(grid)
        valid = np.asarray(self.consts.job_valid)  # [S, N_J]
        out = []
        def finite_mean(a):
            # stalled replicas leave NaN for every valid job; return NaN
            # without numpy's empty-slice warning
            a = a[np.isfinite(a)]
            return float(a.mean()) if a.size else float("nan")

        for r in range(len(self.scenario_names)):
            si, pi = divmod(r, P)
            v = valid[si]
            out.append({
                "scenario": self.scenario_names[r],
                "policy": self.policy_names[r],
                "mean_completion_s": finite_mean(
                    np.asarray(rep["completion_measured"][si, pi])[v]),
                "mean_transmission_s": finite_mean(
                    np.asarray(rep["transmission_time"][si, pi])[v]),
                "energy_kwh": float(en["total_energy_j"][si, pi]) / 3.6e6,
                "makespan_s": float(en["makespan_s"][si, pi]),
                "stalled": bool(self.states.stalled[r]),
            })
        return out


def policy_arrays(policies: Sequence[Any]) -> Dict[str, np.ndarray]:
    """Registry-ordered [P]-shaped arrays from a list of PolicyConfig
    (or partial mappings — registered defaults fill the gaps)."""
    stacked = [as_policy_arrays(p) for p in policies]
    return {name: np.stack([np.asarray(s[name]) for s in stacked])
            for name in policy_field_names()}


def sweep_grid(scenarios: Sequence[Tuple[str, SimSetup]],
               policies: Sequence[Tuple[str, Any]]) -> SweepResult:
    """Deprecated shim over ``repro.api.Experiment``: run every (scenario,
    policy) combination as one vmapped batch and adapt the result to the
    flat replica-major ``SweepResult`` shape.

    The Experiment path keeps the nested-vmap structure — scenarios outer,
    policies inner — so the dense consts tensors (routes is [n_nodes², K, H]
    per scenario) broadcast across the policy axis instead of being
    materialized P times."""
    from ..api import Experiment
    res = Experiment(scenarios=list(scenarios),
                     policies=list(policies)).run()
    S, P = res.n_scenarios, res.n_policies
    states = jax.tree_util.tree_map(
        lambda a: a.reshape((S * P,) + a.shape[2:]), res.states)
    # label from the caller's own name lists, not res.*_names — Experiment
    # de-duplicates repeated names (#n suffix) but this shim must preserve
    # the exact labels it was handed.
    scenario_names = [n for n, _ in scenarios]
    policy_names = [pn for pn, _ in policies]
    return SweepResult(
        states=states, consts=res.consts, meta=res.meta,
        scenario_names=[n for n in scenario_names for _ in range(P)],
        policy_names=[pn for _ in scenario_names for pn in policy_names],
        n_policies=P,
    )
