from .advisor import Advice, advise_allreduce, analytic_time
from .hlo import CollectiveStats, collective_stats, duplicate_fusion_count
from .hw import V5E, HwSpec
from .terms import (RooflineReport, analyze, analyze_raw,
                    count_active_params, count_params, model_flops,
                    peak_memory, raw_counts)

__all__ = ["Advice", "advise_allreduce", "analytic_time",
           "CollectiveStats", "collective_stats", "duplicate_fusion_count",
           "V5E", "HwSpec", "RooflineReport", "analyze", "analyze_raw",
           "raw_counts", "peak_memory",
           "count_active_params", "count_params", "model_flops"]
