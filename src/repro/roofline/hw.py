"""Target-hardware constants (TPU v5e-class, per assignment)."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class HwSpec:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12      # FLOP/s per chip
    hbm_bw: float = 819e9                # bytes/s per chip
    ici_link_bw: float = 50e9            # bytes/s per link (one direction)
    ici_links: int = 4                   # 2D torus: +-x, +-y
    hbm_bytes: float = 16e9              # capacity per chip


V5E = HwSpec()
