"""The "SDN controller for the pod": the paper's DES applied to TPU
collective scheduling (DESIGN.md §3).

The pod ICI fabric is a 2-D torus; candidate collective schedules are
rendered as round-structured flow sets (core.flows) and ranked by
simulated completion time under the paper's fair-share channel model —
exactly the SDN controller's what-if role, with the pod as the data
center.  Analytic ring formulas are provided for large meshes (the DES
cross-validates them on small tori in tests).
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence, Tuple


from repro.core import PolicyConfig, simulate
from repro.core.flows import Flow, flows_setup
from repro.core.topology import Topology, torus_2d
from .hw import HwSpec, V5E

GBIT = 1e9


# ---------------------------------------------------------------------------
# schedule renderers: bytes -> rounds of neighbor flows on a torus
# ---------------------------------------------------------------------------


def ring_allreduce_flows(n: int, gbits: float, *, ring: Sequence[int],
                         bidirectional: bool = False) -> List[Flow]:
    """Ring all-reduce of `gbits` per chip over `ring` (node-id order).

    2(n-1) rounds of gbits/n neighbor transfers; bidirectional splits the
    payload across both ring directions (halving rounds' volume)."""
    flows: List[Flow] = []
    chunk = gbits / n
    dirs = ((1, chunk / 2), (-1, chunk / 2)) if bidirectional \
        else ((1, chunk),)
    for r in range(2 * (n - 1)):
        for i in range(n):
            for d, c in dirs:
                flows.append(Flow(ring[i], ring[(i + d) % n], c, round=r))
    return flows


def torus_2d_allreduce_flows(nx: int, ny: int, gbits: float
                             ) -> List[Flow]:
    """Dimension-ordered: reduce-scatter+all-gather over x rings, then y.

    Phase 1 (x): each of the ny x-rings moves gbits/ny... actually each
    x-ring all-reduces the full payload, then y-rings all-reduce the
    x-reduced shards: standard 2D algorithm moves gbits*(nx-1)/nx over x
    links and gbits*(ny-1)/(nx*ny) over y links per chip."""
    flows: List[Flow] = []
    idx = lambda x, y: x * ny + y
    rbase = 0
    # x-phase: all-reduce along each x ring (payload gbits)
    for r in range(2 * (nx - 1)):
        for y in range(ny):
            for x in range(nx):
                flows.append(Flow(idx(x, y), idx((x + 1) % nx, y),
                                  gbits / nx, round=rbase + r))
    rbase += 2 * (nx - 1)
    # y-phase: all-reduce along each y ring (payload gbits/nx)
    for r in range(2 * (ny - 1)):
        for x in range(nx):
            for y in range(ny):
                flows.append(Flow(idx(x, y), idx(x, (y + 1) % ny),
                                  gbits / (nx * ny), round=rbase + r))
    return flows


# ---------------------------------------------------------------------------
# predictions
# ---------------------------------------------------------------------------


def analytic_time(schedule: str, n_chips: int, bytes_per_chip: float,
                  hw: HwSpec = V5E, mesh_shape: Tuple[int, int] = None
                  ) -> float:
    b = bytes_per_chip
    if schedule == "ring":
        return 2 * (n_chips - 1) / n_chips * b / hw.ici_link_bw
    if schedule == "ring-bidir":
        return (n_chips - 1) / n_chips * b / hw.ici_link_bw
    if schedule == "torus2d":
        nx, ny = mesh_shape
        tx = 2 * (nx - 1) / nx * b / hw.ici_link_bw
        ty = 2 * (ny - 1) / (nx * ny) * b / hw.ici_link_bw
        return tx + ty
    raise ValueError(schedule)


def simulate_schedule(flows: List[Flow], topo: Topology, *,
                      link_gbps: float) -> float:
    """DES completion time (seconds) of a rendered schedule."""
    setup = flows_setup(topo, flows)
    state = simulate(setup, PolicyConfig())
    return float(state.time)


@dataclasses.dataclass
class Advice:
    schedule: str
    predicted_s: float
    source: str   # "des" | "analytic"


def advise_allreduce(bytes_per_chip: float, mesh_shape: Tuple[int, int],
                     hw: HwSpec = V5E, *, des_max_chips: int = 64
                     ) -> List[Advice]:
    """Rank candidate all-reduce schedules for one pod."""
    nx, ny = mesh_shape
    n = nx * ny
    gbits = bytes_per_chip * 8 / GBIT
    out: List[Advice] = []
    if n <= des_max_chips:
        topo = torus_2d(nx, ny, bw=hw.ici_link_bw * 8)
        ring = [x * ny + (y if x % 2 == 0 else ny - 1 - y)
                for x in range(nx) for y in range(ny)]  # boustrophedon
        for name, fl in [
            ("ring", ring_allreduce_flows(n, gbits, ring=ring)),
            ("ring-bidir", ring_allreduce_flows(n, gbits, ring=ring,
                                                bidirectional=True)),
            ("torus2d", torus_2d_allreduce_flows(nx, ny, gbits)),
        ]:
            out.append(Advice(name, simulate_schedule(
                fl, topo, link_gbps=hw.ici_link_bw * 8 / GBIT), "des"))
    else:
        for name in ("ring", "ring-bidir", "torus2d"):
            out.append(Advice(name, analytic_time(
                name, n, bytes_per_chip, hw, mesh_shape), "analytic"))
    return sorted(out, key=lambda a: a.predicted_s)
