"""HLO-text analysis: collective-op inventory and wire-byte accounting.

``cost_analysis()`` has no collective numbers, so we parse the
post-partitioning HLO (``compiled.as_text()``): every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
op contributes its ring-algorithm wire bytes per participating chip:

  all-reduce      2·S·(n-1)/n     (reduce-scatter + all-gather)
  all-gather        S·(n-1)/n     (S = full output size)
  reduce-scatter    S·(n-1)/n     (S = full input size)
  all-to-all        S·(n-1)/n
  collective-permute  S           (point-to-point)

The compiled module is the per-device SPMD program, so shapes are already
per-device; group size n comes from replica_groups (v1 list or v2 iota
form).
"""
from __future__ import annotations

import dataclasses
import re
from typing import Dict, List

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1,
    "f8e5m2": 1, "f8e4m3": 1, "f8e3m4": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

_SHAPE_RE = re.compile(r"\b([a-z0-9]+)\[([0-9,]*)\]")
_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")
_OP_RE = re.compile(
    r"=\s*(\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(")
_GROUPS_V1_RE = re.compile(r"replica_groups=\{\{([0-9, ]*)\}")
_GROUPS_V2_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_SRCDST_RE = re.compile(r"source_target_pairs=")


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str, default: int) -> int:
    m = _GROUPS_V2_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_V1_RE.search(line)
    if m:
        ids = [x for x in m.group(1).split(",") if x.strip()]
        return max(1, len(ids))
    return default


@dataclasses.dataclass
class CollectiveStats:
    counts: Dict[str, int]
    bytes_moved: Dict[str, float]    # output-size bytes per op kind
    wire_bytes: float                # ring-algorithm wire bytes per chip
    ops: List[dict]

    @property
    def total_count(self) -> int:
        return sum(self.counts.values())


def collective_stats(hlo_text: str, *, num_partitions: int = 1
                     ) -> CollectiveStats:
    counts: Dict[str, int] = {}
    moved: Dict[str, float] = {}
    wire = 0.0
    ops: List[dict] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m:
            continue
        shape_txt, kind, phase = m.group(1), m.group(2), m.group(3)
        if phase == "-done":
            continue  # counted at -start
        size = _shape_bytes(shape_txt)
        n = _group_size(line, num_partitions)
        frac = (n - 1) / n if n > 1 else 0.0
        if kind == "all-reduce":
            w = 2.0 * size * frac
        elif kind == "collective-permute":
            w = float(size)
        elif kind == "reduce-scatter":
            # HLO reports the (scattered) OUTPUT shape; input = out * n
            w = size * n * frac
        else:  # all-gather / all-to-all: output size counts
            w = size * frac
        counts[kind] = counts.get(kind, 0) + 1
        moved[kind] = moved.get(kind, 0.0) + size
        wire += w
        ops.append({"kind": kind, "bytes": size, "group": n,
                    "wire_bytes": w})
    return CollectiveStats(counts=counts, bytes_moved=moved,
                           wire_bytes=wire, ops=ops)


def duplicate_fusion_count(hlo_text: str) -> Dict[str, int]:
    """Rough remat indicator: repeated identical fusion shapes."""
    sig: Dict[str, int] = {}
    for line in hlo_text.splitlines():
        if " fusion(" in line:
            m = _SHAPE_RE.search(line)
            if m:
                key = m.group(0)
                sig[key] = sig.get(key, 0) + 1
    return {k: v for k, v in sig.items() if v > 1}
