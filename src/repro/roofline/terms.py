"""Three-term roofline from a compiled dry-run artifact.

  compute_s    = HLO_FLOPs_per_chip / peak_FLOP/s
  memory_s     = HLO_bytes_per_chip / HBM_bw
  collective_s = wire_bytes_per_chip / link_bw

``compiled.cost_analysis()`` reports the per-device SPMD module, so flops
and bytes are already per chip.  MODEL_FLOPS uses 6·N·D (train) or 2·N·D
(inference) with N = active params, D = tokens — the ratio
MODEL_FLOPS/HLO_FLOPs exposes remat/dispatch overhead (>1/3 expected with
full remat since backward recompute ≈ one extra forward).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import numpy as np

from .hlo import collective_stats
from .hw import HwSpec, V5E


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    wire_bytes_per_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    model_flops_global: float
    peak_bytes_per_chip: float
    collectives: Dict[str, int]

    @property
    def dominant(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_time_s(self) -> float:
        """No-overlap upper bound; roofline bound = max(terms)."""
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / (HLO_FLOPs x chips)."""
        total = self.flops_per_chip * self.chips
        return self.model_flops_global / total if total else float("nan")

    @property
    def mfu(self) -> float:
        """Model-FLOPs utilization at the roofline bound."""
        denom = self.step_time_s * self.chips
        if not denom:
            return float("nan")
        return self.model_flops_global / (denom * V5E.peak_flops_bf16)

    def row(self) -> Dict[str, Any]:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "compute_s": self.compute_s, "memory_s": self.memory_s,
            "collective_s": self.collective_s, "dominant": self.dominant,
            "model_flops": self.model_flops_global,
            "useful_ratio": self.useful_flops_ratio,
            "mfu_bound": self.mfu,
            "hbm_gib_per_chip": self.peak_bytes_per_chip / 2**30,
            "collectives": self.collectives,
        }


def _get(d: Dict[str, Any], *names: str) -> float:
    for n in names:
        if n in d and d[n]:
            return float(d[n])
    return 0.0


def raw_counts(compiled, *, chips: int,
               hlo_text: Optional[str] = None) -> Dict[str, Any]:
    """(flops, bytes, wire_bytes, collective counts) of one executable.

    NOTE: XLA cost analysis counts while-loop (lax.scan) bodies ONCE —
    depth-extrapolation in the dry-run corrects this (launch.dryrun)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, list):  # older jax returns [dict]
        ca = ca[0]
    text = hlo_text if hlo_text is not None else compiled.as_text()
    stats = collective_stats(text, num_partitions=chips)
    return {
        "flops": _get(ca, "flops"),
        "bytes": _get(ca, "bytes accessed", "bytes_accessed"),
        "wire_bytes": stats.wire_bytes,
        "counts": stats.counts,
    }


def analyze_raw(*, flops: float, byts: float, wire: float,
                counts: Dict[str, int], arch: str, shape: str,
                mesh_name: str, chips: int, model_flops: float,
                peak_bytes: float = float("nan"),
                hw: HwSpec = V5E) -> RooflineReport:
    return RooflineReport(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        wire_bytes_per_chip=wire,
        compute_s=flops / hw.peak_flops_bf16,
        memory_s=byts / hw.hbm_bw,
        collective_s=wire / hw.ici_link_bw,
        model_flops_global=model_flops,
        peak_bytes_per_chip=peak_bytes,
        collectives=counts,
    )


def peak_memory(compiled) -> float:
    try:
        mem = compiled.memory_analysis()
        return float(getattr(mem, "temp_size_in_bytes", 0)
                     + getattr(mem, "argument_size_in_bytes", 0)
                     + getattr(mem, "output_size_in_bytes", 0)
                     - getattr(mem, "alias_size_in_bytes", 0))
    except Exception:
        return float("nan")


def analyze(compiled, *, arch: str, shape: str, mesh_name: str, chips: int,
            model_flops: float, hw: HwSpec = V5E,
            hlo_text: Optional[str] = None) -> RooflineReport:
    rc = raw_counts(compiled, chips=chips, hlo_text=hlo_text)
    return analyze_raw(flops=rc["flops"], byts=rc["bytes"],
                       wire=rc["wire_bytes"], counts=rc["counts"],
                       arch=arch, shape=shape, mesh_name=mesh_name,
                       chips=chips, model_flops=model_flops,
                       peak_bytes=peak_memory(compiled), hw=hw)


def model_flops(cfg, n_params_active: float, tokens: int,
                train: bool) -> float:
    return (6.0 if train else 2.0) * n_params_active * tokens


def model_flops_cell(cfg, shape, n_params_active: float) -> float:
    """Useful FLOPs of one step: weight matmuls (6ND/2ND) + attention
    context term (4·H·Dh·S_kv per token per attention layer, x3 for the
    backward pass) — the latter dominates the 32k cells."""
    train = shape.kind == "train"
    b, s = shape.global_batch, shape.seq_len
    tokens = b * (s if shape.kind in ("train", "prefill") else 1)
    total = (6.0 if train else 2.0) * n_params_active * tokens

    if cfg.family == "ssm":
        n_attn = 0
    elif cfg.family == "hybrid":
        n_attn = cfg.n_layers // cfg.attn_every
    else:
        n_attn = cfg.n_layers
    hdh = cfg.n_heads * cfg.d_head
    mult = 3.0 if train else 1.0
    if shape.kind in ("train", "prefill"):
        s_kv = s / 2.0  # causal average
    else:
        s_kv = float(s)  # decode: full context per new token
    total += mult * n_attn * 4.0 * hdh * s_kv * tokens
    if cfg.family == "audio":
        enc_tokens = b * cfg.enc_seq
        total += mult * (cfg.n_enc_layers or cfg.n_layers) * 4.0 * hdh \
            * cfg.enc_seq * enc_tokens          # encoder self (bidir)
        total += mult * cfg.n_layers * 4.0 * hdh * cfg.enc_seq * tokens
    return total


def count_params(params: Any) -> int:
    return sum(int(np.prod(l.shape))
               for l in jax.tree_util.tree_leaves(params))


def count_active_params(params: Any, cfg) -> float:
    """Total minus the non-routed fraction of expert banks."""
    total = count_params(params)
    if not getattr(cfg, "is_moe_arch", False) or cfg.n_experts == 0:
        return float(total)
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(params)[0]:
        names = [str(getattr(k, "key", getattr(k, "idx", k)))
                 for k in path]
        if "moe" in names and names[-1] in ("wi", "wg", "wo"):
            expert += int(np.prod(leaf.shape))
    return float(total - expert * (1.0 - cfg.top_k / cfg.n_experts))
