"""AST lint pass: engine-hygiene rules over the source tree
(DESIGN.md §12), stdlib ``ast`` only — no new dependencies.

Scope is deliberate: *engine* rules (tracer-unsafe builtins, 64-bit
literals, frozen-struct mutation) run over ``src/repro/{core,api}``,
benchmark rules (naked timers) over ``benchmarks/``, and determinism
rules (RNG hygiene) over everything scanned.  Every rule id lives in
``repro.analysis.rules`` and is documented in DESIGN.md §12.

A finding on a line carrying ``# jaxcheck: disable=<rule>[,<rule>...]``
is suppressed — that comment doubles as the in-tree justification for
an intentional exception, the AST analogue of a PRIM_BUDGET allowlist
entry.
"""
from __future__ import annotations

import ast
import re
from pathlib import Path
from typing import Dict, List, Sequence, Set

from .rules import AST_RULES, Finding

ENGINE_PREFIXES = ("src/repro/core/", "src/repro/api/")
SCAN_PREFIXES = ENGINE_PREFIXES + ("src/repro/scenarios/", "benchmarks/")
TIMER_PREFIXES = ("benchmarks/",)

# names whose attributes are traced values inside the step kernel by
# repo convention: s/sc = SimState (+ step carry), pol/aux/cache = the
# traced policy/auxiliary/endpoint-cache dicts
TRACED_ATTR_ROOTS = {"s", "sc"}
TRACED_SUBSCRIPT_ROOTS = {"pol", "aux", "cache"}

# frozen structures: attribute assignment on these object names is a
# mutation of EngineConsts / SimMeta outside a constructor
FROZEN_ROOTS = {"meta", "consts"}

SAFE_NP_RANDOM = {"default_rng", "RandomState", "Generator", "SeedSequence",
                  "PCG64", "Philox", "BitGenerator"}

TIMER_ATTRS = {"time", "perf_counter", "monotonic", "process_time"}
SYNC_ATTRS = {"block_until_ready", "device_get"}

DTYPE64 = {"float64", "int64", "uint64", "complex128"}

_DISABLE_RE = re.compile(r"#\s*jaxcheck:\s*disable=([a-z0-9,\-]+)")


def _suppressions(text: str) -> Dict[int, Set[str]]:
    out: Dict[int, Set[str]] = {}
    for i, line in enumerate(text.splitlines(), 1):
        m = _DISABLE_RE.search(line)
        if m:
            out[i] = set(m.group(1).split(","))
    return out


def _name_of(node) -> str:
    return node.id if isinstance(node, ast.Name) else ""


def _attr_chain(node) -> str:
    """Dotted name for Name/Attribute chains ('np.random.rand'), '' if the
    chain roots in something else (a call, a subscript, ...)."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return ""


class _Linter(ast.NodeVisitor):
    def __init__(self, relpath: str):
        self.relpath = relpath
        self.engine = relpath.startswith(ENGINE_PREFIXES)
        self.timers = relpath.startswith(TIMER_PREFIXES)
        self.meta_rule = (relpath.startswith(("src/repro/",))
                         and not relpath.endswith("simmeta.py"))
        self.func_stack: List[str] = []
        self.findings: List[Finding] = []

    # -- plumbing ----------------------------------------------------------

    def _scope(self) -> str:
        return self.func_stack[-1] if self.func_stack else "<module>"

    def _add(self, rule: str, node, message: str) -> None:
        self.findings.append(Finding(
            rule=rule,
            where=f"{self.relpath}:{node.lineno}",
            message=message,
            key=f"{rule}:{self.relpath}:{self._scope()}"))

    # -- function scope (naked-timer + frozen-mutation constructor rule) --

    def _visit_func(self, node) -> None:
        self.func_stack.append(node.name)
        if self.timers:
            self._check_naked_timer(node)
        self.generic_visit(node)
        self.func_stack.pop()

    visit_FunctionDef = _visit_func
    visit_AsyncFunctionDef = _visit_func

    def _check_naked_timer(self, fn) -> None:
        """jaxcheck:naked-timer — a function bracketing work with two or
        more timer reads but never forcing a device sync measures jax's
        async dispatch, not the computation."""
        n_timers, synced = 0, False
        for sub in ast.walk(fn):
            if isinstance(sub, ast.Call):
                chain = _attr_chain(sub.func)
                if chain.startswith("time.") and \
                        chain.split(".", 1)[1] in TIMER_ATTRS:
                    n_timers += 1
                if isinstance(sub.func, ast.Attribute) and \
                        sub.func.attr in SYNC_ATTRS:
                    synced = True
        if n_timers >= 2 and not synced:
            self.func_stack.append(fn.name)   # key under the fn itself
            self._add("naked-timer", fn,
                      f"{fn.name}() reads a timer {n_timers}x but never "
                      "calls block_until_ready/device_get")
            self.func_stack.pop()

    # -- calls: tracer casts, .item(), np.random, 64-bit dtype sinks ------

    def visit_Call(self, node: ast.Call) -> None:
        fname = _name_of(node.func)
        if self.engine and fname in {"float", "int", "bool"} and node.args:
            if self._touches_traced(node.args[0]):
                self._add("tracer-cast", node,
                          f"{fname}() on a likely-traced value — a "
                          "TracerError under jit; use jnp casts")
        if self.engine and isinstance(node.func, ast.Attribute) \
                and node.func.attr == "item":
            self._add("item-call", node,
                      ".item() forces a device sync and breaks under jit")
        chain = _attr_chain(node.func)
        if chain.startswith(("np.random.", "numpy.random.")):
            leaf = chain.rsplit(".", 1)[1]
            if leaf not in SAFE_NP_RANDOM:
                self._add("unseeded-random", node,
                          f"{chain}() uses the process-global legacy RNG")
        self.generic_visit(node)

    def _touches_traced(self, node) -> bool:
        for sub in ast.walk(node):
            if isinstance(sub, ast.Attribute) and \
                    _name_of(sub.value) in TRACED_ATTR_ROOTS:
                return True
            if isinstance(sub, ast.Subscript) and \
                    _name_of(sub.value) in TRACED_SUBSCRIPT_ROOTS:
                return True
        return False

    # -- imports: the stdlib random module --------------------------------

    def visit_Import(self, node: ast.Import) -> None:
        for alias in node.names:
            if alias.name == "random" or alias.name.startswith("random."):
                self._add("random-module", node,
                          "stdlib random is unseeded and process-global")
        self.generic_visit(node)

    def visit_ImportFrom(self, node: ast.ImportFrom) -> None:
        if node.module == "random":
            self._add("random-module", node,
                      "stdlib random is unseeded and process-global")
        self.generic_visit(node)

    # -- subscripts: legacy meta["..."] access ----------------------------

    def visit_Subscript(self, node: ast.Subscript) -> None:
        if self.meta_rule and _name_of(node.value) == "meta":
            sl = node.slice
            if isinstance(sl, ast.Constant) and isinstance(sl.value, str):
                self._add("meta-subscript", node,
                          f'meta[{sl.value!r}] — use meta.{sl.value} on '
                          "the frozen SimMeta")
        self.generic_visit(node)

    # -- assignments: frozen-struct mutation ------------------------------

    def _check_frozen(self, target) -> None:
        if isinstance(target, ast.Attribute) and \
                _name_of(target.value) in FROZEN_ROOTS and \
                self._scope() not in ("__init__", "__post_init__"):
            self._add("frozen-mutation", target,
                      f"assignment to {_name_of(target.value)}."
                      f"{target.attr} — EngineConsts/SimMeta are frozen; "
                      "use _replace()/dataclasses.replace()")

    def visit_Assign(self, node: ast.Assign) -> None:
        if self.relpath.startswith(("src/repro/",)):
            for t in node.targets:
                self._check_frozen(t)
        self.generic_visit(node)

    def visit_AugAssign(self, node: ast.AugAssign) -> None:
        if self.relpath.startswith(("src/repro/",)):
            self._check_frozen(node.target)
        self.generic_visit(node)

    # -- 64-bit jnp literals ----------------------------------------------

    def visit_Attribute(self, node: ast.Attribute) -> None:
        if self.engine and node.attr in DTYPE64:
            chain = _attr_chain(node)
            if chain.startswith(("jnp.", "jax.numpy.")):
                self._add("f64-literal", node,
                          f"{chain} in engine code — the engine is f32 "
                          "end-to-end (np 64-bit on the host is fine)")
        self.generic_visit(node)


def lint_source(text: str, relpath: str) -> List[Finding]:
    """Lint one file's source.  ``relpath`` (posix, repo-relative) decides
    which rule scopes apply."""
    try:
        tree = ast.parse(text)
    except SyntaxError as e:
        return [Finding(rule="tracer-cast", severity="error",
                        where=f"{relpath}:{e.lineno or 0}",
                        message=f"unparsable: {e.msg}",
                        key=f"parse:{relpath}")]
    linter = _Linter(relpath)
    linter.visit(tree)
    suppressed = _suppressions(text)
    out = []
    for f in linter.findings:
        line = int(f.where.rsplit(":", 1)[1])
        if f.rule in suppressed.get(line, ()):
            continue
        out.append(f)
    return out


def lint_tree(root, prefixes: Sequence[str] = SCAN_PREFIXES) -> List[Finding]:
    """Lint every .py file under the scanned prefixes of ``root``."""
    root = Path(root)
    findings: List[Finding] = []
    for prefix in prefixes:
        base = root / prefix
        if not base.exists():
            continue
        for py in sorted(base.rglob("*.py")):
            rel = py.relative_to(root).as_posix()
            findings += lint_source(py.read_text(), rel)
    return findings


assert set(AST_RULES) >= {"tracer-cast", "item-call", "unseeded-random",
                          "random-module", "naked-timer", "meta-subscript",
                          "frozen-mutation", "f64-literal"}
