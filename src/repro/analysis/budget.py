"""The committed primitive-budget ledger (experiments/PRIM_BUDGET.json)
and its diff gate (DESIGN.md §12).

The ledger pins, per traced program, the watched-primitive counts inside
the engine loop body plus the loop-carry signature.  CI re-derives the
counts from the current tree and diffs them against the committed file:

* a watched primitive whose count INCREASED fails — "a sort crept back
  into the xl loop" is exactly this diff, with the offending eqn's
  source location printed by the paired jaxpr findings;
* ``cond`` is the one inversion: a DECREASE fails, because losing a
  ``lax.cond`` means an unbatched fast path collapsed into a
  both-branches ``select_n`` (jaxcheck:batched-cond);
* a changed carry signature (leaves/bytes/digest) fails — the compiled
  while-loop state changed shape, which is never an accident;
* entries under ``allowlist`` are waived with a recorded reason — the
  reviewed way to land an intentional budget change without refreshing
  the whole file.  Keys are ``<program>:<prim>`` (or ``<program>:carry``)
  and contain no line numbers, so they survive unrelated edits.

The ledger records the ``jax`` version that produced it.  When the
running version differs (CI installs jax unpinned), count and carry
mismatches demote to warnings: primitive lowering legitimately shifts
across jax releases, and a version bump should prompt a reviewed
``--update-baseline``, not a red X on an unrelated PR.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Dict, List, Optional, Tuple

import jax

from .checkers import WATCHED
from .rules import Finding

LEDGER_VERSION = 1


def build_ledger(programs: Dict[str, dict],
                 allowlist: Optional[Dict[str, str]] = None) -> dict:
    return {
        "version": LEDGER_VERSION,
        "jax": jax.__version__,
        "watched": list(WATCHED),
        "allowlist": dict(allowlist or {}),
        "programs": {k: programs[k] for k in sorted(programs)},
    }


def load_ledger(path) -> Optional[dict]:
    p = Path(path)
    if not p.exists():
        return None
    with open(p) as f:
        return json.load(f)


def save_ledger(ledger: dict, path) -> None:
    p = Path(path)
    p.parent.mkdir(parents=True, exist_ok=True)
    with open(p, "w") as f:
        json.dump(ledger, f, indent=1, sort_keys=True)
        f.write("\n")


def refresh_ledger(programs: Dict[str, dict],
                   old: Optional[dict]) -> dict:
    """--update-baseline: new counts, but the reviewed allowlist (and its
    reasons) carries over."""
    allow = dict(old.get("allowlist", {})) if old else {}
    return build_ledger(programs, allow)


def _diff_program(key: str, cur: dict, base: dict,
                  allow: Dict[str, str], demote: bool) -> List[Finding]:
    out: List[Finding] = []
    sev = "warning" if demote else "error"

    def finding(rule: str, akey: str, message: str) -> Optional[Finding]:
        if akey in allow:
            return None
        return Finding(rule=rule, where=key, message=message, key=akey,
                       severity=sev)

    cur_loop, base_loop = cur.get("loop", {}), base.get("loop", {})
    for prim in WATCHED:
        c, b = int(cur_loop.get(prim, 0)), int(base_loop.get(prim, 0))
        if prim == "cond":
            if c < b:
                f = finding("batched-cond", f"{key}:cond",
                            f"cond count fell {b} -> {c}: a fast-path "
                            "lax.cond was batched away")
                if f:
                    out.append(f)
        elif c > b:
            rule = ("sort-in-loop" if prim == "sort"
                    else "scatter-in-loop" if prim.startswith("scatter")
                    else "dtype-drift" if prim == "convert_element_type"
                    else "batched-cond" if prim == "select_n"
                    else "carry-stability")
            f = finding(rule, f"{key}:{prim}",
                        f"{prim} count grew {b} -> {c} in the engine "
                        "loop body (budget: experiments/PRIM_BUDGET.json)")
            if f:
                out.append(f)
    cur_carry, base_carry = cur.get("carry"), base.get("carry")
    if cur_carry != base_carry:
        f = finding("carry-stability", f"{key}:carry",
                    f"loop carry signature changed: {base_carry} -> "
                    f"{cur_carry}")
        if f:
            out.append(f)
    return out


def diff_ledger(programs: Dict[str, dict], baseline: dict,
                full_sweep: bool = True) -> Tuple[List[Finding], List[str]]:
    """Diff freshly derived budget rows against the committed baseline.

    Returns ``(findings, notes)``.  ``full_sweep=False`` (a --quick or
    filtered run) skips the missing/extra-program checks — a subset sweep
    legitimately derives fewer rows than the committed file holds.
    """
    findings: List[Finding] = []
    notes: List[str] = []
    allow = baseline.get("allowlist", {})
    demote = baseline.get("jax") != jax.__version__
    if demote:
        notes.append(
            f"baseline jax {baseline.get('jax')} != running jax "
            f"{jax.__version__}: budget mismatches demoted to warnings — "
            "refresh with --update-baseline")
    base_programs = baseline.get("programs", {})
    for key, cur in programs.items():
        base = base_programs.get(key)
        if base is None:
            if full_sweep and f"{key}:new" not in allow:
                # a brand-new program (new scenario / policy choice) is an
                # error even under a jax-version demotion: the committed
                # ledger must cover the whole registry.
                findings.append(Finding(
                    rule="carry-stability", where=key, severity="error",
                    message="program not in the committed budget — run "
                            "tools/jaxcheck.py --update-baseline",
                    key=f"{key}:new"))
            continue
        findings += _diff_program(key, cur, base, allow, demote)
    if full_sweep:
        for key in base_programs:
            if key not in programs and f"{key}:gone" not in allow:
                findings.append(Finding(
                    rule="carry-stability", where=key, severity="error",
                    message="program in the committed budget but not in "
                            "the sweep (scenario or signature removed?) — "
                            "run tools/jaxcheck.py --update-baseline",
                    key=f"{key}:gone"))
    return findings, notes
