"""Recursive jaxpr walking + engine-loop extraction (DESIGN.md §12).

The checkers in ``repro.analysis.checkers`` never pattern-match source
code; they inspect the TRACED program.  This module is the substrate: a
depth-first walk over a (closed) jaxpr that descends into every
sub-jaxpr an equation carries in its params — ``while`` (cond/body),
``cond`` (branches), ``scan``, ``pjit``, ``custom_jvp_call``, remat —
without hard-coding the param names, plus extraction of *the engine
while loop* (the eqn with the widest carry; the simulator is one
``lax.while_loop`` whose carry is the full ``SimState`` + caches, so
nested ``fori_loop`` lowerings never win the tie).
"""
from __future__ import annotations

import dataclasses
import hashlib
from collections import Counter
from typing import Iterator, List, Optional, Sequence, Tuple

from jax.extend import core as jex_core

Jaxpr = jex_core.Jaxpr
ClosedJaxpr = jex_core.ClosedJaxpr


def sub_jaxprs(eqn) -> List[Jaxpr]:
    """Every Jaxpr reachable from ``eqn.params``, unwrapped from
    ClosedJaxpr / tuple / list containers (while, cond, scan, pjit,
    custom_jvp, ... all store their sub-programs there)."""
    out: List[Jaxpr] = []

    def rec(v):
        if isinstance(v, ClosedJaxpr):
            out.append(v.jaxpr)
        elif isinstance(v, Jaxpr):
            out.append(v)
        elif isinstance(v, (tuple, list)):
            for x in v:
                rec(x)

    for val in eqn.params.values():
        rec(val)
    return out


def walk(jaxpr: Jaxpr, path: Tuple[str, ...] = ()) -> Iterator[tuple]:
    """Depth-first ``(eqn, path)`` over ``jaxpr`` and every sub-jaxpr.
    ``path`` elements are ``"<eqn-index>:<primitive>"`` segments, so a
    finding can say *where inside the program* it sits."""
    for i, eqn in enumerate(jaxpr.eqns):
        p = path + (f"{i}:{eqn.primitive.name}",)
        yield eqn, p
        for sub in sub_jaxprs(eqn):
            yield from walk(sub, p)


def source_of(eqn) -> str:
    """``file:line (fn)`` for an equation, best-effort."""
    try:
        from jax._src import source_info_util
        return source_info_util.summarize(eqn.source_info)
    except Exception:
        return "<unknown>"


def prim_counter(jaxpr: Jaxpr) -> Counter:
    """Primitive-name counts over ``jaxpr`` including all sub-jaxprs."""
    c: Counter = Counter()
    for eqn, _ in walk(jaxpr):
        c[eqn.primitive.name] += 1
    return c


@dataclasses.dataclass
class LoopInfo:
    """One extracted ``while`` eqn: its body/cond jaxprs and carry avals
    (the body invars past the loop's hoisted consts)."""
    eqn: object
    path: Tuple[str, ...]
    body: Jaxpr
    cond: Jaxpr
    carry_avals: Sequence[object]

    @property
    def carry_leaves(self) -> int:
        return len(self.carry_avals)


def _loop_info(eqn, path) -> LoopInfo:
    body = eqn.params["body_jaxpr"].jaxpr
    cond = eqn.params["cond_jaxpr"].jaxpr
    nconsts = eqn.params["body_nconsts"]
    carry = [v.aval for v in body.invars[nconsts:]]
    return LoopInfo(eqn=eqn, path=path, body=body, cond=cond,
                    carry_avals=carry)


def while_loops(jaxpr: Jaxpr) -> List[LoopInfo]:
    return [_loop_info(eqn, path) for eqn, path in walk(jaxpr)
            if eqn.primitive.name == "while"]


def engine_loop(closed) -> Optional[LoopInfo]:
    """The engine event loop of a traced program: the ``while`` eqn with
    the widest carry (the full SimState + endpoint cache + done flag —
    every nested ``fori_loop`` carries a handful of leaves at most).
    ``None`` when the program has no while loop (e.g. the streaming
    refill, which is a pure masked rewrite)."""
    jaxpr = closed.jaxpr if isinstance(closed, ClosedJaxpr) else closed
    loops = while_loops(jaxpr)
    if not loops:
        return None
    return max(loops, key=lambda li: li.carry_leaves)


def aval_sig(aval) -> Tuple[Tuple[int, ...], str]:
    return tuple(getattr(aval, "shape", ())), str(getattr(aval, "dtype", "?"))


def carry_signature(avals: Sequence[object]) -> Tuple[int, int, str]:
    """``(leaves, bytes, sha1-12)`` of a carry's structure — the ledger
    entry that makes silent carry growth (an extra leaf, a widened dtype)
    a visible budget diff."""
    sigs = [aval_sig(a) for a in avals]
    nbytes = 0
    for a in avals:
        n = 1
        for d in getattr(a, "shape", ()):
            n *= int(d)
        nbytes += n * getattr(getattr(a, "dtype", None), "itemsize", 4)
    digest = hashlib.sha1(repr(sigs).encode()).hexdigest()[:12]
    return len(sigs), nbytes, digest
