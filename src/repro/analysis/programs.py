"""Traced-program sweep for the jaxpr pass (DESIGN.md §12).

``iter_traces`` yields a ``ProgramTrace`` for every registry scenario x
program kind — the serial runner (``make_packed_simulator`` exactly as
``runners.get_runner`` jits it), the fleet chunk (``make_fleet_chunk``,
one trace per static policy signature: the routing/traffic/placement
combos the cohort scheduler specializes on), and the streaming refill
(``core.streaming.make_refill``).  Tracing is abstract — nothing is
compiled or executed, so even leaf-spine-xl traces in well under a
second — which is the whole point: the invariants are proven before
anything runs.

``doctored_trace`` builds minimal programs that VIOLATE each rule; the
falsifiability tests (tests/test_jaxcheck.py) and the CLI's ``--seed``
flag both use it to prove every checker actually fires.
"""
from __future__ import annotations

import itertools
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..api import runners
from ..api.fleet import STATIC_FIELDS
from ..core.engine import init_fleet_carry, make_consts, make_fleet_chunk
from ..core.policies import as_policy_arrays, policy_fields
from ..core.streaming import STREAM_FIELDS, make_refill
from .checkers import ProgramTrace

FLEET_WIDTH = 4        # lane count for fleet/refill traces: the eqn
#                        structure is width-independent, so small is fine
CHUNK_STEPS = 32

_SCN_CACHE: Dict[str, tuple] = {}


def scenario_consts(name: str):
    """(consts, meta) for a registry scenario, cached per process — the
    host-side build (route DFS etc.) dominates sweep time otherwise."""
    if name not in _SCN_CACHE:
        from ..scenarios import get_scenario
        setup = get_scenario(name).build()
        _SCN_CACHE[name] = make_consts(setup)
    return _SCN_CACHE[name]


def axes_of(consts, meta) -> Dict[str, int]:
    return {
        "jobs": int(consts.job_valid.shape[0]),
        "tasks": int(consts.task_job.shape[0]),
        "packets": int(consts.pkt_job.shape[0]),
        "links": int(meta.n_links),
        "vms": int(meta.n_vms),
    }


def static_sigs() -> List[Tuple[int, ...]]:
    """Every static policy signature the fleet specializes on: the cross
    product of the registered choices of the STATIC_FIELDS axes (today
    routing x traffic x placement = 2*2*3 = 12), derived from the policy
    registry so a new branch value automatically widens the sweep."""
    fields = {f.name: f for f in policy_fields()}
    per_axis = [sorted((fields[n].choices or {n: fields[n].default}).values())
                for n in STATIC_FIELDS]
    return [tuple(sig) for sig in itertools.product(*per_axis)]


def sig_label(sig: Sequence[int]) -> str:
    fields = {f.name: f for f in policy_fields()}
    return "-".join(fields[n].choice_name(v)
                    for n, v in zip(STATIC_FIELDS, sig))


def _sds(x) -> jax.ShapeDtypeStruct:
    return jax.ShapeDtypeStruct(jnp.shape(x), jnp.result_type(x))


def trace_serial(name: str) -> ProgramTrace:
    """The serial runner program, via the ``runners.traced_jaxpr`` hook —
    the SAME fn ``get_runner(meta, "single")`` would jit (policies are
    traced arguments here, so one trace covers every policy value)."""
    consts, meta = scenario_consts(name)
    pol = as_policy_arrays(None)
    closed, n_state = runners.traced_jaxpr(meta, "single", consts, pol)
    return ProgramTrace(
        key=f"{name}/serial", kind="serial", scenario=name, meta=meta,
        closed=closed, axes=axes_of(consts, meta), donated=n_state)


def trace_fleet(name: str, sig: Tuple[int, ...],
                width: int = FLEET_WIDTH,
                chunk_steps: int = CHUNK_STEPS) -> ProgramTrace:
    """One fleet chunk program: static fields closed over as Python ints
    (this is what keeps its dispatch specialized — see the batch-wall
    notes in DESIGN.md §9), lane-varying fields as [W] arrays."""
    consts, meta = scenario_consts(name)
    chunk = make_fleet_chunk(meta, dict(zip(STATIC_FIELDS, sig)),
                             chunk_steps)
    carry0 = jax.eval_shape(lambda c: init_fleet_carry(c, meta, width),
                            consts)
    pol = {k: jax.ShapeDtypeStruct((width,), v.dtype)
           for k, v in as_policy_arrays(None).items()
           if k not in STATIC_FIELDS}
    closed = jax.make_jaxpr(chunk)(consts, pol, carry0)
    return ProgramTrace(
        key=f"{name}/fleet/{sig_label(sig)}", kind="fleet", scenario=name,
        meta=meta, closed=closed, axes=axes_of(consts, meta), sig=tuple(sig),
        donated=len(jax.tree_util.tree_leaves(carry0)))


def trace_refill(name: str, width: int = FLEET_WIDTH) -> ProgramTrace:
    """The streaming refill program for this scenario's meta: streamed
    consts leaves carry a [W] lane axis, everything else is shared —
    exactly how ``Experiment.run_stream`` invokes it."""
    consts, meta = scenario_consts(name)
    axes = axes_of(consts, meta)
    refill = make_refill(meta)
    vconsts = type(consts)(**{
        f: jax.ShapeDtypeStruct((width,) + jnp.shape(getattr(consts, f)),
                                jnp.result_type(getattr(consts, f)))
        if f in STREAM_FIELDS else _sds(getattr(consts, f))
        for f in consts._fields})
    carry0 = jax.eval_shape(lambda c: init_fleet_carry(c, meta, width),
                            consts)
    masks = [jax.ShapeDtypeStruct((width, axes[a]), jnp.bool_)
             for a in ("jobs", "tasks", "packets")]
    lane_m = jax.ShapeDtypeStruct((width,), jnp.bool_)
    closed = jax.make_jaxpr(refill)(vconsts, carry0, *masks, lane_m)
    return ProgramTrace(
        key=f"{name}/refill", kind="refill", scenario=name, meta=meta,
        closed=closed, axes=axes, expect_loop=False,
        expect_loop_cond=False)


def iter_traces(scenarios: Optional[Sequence[str]] = None,
                sigs: Optional[Sequence[Tuple[int, ...]]] = None,
                kinds: Sequence[str] = ("serial", "fleet", "refill"),
                width: int = FLEET_WIDTH,
                chunk_steps: int = CHUNK_STEPS,
                progress=None) -> Iterator[ProgramTrace]:
    """The full sweep: every registry scenario x kind (x static signature
    for the fleet kind).  ``progress`` (a callable taking one string) gets
    a line per program for long runs."""
    if scenarios is None:
        from ..scenarios import list_scenarios
        scenarios = list_scenarios()
    if sigs is None:
        sigs = static_sigs()
    for name in scenarios:
        if "serial" in kinds:
            if progress:
                progress(f"trace {name}/serial")
            yield trace_serial(name)
        if "fleet" in kinds:
            for sig in sigs:
                if progress:
                    progress(f"trace {name}/fleet/{sig_label(sig)}")
                yield trace_fleet(name, sig, width, chunk_steps)
        if "refill" in kinds:
            if progress:
                progress(f"trace {name}/refill")
            yield trace_refill(name, width)


# --- doctored programs: one per rule, used to PROVE the checkers fire ----

def doctored_trace(rule: str, n_packets: int = 64) -> ProgramTrace:
    """A minimal program that VIOLATES ``rule`` (falsifiability: a checker
    that cannot be tripped is not checking anything).  Axes mimic a tiny
    scenario with ``n_packets`` packets."""
    axes = {"packets": n_packets, "tasks": 8, "jobs": 2, "links": 4,
            "vms": 2}
    x = jax.ShapeDtypeStruct((n_packets,), jnp.float32)

    if rule == "sort-in-loop":
        def prog(v):
            def body(c):
                i, w = c
                return i + 1, jnp.sort(w)           # the retired regression

            return jax.lax.while_loop(lambda c: c[0] < 3, body, (0, v))

        closed = jax.make_jaxpr(prog)(x)

    elif rule == "scatter-in-loop":
        def prog(v):
            def body(c):
                i, w = c
                idx = jnp.arange(n_packets)[::-1]
                return i + 1, w.at[idx].set(w)      # full-width scatter

            return jax.lax.while_loop(lambda c: c[0] < 3, body, (0, v))

        closed = jax.make_jaxpr(prog)(x)

    elif rule == "dtype-drift":
        def prog(v):
            def body(c):
                i, w = c
                wide = w.astype(jnp.float32)        # f16 -> f32 widening
                return i + 1, wide.astype(jnp.float16)

            return jax.lax.while_loop(lambda c: c[0] < 3, body, (0, v))

        closed = jax.make_jaxpr(prog)(
            jax.ShapeDtypeStruct((n_packets,), jnp.float16))

    elif rule == "batched-cond":
        def prog(v):
            def body(c):
                i, w = c
                # no lax.cond anywhere: every "fast path" is a select
                return i + 1, jnp.where(w > 0, w * 2.0, w)

            return jax.lax.while_loop(lambda c: c[0] < 3, body, (0, v))

        closed = jax.make_jaxpr(prog)(x)

    elif rule == "donation":
        def prog(v, s):
            def body(c):
                i, w = c
                return i + 1, w + 1.0

            _, out = jax.lax.while_loop(lambda c: c[0] < 3, body, (0, v))
            return out.astype(jnp.int32)            # donated f32 has no
            #                                         f32 output to alias

        closed = jax.make_jaxpr(prog)(x, x)
        return ProgramTrace(
            key="doctored/donation", kind="doctored", scenario="doctored",
            meta="doctored-meta", closed=closed, axes=axes, donated=1)

    else:
        raise ValueError(f"no doctored program for rule {rule!r}")

    return ProgramTrace(
        key=f"doctored/{rule}", kind="doctored", scenario="doctored",
        meta="doctored-meta", closed=closed, axes=axes)


def clean_trace(n_packets: int = 64) -> ProgramTrace:
    """The doctored programs' innocent twin: a while loop that keeps a
    lax.cond fast path, touches no packet-axis sort/scatter, stays f32,
    and aliases its donated input — must pass every checker."""
    axes = {"packets": n_packets, "tasks": 8, "jobs": 2, "links": 4,
            "vms": 2}
    x = jax.ShapeDtypeStruct((n_packets,), jnp.float32)

    def prog(v, s):
        def body(c):
            i, w = c
            w = jax.lax.cond(i % 2 == 0, lambda a: a + 1.0,
                             lambda a: a, w)
            return i + 1, w

        _, out = jax.lax.while_loop(lambda c: c[0] < 3, body, (0, v + s))
        return out

    closed = jax.make_jaxpr(prog)(x, x)
    return ProgramTrace(
        key="doctored/clean", kind="doctored", scenario="doctored",
        meta="doctored-meta", closed=closed, axes=axes, donated=1)
