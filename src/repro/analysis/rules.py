"""Rule catalog + finding model for the static-analysis passes
(DESIGN.md §12).

Every rule has a stable kebab-case id.  Code and docs reference a rule as
a ``jaxcheck:<id>`` token — ``tools/check_design_refs.py`` resolves those
tokens against the DESIGN.md §12 catalog exactly like section references
in docstrings, so a rule cannot be cited without being documented.

Findings carry two locations: ``where`` is the precise spot (``file:line``
for AST findings, ``program @ jaxpr-path [source]`` for jaxpr findings)
and ``key`` is the STABLE identity used by the ``allowlist`` section of
``experiments/PRIM_BUDGET.json`` — keys never embed line numbers, so an
allowlisted finding survives unrelated edits to the same file.
"""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Finding:
    rule: str          # rule id from RULES
    where: str         # file:line or "<program> @ <jaxpr path> [<source>]"
    message: str
    key: str           # stable allowlist key (no line numbers)
    severity: str = "error"

    def render(self) -> str:
        return (f"[{self.severity}] {self.rule}: {self.message}\n"
                f"    at  {self.where}\n"
                f"    key {self.key}")


# --- jaxpr-pass rules (repro.analysis.checkers) ---------------------------
JAXPR_RULES = {
    "sort-in-loop": (
        "a sort over the packet axis inside the engine while-loop body — "
        "the per-step packet sorts PR 5/6 retired must not come back"),
    "scatter-in-loop": (
        "a full-width packet-axis scatter inside the engine while-loop "
        "body (single-element pops and segment-sums are budgeted, not "
        "forbidden)"),
    "dtype-drift": (
        "a 64-bit leaf in the loop carry, or a widening "
        "convert_element_type (f32->f64, i32->i64, f16->f32) inside the "
        "loop body — silent promotion doubles carry traffic"),
    "carry-stability": (
        "programs sharing a SimMeta and kind disagree on the while-loop "
        "carry structure (leaf count / shapes / dtypes)"),
    "batched-cond": (
        "an engine loop body with no lax.cond left at all — every "
        "skip-when-idle fast path has been batched into "
        "both-branches select_n"),
    "donation": (
        "the jitted runner's donation policy is wrong for a backend, or a "
        "donated input aval has no matching output aval to alias into"),
}

# --- AST-pass rules (repro.analysis.astlint) ------------------------------
AST_RULES = {
    "tracer-cast": (
        "float()/int()/bool() applied to a likely-traced value "
        "(state/consts attribute or pol/aux/cache entry) in engine code"),
    "item-call": (
        ".item() in engine code — a device sync on concrete values and a "
        "TracerError under jit"),
    "unseeded-random": (
        "legacy global numpy RNG (np.random.<fn>) — use "
        "np.random.default_rng(seed) / RandomState(seed) so sweeps stay "
        "deterministic"),
    "random-module": (
        "the stdlib random module — unseeded, process-global, and "
        "invisible to the scenario seed plumbing"),
    "naked-timer": (
        "a function that brackets work with two timer reads but never "
        "calls block_until_ready/device_get — with async dispatch the "
        "timer measures dispatch, not compute"),
    "meta-subscript": (
        'meta["..."] dict-style access where the frozen SimMeta is '
        "required — attribute access is the supported spelling"),
    "frozen-mutation": (
        "attribute assignment on a consts/meta object — EngineConsts and "
        "SimMeta are frozen; use _replace()/dataclasses.replace()"),
    "f64-literal": (
        "a 64-bit jnp dtype literal in engine code — the engine is f32 "
        "end-to-end and x64 is never enabled"),
}

RULES = {**JAXPR_RULES, **AST_RULES}
