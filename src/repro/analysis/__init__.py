"""jaxcheck: static analysis over the traced engine programs and the
source tree (DESIGN.md §12).

Two passes, one gate:

* the **jaxpr pass** (``programs`` + ``checkers``) traces every registry
  scenario x program kind to a ClosedJaxpr — nothing compiles or runs —
  and proves structural invariants of the engine's hot while loop:
  no packet-axis sorts or full-width scatters in the body, no silent
  64-bit drift, the unbatched fast-path conds survive, donation is
  aliasable, and the loop carry is stable across same-meta scenarios;
* the **AST pass** (``astlint``) lints the source for tracer-unsafe
  host idioms: builtin casts on traced values, unseeded RNG, naked
  benchmark timers, legacy meta subscripts, frozen-struct mutation;
* the **budget gate** (``budget``) diffs per-program watched-primitive
  counts against the committed ``experiments/PRIM_BUDGET.json``.

Everything drives through ``tools/jaxcheck.py``; falsifiability tests in
``tests/test_jaxcheck.py`` prove each checker fires on a doctored
program and stays quiet on a clean one.
"""
from .rules import AST_RULES, JAXPR_RULES, RULES, Finding  # noqa: F401
from .checkers import WATCHED, ProgramTrace, analyze  # noqa: F401
from .astlint import lint_source, lint_tree  # noqa: F401
from .budget import (build_ledger, diff_ledger, load_ledger,  # noqa: F401
                     refresh_ledger, save_ledger)
from .programs import (clean_trace, doctored_trace, iter_traces,  # noqa: F401
                       static_sigs)
