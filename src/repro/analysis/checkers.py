"""Jaxpr-level checkers over traced engine programs (DESIGN.md §12).

Each checker takes a ``ProgramTrace`` (the traced program plus the axis
sizes and donation info needed to interpret it) and returns ``Finding``s;
``budget_counts`` extracts the per-program primitive counts and carry
signature that land in ``experiments/PRIM_BUDGET.json``.  ``analyze``
drives all of it over a sweep of traces, including the cross-program
carry-stability check (jaxcheck:carry-stability).

The checkers deliberately operate on *structure*, not source: a sort
that sneaks back into the hot loop trips jaxcheck:sort-in-loop no matter
which file introduced it, with the offending eqn's source location in
the finding.
"""
from __future__ import annotations

import dataclasses
from collections import Counter
from typing import Dict, List, Optional, Sequence, Tuple

from .jaxpr_walk import (LoopInfo, aval_sig, carry_signature, engine_loop,
                         source_of, walk)
from .rules import Finding

SCATTER_PRIMS = ("scatter", "scatter-add", "scatter-mul", "scatter-min",
                 "scatter-max")

# budgeted primitives: counted inside the engine loop body per program.
# An INCREASE over the committed baseline fails the gate for every prim
# except "cond", where a DECREASE fails instead — losing a lax.cond means
# an unbatched fast path became a both-branches select (the batch wall,
# jaxcheck:batched-cond).
WATCHED = ("sort",) + SCATTER_PRIMS + (
    "gather", "select_n", "cond", "while", "scan",
    "convert_element_type", "dynamic_update_slice", "dynamic_slice")


@dataclasses.dataclass
class ProgramTrace:
    """One traced engine program plus the context checkers need."""
    key: str                    # ledger key, e.g. "paper-fabric/serial"
    kind: str                   # "serial" | "fleet" | "refill" | "doctored"
    scenario: str
    meta: object                # hashable SimMeta (or a test sentinel)
    closed: object              # ClosedJaxpr
    axes: Dict[str, int]        # {"packets": n, "tasks": n, "jobs": n, ...}
    sig: Optional[Tuple[int, ...]] = None   # fleet static signature
    donated: int = 0            # trailing flat invars that form the
    #                             donated state arg on donating backends
    expect_loop: bool = True    # engine programs must contain a while
    expect_loop_cond: bool = True  # ... whose body keeps >=1 lax.cond


def loop_of(trace: ProgramTrace) -> Optional[LoopInfo]:
    return engine_loop(trace.closed)


def _where(trace: ProgramTrace, path, eqn) -> str:
    return f"{trace.key} @ {'/'.join(path)} [{source_of(eqn)}]"


# --- jaxcheck:sort-in-loop / jaxcheck:scatter-in-loop ---------------------

def check_forbidden(trace: ProgramTrace,
                    loop: Optional[LoopInfo]) -> List[Finding]:
    """Packet-axis sorts and full-width packet-axis scatters in the loop
    body.  The job/vm/task-axis sorts and the single-element pops /
    link segment-sums the vectorized kernel keeps on purpose do NOT
    match: they are caught by the budget counts instead."""
    if loop is None:
        return []
    n_pkt = trace.axes.get("packets", -1)
    out: List[Finding] = []
    for eqn, path in walk(loop.body):
        name = eqn.primitive.name
        if name == "sort":
            if any(n_pkt in tuple(v.aval.shape) for v in eqn.invars
                   if hasattr(v, "aval")):
                out.append(Finding(
                    rule="sort-in-loop",
                    where=_where(trace, path, eqn),
                    message=f"sort over the packet axis (n={n_pkt}) "
                            "inside the engine loop body",
                    key=f"sort-in-loop:{trace.key}"))
        elif name in SCATTER_PRIMS:
            # operands: (operand, indices, updates); full-width means the
            # UPDATES tensor spans the whole packet axis
            if len(eqn.invars) >= 3 and hasattr(eqn.invars[2], "aval"):
                upd = tuple(eqn.invars[2].aval.shape)
                if n_pkt in upd:
                    out.append(Finding(
                        rule="scatter-in-loop",
                        where=_where(trace, path, eqn),
                        message=f"{name} with full packet-axis updates "
                                f"{upd} inside the engine loop body",
                        key=f"scatter-in-loop:{trace.key}"))
    return out


# --- jaxcheck:dtype-drift -------------------------------------------------

def _is_widening(src_dtype, dst_dtype) -> bool:
    import numpy as np
    s, d = np.dtype(src_dtype), np.dtype(dst_dtype)
    same_kind = (s.kind == d.kind) or (s.kind in "iu" and d.kind in "iu")
    return same_kind and s.kind != "b" and d.itemsize > s.itemsize


def check_dtype_drift(trace: ProgramTrace,
                      loop: Optional[LoopInfo]) -> List[Finding]:
    """64-bit carry leaves and widening ``convert_element_type`` eqns in
    the loop body (whole program when there is no loop, e.g. refill)."""
    out: List[Finding] = []
    if loop is not None:
        for i, aval in enumerate(loop.carry_avals):
            shape, dtype = aval_sig(aval)
            if dtype.endswith("64") or dtype == "complex128":
                out.append(Finding(
                    rule="dtype-drift",
                    where=f"{trace.key} @ carry[{i}]",
                    message=f"{dtype} leaf {shape} in the loop carry",
                    key=f"dtype-drift:{trace.key}:carry"))
    body = loop.body if loop is not None else trace.closed.jaxpr
    for eqn, path in walk(body):
        if eqn.primitive.name != "convert_element_type":
            continue
        if not (eqn.invars and hasattr(eqn.invars[0], "aval")):
            continue
        src = eqn.invars[0].aval.dtype
        dst = eqn.params.get("new_dtype")
        if dst is not None and _is_widening(src, dst):
            out.append(Finding(
                rule="dtype-drift",
                where=_where(trace, path, eqn),
                message=f"widening convert {src} -> {dst} in the "
                        "engine loop body",
                key=f"dtype-drift:{trace.key}:{src}->{dst}"))
    return out


# --- jaxcheck:batched-cond ------------------------------------------------

def check_batched_cond(trace: ProgramTrace,
                       loop: Optional[LoopInfo]) -> List[Finding]:
    """Under vmap, a ``lax.cond`` with a batched predicate disappears —
    both branches run and a ``select_n`` merges them.  The serial kernel
    and the fleet chunk both keep at least one REAL cond (the per-step
    done-skip / cohort freeze fast path); a loop body with zero conds
    means every fast path got batched away.  Count drifts smaller than
    zero-vs-some are caught by the budget's cond/select_n entries."""
    if loop is None or not trace.expect_loop_cond:
        return []
    n_cond = sum(1 for eqn, _ in walk(loop.body)
                 if eqn.primitive.name == "cond")
    if n_cond == 0:
        return [Finding(
            rule="batched-cond",
            where=f"{trace.key} @ {'/'.join(loop.path)}",
            message="engine loop body contains no lax.cond at all — the "
                    "unbatched fast paths have been batched into "
                    "both-branches select_n",
            key=f"batched-cond:{trace.key}")]
    return []


# --- jaxcheck:donation ----------------------------------------------------

def check_donation(trace: ProgramTrace) -> List[Finding]:
    """Aval feasibility of buffer donation: every donated input must find
    a distinct output aval of the same shape/dtype to alias into,
    otherwise XLA silently keeps both copies and the donation is a lie.
    (The backend policy itself — donate off-CPU, never on CPU — is
    checked once per run by ``check_donation_policy``.)"""
    if trace.donated <= 0:
        return []
    jaxpr = trace.closed.jaxpr
    donated = [v.aval for v in jaxpr.invars[-trace.donated:]]
    outs = Counter(aval_sig(v.aval) for v in jaxpr.outvars
                   if hasattr(v, "aval"))
    missing = []
    for a in donated:
        sig = aval_sig(a)
        if outs[sig] > 0:
            outs[sig] -= 1
        else:
            missing.append(sig)
    if missing:
        return [Finding(
            rule="donation",
            where=f"{trace.key} @ invars[-{trace.donated}:]",
            message=f"{len(missing)} donated input aval(s) have no "
                    f"matching output to alias into, e.g. {missing[0]}",
            key=f"donation:{trace.key}")]
    return []


def check_donation_policy(donation_argnums) -> List[Finding]:
    """The single-source-of-truth donation policy used by the runner
    cache and the fleet chunk: argument 2 (the t=0 state) is donated on
    every backend EXCEPT cpu, where donation is unsupported and warns."""
    out = []
    for backend, expect in (("cpu", ()), ("gpu", (2,)), ("tpu", (2,))):
        got = tuple(donation_argnums(backend))
        if got != expect:
            out.append(Finding(
                rule="donation",
                where=f"runners.donation_argnums({backend!r})",
                message=f"expected donate_argnums {expect} on {backend}, "
                        f"got {got}",
                key=f"donation:policy:{backend}"))
    return out


# --- jaxcheck:carry-stability ---------------------------------------------

def check_carry_stability(
        entries: Sequence[Tuple[ProgramTrace, Optional[LoopInfo]]],
) -> List[Finding]:
    """Programs sharing a (SimMeta, kind) must agree on the engine-loop
    carry structure — a scenario whose workload seed (not geometry)
    changed may never change the compiled program's carry."""
    groups: Dict[Tuple, Tuple[str, Tuple]] = {}
    out: List[Finding] = []
    for trace, loop in entries:
        if loop is None:
            continue
        leaves, nbytes, digest = carry_signature(loop.carry_avals)
        group = (trace.meta, trace.kind)
        prev = groups.get(group)
        if prev is None:
            groups[group] = (trace.key, (leaves, nbytes, digest))
        elif prev[1] != (leaves, nbytes, digest):
            out.append(Finding(
                rule="carry-stability",
                where=f"{trace.key} vs {prev[0]}",
                message=f"same SimMeta/kind but different loop carry: "
                        f"{(leaves, nbytes, digest)} vs {prev[1]}",
                key=f"carry-stability:{trace.kind}:{trace.scenario}"))
    return out


# --- budget extraction ----------------------------------------------------

def budget_counts(trace: ProgramTrace, loop: Optional[LoopInfo]) -> dict:
    """The committed-ledger row for one program: watched primitive counts
    inside the engine loop body (whole program when loop-free) plus the
    carry signature."""
    body = loop.body if loop is not None else trace.closed.jaxpr
    c: Counter = Counter()
    total = 0
    for eqn, _ in walk(body):
        total += 1
        name = eqn.primitive.name
        if name in WATCHED:
            c[name] += 1
    row = {"loop": {k: int(c.get(k, 0)) for k in WATCHED},
           "eqns": total}
    if loop is not None:
        leaves, nbytes, digest = carry_signature(loop.carry_avals)
        row["carry"] = {"leaves": leaves, "bytes": nbytes, "sig": digest}
    return row


def analyze(traces: Sequence[ProgramTrace]) -> Tuple[List[Finding], dict]:
    """Run every per-program checker plus the cross-program ones.
    Returns ``(findings, programs)`` where ``programs`` maps ledger key
    -> budget row."""
    findings: List[Finding] = []
    programs: dict = {}
    entries: List[Tuple[ProgramTrace, Optional[LoopInfo]]] = []
    for trace in traces:
        loop = loop_of(trace)
        entries.append((trace, loop))
        if trace.expect_loop and loop is None:
            findings.append(Finding(
                rule="carry-stability",
                where=trace.key,
                message="expected an engine while loop but the traced "
                        "program contains none",
                key=f"carry-stability:no-loop:{trace.key}"))
        findings += check_forbidden(trace, loop)
        findings += check_dtype_drift(trace, loop)
        findings += check_batched_cond(trace, loop)
        findings += check_donation(trace)
        programs[trace.key] = budget_counts(trace, loop)
    findings += check_carry_stability(entries)
    return findings, programs
