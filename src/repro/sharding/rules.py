"""Logical -> physical sharding rules (path-based, mesh-shape agnostic).

Scheme (see DESIGN.md): batch/data parallel over ``('pod', 'data')``,
fully-sharded (FSDP/TP mix) params over ``'model'``:

  * every weight matrix shards its FEATURE-EXPANDING dim over 'model'
    (wq/wk/wv/wi/wg: out-dim; wo: in-dim) — contraction stays local,
    XLA SPMD inserts the all-gather/reduce-scatter pairs;
  * embeddings shard the vocab dim (row-parallel lookup);
  * MoE expert banks shard the EXPERT dim over 'model' (EP);
  * mamba shards d_inner over 'model';
  * norms/scalars replicate;
  * stacked-layer leading dims ([L, ...] from ``stack_params``) and the
    hybrid period axis are never sharded (scan axis).

Rules are keyed on the *param leaf path*, so any new model that reuses the
layer zoo inherits a correct sharding with no extra code.
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

MODEL = "model"


def data_axes(mesh) -> Tuple[str, ...]:
    """The batch axes present in this mesh ('pod' optional)."""
    names = mesh.axis_names if hasattr(mesh, "axis_names") else mesh
    return tuple(a for a in ("pod", "data") if a in names)


# (leaf-name, trailing-ndim) -> spec for the trailing dims.
# Leading (stack) dims are padded with None automatically.
_LEAF_RULES: Dict[str, Tuple[Optional[str], ...]] = {
    # embeddings
    "tok": (MODEL, None),            # [V, D] row (vocab) parallel
    "w": (None, MODEL),              # unembed [D, V]
    # attention
    "wq": (None, MODEL), "wk": (None, MODEL), "wv": (None, MODEL),
    "wo": (MODEL, None),
    # mlp (and mamba out-proj handled by name below)
    "wi": (None, MODEL), "wg": (None, MODEL),
    # moe (3-d leaves override by ndim, see below)
    "router": (None, None),
    # mamba
    "in_x": (None, MODEL), "in_z": (None, MODEL),
    "x_proj": (MODEL, None), "dt_proj": (None, MODEL),
    "dt_bias": (MODEL,), "a_log": (MODEL, None), "d_skip": (MODEL,),
    "conv_w": (None, MODEL), "conv_b": (MODEL,),
    "out": (MODEL, None),
    # norms
    "scale": (None,),
}

# MoE expert banks: [E, d_in, d_out] -> expert-parallel over 'model'
_MOE_3D = (MODEL, None, None)


def _leaf_spec(path: Tuple[str, ...], leaf: jnp.ndarray) -> P:
    name = path[-1]
    if name in ("wi", "wg", "wo") and leaf.ndim >= 3 and "moe" in path:
        trailing = _MOE_3D
    elif name in _LEAF_RULES:
        trailing = _LEAF_RULES[name]
    else:
        trailing = (None,) * leaf.ndim
    # trim/pad: leading stack dims get None
    t = trailing[-leaf.ndim:] if len(trailing) > leaf.ndim else trailing
    pad = (None,) * (leaf.ndim - len(t))
    return P(*(pad + tuple(t)))


def _path_names(path) -> Tuple[str, ...]:
    names = []
    for k in path:
        if hasattr(k, "key"):
            names.append(str(k.key))
        elif hasattr(k, "idx"):
            names.append(str(k.idx))
        else:
            names.append(str(k))
    return tuple(names)


def param_specs(params: Any, mesh=None) -> Any:
    """PartitionSpec pytree matching ``params``.

    With ``mesh`` given, dims that do not divide the axis size fall back
    to replicated (argument shardings must divide exactly, unlike
    constraints — e.g. whisper's vocab 51865 on a 16-way axis)."""
    sizes = (dict(zip(mesh.axis_names, mesh.devices.shape))
             if mesh is not None else {})

    def adjust(spec: P, leaf) -> P:
        dims = []
        for d, a in enumerate(tuple(spec) + (None,) * (leaf.ndim - len(spec))):
            if a is None or not sizes:
                dims.append(a)
                continue
            axes = a if isinstance(a, tuple) else (a,)
            prod = 1
            for n in axes:
                prod *= sizes.get(n, 1)
            dims.append(a if leaf.shape[d] % prod == 0 else None)
        return P(*dims)

    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    specs = [adjust(_leaf_spec(_path_names(pth), l), l) for pth, l in flat]
    return jax.tree_util.tree_unflatten(treedef, specs)


def opt_state_specs(params: Any, mesh) -> Any:
    """ZeRO optimizer-state sharding: the f32 moments are 4x the bf16
    params, so they additionally shard over the DATA axes (first dim that
    divides), on top of the params' 'model' sharding.  AdamW is
    elementwise, so the update runs entirely in the moments' sharding;
    only the (bf16) param slices reshard."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    da = tuple(a for a in ("pod", "data") if a in sizes)
    dsize = 1
    for a in da:
        dsize *= sizes[a]
    dspec = da if len(da) > 1 else (da[0] if da else None)

    def one(spec: P, leaf) -> P:
        if leaf.ndim == 0 or dsize <= 1:
            return spec
        dims = list(tuple(spec) + (None,) * (leaf.ndim - len(spec)))
        for i in range(leaf.ndim):
            if dims[i] is None and leaf.shape[i] % dsize == 0 \
                    and leaf.shape[i] >= dsize:
                dims[i] = dspec
                break
        return P(*dims)

    base = param_specs(params, mesh)
    flat, treedef = jax.tree_util.tree_flatten_with_path(params)
    base_flat = jax.tree_util.tree_leaves(
        base, is_leaf=lambda x: isinstance(x, P))
    out = [one(sp, l) for (path, l), sp in zip(flat, base_flat)]
    return jax.tree_util.tree_unflatten(treedef, out)


def batch_specs(batch: Any, mesh, *, fsdp: bool = True) -> Any:
    """Batch dim over as many axes as divide it: FSDP mode tries
    ('pod','data','model') — the model axis is the ZeRO shard domain AND a
    batch axis — falling back to ('pod','data'), then replication (the
    long_500k global_batch=1 case)."""
    order = (("pod", "data", "model") if fsdp else ("pod", "data"))
    axes = tuple(a for a in order if a in mesh.axis_names)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))

    def one(leaf):
        if leaf.ndim == 0:
            return P()
        t = axes
        while t:
            prod = 1
            for a in t:
                prod *= mesh_shape[a]
            if leaf.shape[0] % prod == 0 and leaf.shape[0] >= prod:
                break
            t = t[:-1]
        if not t:
            return P(*(None,) * leaf.ndim)
        spec = t if len(t) > 1 else t[0]
        return P(spec, *(None,) * (leaf.ndim - 1))

    return jax.tree_util.tree_map(one, batch)


def shard_hint(x: jnp.ndarray, *dim_axes) -> jnp.ndarray:
    """with_sharding_constraint that degrades to a no-op outside a mesh.

    dim_axes: one entry per dim — an axis name, a tuple of names, or None.
    Axes missing from the ambient mesh are dropped, and trailing axes are
    trimmed until the dim size divides the axis product (so model code can
    hint ('pod','data','model') unconditionally; a batch of 32 on a
    256-chip submesh degrades to ('pod','data') etc.; smoke tests on one
    device are unaffected).
    """
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return x

    def keep(a, dim_size):
        t = a if isinstance(a, (tuple, list)) else (a,)
        t = tuple(n for n in t if n is not None and n in sizes)
        while t:
            prod = 1
            for n in t:
                prod *= sizes[n]
            if dim_size % prod == 0:
                break
            t = t[:-1]
        if not t:
            return None
        return t if len(t) > 1 else t[0]

    spec = P(*(keep(a, d) for a, d in zip(dim_axes, x.shape)))
    if all(s is None for s in spec):
        return x
    return jax.lax.with_sharding_constraint(x, spec)


def activation_hint(x: jnp.ndarray) -> jnp.ndarray:
    """Layer-boundary [B,S,D] constraint: batch over every axis that
    divides it; if 'model' is left idle (small global batch — the prefill
    shapes), shard the SEQUENCE over it instead (sequence parallelism).
    An idle mesh axis invites GSPMD to split contractions and all-reduce
    activation-sized partials (a 275 GB/chip pattern in prefill_32k)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    except Exception:
        return x
    batch_axes = [a for a in ("pod", "data", "model") if a in sizes]
    t = tuple(batch_axes)
    while t:
        prod = 1
        for n in t:
            prod *= sizes[n]
        if x.shape[0] % prod == 0 and x.shape[0] >= prod:
            break
        t = t[:-1]
    dims = [t if len(t) > 1 else (t[0] if t else None)]
    dims += [None] * (x.ndim - 1)
    if MODEL in sizes and MODEL not in t and x.ndim >= 3 \
            and x.shape[1] % sizes[MODEL] == 0:
        dims[1] = MODEL      # sequence parallel
    if all(d is None for d in dims):
        return x
    return jax.lax.with_sharding_constraint(x, P(*dims))


def replicate_hint(x: jnp.ndarray) -> jnp.ndarray:
    """Constrain to fully replicated inside jit (no-op outside a mesh).

    Applied to a model-sharded weight at its use site this forces the
    FSDP/ZeRO-3 pattern: all-gather the weight in forward, reduce-scatter
    its gradient in backward (the constraint's transpose)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
    except Exception:
        return x
    return jax.lax.with_sharding_constraint(x, P(*(None,) * x.ndim))


def fsdp_params(tree: Any, cfg=None) -> Any:
    """replicate_hint over every leaf (gate with cfg.fsdp when given)."""
    if cfg is not None and not getattr(cfg, "fsdp", True):
        return tree
    return jax.tree_util.tree_map(replicate_hint, tree)


def activation_spec(mesh, ndim: int = 3) -> P:
    da = data_axes(mesh)
    spec = da if len(da) > 1 else (da[0] if da else None)
    return P(spec, *(None,) * (ndim - 1))


def cache_specs_tree(cache: Any, mesh, *, batch_axis_of: int = 1) -> Any:
    """Decode-cache sharding: batch over data axes (when divisible) AND the
    longest non-batch dim over 'model'.

    KV tensors [L, B, S, KV, Dh] shard (B -> data, S -> model): the
    32k-context caches are the dominant HBM consumers in decode cells.
    Mamba states [L, B, Di, N] shard Di over 'model'.  The long_500k B=1
    cells keep batch replicated and ride the model-dim sharding.
    """
    da = data_axes(mesh)
    mesh_shape = dict(zip(mesh.axis_names, mesh.devices.shape))
    data_size = 1
    for a in da:
        data_size *= mesh_shape[a]
    dspec = da if len(da) > 1 else (da[0] if da else None)
    msize = mesh_shape.get(MODEL, 1)

    def one(path, leaf):
        names = _path_names(path)
        if leaf.ndim == 0 or names[-1] == "len":
            return P()
        dims = [None] * leaf.ndim
        b = leaf.shape[batch_axis_of] if leaf.ndim > batch_axis_of else 1
        if b % max(data_size, 1) == 0 and b >= data_size:
            dims[batch_axis_of] = dspec
        # model-shard the LAST dim (Dh for KV caches, d_inner/N for mamba):
        # scatter-at-position and per-head attention stay LOCAL (S-sharding
        # forces per-layer cache gathers); fall back to the widest dim.
        if msize > 1:
            cand_dims = [i for i in range(leaf.ndim - 1, 0, -1)
                         if i != batch_axis_of]
            cand_dims.sort(key=lambda i: (i != leaf.ndim - 1,
                                          -leaf.shape[i]))
            for cand in cand_dims:
                if leaf.shape[cand] % msize == 0 and \
                        leaf.shape[cand] >= msize:
                    dims[cand] = MODEL
                    break
        return P(*dims)

    return jax.tree_util.tree_map_with_path(one, cache)
