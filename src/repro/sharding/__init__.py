from .rules import (activation_spec, batch_specs, cache_specs_tree,
                    data_axes, opt_state_specs, param_specs)

__all__ = ["param_specs", "batch_specs", "activation_spec",
           "cache_specs_tree", "data_axes", "opt_state_specs"]
