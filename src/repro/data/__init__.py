from .pipeline import TokenPipeline, pipeline_jobs

__all__ = ["TokenPipeline", "pipeline_jobs"]
