"""Deterministic synthetic LM data pipeline (+ its MapReduce twin).

``TokenPipeline`` yields {tokens, labels} batches where every token is a
counter-based hash of (seed, shard, step, position) — no state beyond the
step counter, so restore-from-checkpoint reproduces the exact stream on
any number of hosts (elastic re-shard safe: shard assignment is a pure
function of (step, host)).

``pipeline_jobs`` renders the SAME pipeline as the paper's MapReduce DAG
(shard read = map, global shuffle = mapper->reducer transfer, batch
assembly = reduce) so the core DES can predict ingest throughput for a
given interconnect (DESIGN.md §3).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, Iterator, List

import numpy as np

from repro.core.mapreduce import JobSpec


def _hash_u32(x: np.ndarray) -> np.ndarray:
    x = (x ^ 61) ^ (x >> 16)
    x = (x + (x << 3)) & 0xFFFFFFFF
    x = x ^ (x >> 4)
    x = (x * 0x27D4EB2D) & 0xFFFFFFFF
    return x ^ (x >> 15)


@dataclasses.dataclass
class TokenPipeline:
    vocab: int
    batch: int              # per-host batch
    seq: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    step: int = 0

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Pure function of step — the elastic/restart contract."""
        b, s = self.batch, self.seq
        rows = (np.arange(b, dtype=np.uint64)
                + np.uint64(step) * np.uint64(b * self.n_hosts)
                + np.uint64(self.host_id * b))
        pos = np.arange(s + 1, dtype=np.uint64)
        base = (rows[:, None] * np.uint64(1_000_003) + pos[None, :]
                + np.uint64(self.seed) * np.uint64(0x9E3779B9))
        toks = (_hash_u32(base.astype(np.uint32).astype(np.uint64)
                          .astype(np.uint32)) % np.uint32(self.vocab)
                ).astype(np.int32)
        return {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        while True:
            yield self.batch_at(self.step)
            self.step += 1


def pipeline_jobs(*, n_shards: int, shard_gbits: float, n_reducers: int,
                  read_mi: float = 1e3, assemble_mi: float = 1e3,
                  shuffle_fraction: float = 1.0,
                  submit_time: float = 0.0) -> List[JobSpec]:
    """The ingest pipeline as ONE MapReduce job for the DES.

    map = decompress/tokenize a shard, shuffle = re-shard to data-parallel
    consumers, reduce = device batch assembly.
    """
    total = n_shards * shard_gbits
    return [JobSpec(
        submit_time=submit_time, n_map=n_shards, n_reduce=n_reducers,
        map_mi=read_mi, reduce_mi=assemble_mi,
        input_gbits=total, shuffle_gbits=total * shuffle_fraction,
        output_gbits=total * shuffle_fraction)]
