"""Policy plug-points (paper Fig. 8) as a declarative field registry
(DESIGN.md §6).

The Java tool exposes abstract policy classes; we expose integer policy ids
so a vmapped sweep can mix policies per replica (``lax.switch``/``cond``
inside the engine).  Every policy axis is declared ONCE here as a
``PolicyField`` (name → dtype/default/engine-branch table); everything else
derives from the registry:

* ``PolicyConfig`` (the typed per-replica config) reads it at call time —
  one stable class, never a stale rebuilt binding,
* ``as_policy_arrays`` packs any config/mapping into the engine's policy
  dict, filling registered defaults,
* ``repro.scenarios.sweep`` packs policy batches from it,
* a regression test asserts the engine consumes exactly these keys.

Adding a policy axis = one ``register_policy_field`` call plus the engine
branch that reads it.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Mapping, Optional, Tuple

import jax.numpy as jnp

# routing (paper §5.2)
from .routing import ROUTE_LEGACY, ROUTE_SDN  # noqa: F401  (re-export)
# traffic (paper Eq. 3 + beyond-paper)
from .fairshare import TRAFFIC_FAIRSHARE, TRAFFIC_WATERFILL  # noqa: F401

# MapReduce task placement (ApplicationMaster)
PLACE_LEAST_USED = 0   # paper use-case: "VM least-used first"
PLACE_ROUND_ROBIN = 1
PLACE_RANDOM = 2

# job selection (ResourceManager / ApplicationMaster queue)
JOBSEL_FCFS = 0        # paper use-case
JOBSEL_SJF = 1         # shortest (total MI) job first
JOBSEL_PRIORITY = 2    # user-supplied priority value

# recovery after a host failure (DESIGN.md §7)
RECOVERY_RESTART = 0   # YARN re-execution: lost task progress is redone
RECOVERY_RESUME = 1    # beyond-paper checkpointing: progress survives

# flow-rule installation mode (DESIGN.md §10); only meaningful when a
# control-plane config is active (SimMeta.has_ctrl)
INSTALL_REACTIVE = 0   # packet-in: rules install when a packet activates
INSTALL_PROACTIVE = 1  # pre-install a job's rules at admission (overlapped)

# dynamic VM placement under the controller (DESIGN.md §10, S-CORE)
MIG_STATIC = 0         # VMs stay where the cluster spec put them
MIG_CONGESTION = 1     # re-home a VM when its aggregate link cost exceeds
                       # CtrlPlaneConfig.mig_threshold

# YARN speculative execution (DESIGN.md §13); only meaningful when clone
# slots are provisioned (SimMeta.spec_slots > 0)
SPEC_OFF = 0           # stragglers run to completion unassisted
SPEC_ON = 1            # clone the slowest straggler, first finish wins


@dataclasses.dataclass(frozen=True)
class PolicyField:
    """One policy axis: its engine key, dtype, default and branch table."""

    name: str
    default: int
    dtype: Any = jnp.int32
    choices: Optional[Mapping[str, int]] = None  # branch name -> enum value
    doc: str = ""

    def choice_name(self, value: int) -> str:
        """Human label for an enum value (falls back to the number)."""
        for k, v in (self.choices or {}).items():
            if v == int(value):
                return k
        return str(int(value))


_REGISTRY: Dict[str, PolicyField] = {}


def register_policy_field(name: str, default: int, dtype: Any = jnp.int32,
                          choices: Optional[Mapping[str, int]] = None,
                          doc: str = "") -> PolicyField:
    """Declare a policy axis.  ``PolicyConfig`` reads the registry at call
    time, so the new axis is immediately a constructor keyword with its
    registered default — existing instances and import-time bindings stay
    valid."""
    if name in _REGISTRY:
        raise ValueError(f"policy field {name!r} already registered")
    field = PolicyField(name, default, dtype, choices, doc)
    _REGISTRY[name] = field
    return field


def policy_fields() -> Tuple[PolicyField, ...]:
    return tuple(_REGISTRY.values())


def policy_field_names() -> Tuple[str, ...]:
    return tuple(_REGISTRY)


def policy_defaults() -> Dict[str, int]:
    return {f.name: f.default for f in _REGISTRY.values()}


def as_policy_arrays(policy=None, **overrides) -> Dict[str, jnp.ndarray]:
    """The engine's policy dict from any spelling of a policy.

    ``policy`` may be a ``PolicyConfig``, any mapping (possibly partial —
    registered defaults fill the gaps), an object with ``as_arrays()``, or
    ``None``.  Values may be scalars or vmapped arrays; each is cast to the
    field's registered dtype.
    """
    if hasattr(policy, "as_arrays") and not isinstance(policy, Mapping):
        src: Mapping[str, Any] = policy.as_arrays()
    elif policy is None:
        src = {}
    elif isinstance(policy, Mapping):
        src = policy
    else:
        raise TypeError(f"cannot interpret {type(policy).__name__} "
                        "as a policy")
    merged = {**src, **overrides}
    unknown = set(merged) - set(_REGISTRY)
    if unknown:
        raise KeyError(f"unregistered policy field(s): {sorted(unknown)}; "
                       f"known: {list(_REGISTRY)}")
    return {f.name: jnp.asarray(merged.get(f.name, f.default), f.dtype)
            for f in _REGISTRY.values()}


class PolicyConfig:
    """One replica's policy selection — every field may also be a vmapped
    array.  Fields are the registered policy axes (DESIGN.md §6), read from
    the registry at call time: one ``register_policy_field`` call makes a
    new axis a constructor keyword everywhere, with no stale class bindings.
    """

    def __init__(self, **fields):
        unknown = set(fields) - set(_REGISTRY)
        if unknown:
            raise TypeError(
                f"unregistered policy field(s): {sorted(unknown)}; "
                f"known: {list(_REGISTRY)}")
        for f in _REGISTRY.values():
            setattr(self, f.name, fields.get(f.name, f.default))

    def as_arrays(self) -> Dict[str, jnp.ndarray]:
        """Engine policy dict — derived from the registry, field by field.
        Instances created before a late registration fall back to the new
        field's default."""
        return {f.name: jnp.asarray(getattr(self, f.name, f.default),
                                    f.dtype)
                for f in _REGISTRY.values()}

    def replace(self, **fields) -> "PolicyConfig":
        """A copy with the given registered fields replaced."""
        cur = {f.name: getattr(self, f.name, f.default)
               for f in _REGISTRY.values()}
        cur.update(fields)
        return PolicyConfig(**cur)

    def __repr__(self) -> str:
        body = ", ".join(f"{f.name}={getattr(self, f.name, f.default)!r}"
                         for f in _REGISTRY.values())
        return f"PolicyConfig({body})"

    def __eq__(self, other) -> bool:
        if not isinstance(other, PolicyConfig):
            return NotImplemented
        return all(getattr(self, f.name, f.default)
                   == getattr(other, f.name, f.default)
                   for f in _REGISTRY.values())


# ---------------------------------------------------------------------------
# the registered policy axes (the ONE declaration site)
# ---------------------------------------------------------------------------

register_policy_field(
    "routing", ROUTE_SDN,
    choices={"legacy": ROUTE_LEGACY, "sdn": ROUTE_SDN},
    doc="route choice among equal-hop candidates (paper §5.2)")
register_policy_field(
    "traffic", TRAFFIC_FAIRSHARE,
    choices={"fairshare": TRAFFIC_FAIRSHARE, "waterfill": TRAFFIC_WATERFILL},
    doc="channel bandwidth sharing (paper Eq. 3 / beyond-paper max-min)")
register_policy_field(
    "placement", PLACE_LEAST_USED,
    choices={"least-used": PLACE_LEAST_USED, "round-robin": PLACE_ROUND_ROBIN,
             "random": PLACE_RANDOM},
    doc="MapReduce task placement (ApplicationMaster)")
register_policy_field(
    "job_selection", JOBSEL_FCFS,
    choices={"fcfs": JOBSEL_FCFS, "sjf": JOBSEL_SJF,
             "priority": JOBSEL_PRIORITY},
    doc="admission order (ResourceManager queue)")
register_policy_field(
    "job_concurrency", 1_000_000,  # paper use-case: effectively unlimited
    doc="max jobs admitted concurrently (ApplicationMaster width)")
register_policy_field(
    "recovery", RECOVERY_RESTART,
    choices={"restart": RECOVERY_RESTART, "resume": RECOVERY_RESUME},
    doc="host-failure recovery: YARN re-execution vs checkpoint resume "
        "(DESIGN.md §7)")
register_policy_field(
    "install_mode", INSTALL_REACTIVE,
    choices={"reactive": INSTALL_REACTIVE, "proactive": INSTALL_PROACTIVE},
    doc="flow-rule installation: packet-in reactive vs pre-install at job "
        "admission (DESIGN.md §10; inert unless SimMeta.has_ctrl)")
register_policy_field(
    "migration", MIG_STATIC,
    choices={"static": MIG_STATIC, "congestion": MIG_CONGESTION},
    doc="dynamic VM placement: migrate-on-congestion re-homing "
        "(DESIGN.md §10; inert unless SimMeta.has_ctrl)")
register_policy_field(
    "speculation", SPEC_OFF,
    choices={"off": SPEC_OFF, "on": SPEC_ON},
    doc="YARN speculative execution: clone the slowest straggler task "
        "into a pre-allocated per-job slot, first finish wins "
        "(DESIGN.md §13; inert unless SimMeta.spec_slots > 0)")
register_policy_field(
    "seed", 0,
    doc="per-replica hash seed (random placement / legacy route pins)")
