"""Policy plug-points (paper Fig. 8) as enum-selected vectorized branches.

The Java tool exposes abstract classes; we expose integer policy ids so a
vmapped sweep can mix policies per replica (lax.switch/cond inside the
engine).  Extending = adding a branch; the engine is policy-agnostic.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp

# routing (paper §5.2)
from .routing import ROUTE_LEGACY, ROUTE_SDN  # noqa: F401  (re-export)
# traffic (paper Eq. 3 + beyond-paper)
from .fairshare import TRAFFIC_FAIRSHARE, TRAFFIC_WATERFILL  # noqa: F401

# MapReduce task placement (ApplicationMaster)
PLACE_LEAST_USED = 0   # paper use-case: "VM least-used first"
PLACE_ROUND_ROBIN = 1
PLACE_RANDOM = 2

# job selection (ResourceManager / ApplicationMaster queue)
JOBSEL_FCFS = 0        # paper use-case
JOBSEL_SJF = 1         # shortest (total MI) job first
JOBSEL_PRIORITY = 2    # user-supplied priority value


@dataclasses.dataclass
class PolicyConfig:
    """One replica's policy selection — every field may also be a vmapped array."""

    routing: int = ROUTE_SDN
    traffic: int = TRAFFIC_FAIRSHARE
    placement: int = PLACE_LEAST_USED
    job_selection: int = JOBSEL_FCFS
    job_concurrency: int = 1_000_000  # paper use-case: effectively unlimited
    seed: int = 0

    def as_arrays(self):
        return {
            "routing": jnp.asarray(self.routing, jnp.int32),
            "traffic": jnp.asarray(self.traffic, jnp.int32),
            "placement": jnp.asarray(self.placement, jnp.int32),
            "job_selection": jnp.asarray(self.job_selection, jnp.int32),
            "job_concurrency": jnp.asarray(self.job_concurrency, jnp.int32),
            "seed": jnp.asarray(self.seed, jnp.int32),
        }
