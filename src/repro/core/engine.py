"""The discrete-event engine as a single ``lax.while_loop``.

CloudSim's event heap disappears: between events every rate (channel
bandwidth, VM MIPS share, power draw) is piecewise constant, so the next
event time is an analytic ``min`` over fixed-shape state tensors (paper
Eq. 4 generalized to packet finishes, task finishes and job releases).
One while-loop iteration = one event:

  admission -> placement -> task activation -> packet activation (routed) ->
  rates -> dt = earliest horizon -> energy += power*dt -> advance -> completions

Everything is vmap-safe: ``simulate_batch`` sweeps policy/seed vectors as one
tensor program (the beyond-paper capability — see DESIGN.md §2).

The static side of a run is described by a typed, hashable ``SimMeta``
(DESIGN.md §6); ``simulate``/``simulate_batch``/``simulate_scenarios`` are
kept as thin deprecated shims over the unified ``repro.api`` front door
(``Experiment`` + the compiled-runner cache).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from . import fairshare
from .mapreduce import ACTIVE, DONE, SimSetup, VOID, WAITING
from .energy import host_power, switch_power
from .policies import (JOBSEL_PRIORITY, JOBSEL_SJF, PLACE_RANDOM,
                       PLACE_ROUND_ROBIN, as_policy_arrays)
from .routing import choose_route, flow_hash_u32
from .simmeta import SimMeta

_INF = jnp.float32(jnp.inf)


def job_valid_mask(job_n_out):
    """A job slot is live iff it expects output packets — the ONE definition
    of job validity, shared by make_consts and the packed-sweep builder."""
    return job_n_out > 0


class EngineConsts(NamedTuple):
    """Static (replica-shared) tensors, baked from SimSetup."""

    # routing
    routes: jnp.ndarray      # [n_nodes^2, K, H]
    n_cand: jnp.ndarray      # [n_nodes^2]
    link_bw: jnp.ndarray     # [n_links]
    link_src: jnp.ndarray
    link_dst: jnp.ndarray
    # cluster
    vm_host: jnp.ndarray
    vm_total_mips: jnp.ndarray
    vm_core_mips: jnp.ndarray
    host_total_mips: jnp.ndarray
    # jobs / tasks / packets (see mapreduce.py)
    job_release: jnp.ndarray
    job_total_mi: jnp.ndarray
    job_priority: jnp.ndarray
    job_n_out: jnp.ndarray
    job_valid: jnp.ndarray
    task_job: jnp.ndarray
    task_kind: jnp.ndarray
    task_mi: jnp.ndarray
    task_need: jnp.ndarray
    task_valid: jnp.ndarray
    pkt_job: jnp.ndarray
    pkt_phase: jnp.ndarray
    pkt_bits: jnp.ndarray
    pkt_gate_task: jnp.ndarray
    pkt_feeds_task: jnp.ndarray
    pkt_src_task: jnp.ndarray
    pkt_dst_task: jnp.ndarray
    pkt_valid: jnp.ndarray
    # scalars (static python ints/floats hidden in jnp for pytree friendliness)
    n_hosts: jnp.ndarray
    n_switches: jnp.ndarray
    storage_node: jnp.ndarray
    # live VM count — may be < len(vm_host) when consts are padded to a
    # common shape for a multi-scenario sweep (DESIGN.md §5); placement
    # must never pick a pad VM slot.
    n_vms: jnp.ndarray


class SimState(NamedTuple):
    time: jnp.ndarray
    steps: jnp.ndarray
    stalled: jnp.ndarray
    place_counter: jnp.ndarray
    # jobs
    job_admitted: jnp.ndarray
    job_admit_t: jnp.ndarray
    job_out_done: jnp.ndarray
    job_done_t: jnp.ndarray
    # tasks
    task_state: jnp.ndarray
    task_rem: jnp.ndarray
    task_got: jnp.ndarray
    task_vm: jnp.ndarray
    task_start: jnp.ndarray
    task_finish: jnp.ndarray
    # packets
    pkt_state: jnp.ndarray
    pkt_rem: jnp.ndarray
    pkt_pair: jnp.ndarray
    pkt_cand: jnp.ndarray
    pkt_start: jnp.ndarray
    pkt_finish: jnp.ndarray
    # vms / energy
    vm_load: jnp.ndarray
    host_energy: jnp.ndarray
    host_busy: jnp.ndarray
    switch_energy: jnp.ndarray


def make_consts(setup: SimSetup) -> tuple[EngineConsts, SimMeta]:
    rt, cl = setup.route_table, setup.cluster
    consts = EngineConsts(
        routes=jnp.asarray(rt.routes),
        n_cand=jnp.asarray(rt.n_cand),
        link_bw=jnp.asarray(cl.topo.link_bw),
        link_src=jnp.asarray(cl.topo.link_src),
        link_dst=jnp.asarray(cl.topo.link_dst),
        vm_host=jnp.asarray(cl.vm_host),
        vm_total_mips=jnp.asarray(cl.vm_total_mips),
        vm_core_mips=jnp.asarray(cl.vm_core_mips),
        host_total_mips=jnp.asarray(cl.host_total_mips),
        job_release=jnp.asarray(setup.job_release),
        job_total_mi=jnp.asarray(setup.job_total_mi),
        job_priority=jnp.asarray(setup.job_priority),
        job_n_out=jnp.asarray(setup.job_n_out),
        job_valid=jnp.asarray(job_valid_mask(setup.job_n_out)),
        task_job=jnp.asarray(setup.task_job),
        task_kind=jnp.asarray(setup.task_kind),
        task_mi=jnp.asarray(setup.task_mi),
        task_need=jnp.asarray(setup.task_need),
        task_valid=jnp.asarray(setup.task_valid),
        pkt_job=jnp.asarray(setup.pkt_job),
        pkt_phase=jnp.asarray(setup.pkt_phase),
        pkt_bits=jnp.asarray(setup.pkt_bits),
        pkt_gate_task=jnp.asarray(setup.pkt_gate_task),
        pkt_feeds_task=jnp.asarray(setup.pkt_feeds_task),
        pkt_src_task=jnp.asarray(setup.pkt_src_task),
        pkt_dst_task=jnp.asarray(setup.pkt_dst_task),
        pkt_valid=jnp.asarray(setup.pkt_valid),
        n_hosts=jnp.asarray(cl.topo.n_hosts, jnp.int32),
        n_switches=jnp.asarray(cl.topo.n_switches, jnp.int32),
        storage_node=jnp.asarray(cl.storage_node, jnp.int32),
        n_vms=jnp.asarray(int(cl.vm_host.shape[0]), jnp.int32),
    )
    meta = SimMeta(
        n_nodes=cl.topo.n_nodes,
        n_links=cl.topo.n_links,
        n_hosts=cl.topo.n_hosts,
        n_switches=cl.topo.n_switches,
        n_vms=int(cl.vm_host.shape[0]),
        intra_bw=cl.intra_bw,
        energy=cl.energy,
        max_steps=4 * (setup.n_packets + setup.n_tasks) + 4 * setup.n_jobs + 64,
    )
    return consts, meta


def init_state_from_consts(c: EngineConsts, n_switches: int) -> SimState:
    """t=0 state derived purely from (possibly padded) const tensors.

    ``n_switches`` is the STATIC switch-tensor length (padded max in a
    multi-scenario sweep) — it cannot be read off any consts array, every
    other shape can.  Pad job/task/packet slots start VOID/zero so they are
    inert for the whole run (DESIGN.md §5).
    """
    n_j = c.job_release.shape[0]
    n_t = c.task_job.shape[0]
    n_p = c.pkt_job.shape[0]
    f = jnp.float32
    return SimState(
        time=f(0.0), steps=jnp.int32(0), stalled=jnp.asarray(False),
        place_counter=jnp.int32(0),
        job_admitted=jnp.zeros(n_j, bool),
        job_admit_t=jnp.full(n_j, jnp.nan, f),
        job_out_done=jnp.zeros(n_j, jnp.int32),
        job_done_t=jnp.full(n_j, jnp.nan, f),
        task_state=jnp.where(c.task_valid, WAITING, VOID).astype(jnp.int32),
        task_rem=c.task_mi.astype(f),
        task_got=jnp.zeros(n_t, jnp.int32),
        task_vm=jnp.full(n_t, -1, jnp.int32),
        task_start=jnp.full(n_t, jnp.nan, f),
        task_finish=jnp.full(n_t, jnp.nan, f),
        pkt_state=jnp.where(c.pkt_valid, WAITING, VOID).astype(jnp.int32),
        pkt_rem=c.pkt_bits.astype(f),
        pkt_pair=jnp.full(n_p, -1, jnp.int32),
        pkt_cand=jnp.full(n_p, -1, jnp.int32),
        pkt_start=jnp.full(n_p, jnp.nan, f),
        pkt_finish=jnp.full(n_p, jnp.nan, f),
        vm_load=jnp.zeros(c.vm_host.shape[0], jnp.int32),
        host_energy=jnp.zeros(c.host_total_mips.shape[0], f),
        host_busy=jnp.zeros(c.host_total_mips.shape[0], f),
        switch_energy=jnp.zeros(n_switches, f),
    )


def init_state(setup: SimSetup) -> SimState:
    consts, meta = make_consts(setup)
    return init_state_from_consts(consts, meta.n_switches)


# ---------------------------------------------------------------------------
# step phases
# ---------------------------------------------------------------------------


def _admit_and_place(c: EngineConsts, meta, pol, s: SimState) -> SimState:
    """Admit released jobs (job-selection policy) while concurrency slots are
    free; place each admitted job's tasks onto VMs (placement policy)."""
    # live VM count (c.n_vms) may be smaller than the padded tensor length
    # in a packed multi-scenario sweep — pad slots must never win placement.
    n_vms = c.n_vms
    vm_slot_live = jnp.arange(meta.n_vms) < n_vms

    def admit_one(_, s: SimState) -> SimState:
        released = (~s.job_admitted) & c.job_valid & (c.job_release <= s.time)
        running = s.job_admitted & (s.job_out_done < c.job_n_out) & c.job_valid
        free = jnp.sum(running.astype(jnp.int32)) < pol["job_concurrency"]
        any_wait = jnp.any(released)
        # job-selection key (smaller = better)
        key = jnp.where(
            pol["job_selection"] == JOBSEL_SJF, c.job_total_mi,
            jnp.where(pol["job_selection"] == JOBSEL_PRIORITY,
                      -c.job_priority, c.job_release))
        key = jnp.where(released, key, _INF)
        j = jnp.argmin(key).astype(jnp.int32)
        do = free & any_wait

        def place(s: SimState) -> SimState:
            mine = (c.task_job == j) & c.task_valid

            def place_one(t, carry):
                vm_load, task_vm, counter = carry
                is_mine = mine[t]
                h = flow_hash_u32(jnp.int32(t), j, pol["seed"])
                masked_load = jnp.where(vm_slot_live, vm_load,
                                        jnp.iinfo(jnp.int32).max)
                pick = jnp.where(
                    pol["placement"] == PLACE_ROUND_ROBIN, counter % n_vms,
                    jnp.where(pol["placement"] == PLACE_RANDOM, h % n_vms,
                              jnp.argmin(masked_load).astype(jnp.int32)))
                pick = pick.astype(jnp.int32)
                vm_load = jnp.where(is_mine, vm_load.at[pick].add(1), vm_load)
                task_vm = jnp.where(is_mine, task_vm.at[t].set(pick), task_vm)
                counter = counter + jnp.where(is_mine, 1, 0)
                return vm_load, task_vm, counter

            vm_load, task_vm, counter = jax.lax.fori_loop(
                0, task_vm_len, place_one,
                (s.vm_load, s.task_vm, s.place_counter))
            return s._replace(
                vm_load=vm_load, task_vm=task_vm, place_counter=counter,
                job_admitted=s.job_admitted.at[j].set(True),
                job_admit_t=s.job_admit_t.at[j].set(s.time))

        task_vm_len = s.task_vm.shape[0]
        return jax.lax.cond(do, place, lambda s: s, s)

    return jax.lax.fori_loop(0, s.job_admitted.shape[0], admit_one, s)


def _route_links(c: EngineConsts, s: SimState, mask: jnp.ndarray) -> jnp.ndarray:
    """[N_P, H] link ids of each packet's chosen route (-1 where masked)."""
    pair = jnp.maximum(s.pkt_pair, 0)
    cand = jnp.maximum(s.pkt_cand, 0)
    links = c.routes[pair, cand]
    return jnp.where(mask[:, None], links, -1)


NODE_OFFSET = 1 << 20  # pkt_src/dst_task >= NODE_OFFSET encodes a direct
                       # node id (flow-level frontend, core.flows)


def _pkt_endpoints(c: EngineConsts, s: SimState):
    """Resolve src/dst node of every packet from current task placement.

    -1 -> SAN storage; >= NODE_OFFSET -> direct node id; else task id."""
    n_tasks = s.task_vm.shape[0]

    def node_of(task_idx):
        t = jnp.clip(task_idx, 0, n_tasks - 1)
        vm = jnp.maximum(s.task_vm[t], 0)
        node = jnp.where(task_idx < 0, c.storage_node, c.vm_host[vm])
        return jnp.where(task_idx >= NODE_OFFSET,
                         task_idx - NODE_OFFSET, node).astype(jnp.int32)
    return node_of(c.pkt_src_task), node_of(c.pkt_dst_task)


def _activate(c: EngineConsts, meta, pol, s: SimState) -> SimState:
    """Task activation (vectorized) then packet activation (ordered fori —
    the controller serializes arrivals; each sees earlier channel counts)."""
    # tasks: all inputs arrived
    t_ready = ((s.task_state == WAITING) & (s.task_got >= c.task_need)
               & (s.task_vm >= 0))
    task_state = jnp.where(t_ready, ACTIVE, s.task_state)
    task_start = jnp.where(t_ready, s.time, s.task_start)
    s = s._replace(task_state=task_state, task_start=task_start)

    # packets: job admitted & gate task done
    gate = c.pkt_gate_task
    gate_ok = jnp.where(gate < 0, True,
                        s.task_state[jnp.maximum(gate, 0)] == DONE)
    admitted = s.job_admitted[jnp.maximum(c.pkt_job, 0)]
    p_ready = (s.pkt_state == WAITING) & admitted & gate_ok & c.pkt_valid
    src_node, dst_node = _pkt_endpoints(c, s)
    n_nodes = meta.n_nodes
    # unreachable pairs (no candidate route, different nodes) never
    # activate -> the engine reports a stall instead of free transfer
    pair_all = (src_node * n_nodes + dst_node).astype(jnp.int32)
    reachable = (c.n_cand[pair_all] > 0) | (src_node == dst_node)
    p_ready = p_ready & reachable

    ch0 = fairshare.channel_counts(
        _route_links(c, s, s.pkt_state == ACTIVE), s.pkt_state == ACTIVE,
        meta.n_links)

    def act_one(i, carry):
        pkt_state, pkt_pair, pkt_cand, pkt_start, ch = carry
        ready = p_ready[i]
        pair = (src_node[i] * n_nodes + dst_node[i]).astype(jnp.int32)
        # legacy flow = task-to-task connection (§4: "task-to-task
        # communication"); each flow picks its equal-hop route independently
        # at random and keeps it (§5.2).
        fh = flow_hash_u32(c.pkt_src_task[i] + 1, c.pkt_dst_task[i] + 1,
                           pol["seed"])
        cand = choose_route(pol["routing"], c.routes[pair], c.n_cand[pair],
                            c.link_bw, ch, fh)
        links = c.routes[pair, cand]
        valid = links >= 0
        ch_new = ch.at[jnp.maximum(links, 0)].add(valid.astype(jnp.int32))
        return (
            jnp.where(ready, pkt_state.at[i].set(ACTIVE), pkt_state),
            jnp.where(ready, pkt_pair.at[i].set(pair), pkt_pair),
            jnp.where(ready, pkt_cand.at[i].set(cand), pkt_cand),
            jnp.where(ready, pkt_start.at[i].set(s.time), pkt_start),
            jnp.where(ready, ch_new, ch),
        )

    pkt_state, pkt_pair, pkt_cand, pkt_start, _ = jax.lax.fori_loop(
        0, s.pkt_state.shape[0], act_one,
        (s.pkt_state, s.pkt_pair, s.pkt_cand, s.pkt_start, ch0))
    return s._replace(pkt_state=pkt_state, pkt_pair=pkt_pair,
                      pkt_cand=pkt_cand, pkt_start=pkt_start)


def _rates(c: EngineConsts, meta, pol, s: SimState):
    p_active = s.pkt_state == ACTIVE
    links = _route_links(c, s, p_active)
    pkt_rate = fairshare.rates(pol["traffic"], links, p_active, c.link_bw,
                               meta.intra_bw)
    t_active = s.task_state == ACTIVE
    vm = jnp.maximum(s.task_vm, 0)
    n_on_vm = jnp.zeros_like(c.vm_total_mips, jnp.int32).at[vm].add(
        t_active.astype(jnp.int32))
    share = c.vm_total_mips[vm] / jnp.maximum(n_on_vm[vm], 1).astype(jnp.float32)
    task_rate = jnp.where(t_active, jnp.minimum(c.vm_core_mips[vm], share), 0.0)
    return pkt_rate, task_rate, links, p_active, t_active


def _finished(c: EngineConsts, meta, s: SimState) -> jnp.ndarray:
    all_done = jnp.all(~c.job_valid | (s.job_out_done >= c.job_n_out))
    return all_done | s.stalled | (s.steps >= meta.max_steps)


def _step(c: EngineConsts, meta, pol, s: SimState) -> SimState:
    s = _admit_and_place(c, meta, pol, s)
    s = _activate(c, meta, pol, s)
    pkt_rate, task_rate, links, p_active, t_active = _rates(c, meta, pol, s)

    # earliest horizon (Eq. 4 generalized)
    dt_p = jnp.min(jnp.where(p_active & (pkt_rate > 0),
                             s.pkt_rem / pkt_rate, _INF))
    dt_t = jnp.min(jnp.where(t_active & (task_rate > 0),
                             s.task_rem / task_rate, _INF))
    future = (~s.job_admitted) & c.job_valid & (c.job_release > s.time)
    dt_r = jnp.min(jnp.where(future, c.job_release - s.time, _INF))
    dt = jnp.minimum(jnp.minimum(dt_p, dt_t), dt_r)
    stalled = jnp.isinf(dt)
    dt = jnp.where(stalled, 0.0, dt)

    # energy (power is constant over [t, t+dt))
    vm_safe = jnp.maximum(s.task_vm, 0)
    host_of_task = c.vm_host[vm_safe]
    mips_used = jnp.zeros_like(c.host_total_mips).at[host_of_task].add(
        jnp.where(t_active, task_rate, 0.0))
    util = jnp.clip(mips_used / jnp.maximum(c.host_total_mips, 1e-9), 0.0, 1.0)
    host_energy = s.host_energy + host_power(util, meta.energy) * dt
    host_busy = s.host_busy + jnp.where(util > 0, dt, 0.0)
    ch = fairshare.channel_counts(links, p_active, meta.n_links)
    live_link = (ch > 0).astype(jnp.int32)
    node_ports = jnp.zeros(meta.n_nodes, jnp.int32)
    node_ports = node_ports.at[c.link_src].add(live_link)
    node_ports = node_ports.at[c.link_dst].add(live_link)
    sw_ports = jax.lax.dynamic_slice_in_dim(node_ports, meta.n_hosts,
                                            meta.n_switches)
    switch_energy = s.switch_energy + switch_power(sw_ports, meta.energy) * dt

    # advance
    time = s.time + dt
    pkt_rem = jnp.where(p_active, s.pkt_rem - pkt_rate * dt, s.pkt_rem)
    task_rem = jnp.where(t_active, s.task_rem - task_rate * dt, s.task_rem)
    pkt_tol = c.pkt_bits * 1e-6 + 1.0
    task_tol = c.task_mi * 1e-6 + 1e-6
    p_done_now = p_active & (pkt_rem <= pkt_tol)
    t_done_now = t_active & (task_rem <= task_tol)

    pkt_state = jnp.where(p_done_now, DONE, s.pkt_state)
    pkt_finish = jnp.where(p_done_now, time, s.pkt_finish)
    task_state = jnp.where(t_done_now, DONE, s.task_state)
    task_finish = jnp.where(t_done_now, time, s.task_finish)

    # completions feed gates
    feeds = jnp.maximum(c.pkt_feeds_task, 0)
    task_got = s.task_got.at[feeds].add(
        (p_done_now & (c.pkt_feeds_task >= 0)).astype(jnp.int32))
    out_pkt = p_done_now & (c.pkt_feeds_task < 0)
    job_of = jnp.maximum(c.pkt_job, 0)
    job_out_done = s.job_out_done.at[job_of].add(out_pkt.astype(jnp.int32))
    newly_job_done = (job_out_done >= c.job_n_out) & \
        (s.job_out_done < c.job_n_out) & c.job_valid
    job_done_t = jnp.where(newly_job_done, time, s.job_done_t)
    vm_load = s.vm_load.at[vm_safe].add(-t_done_now.astype(jnp.int32))

    return s._replace(
        time=time, steps=s.steps + 1, stalled=stalled,
        job_out_done=job_out_done, job_done_t=job_done_t,
        task_state=task_state, task_rem=task_rem, task_got=task_got,
        task_finish=task_finish,
        pkt_state=pkt_state, pkt_rem=pkt_rem, pkt_finish=pkt_finish,
        vm_load=vm_load, host_energy=host_energy, host_busy=host_busy,
        switch_energy=switch_energy)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def make_packed_simulator(meta):
    """Returns ``run(consts, policy_dict) -> SimState`` with consts as an
    ARGUMENT, so a heterogeneous-scenario sweep can vmap over consts and
    policies together (see ``repro.scenarios.sweep``, DESIGN.md §5).

    ``meta`` is a ``SimMeta`` (a legacy meta dict is coerced): only static
    shapes + scalar params shared by every replica in the batch (padded
    maxima for a packed sweep).
    """
    meta = SimMeta.coerce(meta)

    def run(consts: EngineConsts, pol: Dict[str, jnp.ndarray]) -> SimState:
        s0 = init_state_from_consts(consts, meta.n_switches)

        def cond(s):
            return ~_finished(consts, meta, s)

        def body(s):
            new = _step(consts, meta, pol, s)
            live = ~_finished(consts, meta, s)
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(live, n, o), new, s)

        return jax.lax.while_loop(cond, body, s0)

    return run


def make_simulator(setup: SimSetup):
    """Returns a jit-able ``run(policy_dict) -> SimState`` closure."""
    consts, meta = make_consts(setup)
    run = make_packed_simulator(meta)
    return partial(run, consts)


# --- deprecated shims ------------------------------------------------------
# The unified front door is ``repro.api`` (DESIGN.md §6): ``Experiment``
# dispatches single / policy-batch / packed-scenario execution through one
# compiled-runner cache, so repeated calls with an equal ``SimMeta`` reuse
# the traced program.  These wrappers keep the old spellings working and are
# proven bit-identical to the Experiment path by tests/test_api.py.


def simulate(setup: SimSetup, policy=None) -> SimState:
    """Deprecated shim: run one replica via the cached runner
    (policy: PolicyConfig, dict of scalars, or None for defaults).
    Prefer ``repro.api.Experiment(scenarios=setup, policies=policy).run()``.
    """
    from ..api import runners  # local import: api sits above core
    consts, meta = make_consts(setup)
    return runners.get_runner(meta, "single")(consts, as_policy_arrays(policy))


def simulate_batch(setup: SimSetup, pols: Dict[str, jnp.ndarray]) -> SimState:
    """Deprecated shim: vmap over a policy sweep — every dict value has a
    leading replica dim (missing registered fields broadcast their default).
    Prefer ``repro.api.Experiment``."""
    from ..api import runners
    consts, meta = make_consts(setup)
    pols = as_policy_arrays(pols)
    width = max((v.shape[0] for v in pols.values() if v.ndim), default=1)
    pols = {k: v if v.ndim else jnp.broadcast_to(v, (width,))
            for k, v in pols.items()}
    return runners.get_runner(meta, "policy_batch")(consts, pols)


def simulate_scenarios(consts: EngineConsts, meta,
                       pols: Dict[str, jnp.ndarray]) -> SimState:
    """Deprecated shim: ZIPPED batch over packed consts — every consts array
    and every policy value shares one leading replica dim R, and replica i
    runs consts[i] under pols[i].  Build consts with
    ``scenarios.sweep.pack_setups``; for the full scenario×policy cross
    product prefer ``repro.api.Experiment`` (or ``sweep_grid``), which nests
    the vmaps so consts broadcast over the policy axis."""
    from ..api import runners
    return runners.get_runner(SimMeta.coerce(meta), "zipped")(consts, pols)
