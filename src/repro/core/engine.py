"""The discrete-event engine as a single ``lax.while_loop``.

CloudSim's event heap disappears: between events every rate (channel
bandwidth, VM MIPS share, power draw) is piecewise constant, so the next
event time is an analytic ``min`` over fixed-shape state tensors (paper
Eq. 4 generalized to packet finishes, task finishes and job releases).
One while-loop iteration = one event:

  failure/recovery transitions (DESIGN.md §7, traced only when a schedule
  has a finite instant) -> admission -> placement -> task activation ->
  packet activation (routed) -> rates -> dt = earliest horizon ->
  energy += power*dt -> advance -> completions

Everything is vmap-safe: ``simulate_batch`` sweeps policy/seed vectors as one
tensor program (the beyond-paper capability — see DESIGN.md §2).

The static side of a run is described by a typed, hashable ``SimMeta``
(DESIGN.md §6); ``simulate``/``simulate_batch``/``simulate_scenarios`` are
kept as thin deprecated shims over the unified ``repro.api`` front door
(``Experiment`` + the compiled-runner cache).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp

from . import fairshare
from .failures import no_failures
from .mapreduce import ACTIVE, DONE, SimSetup, VOID, WAITING
from .energy import host_power, switch_power
from .policies import (JOBSEL_PRIORITY, JOBSEL_SJF, PLACE_RANDOM,
                       PLACE_ROUND_ROBIN, RECOVERY_RESTART, as_policy_arrays)
from .routing import choose_route, flow_hash_u32
from .simmeta import SimMeta

_INF = jnp.float32(jnp.inf)


def job_valid_mask(job_n_out):
    """A job slot is live iff it expects output packets — the ONE definition
    of job validity, shared by make_consts and the packed-sweep builder."""
    return job_n_out > 0


class EngineConsts(NamedTuple):
    """Static (replica-shared) tensors, baked from SimSetup."""

    # routing
    routes: jnp.ndarray      # [n_nodes^2, K, H]
    n_cand: jnp.ndarray      # [n_nodes^2]
    link_bw: jnp.ndarray     # [n_links]
    link_src: jnp.ndarray
    link_dst: jnp.ndarray
    # cluster
    vm_host: jnp.ndarray
    vm_total_mips: jnp.ndarray
    vm_core_mips: jnp.ndarray
    host_total_mips: jnp.ndarray
    # jobs / tasks / packets (see mapreduce.py)
    job_release: jnp.ndarray
    job_total_mi: jnp.ndarray
    job_priority: jnp.ndarray
    job_n_out: jnp.ndarray
    job_valid: jnp.ndarray
    task_job: jnp.ndarray
    task_kind: jnp.ndarray
    task_mi: jnp.ndarray
    task_need: jnp.ndarray
    task_valid: jnp.ndarray
    pkt_job: jnp.ndarray
    pkt_phase: jnp.ndarray
    pkt_bits: jnp.ndarray
    pkt_gate_task: jnp.ndarray
    pkt_feeds_task: jnp.ndarray
    pkt_src_task: jnp.ndarray
    pkt_dst_task: jnp.ndarray
    pkt_valid: jnp.ndarray
    # scalars (static python ints/floats hidden in jnp for pytree friendliness)
    n_hosts: jnp.ndarray
    n_switches: jnp.ndarray
    storage_node: jnp.ndarray
    # live VM count — may be < len(vm_host) when consts are padded to a
    # common shape for a multi-scenario sweep (DESIGN.md §5); placement
    # must never pick a pad VM slot.
    n_vms: jnp.ndarray
    # failure schedule (DESIGN.md §7): outage window [fail_t, recover_t)
    # per host / per directed link; inf = never.  Just more piecewise-
    # constant rate breakpoints for the analytic dt min.
    host_fail_t: jnp.ndarray     # f32 [n_hosts]
    host_recover_t: jnp.ndarray  # f32 [n_hosts]
    link_fail_t: jnp.ndarray     # f32 [n_links]
    link_recover_t: jnp.ndarray  # f32 [n_links]


class SimState(NamedTuple):
    time: jnp.ndarray
    steps: jnp.ndarray
    stalled: jnp.ndarray
    place_counter: jnp.ndarray
    # jobs
    job_admitted: jnp.ndarray
    job_admit_t: jnp.ndarray
    job_out_done: jnp.ndarray
    job_done_t: jnp.ndarray
    # tasks
    task_state: jnp.ndarray
    task_rem: jnp.ndarray
    task_got: jnp.ndarray
    task_vm: jnp.ndarray
    task_start: jnp.ndarray
    task_finish: jnp.ndarray
    # packets
    pkt_state: jnp.ndarray
    pkt_rem: jnp.ndarray
    pkt_pair: jnp.ndarray
    pkt_cand: jnp.ndarray
    pkt_start: jnp.ndarray
    pkt_finish: jnp.ndarray
    # vms / energy
    vm_load: jnp.ndarray
    host_energy: jnp.ndarray
    host_busy: jnp.ndarray
    switch_energy: jnp.ndarray
    # failure & recovery (DESIGN.md §7)
    host_dead: jnp.ndarray      # bool [n_hosts]: inside outage window
    link_dead: jnp.ndarray      # bool [n_links]
    task_restarts: jnp.ndarray  # int32 [n_tasks]: YARN re-executions
    pkt_reroutes: jnp.ndarray   # int32 [n_packets]: failure-driven reverts
    job_downtime: jnp.ndarray   # f32 [n_jobs]: admitted-but-zero-progress s


def default_max_steps(setup: SimSetup) -> int:
    """Step cap: the no-failure event bound, plus — when a failure schedule
    is present — one full re-execution budget per fail/recover instant
    (each failure can revert every in-flight task/packet at most once).
    The failure-mode cap is quantized to the next power of two so that
    schedules differing only in outage COUNT share a ``SimMeta`` and hit
    the compiled-runner cache (DESIGN.md §6)."""
    base = 4 * (setup.n_packets + setup.n_tasks) + 4 * setup.n_jobs + 64
    sched = setup.failures
    if sched is not None and sched.any_failures:
        exact = base * (1 + sched.n_events) + 2 * sched.n_events
        return 1 << (exact - 1).bit_length()
    return base


def make_consts(setup: SimSetup) -> tuple[EngineConsts, SimMeta]:
    rt, cl = setup.route_table, setup.cluster
    sched = setup.failures
    if sched is None:
        sched = no_failures(cl.topo.n_hosts, cl.topo.n_links)
    else:
        sched.validate(cl.topo.n_hosts, cl.topo.n_links)
    consts = EngineConsts(
        routes=jnp.asarray(rt.routes),
        n_cand=jnp.asarray(rt.n_cand),
        link_bw=jnp.asarray(cl.topo.link_bw),
        link_src=jnp.asarray(cl.topo.link_src),
        link_dst=jnp.asarray(cl.topo.link_dst),
        vm_host=jnp.asarray(cl.vm_host),
        vm_total_mips=jnp.asarray(cl.vm_total_mips),
        vm_core_mips=jnp.asarray(cl.vm_core_mips),
        host_total_mips=jnp.asarray(cl.host_total_mips),
        job_release=jnp.asarray(setup.job_release),
        job_total_mi=jnp.asarray(setup.job_total_mi),
        job_priority=jnp.asarray(setup.job_priority),
        job_n_out=jnp.asarray(setup.job_n_out),
        job_valid=jnp.asarray(job_valid_mask(setup.job_n_out)),
        task_job=jnp.asarray(setup.task_job),
        task_kind=jnp.asarray(setup.task_kind),
        task_mi=jnp.asarray(setup.task_mi),
        task_need=jnp.asarray(setup.task_need),
        task_valid=jnp.asarray(setup.task_valid),
        pkt_job=jnp.asarray(setup.pkt_job),
        pkt_phase=jnp.asarray(setup.pkt_phase),
        pkt_bits=jnp.asarray(setup.pkt_bits),
        pkt_gate_task=jnp.asarray(setup.pkt_gate_task),
        pkt_feeds_task=jnp.asarray(setup.pkt_feeds_task),
        pkt_src_task=jnp.asarray(setup.pkt_src_task),
        pkt_dst_task=jnp.asarray(setup.pkt_dst_task),
        pkt_valid=jnp.asarray(setup.pkt_valid),
        n_hosts=jnp.asarray(cl.topo.n_hosts, jnp.int32),
        n_switches=jnp.asarray(cl.topo.n_switches, jnp.int32),
        storage_node=jnp.asarray(cl.storage_node, jnp.int32),
        n_vms=jnp.asarray(int(cl.vm_host.shape[0]), jnp.int32),
        host_fail_t=jnp.asarray(sched.host_fail_t, jnp.float32),
        host_recover_t=jnp.asarray(sched.host_recover_t, jnp.float32),
        link_fail_t=jnp.asarray(sched.link_fail_t, jnp.float32),
        link_recover_t=jnp.asarray(sched.link_recover_t, jnp.float32),
    )
    meta = SimMeta(
        n_nodes=cl.topo.n_nodes,
        n_links=cl.topo.n_links,
        n_hosts=cl.topo.n_hosts,
        n_switches=cl.topo.n_switches,
        n_vms=int(cl.vm_host.shape[0]),
        intra_bw=cl.intra_bw,
        energy=cl.energy,
        max_steps=default_max_steps(setup),
        has_failures=sched.any_failures,
    )
    return consts, meta


def init_state_from_consts(c: EngineConsts, n_switches: int) -> SimState:
    """t=0 state derived purely from (possibly padded) const tensors.

    ``n_switches`` is the STATIC switch-tensor length (padded max in a
    multi-scenario sweep) — it cannot be read off any consts array, every
    other shape can.  Pad job/task/packet slots start VOID/zero so they are
    inert for the whole run (DESIGN.md §5).
    """
    n_j = c.job_release.shape[0]
    n_t = c.task_job.shape[0]
    n_p = c.pkt_job.shape[0]
    f = jnp.float32
    return SimState(
        time=f(0.0), steps=jnp.int32(0), stalled=jnp.asarray(False),
        place_counter=jnp.int32(0),
        job_admitted=jnp.zeros(n_j, bool),
        job_admit_t=jnp.full(n_j, jnp.nan, f),
        job_out_done=jnp.zeros(n_j, jnp.int32),
        job_done_t=jnp.full(n_j, jnp.nan, f),
        task_state=jnp.where(c.task_valid, WAITING, VOID).astype(jnp.int32),
        task_rem=c.task_mi.astype(f),
        task_got=jnp.zeros(n_t, jnp.int32),
        task_vm=jnp.full(n_t, -1, jnp.int32),
        task_start=jnp.full(n_t, jnp.nan, f),
        task_finish=jnp.full(n_t, jnp.nan, f),
        pkt_state=jnp.where(c.pkt_valid, WAITING, VOID).astype(jnp.int32),
        pkt_rem=c.pkt_bits.astype(f),
        pkt_pair=jnp.full(n_p, -1, jnp.int32),
        pkt_cand=jnp.full(n_p, -1, jnp.int32),
        pkt_start=jnp.full(n_p, jnp.nan, f),
        pkt_finish=jnp.full(n_p, jnp.nan, f),
        vm_load=jnp.zeros(c.vm_host.shape[0], jnp.int32),
        host_energy=jnp.zeros(c.host_total_mips.shape[0], f),
        host_busy=jnp.zeros(c.host_total_mips.shape[0], f),
        switch_energy=jnp.zeros(n_switches, f),
        host_dead=jnp.zeros(c.host_fail_t.shape[0], bool),
        link_dead=jnp.zeros(c.link_fail_t.shape[0], bool),
        task_restarts=jnp.zeros(n_t, jnp.int32),
        pkt_reroutes=jnp.zeros(n_p, jnp.int32),
        job_downtime=jnp.zeros(n_j, f),
    )


def init_state(setup: SimSetup) -> SimState:
    consts, meta = make_consts(setup)
    return init_state_from_consts(consts, meta.n_switches)


# ---------------------------------------------------------------------------
# step phases
# ---------------------------------------------------------------------------


def _effective_link_bw(c: EngineConsts, meta, s: SimState) -> jnp.ndarray:
    """Per-link capacity with dead links at 0 (DESIGN.md §7).  Without
    failures this IS ``c.link_bw`` — the no-failure trace is unchanged."""
    if meta.has_failures:
        return jnp.where(s.link_dead, 0.0, c.link_bw)
    return c.link_bw


def _apply_failures(c: EngineConsts, pol, s: SimState) -> SimState:
    """Fire every fail/recover transition whose instant has been reached.

    Failure instants join the dt horizon (``_step``), so ``s.time`` lands
    exactly on each one; here — at the top of the next iteration — the dead
    masks are recomputed from the schedule and the DELTA vs the previous
    masks drives the one-shot transitions (DESIGN.md §7):

      * WAITING/ACTIVE tasks on a newly-dead host revert to WAITING and
        unplace (``task_vm=-1``) — YARN re-execution on heartbeat loss;
        under ``recovery=restart`` their progress is lost, under ``resume``
        (beyond-paper checkpointing) ``task_rem`` survives.
      * In-flight packets whose chosen route crosses a newly-dead link
        revert to WAITING for re-routing (bits already delivered survive:
        the stream resumes on the new route).
      * In-flight packets whose src/dst HOST newly died revert too — the
        connection died with the endpoint — and retransmit from scratch
        under ``restart``.

    DONE work is never reverted (completed outputs are durable — the SAN
    holds T3 results, map outputs are re-fetchable); recovery instants need
    no transition, the masks simply clear.
    """
    t = s.time
    host_dead = (c.host_fail_t <= t) & (t < c.host_recover_t)
    link_dead = (c.link_fail_t <= t) & (t < c.link_recover_t)
    new_h = host_dead & ~s.host_dead
    new_l = link_dead & ~s.link_dead
    restart = pol["recovery"] == RECOVERY_RESTART

    # packets first: endpoints must resolve against the ACTIVATION-time
    # placement, i.e. before any task unplaces below.
    n_hosts_pad = c.host_fail_t.shape[0]
    src_node, dst_node = _pkt_endpoints(c, s)
    p_active = s.pkt_state == ACTIVE
    links = _route_links(c, s, p_active)
    route_hit = p_active & jnp.any(
        (links >= 0) & new_l[jnp.maximum(links, 0)], axis=-1)

    def _endpoint_died(node):
        return (node < c.n_hosts) & new_h[jnp.clip(node, 0, n_hosts_pad - 1)]

    ep_hit = p_active & (_endpoint_died(src_node) | _endpoint_died(dst_node))
    hit_p = route_hit | ep_hit
    pkt_state = jnp.where(hit_p, WAITING, s.pkt_state)
    pkt_rem = jnp.where(ep_hit & restart, c.pkt_bits.astype(jnp.float32),
                        s.pkt_rem)
    pkt_pair = jnp.where(hit_p, -1, s.pkt_pair)
    pkt_cand = jnp.where(hit_p, -1, s.pkt_cand)
    pkt_reroutes = s.pkt_reroutes + hit_p.astype(jnp.int32)

    # tasks on newly-dead hosts
    vm_safe = jnp.maximum(s.task_vm, 0)
    task_host = jnp.clip(c.vm_host[vm_safe], 0, n_hosts_pad - 1)
    hit_t = (c.task_valid & (s.task_vm >= 0) & new_h[task_host]
             & ((s.task_state == ACTIVE) | (s.task_state == WAITING)))
    task_state = jnp.where(hit_t, WAITING, s.task_state)
    task_rem = jnp.where(hit_t & restart, c.task_mi.astype(jnp.float32),
                         s.task_rem)
    task_start = jnp.where(hit_t, jnp.nan, s.task_start)
    vm_load = s.vm_load.at[vm_safe].add(-hit_t.astype(jnp.int32))
    task_vm = jnp.where(hit_t, -1, s.task_vm)
    task_restarts = s.task_restarts + hit_t.astype(jnp.int32)

    return s._replace(
        host_dead=host_dead, link_dead=link_dead,
        pkt_state=pkt_state, pkt_rem=pkt_rem, pkt_pair=pkt_pair,
        pkt_cand=pkt_cand, pkt_reroutes=pkt_reroutes,
        task_state=task_state, task_rem=task_rem, task_start=task_start,
        task_vm=task_vm, vm_load=vm_load, task_restarts=task_restarts)


def _admit_and_place(c: EngineConsts, meta, pol, s: SimState) -> SimState:
    """Admit released jobs (job-selection policy) while concurrency slots are
    free; place each admitted job's tasks onto VMs (placement policy).

    With failures enabled, placement only considers VMs on LIVE hosts (the
    ResourceManager's heartbeat view — DESIGN.md §7) and a second pass
    re-places unplaced tasks of already-admitted jobs (YARN re-execution
    after a host loss)."""
    # live VM count (c.n_vms) may be smaller than the padded tensor length
    # in a packed multi-scenario sweep — pad slots must never win placement.
    n_vms = c.n_vms
    vm_slot_live = jnp.arange(meta.n_vms) < n_vms
    if meta.has_failures:
        vm_live = vm_slot_live & ~s.host_dead[
            jnp.clip(c.vm_host, 0, c.host_fail_t.shape[0] - 1)]
        n_live = jnp.sum(vm_live.astype(jnp.int32))
        # position of each live VM slot among the live ones, for the
        # k-th-live remap (identical to `k` itself when nothing is dead,
        # since pad slots sit at the tail)
        live_pos = jnp.cumsum(vm_live.astype(jnp.int32)) - 1
    else:
        vm_live, n_live, live_pos = vm_slot_live, n_vms, None

    def pick_vm(vm_load, counter, h):
        masked_load = jnp.where(vm_live, vm_load, jnp.iinfo(jnp.int32).max)
        if meta.has_failures:
            def kth_live(k):
                return jnp.argmax(vm_live & (live_pos == k)).astype(jnp.int32)
            rr = kth_live(counter % jnp.maximum(n_live, 1))
            rnd = kth_live(h % jnp.maximum(n_live, 1))
        else:
            rr, rnd = counter % n_vms, h % n_vms
        pick = jnp.where(
            pol["placement"] == PLACE_ROUND_ROBIN, rr,
            jnp.where(pol["placement"] == PLACE_RANDOM, rnd,
                      jnp.argmin(masked_load).astype(jnp.int32)))
        return pick.astype(jnp.int32)

    def place_mask(s: SimState, mine) -> SimState:
        """Place every task in ``mine`` (ordered fori: round-robin counter
        and least-used load must see earlier placements)."""
        def place_one(t, carry):
            vm_load, task_vm, counter = carry
            is_mine = mine[t]
            h = flow_hash_u32(jnp.int32(t), c.task_job[t], pol["seed"])
            pick = pick_vm(vm_load, counter, h)
            vm_load = jnp.where(is_mine, vm_load.at[pick].add(1), vm_load)
            task_vm = jnp.where(is_mine, task_vm.at[t].set(pick), task_vm)
            counter = counter + jnp.where(is_mine, 1, 0)
            return vm_load, task_vm, counter

        vm_load, task_vm, counter = jax.lax.fori_loop(
            0, s.task_vm.shape[0], place_one,
            (s.vm_load, s.task_vm, s.place_counter))
        return s._replace(vm_load=vm_load, task_vm=task_vm,
                          place_counter=counter)

    def admit_one(_, s: SimState) -> SimState:
        released = (~s.job_admitted) & c.job_valid & (c.job_release <= s.time)
        running = s.job_admitted & (s.job_out_done < c.job_n_out) & c.job_valid
        free = jnp.sum(running.astype(jnp.int32)) < pol["job_concurrency"]
        any_wait = jnp.any(released)
        # job-selection key (smaller = better)
        key = jnp.where(
            pol["job_selection"] == JOBSEL_SJF, c.job_total_mi,
            jnp.where(pol["job_selection"] == JOBSEL_PRIORITY,
                      -c.job_priority, c.job_release))
        key = jnp.where(released, key, _INF)
        j = jnp.argmin(key).astype(jnp.int32)
        do = free & any_wait
        if meta.has_failures:
            # no live NodeManager, no admission (the RM has nowhere to
            # place): the job waits for a host recovery breakpoint
            do = do & (n_live > 0)

        def place(s: SimState) -> SimState:
            s = place_mask(s, (c.task_job == j) & c.task_valid)
            return s._replace(
                job_admitted=s.job_admitted.at[j].set(True),
                job_admit_t=s.job_admit_t.at[j].set(s.time))

        return jax.lax.cond(do, place, lambda s: s, s)

    s = jax.lax.fori_loop(0, s.job_admitted.shape[0], admit_one, s)

    if meta.has_failures:
        # re-place tasks a host failure unplaced (jobs already admitted);
        # with no live VM they stay unplaced and wait for a recovery.
        orphaned = (c.task_valid & (s.task_vm < 0)
                    & (s.task_state == WAITING)
                    & s.job_admitted[jnp.maximum(c.task_job, 0)]
                    & (n_live > 0))
        s = jax.lax.cond(jnp.any(orphaned),
                         lambda s: place_mask(s, orphaned), lambda s: s, s)
    return s


def _route_links(c: EngineConsts, s: SimState, mask: jnp.ndarray) -> jnp.ndarray:
    """[N_P, H] link ids of each packet's chosen route (-1 where masked)."""
    pair = jnp.maximum(s.pkt_pair, 0)
    cand = jnp.maximum(s.pkt_cand, 0)
    links = c.routes[pair, cand]
    return jnp.where(mask[:, None], links, -1)


NODE_OFFSET = 1 << 20  # pkt_src/dst_task >= NODE_OFFSET encodes a direct
                       # node id (flow-level frontend, core.flows)


def _pkt_endpoints(c: EngineConsts, s: SimState):
    """Resolve src/dst node of every packet from current task placement.

    -1 -> SAN storage; >= NODE_OFFSET -> direct node id; else task id."""
    n_tasks = s.task_vm.shape[0]

    def node_of(task_idx):
        t = jnp.clip(task_idx, 0, n_tasks - 1)
        vm = jnp.maximum(s.task_vm[t], 0)
        node = jnp.where(task_idx < 0, c.storage_node, c.vm_host[vm])
        return jnp.where(task_idx >= NODE_OFFSET,
                         task_idx - NODE_OFFSET, node).astype(jnp.int32)
    return node_of(c.pkt_src_task), node_of(c.pkt_dst_task)


def _activate(c: EngineConsts, meta, pol, s: SimState) -> SimState:
    """Task activation (vectorized) then packet activation (ordered fori —
    the controller serializes arrivals; each sees earlier channel counts)."""
    # tasks: all inputs arrived
    t_ready = ((s.task_state == WAITING) & (s.task_got >= c.task_need)
               & (s.task_vm >= 0))
    task_state = jnp.where(t_ready, ACTIVE, s.task_state)
    task_start = jnp.where(t_ready, s.time, s.task_start)
    s = s._replace(task_state=task_state, task_start=task_start)

    # packets: job admitted & gate task done
    gate = c.pkt_gate_task
    gate_ok = jnp.where(gate < 0, True,
                        s.task_state[jnp.maximum(gate, 0)] == DONE)
    admitted = s.job_admitted[jnp.maximum(c.pkt_job, 0)]
    p_ready = (s.pkt_state == WAITING) & admitted & gate_ok & c.pkt_valid
    src_node, dst_node = _pkt_endpoints(c, s)
    n_nodes = meta.n_nodes
    # unreachable pairs (no candidate route, different nodes) never
    # activate -> the engine reports a stall instead of free transfer
    pair_all = (src_node * n_nodes + dst_node).astype(jnp.int32)
    reachable = (c.n_cand[pair_all] > 0) | (src_node == dst_node)
    p_ready = p_ready & reachable
    if meta.has_failures:
        # a packet whose endpoint task was unplaced by a host failure must
        # wait for re-placement — its endpoints cannot resolve yet
        n_tasks = s.task_vm.shape[0]

        def _ep_placed(ref):
            is_task = (ref >= 0) & (ref < NODE_OFFSET)
            return jnp.where(is_task,
                             s.task_vm[jnp.clip(ref, 0, n_tasks - 1)] >= 0,
                             True)

        p_ready = (p_ready & _ep_placed(c.pkt_src_task)
                   & _ep_placed(c.pkt_dst_task))

    link_bw = _effective_link_bw(c, meta, s)
    ch0 = fairshare.channel_counts(
        _route_links(c, s, s.pkt_state == ACTIVE), s.pkt_state == ACTIVE,
        meta.n_links)

    def act_one(i, carry):
        pkt_state, pkt_pair, pkt_cand, pkt_start, ch = carry
        ready = p_ready[i]
        pair = (src_node[i] * n_nodes + dst_node[i]).astype(jnp.int32)
        # legacy flow = task-to-task connection (§4: "task-to-task
        # communication"); each flow picks its equal-hop route independently
        # at random and keeps it (§5.2).
        fh = flow_hash_u32(c.pkt_src_task[i] + 1, c.pkt_dst_task[i] + 1,
                           pol["seed"])
        # SDN's global view includes link liveness (link_bw has dead links
        # at 0, so their candidates lose the bottleneck argmax); the legacy
        # static hash is failure-blind and can re-pin the dead route.
        cand = choose_route(pol["routing"], c.routes[pair], c.n_cand[pair],
                            link_bw, ch, fh)
        links = c.routes[pair, cand]
        valid = links >= 0
        ch_new = ch.at[jnp.maximum(links, 0)].add(valid.astype(jnp.int32))
        if meta.has_failures:
            # a failure-reverted packet re-activates but keeps its FIRST
            # start: its measured duration includes the outage
            start_val = jnp.where(jnp.isnan(pkt_start[i]), s.time,
                                  pkt_start[i])
        else:
            start_val = s.time
        return (
            jnp.where(ready, pkt_state.at[i].set(ACTIVE), pkt_state),
            jnp.where(ready, pkt_pair.at[i].set(pair), pkt_pair),
            jnp.where(ready, pkt_cand.at[i].set(cand), pkt_cand),
            jnp.where(ready, pkt_start.at[i].set(start_val), pkt_start),
            jnp.where(ready, ch_new, ch),
        )

    pkt_state, pkt_pair, pkt_cand, pkt_start, _ = jax.lax.fori_loop(
        0, s.pkt_state.shape[0], act_one,
        (s.pkt_state, s.pkt_pair, s.pkt_cand, s.pkt_start, ch0))
    return s._replace(pkt_state=pkt_state, pkt_pair=pkt_pair,
                      pkt_cand=pkt_cand, pkt_start=pkt_start)


def _rates(c: EngineConsts, meta, pol, s: SimState):
    p_active = s.pkt_state == ACTIVE
    links = _route_links(c, s, p_active)
    pkt_rate = fairshare.rates(pol["traffic"], links, p_active,
                               _effective_link_bw(c, meta, s),
                               meta.intra_bw)
    t_active = s.task_state == ACTIVE
    vm = jnp.maximum(s.task_vm, 0)
    n_on_vm = jnp.zeros_like(c.vm_total_mips, jnp.int32).at[vm].add(
        t_active.astype(jnp.int32))
    share = c.vm_total_mips[vm] / jnp.maximum(n_on_vm[vm], 1).astype(jnp.float32)
    task_rate = jnp.where(t_active, jnp.minimum(c.vm_core_mips[vm], share), 0.0)
    if meta.has_failures:
        # belt-and-braces: a task stranded on a dead host executes nothing
        # (can only happen when EVERY host was dead at placement time)
        task_rate = jnp.where(
            s.host_dead[jnp.clip(c.vm_host[vm], 0,
                                 c.host_fail_t.shape[0] - 1)],
            0.0, task_rate)
    return pkt_rate, task_rate, links, p_active, t_active


def _finished(c: EngineConsts, meta, s: SimState) -> jnp.ndarray:
    all_done = jnp.all(~c.job_valid | (s.job_out_done >= c.job_n_out))
    return all_done | s.stalled | (s.steps >= meta.max_steps)


def _step(c: EngineConsts, meta, pol, s: SimState) -> SimState:
    if meta.has_failures:
        s = _apply_failures(c, pol, s)
    s = _admit_and_place(c, meta, pol, s)
    s = _activate(c, meta, pol, s)
    pkt_rate, task_rate, links, p_active, t_active = _rates(c, meta, pol, s)

    # earliest horizon (Eq. 4 generalized)
    dt_p = jnp.min(jnp.where(p_active & (pkt_rate > 0),
                             s.pkt_rem / pkt_rate, _INF))
    dt_t = jnp.min(jnp.where(t_active & (task_rate > 0),
                             s.task_rem / task_rate, _INF))
    future = (~s.job_admitted) & c.job_valid & (c.job_release > s.time)
    dt_r = jnp.min(jnp.where(future, c.job_release - s.time, _INF))
    dt = jnp.minimum(jnp.minimum(dt_p, dt_t), dt_r)
    if meta.has_failures:
        # fail/recover instants are rate breakpoints exactly like job
        # releases — they join the analytic min, no event heap needed
        # (DESIGN.md §7)
        def _next(ts):
            return jnp.min(jnp.where(ts > s.time, ts - s.time, _INF))

        dt_f = jnp.minimum(
            jnp.minimum(_next(c.host_fail_t), _next(c.host_recover_t)),
            jnp.minimum(_next(c.link_fail_t), _next(c.link_recover_t)))
        dt = jnp.minimum(dt, dt_f)
    stalled = jnp.isinf(dt)
    dt = jnp.where(stalled, 0.0, dt)

    # energy (power is constant over [t, t+dt))
    vm_safe = jnp.maximum(s.task_vm, 0)
    host_of_task = c.vm_host[vm_safe]
    mips_used = jnp.zeros_like(c.host_total_mips).at[host_of_task].add(
        jnp.where(t_active, task_rate, 0.0))
    util = jnp.clip(mips_used / jnp.maximum(c.host_total_mips, 1e-9), 0.0, 1.0)
    if meta.has_failures:
        util = jnp.where(s.host_dead, 0.0, util)  # dead hosts draw 0 W
    host_energy = s.host_energy + host_power(util, meta.energy) * dt
    host_busy = s.host_busy + jnp.where(util > 0, dt, 0.0)
    ch = fairshare.channel_counts(links, p_active, meta.n_links)
    live_link = (ch > 0).astype(jnp.int32)
    if meta.has_failures:
        live_link = jnp.where(s.link_dead, 0, live_link)  # port is down
    node_ports = jnp.zeros(meta.n_nodes, jnp.int32)
    node_ports = node_ports.at[c.link_src].add(live_link)
    node_ports = node_ports.at[c.link_dst].add(live_link)
    sw_ports = jax.lax.dynamic_slice_in_dim(node_ports, meta.n_hosts,
                                            meta.n_switches)
    switch_energy = s.switch_energy + switch_power(sw_ports, meta.energy) * dt

    if meta.has_failures:
        # per-job downtime: admitted, not done, and NOTHING of the job's
        # moves over [t, t+dt) — the failure-induced outage metric
        n_j = s.job_downtime.shape[0]
        prog_t = ((t_active & (task_rate > 0) & c.task_valid)
                  .astype(jnp.int32))
        prog_p = ((p_active & (pkt_rate > 0) & c.pkt_valid)
                  .astype(jnp.int32))
        job_prog = jnp.zeros(n_j, jnp.int32)
        job_prog = job_prog.at[jnp.maximum(c.task_job, 0)].max(prog_t)
        job_prog = job_prog.at[jnp.maximum(c.pkt_job, 0)].max(prog_p)
        job_live = (s.job_admitted & (s.job_out_done < c.job_n_out)
                    & c.job_valid)
        job_downtime = s.job_downtime + jnp.where(
            job_live & (job_prog == 0), dt, 0.0)
    else:
        job_downtime = s.job_downtime

    # advance
    time = s.time + dt
    pkt_rem = jnp.where(p_active, s.pkt_rem - pkt_rate * dt, s.pkt_rem)
    task_rem = jnp.where(t_active, s.task_rem - task_rate * dt, s.task_rem)
    pkt_tol = c.pkt_bits * 1e-6 + 1.0
    task_tol = c.task_mi * 1e-6 + 1e-6
    p_done_now = p_active & (pkt_rem <= pkt_tol)
    t_done_now = t_active & (task_rem <= task_tol)

    pkt_state = jnp.where(p_done_now, DONE, s.pkt_state)
    pkt_finish = jnp.where(p_done_now, time, s.pkt_finish)
    task_state = jnp.where(t_done_now, DONE, s.task_state)
    task_finish = jnp.where(t_done_now, time, s.task_finish)

    # completions feed gates
    feeds = jnp.maximum(c.pkt_feeds_task, 0)
    task_got = s.task_got.at[feeds].add(
        (p_done_now & (c.pkt_feeds_task >= 0)).astype(jnp.int32))
    out_pkt = p_done_now & (c.pkt_feeds_task < 0)
    job_of = jnp.maximum(c.pkt_job, 0)
    job_out_done = s.job_out_done.at[job_of].add(out_pkt.astype(jnp.int32))
    newly_job_done = (job_out_done >= c.job_n_out) & \
        (s.job_out_done < c.job_n_out) & c.job_valid
    job_done_t = jnp.where(newly_job_done, time, s.job_done_t)
    vm_load = s.vm_load.at[vm_safe].add(-t_done_now.astype(jnp.int32))

    return s._replace(
        time=time, steps=s.steps + 1, stalled=stalled,
        job_out_done=job_out_done, job_done_t=job_done_t,
        task_state=task_state, task_rem=task_rem, task_got=task_got,
        task_finish=task_finish,
        pkt_state=pkt_state, pkt_rem=pkt_rem, pkt_finish=pkt_finish,
        vm_load=vm_load, host_energy=host_energy, host_busy=host_busy,
        switch_energy=switch_energy, job_downtime=job_downtime)


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def make_packed_simulator(meta):
    """Returns ``run(consts, policy_dict) -> SimState`` with consts as an
    ARGUMENT, so a heterogeneous-scenario sweep can vmap over consts and
    policies together (see ``repro.scenarios.sweep``, DESIGN.md §5).

    ``meta`` is a ``SimMeta`` (a legacy meta dict is coerced): only static
    shapes + scalar params shared by every replica in the batch (padded
    maxima for a packed sweep).
    """
    meta = SimMeta.coerce(meta)

    def run(consts: EngineConsts, pol: Dict[str, jnp.ndarray]) -> SimState:
        s0 = init_state_from_consts(consts, meta.n_switches)

        def cond(s):
            return ~_finished(consts, meta, s)

        def body(s):
            new = _step(consts, meta, pol, s)
            live = ~_finished(consts, meta, s)
            return jax.tree_util.tree_map(
                lambda n, o: jnp.where(live, n, o), new, s)

        return jax.lax.while_loop(cond, body, s0)

    return run


def make_simulator(setup: SimSetup):
    """Returns a jit-able ``run(policy_dict) -> SimState`` closure."""
    consts, meta = make_consts(setup)
    run = make_packed_simulator(meta)
    return partial(run, consts)


# --- deprecated shims ------------------------------------------------------
# The unified front door is ``repro.api`` (DESIGN.md §6): ``Experiment``
# dispatches single / policy-batch / packed-scenario execution through one
# compiled-runner cache, so repeated calls with an equal ``SimMeta`` reuse
# the traced program.  These wrappers keep the old spellings working and are
# proven bit-identical to the Experiment path by tests/test_api.py.


def simulate(setup: SimSetup, policy=None) -> SimState:
    """Deprecated shim: run one replica via the cached runner
    (policy: PolicyConfig, dict of scalars, or None for defaults).
    Prefer ``repro.api.Experiment(scenarios=setup, policies=policy).run()``.
    """
    from ..api import runners  # local import: api sits above core
    consts, meta = make_consts(setup)
    return runners.get_runner(meta, "single")(consts, as_policy_arrays(policy))


def simulate_batch(setup: SimSetup, pols: Dict[str, jnp.ndarray]) -> SimState:
    """Deprecated shim: vmap over a policy sweep — every dict value has a
    leading replica dim (missing registered fields broadcast their default).
    Prefer ``repro.api.Experiment``."""
    from ..api import runners
    consts, meta = make_consts(setup)
    pols = as_policy_arrays(pols)
    width = max((v.shape[0] for v in pols.values() if v.ndim), default=1)
    pols = {k: v if v.ndim else jnp.broadcast_to(v, (width,))
            for k, v in pols.items()}
    return runners.get_runner(meta, "policy_batch")(consts, pols)


def simulate_scenarios(consts: EngineConsts, meta,
                       pols: Dict[str, jnp.ndarray]) -> SimState:
    """Deprecated shim: ZIPPED batch over packed consts — every consts array
    and every policy value shares one leading replica dim R, and replica i
    runs consts[i] under pols[i].  Build consts with
    ``scenarios.sweep.pack_setups``; for the full scenario×policy cross
    product prefer ``repro.api.Experiment`` (or ``sweep_grid``), which nests
    the vmaps so consts broadcast over the policy axis."""
    from ..api import runners
    return runners.get_runner(SimMeta.coerce(meta), "zipped")(consts, pols)
