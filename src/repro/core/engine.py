"""The discrete-event engine as a single ``lax.while_loop``.

CloudSim's event heap disappears: between events every rate (channel
bandwidth, VM MIPS share, power draw) is piecewise constant, so the next
event time is an analytic ``min`` over fixed-shape state tensors (paper
Eq. 4 generalized to packet finishes, task finishes and job releases).
One while-loop iteration = one event:

  failure/recovery transitions (DESIGN.md §7, traced only when a schedule
  has a finite instant) -> admission -> placement -> task activation ->
  packet activation (routed) -> rates -> dt = earliest horizon ->
  energy += power*dt -> advance -> completions

The step interior is (near-)fully data-parallel (DESIGN.md §8): admission
ranks released jobs against the concurrency budget in one stable sort,
placement resolves a whole batch of tasks by rank-plus-counter arithmetic
over the live-VM prefix-sum remap (with a compacted scan only for the
load-feedback least-used policy), packet activation iterates only the
ready set (the legacy hash route needs no feedback and vectorizes
entirely), and the per-step network tensors — route links, channel
counts, effective link bandwidth — are computed once and threaded through
rates and energy.  Sequential tie-break order is preserved everywhere, so
the kernel is bit-identical to the scalar event loop it replaced
(tests/test_engine_equiv.py).

Everything is vmap-safe: ``simulate_batch`` sweeps policy/seed vectors as one
tensor program (the beyond-paper capability — see DESIGN.md §2).

The static side of a run is described by a typed, hashable ``SimMeta``
(DESIGN.md §6); ``simulate``/``simulate_batch``/``simulate_scenarios`` are
kept as thin deprecated shims over the unified ``repro.api`` front door
(``Experiment`` + the compiled-runner cache).
"""
from __future__ import annotations

from functools import partial
from typing import Dict, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from . import fairshare
from .ctrlplane import no_ctrl
from .failures import no_degradation, no_failures
from .mapreduce import ACTIVE, DONE, INSTALLING, SimSetup, VOID, WAITING
from .energy import host_power, switch_power
from .policies import (INSTALL_PROACTIVE, JOBSEL_PRIORITY, JOBSEL_SJF,
                       MIG_CONGESTION, PLACE_RANDOM, PLACE_ROUND_ROBIN,
                       RECOVERY_RESTART, SPEC_ON, as_policy_arrays)
from .routing import (ROUTE_SDN, flow_hash_u32, legacy_route_choice,
                      sdn_route_choice)
from .simmeta import SimMeta

_INF = jnp.float32(jnp.inf)


def static_policy_value(x):
    """Python int value of a policy field when it is host-static (a plain
    int / numpy scalar), else ``None``.

    Fleet cohorts group lanes by branch-selecting policy fields (routing,
    traffic, placement) and pass them as Python ints, so the engine can
    specialize the dispatch at trace time — under ``vmap`` a ``lax.cond``
    with a batched predicate lowers to a select that EXECUTES both
    branches, which is exactly the batch-wall pathology the fleet path
    exists to avoid (DESIGN.md §9).  Traced fields keep the vmap-safe
    dynamic dispatch unchanged."""
    if isinstance(x, (bool, int, np.integer)):
        return int(x)
    if isinstance(x, np.ndarray) and x.ndim == 0:
        return int(x)
    return None


def job_valid_mask(job_n_out):
    """A job slot is live iff it expects output packets — the ONE definition
    of job validity, shared by make_consts and the packed-sweep builder."""
    return job_n_out > 0


def task_rank_in_job_np(task_job) -> np.ndarray:
    """Host-side: position of each task among the tasks sharing its job id,
    in task-index order (pad tasks form their own ``-1`` group).  Static
    per setup — shared by make_consts and the packed-sweep builder."""
    tj = np.asarray(task_job, np.int64)
    order = np.argsort(tj, kind="stable")
    g = tj[order]
    n = g.shape[0]
    starts = np.r_[0, np.flatnonzero(g[1:] != g[:-1]) + 1]
    sizes = np.diff(np.r_[starts, n])
    out = np.empty(n, np.int32)
    out[order] = (np.arange(n) - np.repeat(starts, sizes)).astype(np.int32)
    return out


def job_n_tasks_np(task_job, task_valid, n_jobs: int) -> np.ndarray:
    """Host-side: valid-task count per job (static per setup)."""
    tj = np.asarray(task_job, np.int64)
    tv = np.asarray(task_valid, bool)
    return np.bincount(tj[tv & (tj >= 0)],
                       minlength=n_jobs).astype(np.int32)[:n_jobs]


class EngineConsts(NamedTuple):
    """Static (replica-shared) tensors, baked from SimSetup."""

    # routing
    routes: jnp.ndarray      # [n_nodes^2, K, H]
    n_cand: jnp.ndarray      # [n_nodes^2]
    link_bw: jnp.ndarray     # [n_links]
    link_src: jnp.ndarray
    link_dst: jnp.ndarray
    # cluster
    vm_host: jnp.ndarray
    vm_total_mips: jnp.ndarray
    vm_core_mips: jnp.ndarray
    host_total_mips: jnp.ndarray
    # jobs / tasks / packets (see mapreduce.py)
    job_release: jnp.ndarray
    job_total_mi: jnp.ndarray
    job_priority: jnp.ndarray
    job_n_out: jnp.ndarray
    job_valid: jnp.ndarray
    task_job: jnp.ndarray
    task_kind: jnp.ndarray
    task_mi: jnp.ndarray
    task_need: jnp.ndarray
    task_valid: jnp.ndarray
    # position of each task among its job's tasks (index order) and each
    # job's valid-task count: the batched placement pass turns admission
    # rank + these into placement positions by pure arithmetic — no
    # per-step sort over the task axis (DESIGN.md §8)
    task_rank_in_job: jnp.ndarray  # int32 [n_tasks]
    job_n_tasks: jnp.ndarray       # int32 [n_jobs]
    pkt_job: jnp.ndarray
    pkt_phase: jnp.ndarray
    pkt_bits: jnp.ndarray
    pkt_gate_task: jnp.ndarray
    pkt_feeds_task: jnp.ndarray
    pkt_src_task: jnp.ndarray
    pkt_dst_task: jnp.ndarray
    pkt_valid: jnp.ndarray
    # scalars (static python ints/floats hidden in jnp for pytree friendliness)
    n_hosts: jnp.ndarray
    n_switches: jnp.ndarray
    storage_node: jnp.ndarray
    # live VM count — may be < len(vm_host) when consts are padded to a
    # common shape for a multi-scenario sweep (DESIGN.md §5); placement
    # must never pick a pad VM slot.
    n_vms: jnp.ndarray
    # failure schedule (DESIGN.md §7): outage window [fail_t, recover_t)
    # per host / per directed link; inf = never.  Just more piecewise-
    # constant rate breakpoints for the analytic dt min.
    host_fail_t: jnp.ndarray     # f32 [n_hosts]
    host_recover_t: jnp.ndarray  # f32 [n_hosts]
    link_fail_t: jnp.ndarray     # f32 [n_links]
    link_recover_t: jnp.ndarray  # f32 [n_links]
    # the same instants concatenated ([2*n_hosts + 2*n_links], inf=never):
    # the dt horizon mins over ONE tensor per step (DESIGN.md §8)
    fail_breaks: jnp.ndarray
    # gray-failure degradation schedule (DESIGN.md §13): a host runs at
    # host_deg_factor x MIPS on [host_slow_t, host_restore_t), a directed
    # link at link_deg_factor x bandwidth on its window — piecewise-
    # constant multipliers joining the same analytic dt min as the outage
    # tensors above.  inf slow_t / factor 1.0 = never.
    host_slow_t: jnp.ndarray     # f32 [n_hosts]
    host_restore_t: jnp.ndarray  # f32 [n_hosts]
    host_deg_factor: jnp.ndarray # f32 [n_hosts]
    link_slow_t: jnp.ndarray     # f32 [n_links]
    link_restore_t: jnp.ndarray  # f32 [n_links]
    link_deg_factor: jnp.ndarray # f32 [n_links]
    # live-window slow/restore instants concatenated (inert windows masked
    # to inf) — one masked min per step, mirroring fail_breaks
    deg_breaks: jnp.ndarray      # f32 [2*n_hosts + 2*n_links]
    # control plane (DESIGN.md §10): scalar resource parameters — the
    # identity values (0 latency, inf rate, inf threshold) when the replica
    # carries no CtrlPlaneConfig, so a packed sweep can mix configs.
    # ctrl_on gates the install/pre-pin paths per replica: an identity-
    # config lane in a mixed batch must bypass the controller entirely
    # (zero counters), not merely pay zero latency for it
    ctrl_on: jnp.ndarray        # bool []: this replica's config is live
    ctrl_latency: jnp.ndarray   # f32 []: flow-mod propagation latency (s)
    ctrl_rate: jnp.ndarray      # f32 []: controller rule installs per second
    mig_threshold: jnp.ndarray  # f32 []: aggregate route-hop migration trigger
    mig_cost: jnp.ndarray       # f32 []: compute pause per migration (s)
    mig_cooldown: jnp.ndarray   # f32 []: min quiet time between migrations
    mig_limit: jnp.ndarray      # i32 []: total migration budget per run
    # candidate-0 hop count per (src*n_nodes+dst) pair — the migration
    # policy's distance estimate; 0 on the diagonal, UNREACHABLE_HOPS where
    # no route exists
    pair_hops: jnp.ndarray      # i32 [n_nodes^2]
    # controller failover (DESIGN.md §13): the primary controller is down
    # on [ctrl_fail_t, ctrl_recover_t); rule requests inside the first
    # ctrl_failover_delay seconds of the outage park until the backup's
    # leader election completes, then the backup serves at its own
    # rate/latency.  inf fail_t = never (the scalars are inert).
    ctrl_fail_t: jnp.ndarray         # f32 []
    ctrl_recover_t: jnp.ndarray      # f32 []
    ctrl_failover_delay: jnp.ndarray # f32 []
    ctrl_backup_rate: jnp.ndarray    # f32 []
    ctrl_backup_latency: jnp.ndarray # f32 []


class SimState(NamedTuple):
    time: jnp.ndarray
    steps: jnp.ndarray
    stalled: jnp.ndarray
    place_counter: jnp.ndarray
    # jobs
    job_admitted: jnp.ndarray
    job_admit_t: jnp.ndarray
    job_out_done: jnp.ndarray
    job_done_t: jnp.ndarray
    # tasks
    task_state: jnp.ndarray
    task_rem: jnp.ndarray
    task_got: jnp.ndarray
    task_vm: jnp.ndarray
    task_start: jnp.ndarray
    task_finish: jnp.ndarray
    # packets
    pkt_state: jnp.ndarray
    pkt_rem: jnp.ndarray
    pkt_pair: jnp.ndarray
    pkt_cand: jnp.ndarray
    pkt_start: jnp.ndarray
    pkt_finish: jnp.ndarray
    # vms / energy
    vm_load: jnp.ndarray
    host_energy: jnp.ndarray
    host_busy: jnp.ndarray
    switch_energy: jnp.ndarray
    # failure & recovery (DESIGN.md §7)
    host_dead: jnp.ndarray      # bool [n_hosts]: inside outage window
    link_dead: jnp.ndarray      # bool [n_links]
    task_restarts: jnp.ndarray  # int32 [n_tasks]: YARN re-executions
    pkt_reroutes: jnp.ndarray   # int32 [n_packets]: failure-driven reverts
    job_downtime: jnp.ndarray   # f32 [n_jobs]: admitted-but-zero-progress s
    # control plane (DESIGN.md §10).  All of it rides in the carry so the
    # flow-table / controller-queue evolution stays inside the one
    # while_loop; with has_ctrl=False every field passes through untouched.
    vm_host: jnp.ndarray        # i32 [n_vms]: LIVE placement (migration
    #                             re-homes VMs; == c.vm_host when static)
    ftab_pair: jnp.ndarray      # i32 [n_switches, ctrl_slots]: cached pair
    #                             per flow-table slot (-1 = empty)
    ftab_ready: jnp.ndarray     # f32 [n_switches, ctrl_slots]: instant the
    #                             slot's rule finishes installing
    ftab_stamp: jnp.ndarray     # i32 [n_switches, ctrl_slots]: LRU stamp
    ctrl_busy: jnp.ndarray      # f32 []: controller next-free instant
    ctrl_stamp: jnp.ndarray     # i32 []: monotone LRU counter
    ctrl_installs: jnp.ndarray  # i32 []: rule installs requested
    ctrl_evictions: jnp.ndarray # i32 []: rules LRU-displaced (or uncached)
    ctrl_reinstalls: jnp.ndarray  # i32 []: installs for churn-evicted flows
    ctrl_queue_wait: jnp.ndarray  # f32 []: summed wait in the ctrl queue
    pkt_ready_t: jnp.ndarray    # f32 [n_packets]: INSTALLING wake instant
    pkt_install_wait: jnp.ndarray  # f32 [n_packets]: summed install stall
    vm_mig_until: jnp.ndarray   # f32 [n_vms]: migration compute-pause end
    vm_migrations: jnp.ndarray  # i32 [n_vms]: re-homings taken
    # gray failures, speculation & failover (DESIGN.md §13).  The spec_*
    # tensors are the statically pre-allocated per-job clone slots
    # ([n_jobs * SimMeta.spec_slots], zero-length when speculation is
    # structurally off); everything passes through untouched when the
    # corresponding meta switch is off.
    degraded_time: jnp.ndarray  # f32 []: time with any live deg window
    spec_of: jnp.ndarray        # i32 [S]: cloned original task (-1 free)
    spec_vm: jnp.ndarray        # i32 [S]: VM the clone runs on
    spec_rem: jnp.ndarray       # f32 [S]: clone's remaining MI
    spec_start: jnp.ndarray     # f32 [S]: clone launch instant
    task_cloned: jnp.ndarray    # bool [n_tasks]: ever speculated (once)
    spec_launches: jnp.ndarray  # i32 []: clones launched
    spec_wins: jnp.ndarray      # i32 []: clones that beat their original
    spec_wasted: jnp.ndarray    # f32 []: losing-copy runtime (VM-seconds)
    ctrl_failovers: jnp.ndarray # i32 []: primary-controller outages hit
    ctrl_failover_park: jnp.ndarray  # f32 []: install delay added by the
    #                             leader-election gap (summed)


def default_max_steps(setup: SimSetup) -> int:
    """Step cap: the no-failure event bound, plus — when a failure schedule
    is present — one full re-execution budget per fail/recover instant
    (each failure can revert every in-flight task/packet at most once).
    The failure-mode cap is quantized to the next power of two so that
    schedules differing only in outage COUNT share a ``SimMeta`` and hit
    the compiled-runner cache (DESIGN.md §6)."""
    base = 4 * (setup.n_packets + setup.n_tasks) + 4 * setup.n_jobs + 64
    sched = setup.failures
    steps = base
    quantize = False
    if sched is not None and sched.any_failures:
        steps = base * (1 + sched.n_events) + 2 * sched.n_events
        quantize = True
    deg = setup.degradation
    if deg is not None and deg.any_degradation:
        # a degradation window reverts nothing — it only adds its
        # slow/restore breakpoints as extra event steps (DESIGN.md §13)
        steps = steps + 4 * deg.n_events + 8
        quantize = True
    if setup.spec_slots > 0:
        # each task is cloned at most once: one clone finish breakpoint
        # plus one cleanup-visible cancellation step per task bounds the
        # speculation event budget (DESIGN.md §13)
        steps = steps + 2 * setup.n_tasks
        quantize = True
    cfg = setup.ctrl
    if cfg is not None and cfg.any_ctrl:
        # reactive installation splits each packet activation into a
        # park + wake pair (one extra breakpoint per packet), and every
        # migration can revert + re-run every in-flight packet once
        # (DESIGN.md §10) — same quantization rationale as failures
        steps = 2 * steps + cfg.mig_limit * (3 * setup.n_packets + 4)
        quantize = True
    if quantize:
        return 1 << (steps - 1).bit_length()
    return steps


UNREACHABLE_HOPS = 1 << 20  # pair_hops sentinel: no candidate route


def pair_hops_np(route_len, n_cand, n_nodes: int) -> np.ndarray:
    """Host-side candidate-0 hop count per node pair (the migration cost
    estimate, DESIGN.md §10): 0 on the diagonal (intra-host is free),
    ``UNREACHABLE_HOPS`` where no route exists.  Static per route table —
    shared by make_consts and the packed-sweep builder."""
    hops = np.where(np.asarray(n_cand) > 0,
                    np.asarray(route_len)[:, 0], UNREACHABLE_HOPS)
    hops = hops.astype(np.int32).copy()
    diag = np.arange(n_nodes, dtype=np.int64)
    hops[diag * n_nodes + diag] = 0
    return hops


def make_consts(setup: SimSetup) -> tuple[EngineConsts, SimMeta]:
    rt, cl = setup.route_table, setup.cluster
    sched = setup.failures
    if sched is None:
        sched = no_failures(cl.topo.n_hosts, cl.topo.n_links)
    else:
        sched.validate(cl.topo.n_hosts, cl.topo.n_links)
    deg = setup.degradation
    if deg is None:
        deg = no_degradation(cl.topo.n_hosts, cl.topo.n_links)
    else:
        deg.validate(cl.topo.n_hosts, cl.topo.n_links)
    cfg = (setup.ctrl or no_ctrl()).validate()
    consts = EngineConsts(
        routes=jnp.asarray(rt.routes),
        n_cand=jnp.asarray(rt.n_cand),
        link_bw=jnp.asarray(cl.topo.link_bw),
        link_src=jnp.asarray(cl.topo.link_src),
        link_dst=jnp.asarray(cl.topo.link_dst),
        vm_host=jnp.asarray(cl.vm_host),
        vm_total_mips=jnp.asarray(cl.vm_total_mips),
        vm_core_mips=jnp.asarray(cl.vm_core_mips),
        host_total_mips=jnp.asarray(cl.host_total_mips),
        job_release=jnp.asarray(setup.job_release),
        job_total_mi=jnp.asarray(setup.job_total_mi),
        job_priority=jnp.asarray(setup.job_priority),
        job_n_out=jnp.asarray(setup.job_n_out),
        job_valid=jnp.asarray(job_valid_mask(setup.job_n_out)),
        task_job=jnp.asarray(setup.task_job),
        task_kind=jnp.asarray(setup.task_kind),
        task_mi=jnp.asarray(setup.task_mi),
        task_need=jnp.asarray(setup.task_need),
        task_valid=jnp.asarray(setup.task_valid),
        task_rank_in_job=jnp.asarray(task_rank_in_job_np(setup.task_job)),
        job_n_tasks=jnp.asarray(job_n_tasks_np(
            setup.task_job, setup.task_valid, setup.n_jobs)),
        pkt_job=jnp.asarray(setup.pkt_job),
        pkt_phase=jnp.asarray(setup.pkt_phase),
        pkt_bits=jnp.asarray(setup.pkt_bits),
        pkt_gate_task=jnp.asarray(setup.pkt_gate_task),
        pkt_feeds_task=jnp.asarray(setup.pkt_feeds_task),
        pkt_src_task=jnp.asarray(setup.pkt_src_task),
        pkt_dst_task=jnp.asarray(setup.pkt_dst_task),
        pkt_valid=jnp.asarray(setup.pkt_valid),
        n_hosts=jnp.asarray(cl.topo.n_hosts, jnp.int32),
        n_switches=jnp.asarray(cl.topo.n_switches, jnp.int32),
        storage_node=jnp.asarray(cl.storage_node, jnp.int32),
        n_vms=jnp.asarray(int(cl.vm_host.shape[0]), jnp.int32),
        host_fail_t=jnp.asarray(sched.host_fail_t, jnp.float32),
        host_recover_t=jnp.asarray(sched.host_recover_t, jnp.float32),
        link_fail_t=jnp.asarray(sched.link_fail_t, jnp.float32),
        link_recover_t=jnp.asarray(sched.link_recover_t, jnp.float32),
        fail_breaks=jnp.asarray(sched.instants(), jnp.float32),
        host_slow_t=jnp.asarray(deg.host_slow_t, jnp.float32),
        host_restore_t=jnp.asarray(deg.host_restore_t, jnp.float32),
        host_deg_factor=jnp.asarray(deg.host_factor, jnp.float32),
        link_slow_t=jnp.asarray(deg.link_slow_t, jnp.float32),
        link_restore_t=jnp.asarray(deg.link_restore_t, jnp.float32),
        link_deg_factor=jnp.asarray(deg.link_factor, jnp.float32),
        deg_breaks=jnp.asarray(deg.instants(), jnp.float32),
        ctrl_on=jnp.asarray(cfg.any_ctrl),
        ctrl_latency=jnp.asarray(cfg.install_latency, jnp.float32),
        ctrl_rate=jnp.asarray(cfg.ctrl_rate, jnp.float32),
        mig_threshold=jnp.asarray(cfg.mig_threshold, jnp.float32),
        mig_cost=jnp.asarray(cfg.mig_cost, jnp.float32),
        mig_cooldown=jnp.asarray(cfg.mig_cooldown, jnp.float32),
        mig_limit=jnp.asarray(cfg.mig_limit, jnp.int32),
        pair_hops=jnp.asarray(pair_hops_np(rt.route_len, rt.n_cand,
                                           cl.topo.n_nodes)),
        ctrl_fail_t=jnp.asarray(cfg.ctrl_fail_t, jnp.float32),
        ctrl_recover_t=jnp.asarray(cfg.ctrl_recover_t, jnp.float32),
        ctrl_failover_delay=jnp.asarray(cfg.failover_delay, jnp.float32),
        ctrl_backup_rate=jnp.asarray(cfg.backup_rate, jnp.float32),
        ctrl_backup_latency=jnp.asarray(cfg.backup_latency, jnp.float32),
    )
    meta = SimMeta(
        n_nodes=cl.topo.n_nodes,
        n_links=cl.topo.n_links,
        n_hosts=cl.topo.n_hosts,
        n_switches=cl.topo.n_switches,
        n_vms=int(cl.vm_host.shape[0]),
        intra_bw=cl.intra_bw,
        energy=cl.energy,
        max_steps=default_max_steps(setup),
        has_failures=sched.any_failures,
        has_ctrl=cfg.any_ctrl,
        ctrl_slots=cfg.table_slots if cfg.any_ctrl else 0,
        has_degradation=deg.any_degradation,
        spec_slots=setup.spec_slots,
    )
    return consts, meta


def init_state_from_consts(c: EngineConsts, n_switches: int,
                           ctrl_slots: int = 0,
                           spec_slots: int = 0) -> SimState:
    """t=0 state derived purely from (possibly padded) const tensors.

    ``n_switches`` is the STATIC switch-tensor length (padded max in a
    multi-scenario sweep) — it cannot be read off any consts array, every
    other shape can.  Pad job/task/packet slots start VOID/zero so they are
    inert for the whole run (DESIGN.md §5).  ``ctrl_slots`` is the static
    per-switch flow-table width (``SimMeta.ctrl_slots``) — 0 gives the
    flow-table tensors a zero-length slot axis (DESIGN.md §10).
    ``spec_slots`` is the static per-job speculative-clone slot count
    (``SimMeta.spec_slots``) — 0 gives the clone tensors a zero-length
    axis (DESIGN.md §13).
    """
    n_j = c.job_release.shape[0]
    n_t = c.task_job.shape[0]
    n_p = c.pkt_job.shape[0]
    n_s = n_j * spec_slots
    f = jnp.float32
    return SimState(
        time=f(0.0), steps=jnp.int32(0), stalled=jnp.asarray(False),
        place_counter=jnp.int32(0),
        job_admitted=jnp.zeros(n_j, bool),
        job_admit_t=jnp.full(n_j, jnp.nan, f),
        job_out_done=jnp.zeros(n_j, jnp.int32),
        job_done_t=jnp.full(n_j, jnp.nan, f),
        task_state=jnp.where(c.task_valid, WAITING, VOID).astype(jnp.int32),
        task_rem=c.task_mi.astype(f),
        task_got=jnp.zeros(n_t, jnp.int32),
        task_vm=jnp.full(n_t, -1, jnp.int32),
        task_start=jnp.full(n_t, jnp.nan, f),
        task_finish=jnp.full(n_t, jnp.nan, f),
        pkt_state=jnp.where(c.pkt_valid, WAITING, VOID).astype(jnp.int32),
        pkt_rem=c.pkt_bits.astype(f),
        pkt_pair=jnp.full(n_p, -1, jnp.int32),
        pkt_cand=jnp.full(n_p, -1, jnp.int32),
        pkt_start=jnp.full(n_p, jnp.nan, f),
        pkt_finish=jnp.full(n_p, jnp.nan, f),
        vm_load=jnp.zeros(c.vm_host.shape[0], jnp.int32),
        host_energy=jnp.zeros(c.host_total_mips.shape[0], f),
        host_busy=jnp.zeros(c.host_total_mips.shape[0], f),
        switch_energy=jnp.zeros(n_switches, f),
        host_dead=jnp.zeros(c.host_fail_t.shape[0], bool),
        link_dead=jnp.zeros(c.link_fail_t.shape[0], bool),
        task_restarts=jnp.zeros(n_t, jnp.int32),
        pkt_reroutes=jnp.zeros(n_p, jnp.int32),
        job_downtime=jnp.zeros(n_j, f),
        vm_host=c.vm_host.astype(jnp.int32),
        ftab_pair=jnp.full((n_switches, ctrl_slots), -1, jnp.int32),
        ftab_ready=jnp.zeros((n_switches, ctrl_slots), f),
        ftab_stamp=jnp.zeros((n_switches, ctrl_slots), jnp.int32),
        ctrl_busy=f(0.0),
        ctrl_stamp=jnp.int32(0),
        ctrl_installs=jnp.int32(0),
        ctrl_evictions=jnp.int32(0),
        ctrl_reinstalls=jnp.int32(0),
        ctrl_queue_wait=f(0.0),
        pkt_ready_t=jnp.full(n_p, jnp.inf, f),
        pkt_install_wait=jnp.zeros(n_p, f),
        vm_mig_until=jnp.zeros(c.vm_host.shape[0], f),
        vm_migrations=jnp.zeros(c.vm_host.shape[0], jnp.int32),
        degraded_time=f(0.0),
        spec_of=jnp.full(n_s, -1, jnp.int32),
        spec_vm=jnp.full(n_s, -1, jnp.int32),
        spec_rem=jnp.zeros(n_s, f),
        spec_start=jnp.zeros(n_s, f),
        task_cloned=jnp.zeros(n_t, bool),
        spec_launches=jnp.int32(0),
        spec_wins=jnp.int32(0),
        spec_wasted=f(0.0),
        ctrl_failovers=jnp.int32(0),
        ctrl_failover_park=f(0.0),
    )


def init_state(setup: SimSetup) -> SimState:
    consts, meta = make_consts(setup)
    return init_state_from_consts(consts, meta.n_switches, meta.ctrl_slots,
                                  meta.spec_slots)


# ---------------------------------------------------------------------------
# step phases
# ---------------------------------------------------------------------------


def _effective_link_bw(c: EngineConsts, meta, s: SimState) -> jnp.ndarray:
    """Per-link capacity with gray-degradation windows applied (DESIGN.md
    §13) and dead links at 0 (DESIGN.md §7).  Without degradation and
    failures this IS ``c.link_bw`` — the off-switch trace is unchanged.
    SDN's bottleneck route choice reads this tensor, so it steers around
    degraded links exactly like dead ones; the legacy static hash is
    degradation-blind."""
    bw = c.link_bw
    if meta.has_degradation:
        slow = (c.link_slow_t <= s.time) & (s.time < c.link_restore_t)
        bw = jnp.where(slow, bw * c.link_deg_factor, bw)
    if meta.has_failures:
        bw = jnp.where(s.link_dead, 0.0, bw)
    return bw


def _host_deg_factor(c: EngineConsts, s: SimState) -> jnp.ndarray:
    """Per-host MIPS multiplier from the gray-degradation windows
    (DESIGN.md §13): ``host_deg_factor`` inside ``[slow_t, restore_t)``,
    1.0 outside.  Only traced when ``meta.has_degradation``."""
    slow = (c.host_slow_t <= s.time) & (s.time < c.host_restore_t)
    return jnp.where(slow, c.host_deg_factor, jnp.float32(1.0))


def _effective_host_mips(c: EngineConsts, meta, s: SimState) -> jnp.ndarray:
    """Per-host MIPS capacity with gray-degradation windows applied
    (DESIGN.md §13) — the compute-side twin of ``_effective_link_bw``.
    Feeds the energy utilization denominator: a saturated degraded host
    draws full power for less work (the gray-failure energy story).
    Without degradation this IS ``c.host_total_mips``."""
    if meta.has_degradation:
        return c.host_total_mips * _host_deg_factor(c, s)
    return c.host_total_mips


def _vm_host(c: EngineConsts, meta, s: SimState) -> jnp.ndarray:
    """Effective VM -> host placement: the MUTABLE ``s.vm_host`` when the
    control plane is on (migration re-homes VMs — DESIGN.md §10), else the
    static ``c.vm_host`` — the no-ctrl trace is unchanged."""
    if meta.has_ctrl:
        return s.vm_host
    return c.vm_host


def _apply_failures(c: EngineConsts, meta, pol, s: SimState, cache):
    """Fire every fail/recover transition whose instant has been reached.

    Failure instants join the dt horizon (``_step``), so ``s.time`` lands
    exactly on each one; here — at the top of the next iteration — the dead
    masks are recomputed from the schedule and the DELTA vs the previous
    masks drives the one-shot transitions (DESIGN.md §7):

      * WAITING/ACTIVE tasks on a newly-dead host revert to WAITING and
        unplace (``task_vm=-1``) — YARN re-execution on heartbeat loss;
        under ``recovery=restart`` their progress is lost, under ``resume``
        (beyond-paper checkpointing) ``task_rem`` survives.
      * In-flight packets whose chosen route crosses a newly-dead link
        revert to WAITING for re-routing (bits already delivered survive:
        the stream resumes on the new route).
      * In-flight packets whose src/dst HOST newly died revert too — the
        connection died with the endpoint — and retransmit from scratch
        under ``restart``.

    DONE work is never reverted (completed outputs are durable — the SAN
    holds T3 results, map outputs are re-fetchable); recovery instants need
    no transition, the masks simply clear.

    The revert scans (per-packet route intersection, per-task host lookup)
    only matter on the handful of steps where something newly died, so
    they sit behind a ``lax.cond`` on the death delta — recovery-only and
    steady-state steps just refresh the dead masks (DESIGN.md §8).
    """
    t = s.time
    host_dead = (c.host_fail_t <= t) & (t < c.host_recover_t)
    link_dead = (c.link_fail_t <= t) & (t < c.link_recover_t)
    new_h = host_dead & ~s.host_dead
    new_l = link_dead & ~s.link_dead
    s = s._replace(host_dead=host_dead, link_dead=link_dead)
    restart = pol["recovery"] == RECOVERY_RESTART

    def transitions(args):
        s, nc0 = args
        # packets first: endpoints must resolve against the ACTIVATION-time
        # placement, i.e. before any task unplaces below.
        n_hosts_pad = c.host_fail_t.shape[0]
        src_node, dst_node = _pkt_endpoints(c, meta, s)
        p_active = s.pkt_state == ACTIVE
        if meta.has_ctrl:
            # a routed packet is also one parked in INSTALLING or one the
            # proactive pass pre-pinned while WAITING (DESIGN.md §10) —
            # a dead link/endpoint invalidates those routes too
            routed = (p_active | (s.pkt_state == INSTALLING)
                      | ((s.pkt_state == WAITING) & (s.pkt_cand >= 0)))
        else:
            routed = p_active
        links = _route_links(c, s, routed)
        route_hit = routed & jnp.any(
            (links >= 0) & new_l[jnp.maximum(links, 0)], axis=-1)

        def _endpoint_died(node):
            return (node < c.n_hosts) & new_h[jnp.clip(node, 0,
                                                       n_hosts_pad - 1)]

        ep_hit = routed & (_endpoint_died(src_node)
                           | _endpoint_died(dst_node))
        hit_p = route_hit | ep_hit
        pkt_state = jnp.where(hit_p, WAITING, s.pkt_state)
        pkt_rem = jnp.where(ep_hit & restart, c.pkt_bits.astype(jnp.float32),
                            s.pkt_rem)
        pkt_pair = jnp.where(hit_p, -1, s.pkt_pair)
        pkt_cand = jnp.where(hit_p, -1, s.pkt_cand)
        pkt_reroutes = s.pkt_reroutes + hit_p.astype(jnp.int32)
        if meta.has_ctrl:
            # a reverted INSTALLING packet re-requests its rules later
            s = s._replace(pkt_ready_t=jnp.where(hit_p, jnp.inf,
                                                 s.pkt_ready_t))
            # only the packets that were ACTIVE hold channels to release
            hit_drop = hit_p & p_active
        else:
            hit_drop = hit_p

        # tasks on newly-dead hosts
        vm_safe = jnp.maximum(s.task_vm, 0)
        task_host = jnp.clip(_vm_host(c, meta, s)[vm_safe], 0,
                             n_hosts_pad - 1)
        hit_t = (c.task_valid & (s.task_vm >= 0) & new_h[task_host]
                 & ((s.task_state == ACTIVE) | (s.task_state == WAITING)))
        task_state = jnp.where(hit_t, WAITING, s.task_state)
        task_rem = jnp.where(hit_t & restart, c.task_mi.astype(jnp.float32),
                             s.task_rem)
        task_start = jnp.where(hit_t, jnp.nan, s.task_start)
        # one-hot contraction, not a scatter: this runs EVERY step under a
        # vmapped cond, and batched scatters serialize per lane
        vm_iota = jnp.arange(s.vm_load.shape[0], dtype=jnp.int32)
        vm_load = s.vm_load - jnp.sum(
            (vm_safe[:, None] == vm_iota[None, :]) & hit_t[:, None],
            axis=0).astype(jnp.int32)
        task_vm = jnp.where(hit_t, -1, s.task_vm)
        task_restarts = s.task_restarts + hit_t.astype(jnp.int32)

        s = s._replace(
            pkt_state=pkt_state, pkt_rem=pkt_rem, pkt_pair=pkt_pair,
            pkt_cand=pkt_cand, pkt_reroutes=pkt_reroutes,
            task_state=task_state, task_rem=task_rem, task_start=task_start,
            task_vm=task_vm, vm_load=vm_load, task_restarts=task_restarts)
        # reverted packets left the active set: subtract exactly their
        # channel contributions via a compacted per-packet scan (loop
        # length = the revert count, zero on recovery-only steps).  The
        # carried nc is maintained exactly by activation/completion, so
        # this equals a from-scratch recount bit-for-bit — but a recount's
        # [n_p, H, n_links] one-hot runs EVERY step under a vmapped cond
        # (DESIGN.md §9) and dominated the failure-grid fleet profile.
        n_p = hit_p.shape[0]
        pidx = jnp.arange(n_p, dtype=jnp.int32)
        liota = jnp.arange(meta.n_links, dtype=jnp.int32)

        def drop_one(k, carry):
            nc, cursor = carry
            i = jnp.min(jnp.where(hit_drop & (pidx > cursor), pidx, n_p))
            links_k = links[jnp.minimum(i, n_p - 1)]
            nc = nc - jnp.sum((links_k[:, None] == liota[None, :])
                              .astype(jnp.int32), axis=0)
            return nc, i

        nc, _ = jax.lax.fori_loop(0, jnp.sum(hit_drop.astype(jnp.int32)),
                                  drop_one, (nc0, jnp.int32(-1)))
        return s, nc

    s, nc = jax.lax.cond(jnp.any(new_h) | jnp.any(new_l), transitions,
                         lambda args: args, (s, cache["nc"]))
    return s, {**cache, "nc": nc}


def _place_batch(c: EngineConsts, meta, pol, aux, s: SimState, mine, pos,
                 vm_live, n_live) -> SimState:
    """Place every task in ``mine`` preserving the sequential placement
    order.  ``pos`` is each mine-task's 0-based position in that order
    (garbage outside ``mine`` — masked here), computed by the caller with
    prefix-sum arithmetic so no per-step sort is needed (DESIGN.md §8).

    Round-robin and random placement need no load feedback, so their picks
    are pure rank-plus-counter / hash arithmetic against the k-th-live VM
    remap.  Least-used must see each earlier placement's load bump, so it
    runs a compacted scan over the tasks-to-place only (loop length = the
    live placement count, not the padded task axis).

    Nothing axis-wide happens outside the branch actually taken: the
    vectorized picks (and the live-VM remap they index) build inside
    ``place_vec``, and the least-used scan finds its k-th task by a
    per-trip masked argmax instead of a precomputed inverse-permutation
    scatter — under a vmapped cond this body runs EVERY step, and a
    batched scatter serializes one row per lane (DESIGN.md §9)."""
    counter0 = s.place_counter
    n_mine = jnp.sum(mine.astype(jnp.int32))
    mod = jnp.maximum(n_live, 1)

    def place_vec(_):
        # kth[k] = slot index of the k-th live VM (stable sort: live slots
        # first in ascending index order, so a ``% mod`` pick never lands
        # on a dead/pad slot) — same values the old prefix-sum scatter
        # produced
        kth = jnp.argsort(~vm_live)
        rr_pick = kth[(counter0 + pos) % mod]
        rnd_pick = kth[aux["task_hash"] % mod]
        vec_pick = jnp.where(pol["placement"] == PLACE_ROUND_ROBIN,
                             rr_pick, rnd_pick)
        task_vm = jnp.where(mine, vec_pick, s.task_vm)
        vm_load = s.vm_load.at[
            jnp.where(mine, vec_pick, meta.n_vms)].add(1, mode="drop")
        return vm_load, task_vm

    def place_scan(_):
        imax = jnp.iinfo(jnp.int32).max

        def place_one(k, carry):
            vm_load, task_vm = carry
            t = jnp.argmax(mine & (pos == k)).astype(jnp.int32)
            pick = jnp.argmin(jnp.where(vm_live, vm_load, imax)
                              ).astype(jnp.int32)
            return vm_load.at[pick].add(1), task_vm.at[t].set(pick)

        return jax.lax.fori_loop(0, n_mine, place_one,
                                 (s.vm_load, s.task_vm))

    # any placement id that is neither round-robin nor random falls to the
    # load-feedback scan — same fallback the scalar kernel had.  A
    # host-static placement id (fleet cohorts — DESIGN.md §9) picks the
    # branch at trace time so vmap never builds the unused one.
    placement_static = static_policy_value(pol["placement"])
    if placement_static is not None:
        branch = (place_scan if placement_static not in
                  (PLACE_ROUND_ROBIN, PLACE_RANDOM) else place_vec)
        vm_load, task_vm = branch(None)
    else:
        use_scan = ((pol["placement"] != PLACE_ROUND_ROBIN)
                    & (pol["placement"] != PLACE_RANDOM))
        vm_load, task_vm = jax.lax.cond(use_scan, place_scan, place_vec,
                                        None)
    return s._replace(vm_load=vm_load, task_vm=task_vm,
                      place_counter=counter0 + n_mine)


def _admit_and_place(c: EngineConsts, meta, pol, aux, s: SimState):
    """Admit released jobs (job-selection policy) while concurrency slots are
    free; place each admitted job's tasks onto VMs (placement policy).

    Both halves are batched (DESIGN.md §8).  Admission: one stable sort of
    the released jobs by the policy key (ties by job index, exactly the
    repeated-argmin order of the scalar loop) admits the top
    ``concurrency - running`` of them at once — each sequential admission
    raised ``running`` by one, so the budget IS a rank cutoff.  Placement:
    every newly-admitted job's tasks are placed in one ``_place_batch``
    whose order key is the admission rank.

    With failures enabled, placement only considers VMs on LIVE hosts (the
    ResourceManager's heartbeat view — DESIGN.md §7) and a second batch
    re-places unplaced tasks of already-admitted jobs (YARN re-execution
    after a host loss).

    Returns ``(s, placed, admit_now)``: ``placed`` is True iff any task
    placement changed this step — ``_step`` uses it to refresh the
    packet-endpoint cache only when needed; ``admit_now`` marks the jobs
    admitted THIS step (the proactive install pass pre-pins exactly their
    packets — DESIGN.md §10)."""
    # live VM count (c.n_vms) may be smaller than the padded tensor length
    # in a packed multi-scenario sweep — pad slots must never win placement.
    n_vms = c.n_vms
    vm_live = jnp.arange(meta.n_vms) < n_vms
    if meta.has_failures:
        vm_live = vm_live & ~s.host_dead[
            jnp.clip(_vm_host(c, meta, s), 0, c.host_fail_t.shape[0] - 1)]
    n_live = jnp.sum(vm_live.astype(jnp.int32))

    n_j = s.job_admitted.shape[0]
    released = (~s.job_admitted) & c.job_valid & (c.job_release <= s.time)
    running = jnp.sum((s.job_admitted & (s.job_out_done < c.job_n_out)
                       & c.job_valid).astype(jnp.int32))
    slots = jnp.maximum(pol["job_concurrency"].astype(jnp.int32) - running, 0)
    if meta.has_failures:
        # no live NodeManager, no admission (the RM has nowhere to place):
        # jobs wait for a host recovery breakpoint
        slots = jnp.where(n_live > 0, slots, 0)
    # job-selection key (smaller = better)
    key = jnp.where(
        pol["job_selection"] == JOBSEL_SJF, c.job_total_mi,
        jnp.where(pol["job_selection"] == JOBSEL_PRIORITY,
                  -c.job_priority, c.job_release))
    key = jnp.where(released, key, _INF)
    # rank = inverse of the stable sort permutation (argsort of argsort);
    # no job-axis scatter — this runs every step under vmap (DESIGN.md §9)
    ord_j = jnp.argsort(key)
    rank = jnp.argsort(ord_j).astype(jnp.int32)
    admit_now = released & (rank < slots)

    job_of_task = jnp.maximum(c.task_job, 0)
    any_admit = jnp.any(admit_now)

    def admit_place(s: SimState) -> SimState:
        # placement position of every admitted task by prefix-sum
        # arithmetic (admission-rank-major, task-index-minor —
        # DESIGN.md §8): offset each job's static task block by the task
        # counts of better-ranked admitted jobs, then add the task's
        # static rank within its job.
        mine = c.task_valid & admit_now[job_of_task]
        # rank-major task counts by GATHERING through the sort permutation
        # (cnt_by_rank[r] = task count of the rank-r job) — not a scatter
        cnt_by_rank = jnp.where(admit_now[ord_j], c.job_n_tasks[ord_j], 0)
        off_by_rank = jnp.cumsum(cnt_by_rank) - cnt_by_rank  # exclusive
        pos = off_by_rank[rank[job_of_task]] + c.task_rank_in_job
        return _place_batch(c, meta, pol, aux, s, mine, pos, vm_live,
                            n_live)

    s = jax.lax.cond(any_admit, admit_place, lambda s: s, s)
    s = s._replace(job_admitted=s.job_admitted | admit_now,
                   job_admit_t=jnp.where(admit_now, s.time, s.job_admit_t))
    placed = any_admit

    if meta.has_failures:
        # re-place tasks a host failure unplaced (jobs already admitted);
        # with no live VM they stay unplaced and wait for a recovery.
        orphaned = (c.task_valid & (s.task_vm < 0)
                    & (s.task_state == WAITING)
                    & s.job_admitted[job_of_task]
                    & (n_live > 0))
        s = jax.lax.cond(
            jnp.any(orphaned),
            lambda s: _place_batch(
                c, meta, pol, aux, s, orphaned,
                jnp.cumsum(orphaned.astype(jnp.int32)) - 1, vm_live,
                n_live),
            lambda s: s, s)
        placed = placed | jnp.any(orphaned)
    return s, placed, admit_now


def _route_links(c: EngineConsts, s: SimState, mask: jnp.ndarray) -> jnp.ndarray:
    """[N_P, H] link ids of each packet's chosen route (-1 where masked)."""
    pair = jnp.maximum(s.pkt_pair, 0)
    cand = jnp.maximum(s.pkt_cand, 0)
    links = c.routes[pair, cand]
    return jnp.where(mask[:, None], links, -1)


NODE_OFFSET = 1 << 20  # pkt_src/dst_task >= NODE_OFFSET encodes a direct
                       # node id (flow-level frontend, core.flows)


def _pkt_endpoints(c: EngineConsts, meta, s: SimState):
    """Resolve src/dst node of every packet from current task placement
    (the LIVE placement under migration — ``_vm_host``, DESIGN.md §10).

    -1 -> SAN storage; >= NODE_OFFSET -> direct node id; else task id."""
    n_tasks = s.task_vm.shape[0]
    vm_host = _vm_host(c, meta, s)

    def node_of(task_idx):
        t = jnp.clip(task_idx, 0, n_tasks - 1)
        vm = jnp.maximum(s.task_vm[t], 0)
        node = jnp.where(task_idx < 0, c.storage_node, vm_host[vm])
        return jnp.where(task_idx >= NODE_OFFSET,
                         task_idx - NODE_OFFSET, node).astype(jnp.int32)
    return node_of(c.pkt_src_task), node_of(c.pkt_dst_task)


def _endpoint_cache(c: EngineConsts, meta, s: SimState):
    """Per-packet (src*n_nodes+dst) pair index and reachability, derived
    purely from the current task placement.  Placement changes on only a
    handful of steps (admissions, failure re-placements), so ``_step``
    keeps this in the while-loop carry and refreshes it under a
    ``lax.cond`` instead of re-resolving every event (DESIGN.md §8).

    Packets whose endpoint task is currently UNPLACED get a garbage pair —
    harmless: with failures enabled ``_activate``'s ``_ep_placed`` check
    (which reads ``task_vm`` live) blocks them, and without failures every
    valid task of an admitted job is placed at admission."""
    src_node, dst_node = _pkt_endpoints(c, meta, s)
    pair = (src_node * meta.n_nodes + dst_node).astype(jnp.int32)
    # unreachable pairs (no candidate route, different nodes) never
    # activate -> the engine reports a stall instead of free transfer
    reachable = (c.n_cand[pair] > 0) | (src_node == dst_node)
    return {"pair": pair, "reachable": reachable}


def _activate(c: EngineConsts, meta, pol, aux, cache, s: SimState):
    """Task activation then packet activation, both batched (DESIGN.md §8).

    The controller serializes packet arrivals — each SDN pick must see the
    channels admitted just before it — so activation scans a COMPACTED
    ready set (loop length = the live ready count, not the padded packet
    axis; index order preserved).  The legacy hash route needs no channel
    feedback: its picks are computed vectorially up front and the scan
    merely applies them while counting channels (a per-ready-packet
    update beats a packet-axis scatter on CPU for typical burst sizes).
    Steps where nothing becomes ready skip the routing work altogether
    (``lax.cond`` on the ready count).

    When the routing policy arrives host-static (``static_policy_value``,
    fleet cohorts — DESIGN.md §9) the dispatch specializes at trace time:
    legacy routing drops the scan entirely (no channel feedback, so one
    vectorized gather + scatter-add reproduces the sequential result
    bit-for-bit), and SDN routing precomputes the pop order with one sort
    so the scan body loses its per-iteration argmax + mask scatter.

    Returns ``(s, links, p_active, nc, link_bw)`` — the post-activation
    route-link tensor, active mask, per-link channel counts and effective
    link bandwidth are each computed ONCE here and threaded through rates
    and energy (the fused per-step network pass)."""
    # tasks: all inputs arrived
    t_ready = ((s.task_state == WAITING) & (s.task_got >= c.task_need)
               & (s.task_vm >= 0))
    task_state = jnp.where(t_ready, ACTIVE, s.task_state)
    task_start = jnp.where(t_ready, s.time, s.task_start)
    s = s._replace(task_state=task_state, task_start=task_start)

    # packets: job admitted & gate task done & endpoints routable (the
    # pair/reachability tensors come from the placement-change cache)
    gate = c.pkt_gate_task
    gate_ok = jnp.where(gate < 0, True,
                        s.task_state[jnp.maximum(gate, 0)] == DONE)
    admitted = s.job_admitted[jnp.maximum(c.pkt_job, 0)]
    p_ready = (s.pkt_state == WAITING) & admitted & gate_ok & c.pkt_valid
    pair_all = cache["pair"]
    p_ready = p_ready & cache["reachable"]
    if meta.has_failures:
        # a packet whose endpoint task was unplaced by a host failure must
        # wait for re-placement — its endpoints cannot resolve yet
        n_tasks = s.task_vm.shape[0]

        def _ep_placed(ref):
            is_task = (ref >= 0) & (ref < NODE_OFFSET)
            return jnp.where(is_task,
                             s.task_vm[jnp.clip(ref, 0, n_tasks - 1)] >= 0,
                             True)

        p_ready = (p_ready & _ep_placed(c.pkt_src_task)
                   & _ep_placed(c.pkt_dst_task))

    link_bw = _effective_link_bw(c, meta, s)

    def _apply_ready(s, cand, nc):
        # commit the activation: only ready packets change, so a step with
        # an empty ready set leaves (s, nc) bit-identical
        if meta.has_failures:
            # a failure-reverted packet re-activates but keeps its FIRST
            # start: its measured duration includes the outage
            start_val = jnp.where(jnp.isnan(s.pkt_start), s.time,
                                  s.pkt_start)
        else:
            start_val = jnp.broadcast_to(s.time, s.pkt_start.shape)
        return s._replace(
            pkt_state=jnp.where(p_ready, ACTIVE, s.pkt_state),
            pkt_pair=jnp.where(p_ready, pair_all, s.pkt_pair),
            pkt_cand=jnp.where(p_ready, cand, s.pkt_cand),
            pkt_start=jnp.where(p_ready, start_val, s.pkt_start)), nc

    routing_static = static_policy_value(pol["routing"])
    if routing_static is not None and routing_static != ROUTE_SDN:
        # static legacy: no channel feedback -> no scan.  Every ready
        # packet's hash pick and its route links are gathered at once and
        # the channel counts bumped by one order-independent integer
        # scatter-add — commutative, so bit-identical to the sequential
        # pop order the dynamic path preserves.
        cand = legacy_route_choice(c.n_cand[pair_all], aux["pkt_hash"])
        # channel bump over the ready set only — compacted pop-order scan
        # like the SDN branch minus the route choice (a whole-packet-axis
        # one-hot contraction moves ~100x more elements than the few ready
        # packets justify, and a packet-axis scatter serializes per row
        # under vmap).  The pop order is a cursor-chained masked min per
        # trip, NOT a precomputed sort: a packet-axis sort runs EVERY step
        # (most of which have an empty ready set) and was one of the
        # largest single per-step costs, while the per-trip min only runs
        # ``n_ready`` times.  Ascending index order is exactly what the
        # sort yielded — bit-identical.
        n_p = p_ready.shape[0]
        n_l = cache["nc"].shape[0]
        idx = jnp.arange(n_p, dtype=jnp.int32)
        n_ready = jnp.sum(p_ready.astype(jnp.int32))
        link_iota = jnp.arange(n_l, dtype=jnp.int32)
        links_all = c.routes[pair_all, cand]  # [P, H]
        links_safe = jnp.where(links_all >= 0, links_all, -1)

        def bump_one(k, carry):
            ch, cursor = carry
            i = jnp.min(jnp.where(p_ready & (idx > cursor), idx, n_p))
            links = links_safe[jnp.minimum(i, n_p - 1)]     # [H]
            ch = ch + jnp.sum((links[:, None] == link_iota[None, :])
                              .astype(jnp.int32), axis=0)
            return ch, i

        nc, _ = jax.lax.fori_loop(0, n_ready, bump_one,
                                  (cache["nc"], jnp.int32(-1)))
        s, nc = _apply_ready(s, cand, nc)
    elif routing_static == ROUTE_SDN:
        # static SDN: the controller feedback loop stays sequential, but
        # the scan body is restructured to be scatter-free — under vmap an
        # XLA/CPU scatter serializes one row per lane, so the two scatters
        # of the dynamic body dominate the whole step at fleet widths.
        # The pop order (ascending packet index — exactly what the
        # argmax-chain yields) comes from a cursor-chained masked min per
        # trip, NOT a precomputed packet-axis sort (which would run EVERY
        # step, ready set or not, and was one of the largest single
        # per-step costs); picks land in a POP-ORDER sequence at the
        # (unbatched) loop index — a dynamic_update_slice, not a scatter —
        # and are mapped back to the packet axis afterwards by a rank
        # gather; the channel bump is a dense one-hot compare-sum,
        # bit-identical to the scatter-add (integer adds of the same six
        # links).
        n_p = p_ready.shape[0]
        n_l = cache["nc"].shape[0]
        idx = jnp.arange(n_p, dtype=jnp.int32)
        rank = jnp.cumsum(p_ready.astype(jnp.int32)) - 1
        n_ready = jnp.sum(p_ready.astype(jnp.int32))
        link_iota = jnp.arange(n_l, dtype=jnp.int32)

        def act_sdn(k, carry):
            ch, cand_seq, cursor = carry
            i = jnp.min(jnp.where(p_ready & (idx > cursor), idx, n_p))
            pair = pair_all[jnp.minimum(i, n_p - 1)]
            cand = sdn_route_choice(c.routes[pair], c.n_cand[pair],
                                    link_bw, ch)
            links = c.routes[pair, cand]  # [H]
            bump = jnp.sum((links[:, None] == link_iota[None, :])
                           .astype(jnp.int32), axis=0)
            return ch + bump, \
                jax.lax.dynamic_update_index_in_dim(cand_seq, cand, k, 0), i

        nc, cand_seq, _ = jax.lax.fori_loop(
            0, n_ready, act_sdn,
            (cache["nc"], jnp.zeros(n_p, jnp.int32), jnp.int32(-1)))
        cand = cand_seq[jnp.maximum(rank, 0)]
        s, nc = _apply_ready(s, cand, nc)
    else:
        def activate_ready(args):
            s, nc = args
            # legacy flow = task-to-task connection (§4: "task-to-task
            # communication"); each flow picks its equal-hop route
            # independently at random and keeps it (§5.2).  No channel
            # feedback -> one shot (the flow hash is loop-invariant,
            # precomputed in ``aux``).
            legacy_cand = legacy_route_choice(c.n_cand[pair_all],
                                              aux["pkt_hash"])
            n_ready = jnp.sum(p_ready.astype(jnp.int32))
            is_sdn = pol["routing"] == ROUTE_SDN

            # one scan over the ready set only, in packet-index order (the
            # argmax-chain pops the first set bit each iteration — no sort,
            # no packet-axis scatter).  The carried ``nc`` doubles as the
            # controller's live view: each SDN pick sees the channels
            # admitted just before it, and the final value IS the
            # post-activation channel count (DESIGN.md §8).  SDN's global
            # view includes link liveness (link_bw has dead links at 0, so
            # their candidates lose the bottleneck argmax); the legacy
            # static hash is failure-blind and can re-pin the dead route.
            def act_one(_, carry):
                ch, cand_all, mask = carry
                i = jnp.argmax(mask).astype(jnp.int32)
                mask = mask.at[i].set(False)
                pair = pair_all[i]
                cand = jnp.where(
                    is_sdn,
                    sdn_route_choice(c.routes[pair], c.n_cand[pair],
                                     link_bw, ch),
                    legacy_cand[i])
                links = c.routes[pair, cand]
                ch = ch.at[jnp.maximum(links, 0)].add(
                    (links >= 0).astype(jnp.int32))
                return ch, cand_all.at[i].set(cand), mask

            nc, cand, _ = jax.lax.fori_loop(0, n_ready, act_one,
                                            (nc, legacy_cand, p_ready))
            return _apply_ready(s, cand, nc)

        s, nc = jax.lax.cond(jnp.any(p_ready), activate_ready,
                             lambda args: args, (s, cache["nc"]))

    p_active = s.pkt_state == ACTIVE
    links = _route_links(c, s, p_active)
    return s, links, p_active, nc, link_bw


def _ctrl_request(c: EngineConsts, meta, pair, links, active_req,
                  pre_routed, t, tbl):
    """One flow's rule lookup + install request against the flow-table /
    controller carry (DESIGN.md §10).

    ``tbl`` = ``(ftab_pair, ftab_ready, ftab_stamp, ctrl_busy, ctrl_stamp,
    installs, evictions, reinstalls, queue_wait)``; returns
    ``(ready, tbl')`` where ``ready`` is the instant every rule on the
    route is usable.  ``active_req`` gates EVERY mutation (False = a pure
    lookup pass-through); ``pre_routed`` marks a flow that held a route
    before (its misses are churn: counted as reinstalls too).

    The route's switch hops are found from the link sources (routes are
    simple paths, so a route visits each switch at most once — the one-hot
    table writes below never collide).  Each miss takes one controller
    service slot FIFO behind ``ctrl_busy`` (``begin = max(t, busy)``,
    ``svc = misses / rate``) plus the flow-mod latency; cache hits are
    free but the flow still waits for any hit entry that is itself mid-
    install.  A missing rule lands in its switch's first empty slot, else
    the least-recently-stamped one (LRU); displacing a live entry counts
    an eviction.  With ``ctrl_slots == 0`` (no caching) every install is
    evicted immediately, so ``occupied == installs - evictions`` holds for
    every config (the conservation law, tests/test_fairshare.py).

    Controller failover (DESIGN.md §13): the primary is down on
    ``[ctrl_fail_t, ctrl_recover_t)``.  During the leader-election gap
    (the first ``ctrl_failover_delay`` seconds of the outage) install
    requests PARK — their service begin is pushed to the gap end and the
    parked seconds accumulate in the tbl's ``park`` slot; after the gap
    the backup serves with its own rate/latency until the primary
    recovers.  With ``ctrl_fail_t == inf`` every ``where`` below picks
    the primary branch, so pre-failover configs are numerically
    untouched."""
    (fpair, fready, fstamp, busy, stamp, installs, evicts, reinst,
     qwait, park) = tbl
    T = meta.ctrl_slots
    nodes = c.link_src[jnp.maximum(links, 0)]
    # switch node ids sit at [n_hosts, n_hosts + n_switches) — the PADDED
    # offsets in a packed sweep, same convention as the energy port count
    is_sw = ((links >= 0) & (nodes >= meta.n_hosts)
             & (nodes < meta.n_hosts + meta.n_switches))
    sw = jnp.where(is_sw, nodes - meta.n_hosts, 0)       # [H], clipped
    if T > 0:
        rows = fpair[sw]                                 # [H, T]
        hitmask = (rows == pair) & is_sw[:, None]
        hit = jnp.any(hitmask, axis=1)
        hit_ready = jnp.max(jnp.where(hitmask, fready[sw], -_INF))
    else:
        hit = jnp.zeros_like(is_sw)
        hit_ready = -_INF
    miss = is_sw & ~hit
    m = jnp.sum(miss.astype(jnp.int32))
    begin = jnp.maximum(t, busy)
    # failover: inside the primary outage the backup's rate/latency apply,
    # and requests landing in the leader-election gap park until it ends
    down = (t >= c.ctrl_fail_t) & (t < c.ctrl_recover_t)
    gap_end = jnp.minimum(c.ctrl_fail_t + c.ctrl_failover_delay,
                          c.ctrl_recover_t)
    rate = jnp.where(down, c.ctrl_backup_rate, c.ctrl_rate)
    lat = jnp.where(down, c.ctrl_backup_latency, c.ctrl_latency)
    begin2 = jnp.where(down, jnp.maximum(begin, gap_end), begin)
    svc = m.astype(jnp.float32) / rate                   # inf rate -> 0
    ready = jnp.maximum(jnp.maximum(
        jnp.where(m > 0, begin2 + svc + lat, -_INF),
        hit_ready), t)
    do_install = active_req & (m > 0)
    busy = jnp.where(do_install, begin2 + svc, busy)
    qwait = qwait + jnp.where(do_install, begin - t, 0.0)
    park = park + jnp.where(do_install, begin2 - begin, 0.0)
    installs = installs + jnp.where(active_req, m, 0)
    reinst = reinst + jnp.where(active_req & pre_routed, m, 0)
    if T > 0:
        sw_iota = jnp.arange(meta.n_switches, dtype=jnp.int32)
        new_stamp = stamp + 1
        # LRU victim per route hop: empty slots (key -1) win over any
        # stamp, then oldest stamp, ties to the lowest slot index
        key = jnp.where(rows < 0, -1, fstamp[sw])        # [H, T]
        slot = jnp.argmin(key, axis=1)                   # [H]
        displaced = jnp.take_along_axis(rows, slot[:, None],
                                        axis=1)[:, 0] >= 0
        evicts = evicts + jnp.where(
            do_install, jnp.sum((miss & displaced).astype(jnp.int32)), 0)
        # [H, SW, T] one-hot masks contracted over the route-hop axis —
        # NOT scatters (batched scatters serialize per lane, DESIGN.md §9)
        write_h = miss & do_install
        touch_h = hit & active_req
        sw_oh = (sw[:, None] == sw_iota[None, :]) & is_sw[:, None]
        slot_oh = slot[:, None] == jnp.arange(T, dtype=jnp.int32)[None, :]
        wmask = jnp.any(sw_oh[:, :, None]
                        & (write_h[:, None] & slot_oh)[:, None, :], axis=0)
        tmask = jnp.any(sw_oh[:, :, None]
                        & (hitmask & touch_h[:, None])[:, None, :], axis=0)
        fpair = jnp.where(wmask, pair, fpair)
        fready = jnp.where(wmask, ready, fready)
        fstamp = jnp.where(wmask | tmask, new_stamp, fstamp)
        stamp = jnp.where(active_req, new_stamp, stamp)
    else:
        # no caching: nothing is retained, so every install is counted
        # displaced immediately — the conservation law stays exact
        evicts = evicts + jnp.where(do_install, m, 0)
    return ready, (fpair, fready, fstamp, busy, stamp, installs, evicts,
                   reinst, qwait, park)


def _ctrl_tbl(s: SimState):
    return (s.ftab_pair, s.ftab_ready, s.ftab_stamp, s.ctrl_busy,
            s.ctrl_stamp, s.ctrl_installs, s.ctrl_evictions,
            s.ctrl_reinstalls, s.ctrl_queue_wait, s.ctrl_failover_park)


def _with_ctrl_tbl(s: SimState, tbl) -> SimState:
    (fpair, fready, fstamp, busy, stamp, installs, evicts, reinst,
     qwait, park) = tbl
    return s._replace(
        ftab_pair=fpair, ftab_ready=fready, ftab_stamp=fstamp,
        ctrl_busy=busy, ctrl_stamp=stamp, ctrl_installs=installs,
        ctrl_evictions=evicts, ctrl_reinstalls=reinst,
        ctrl_queue_wait=qwait, ctrl_failover_park=park)


def _activate_ctrl(c: EngineConsts, meta, pol, aux, cache, s: SimState):
    """Packet activation with the control plane in the loop (DESIGN.md
    §10) — replaces ``_activate``'s routing dispatch when
    ``meta.has_ctrl`` (``_activate`` itself is untouched: the off switch
    must trace the exact pre-control-plane program).

    One compacted pop-order scan (ascending packet index — the same order
    every plain path uses) over the union of the newly-ready set and the
    WAKE set: INSTALLING packets whose ``pkt_ready_t`` has arrived.  Per
    popped packet:

      * legacy routing bypasses the controller entirely — the static hash
        pick needs no flow-mod round trip — and activates immediately;
        that asymmetry is what lets legacy BEAT a slow controller
        (benchmarks/ctrl_sweep.py);
      * an SDN packet resolves its route (the stored candidate when the
        proactive pass pre-pinned one, else the live bottleneck pick) and
        requests its missing rules via ``_ctrl_request`` — unless the
        replica's ``ctrl_on`` is False (an identity-config lane in a mixed
        packed sweep bypasses the controller like legacy: zero counters).
        ``ready <= t`` (all rules cached and usable)
        activates in the SAME iteration, keeping the
        channel-bump order identical to the plain engine; otherwise the
        packet parks in INSTALLING with ``pkt_ready_t = ready`` joining
        the analytic dt min, and accrues ``pkt_install_wait``;
      * a woken packet activates unconditionally on its stored route: its
        rules WERE installed at request time, and later LRU churn only
        affects FUTURE flows — re-blocking a woken packet on a re-lookup
        could livelock two flows thrashing one slot.

    Only activating packets bump the channel counts (an INSTALLING packet
    holds no links), so the carried ``nc`` stays exact."""
    # tasks: identical to _activate
    t_ready = ((s.task_state == WAITING) & (s.task_got >= c.task_need)
               & (s.task_vm >= 0))
    s = s._replace(task_state=jnp.where(t_ready, ACTIVE, s.task_state),
                   task_start=jnp.where(t_ready, s.time, s.task_start))

    # ready set: same gates as _activate
    gate = c.pkt_gate_task
    gate_ok = jnp.where(gate < 0, True,
                        s.task_state[jnp.maximum(gate, 0)] == DONE)
    admitted = s.job_admitted[jnp.maximum(c.pkt_job, 0)]
    p_ready = (s.pkt_state == WAITING) & admitted & gate_ok & c.pkt_valid
    pair_all = cache["pair"]
    p_ready = p_ready & cache["reachable"]
    if meta.has_failures:
        n_tasks = s.task_vm.shape[0]

        def _ep_placed(ref):
            is_task = (ref >= 0) & (ref < NODE_OFFSET)
            return jnp.where(is_task,
                             s.task_vm[jnp.clip(ref, 0, n_tasks - 1)] >= 0,
                             True)

        p_ready = (p_ready & _ep_placed(c.pkt_src_task)
                   & _ep_placed(c.pkt_dst_task))
    p_wake = (s.pkt_state == INSTALLING) & (s.pkt_ready_t <= s.time)
    pop = p_ready | p_wake

    link_bw = _effective_link_bw(c, meta, s)
    n_p = pop.shape[0]
    n_l = cache["nc"].shape[0]
    idx = jnp.arange(n_p, dtype=jnp.int32)
    liota = jnp.arange(n_l, dtype=jnp.int32)
    n_pop = jnp.sum(pop.astype(jnp.int32))
    legacy_cand = legacy_route_choice(c.n_cand[pair_all], aux["pkt_hash"])
    is_sdn = pol["routing"] == ROUTE_SDN
    t_now = s.time

    def pop_one(k, carry):
        (nc, pkt_state, pkt_pair, pkt_cand, pkt_start, pkt_ready_t,
         pkt_wait, tbl, cursor) = carry
        i = jnp.min(jnp.where(pop & (idx > cursor), idx, n_p))
        safe = jnp.minimum(i, n_p - 1)
        woken = p_wake[safe]
        pre_routed = pkt_cand[safe] >= 0
        pair = jnp.where(pre_routed, pkt_pair[safe], pair_all[safe])
        cand = jnp.where(
            pre_routed, pkt_cand[safe],
            jnp.where(is_sdn,
                      sdn_route_choice(c.routes[pair], c.n_cand[pair],
                                       link_bw, nc),
                      legacy_cand[safe]))
        links = c.routes[pair, cand]                     # [H]
        needs_ctrl = is_sdn & ~woken & c.ctrl_on
        ready, tbl = _ctrl_request(c, meta, pair, links, needs_ctrl,
                                   pre_routed & ~woken, t_now, tbl)
        act_now = woken | ~needs_ctrl | (ready <= t_now)
        oh = idx == i
        start_i = jnp.where(jnp.isnan(pkt_start[safe]), t_now,
                            pkt_start[safe])
        pkt_state = jnp.where(oh, jnp.where(act_now, ACTIVE, INSTALLING),
                              pkt_state)
        pkt_pair = jnp.where(oh, pair, pkt_pair)
        pkt_cand = jnp.where(oh, cand, pkt_cand)
        pkt_start = jnp.where(oh, start_i, pkt_start)
        pkt_ready_t = jnp.where(oh, jnp.where(act_now, _INF, ready),
                                pkt_ready_t)
        pkt_wait = pkt_wait + jnp.where(
            oh & ~act_now, jnp.maximum(ready - t_now, 0.0), 0.0)
        bump = jnp.sum(((links[:, None] == liota[None, :])
                        & (links >= 0)[:, None]).astype(jnp.int32), axis=0)
        nc = nc + bump * act_now.astype(jnp.int32)
        return (nc, pkt_state, pkt_pair, pkt_cand, pkt_start, pkt_ready_t,
                pkt_wait, tbl, i)

    carry0 = (cache["nc"], s.pkt_state, s.pkt_pair, s.pkt_cand,
              s.pkt_start, s.pkt_ready_t, s.pkt_install_wait, _ctrl_tbl(s),
              jnp.int32(-1))
    (nc, pkt_state, pkt_pair, pkt_cand, pkt_start, pkt_ready_t, pkt_wait,
     tbl, _) = jax.lax.fori_loop(0, n_pop, pop_one, carry0)
    s = _with_ctrl_tbl(s._replace(
        pkt_state=pkt_state, pkt_pair=pkt_pair, pkt_cand=pkt_cand,
        pkt_start=pkt_start, pkt_ready_t=pkt_ready_t,
        pkt_install_wait=pkt_wait), tbl)

    p_active = s.pkt_state == ACTIVE
    links = _route_links(c, s, p_active)
    return s, links, p_active, nc, link_bw


def _preinstall(c: EngineConsts, meta, pol, aux, cache, s: SimState,
                admit_now) -> SimState:
    """Proactive flow-rule installation at job admission (DESIGN.md §10):
    scan the newly-admitted jobs' unrouted packets in index order, resolve
    each against the admission-time placement, install the missing rules
    (advancing the controller queue) and pin the route in
    ``pkt_pair``/``pkt_cand``.  The packets stay WAITING — their phase
    gates still apply — but by first use the rules are (usually) already
    cached, so the install latency overlaps compute instead of stalling
    the transfer; churn-evicted pins fall back to the reactive path and
    count as reinstalls.

    The route picks use a SCRATCH channel view (the live counts plus each
    earlier pin) so a job's flows spread over candidates the way the
    reactive controller would spread them — but pinned at admission time,
    blind to the traffic that develops later.  That lost adaptivity is
    proactive's intrinsic trade against reactive's install stall."""
    mask = (c.pkt_valid & admit_now[jnp.maximum(c.pkt_job, 0)]
            & (s.pkt_cand < 0) & cache["reachable"] & c.ctrl_on)
    pair_all = cache["pair"]
    link_bw = _effective_link_bw(c, meta, s)
    n_p = mask.shape[0]
    n_l = cache["nc"].shape[0]
    idx = jnp.arange(n_p, dtype=jnp.int32)
    liota = jnp.arange(n_l, dtype=jnp.int32)
    t_now = s.time

    def pre_one(k, carry):
        pkt_pair, pkt_cand, tbl, snc, cursor = carry
        i = jnp.min(jnp.where(mask & (idx > cursor), idx, n_p))
        safe = jnp.minimum(i, n_p - 1)
        pair = pair_all[safe]
        cand = sdn_route_choice(c.routes[pair], c.n_cand[pair], link_bw,
                                snc)
        links = c.routes[pair, cand]
        _, tbl = _ctrl_request(c, meta, pair, links, jnp.asarray(True),
                               jnp.asarray(False), t_now, tbl)
        oh = idx == i
        pkt_pair = jnp.where(oh, pair, pkt_pair)
        pkt_cand = jnp.where(oh, cand, pkt_cand)
        snc = snc + jnp.sum(((links[:, None] == liota[None, :])
                             & (links >= 0)[:, None]).astype(jnp.int32),
                            axis=0)
        return pkt_pair, pkt_cand, tbl, snc, i

    carry0 = (s.pkt_pair, s.pkt_cand, _ctrl_tbl(s), cache["nc"],
              jnp.int32(-1))
    pkt_pair, pkt_cand, tbl, _, _ = jax.lax.fori_loop(
        0, jnp.sum(mask.astype(jnp.int32)), pre_one, carry0)
    return _with_ctrl_tbl(
        s._replace(pkt_pair=pkt_pair, pkt_cand=pkt_cand), tbl)


def _maybe_migrate(c: EngineConsts, meta, pol, s: SimState, cache):
    """Migrate-on-congestion dynamic placement (DESIGN.md §10, the S-CORE
    direction): at most one VM per step re-homes when its aggregate
    route-hop cost over active packets exceeds ``mig_threshold``.

    cost(v) = sum of current-route hop counts (``pair_hops``) over ACTIVE
    packets whose src or dst task runs on v.  The costliest eligible VM
    (over threshold, out of cooldown, global ``mig_limit`` not exhausted)
    moves to the live host minimizing the estimated cost — candidate-0
    hops of each of its packets' pairs with the VM's endpoint re-homed —
    requiring strict improvement over the same estimate at the current
    host.  The move is controller-mediated (one service slot), live: the
    VM's tasks keep their slot but execute nothing until ``vm_mig_until``
    (which joins the dt min), while every routed packet touching the VM
    reverts to WAITING through the PR-4 revert machinery (active ones
    release their channels) and re-routes against the new placement.

    Returns ``(s, cache, migrated)``; ``migrated`` forces the endpoint
    cache refresh in ``_step``."""
    mig_static = static_policy_value(pol["migration"])
    if mig_static is not None and mig_static != MIG_CONGESTION:
        return s, cache, jnp.asarray(False)
    n_vms = meta.n_vms
    n_t = s.task_vm.shape[0]
    n_p = s.pkt_state.shape[0]
    n_pairs = c.pair_hops.shape[0]

    def attempt(args):
        s, nc0 = args
        t = s.time
        viota = jnp.arange(n_vms, dtype=jnp.int32)

        def ep_vm(ref):
            is_task = (ref >= 0) & (ref < NODE_OFFSET)
            vm = s.task_vm[jnp.clip(ref, 0, n_t - 1)]
            return jnp.where(is_task, vm, -1)            # [n_p]

        src_vm = ep_vm(c.pkt_src_task)
        dst_vm = ep_vm(c.pkt_dst_task)
        p_active = s.pkt_state == ACTIVE
        cost_p = jnp.where(
            p_active, c.pair_hops[jnp.maximum(s.pkt_pair, 0)], 0
        ).astype(jnp.float32)
        cost = (jnp.sum(jnp.where(src_vm[:, None] == viota[None, :],
                                  cost_p[:, None], 0.0), axis=0)
                + jnp.sum(jnp.where(dst_vm[:, None] == viota[None, :],
                                    cost_p[:, None], 0.0), axis=0))
        elig = ((viota < c.n_vms) & (cost > c.mig_threshold)
                & (t >= s.vm_mig_until + c.mig_cooldown)
                & (jnp.sum(s.vm_migrations) < c.mig_limit))
        any_elig = jnp.any(elig)
        v = jnp.argmax(jnp.where(elig, cost, -1.0)).astype(jnp.int32)

        # estimated cost of v's active flows per candidate home: move v's
        # endpoint to host h (hosts ARE nodes [0, n_hosts)), keep the
        # other end, read the candidate-0 hop count
        src_node, dst_node = _pkt_endpoints(c, meta, s)
        mine_s = p_active & (src_vm == v)
        mine_d = p_active & (dst_vm == v)
        mine = mine_s | mine_d
        n_h = c.host_fail_t.shape[0]
        hiota = jnp.arange(n_h, dtype=jnp.int32)
        new_src = jnp.where(mine_s[None, :], hiota[:, None],
                            src_node[None, :])
        new_dst = jnp.where(mine_d[None, :], hiota[:, None],
                            dst_node[None, :])
        est_pair = jnp.clip(new_src * meta.n_nodes + new_dst, 0,
                            n_pairs - 1)
        est = jnp.where(mine[None, :], c.pair_hops[est_pair], 0)
        est_cost = jnp.sum(est.astype(jnp.float32), axis=1)  # [n_h]
        host_live = hiota < c.n_hosts
        if meta.has_failures:
            host_live = host_live & ~s.host_dead
        cur_host = jnp.clip(s.vm_host[jnp.minimum(v, n_vms - 1)], 0,
                            n_h - 1)
        h_best = jnp.argmin(jnp.where(host_live, est_cost, _INF)
                            ).astype(jnp.int32)
        do = (any_elig & (est_cost[h_best] < est_cost[cur_host])
              & (h_best != cur_host))

        vm_oh = (viota == v) & do
        vm_host = jnp.where(vm_oh, h_best, s.vm_host)
        vm_mig_until = jnp.where(vm_oh, t + c.mig_cost, s.vm_mig_until)
        vm_migrations = s.vm_migrations + vm_oh.astype(jnp.int32)
        ctrl_busy = jnp.where(
            do, jnp.maximum(t, s.ctrl_busy) + 1.0 / c.ctrl_rate,
            s.ctrl_busy)

        # revert every routed packet touching v (active ones release their
        # channels via the compacted drop scan — PR-4 machinery)
        routed = (p_active | (s.pkt_state == INSTALLING)
                  | ((s.pkt_state == WAITING) & (s.pkt_cand >= 0)))
        hit_p = routed & ((src_vm == v) | (dst_vm == v)) & do
        hit_drop = hit_p & p_active
        links = _route_links(c, s, hit_drop)
        pidx = jnp.arange(n_p, dtype=jnp.int32)
        liota = jnp.arange(meta.n_links, dtype=jnp.int32)

        def drop_one(k, carry):
            nc, cursor = carry
            i = jnp.min(jnp.where(hit_drop & (pidx > cursor), pidx, n_p))
            links_k = links[jnp.minimum(i, n_p - 1)]
            nc = nc - jnp.sum((links_k[:, None] == liota[None, :])
                              .astype(jnp.int32), axis=0)
            return nc, i

        nc, _ = jax.lax.fori_loop(0, jnp.sum(hit_drop.astype(jnp.int32)),
                                  drop_one, (nc0, jnp.int32(-1)))
        s = s._replace(
            vm_host=vm_host, vm_mig_until=vm_mig_until,
            vm_migrations=vm_migrations, ctrl_busy=ctrl_busy,
            pkt_state=jnp.where(hit_p, WAITING, s.pkt_state),
            pkt_pair=jnp.where(hit_p, -1, s.pkt_pair),
            pkt_cand=jnp.where(hit_p, -1, s.pkt_cand),
            pkt_ready_t=jnp.where(hit_p, jnp.inf, s.pkt_ready_t),
            pkt_reroutes=s.pkt_reroutes + hit_p.astype(jnp.int32))
        return s, nc, do

    enabled = ((pol["migration"] == MIG_CONGESTION)
               & jnp.isfinite(c.mig_threshold))
    s, nc, migrated = jax.lax.cond(
        enabled, attempt, lambda args: (args[0], args[1],
                                        jnp.asarray(False)),
        (s, cache["nc"]))
    return s, {**cache, "nc": nc}, migrated


def _rates(c: EngineConsts, meta, pol, s: SimState, links, p_active,
           nc, link_bw):
    """Piecewise-constant packet/task rates from the fused network tensors
    (``links``/``p_active``/``nc``/``link_bw`` come straight from
    ``_activate`` — nothing here is recomputed, DESIGN.md §8).

    With clone slots provisioned (``meta.spec_slots > 0``, DESIGN.md §13)
    the speculative clones join the per-VM census — a clone steals fair
    share from its VM's resident tasks exactly like a real task — and the
    returned ``spec_rate`` carries their MIPS rates (``None`` when
    speculation is structurally off: the trace is unchanged)."""
    pkt_rate = fairshare.rates(pol["traffic"], links, p_active, link_bw,
                               meta.intra_bw, nc=nc)
    t_active = s.task_state == ACTIVE
    vm = jnp.maximum(s.task_vm, 0)
    # task-axis one-hot contraction, not a scatter (batched scatters
    # serialize per lane under vmap — DESIGN.md §9); int adds commute
    vm_iota = jnp.arange(c.vm_total_mips.shape[0], dtype=jnp.int32)
    n_on_vm = jnp.sum((vm[:, None] == vm_iota[None, :]) & t_active[:, None],
                      axis=0).astype(jnp.int32)
    s_active = None
    if meta.spec_slots > 0:
        s_active = s.spec_of >= 0
        svm = jnp.maximum(s.spec_vm, 0)
        n_on_vm = n_on_vm + jnp.sum(
            (svm[:, None] == vm_iota[None, :]) & s_active[:, None],
            axis=0).astype(jnp.int32)
    share = c.vm_total_mips[vm] / jnp.maximum(n_on_vm[vm], 1).astype(jnp.float32)
    task_rate = jnp.where(t_active, jnp.minimum(c.vm_core_mips[vm], share), 0.0)
    if meta.has_degradation:
        # gray windows throttle every task on the host (DESIGN.md §13);
        # scaling the final rate scales the per-core ceiling and the fair
        # share uniformly — the whole host is slow, not one VM
        hfac = _host_deg_factor(c, s)
        task_rate = task_rate * hfac[jnp.clip(
            _vm_host(c, meta, s)[vm], 0, c.host_slow_t.shape[0] - 1)]
    if meta.has_failures:
        # belt-and-braces: a task stranded on a dead host executes nothing
        # (can only happen when EVERY host was dead at placement time)
        task_rate = jnp.where(
            s.host_dead[jnp.clip(_vm_host(c, meta, s)[vm], 0,
                                 c.host_fail_t.shape[0] - 1)],
            0.0, task_rate)
    if meta.has_ctrl:
        # live migration (DESIGN.md §10): the VM keeps its tasks but
        # executes nothing until the re-homing completes; vm_mig_until is
        # a dt breakpoint, so the pause ends exactly on time
        task_rate = jnp.where(s.vm_mig_until[vm] > s.time, 0.0, task_rate)
    spec_rate = None
    if meta.spec_slots > 0:
        svm = jnp.maximum(s.spec_vm, 0)
        share_s = c.vm_total_mips[svm] / jnp.maximum(
            n_on_vm[svm], 1).astype(jnp.float32)
        spec_rate = jnp.where(
            s_active, jnp.minimum(c.vm_core_mips[svm], share_s), 0.0)
        host_of_clone = jnp.clip(_vm_host(c, meta, s)[svm], 0,
                                 c.host_fail_t.shape[0] - 1)
        if meta.has_degradation:
            spec_rate = spec_rate * _host_deg_factor(c, s)[host_of_clone]
        if meta.has_failures:
            spec_rate = jnp.where(s.host_dead[host_of_clone], 0.0,
                                  spec_rate)
        if meta.has_ctrl:
            spec_rate = jnp.where(s.vm_mig_until[svm] > s.time, 0.0,
                                  spec_rate)
    return pkt_rate, task_rate, t_active, spec_rate


def _speculate(c: EngineConsts, meta, pol, aux, s: SimState) -> SimState:
    """YARN speculative execution (DESIGN.md §13): in-loop straggler
    detection + clone launch into the statically pre-allocated per-job
    clone slots.  Only called when ``meta.spec_slots > 0``; the whole body
    is additionally gated on ``pol["speculation"] == SPEC_ON`` (trace-time
    skipped when the policy is statically off), so an off replica's state
    never moves.

    Two halves, both scatter-free one-hot contractions:

    * CLEANUP — a clone whose original left ACTIVE (finished first,
      failed-and-restarted, or reverted by an outage) or whose own host
      died is cancelled: its elapsed seconds land in ``spec_wasted`` and
      its VM container frees.  A task keeps ``task_cloned`` forever —
      one speculative attempt per task per run, like YARN's default.
    * LAUNCH — at most ONE clone per event step (the AM heartbeat batch):
      among ACTIVE tasks whose observed mean rate ``(mi - rem)/elapsed``
      is below HALF their job's live median rate (the per-job median is
      an O(n^2) pairwise rank count — no sort in the loop body,
      DESIGN.md §8), the slowest uncloned one with a free slot in its
      job's slot block gets a clone on the least-loaded live VM that
      avoids the original's host (so a gray host can't host both copies)
      and, when any exists, sits on a host OUTSIDE every current
      degradation window — a clone on a second browned-out host just
      doubles the waste.  The clone restarts from zero work —
      speculation races, it does not checkpoint."""
    spec_static = static_policy_value(pol["speculation"])
    if spec_static is not None and spec_static != SPEC_ON:
        return s
    S = s.spec_of.shape[0]
    n_t = s.task_rem.shape[0]
    n_j = s.job_admitted.shape[0]
    n_hosts_pad = c.host_fail_t.shape[0]
    t = s.time
    tiota = jnp.arange(n_t, dtype=jnp.int32)
    jiota = jnp.arange(n_j, dtype=jnp.int32)
    siota = jnp.arange(S, dtype=jnp.int32)
    vm_iota = jnp.arange(s.vm_load.shape[0], dtype=jnp.int32)
    slot_job = siota // meta.spec_slots
    vm_host = _vm_host(c, meta, s)

    def do_spec(s: SimState) -> SimState:
        # --- cleanup
        orig = jnp.maximum(s.spec_of, 0)
        live = s.spec_of >= 0
        gone = s.task_state[orig] != ACTIVE
        cancel = live & gone
        if meta.has_failures:
            clone_host = jnp.clip(vm_host[jnp.maximum(s.spec_vm, 0)], 0,
                                  n_hosts_pad - 1)
            cancel = cancel | (live & s.host_dead[clone_host])
        spec_wasted = s.spec_wasted + jnp.sum(
            jnp.where(cancel, t - s.spec_start, 0.0))
        vm_load = s.vm_load - jnp.sum(
            (jnp.maximum(s.spec_vm, 0)[:, None] == vm_iota[None, :])
            & cancel[:, None], axis=0).astype(jnp.int32)
        spec_of = jnp.where(cancel, -1, s.spec_of)

        # --- straggler detection (per-job live median of observed rates)
        elapsed = t - s.task_start
        el_ok = (s.task_state == ACTIVE) & c.task_valid & (elapsed > 1e-9)
        rate = jnp.where(el_ok, (c.task_mi - s.task_rem)
                         / jnp.maximum(elapsed, 1e-9), 0.0)
        job = jnp.maximum(c.task_job, 0)
        same = ((job[:, None] == job[None, :])
                & el_ok[:, None] & el_ok[None, :])
        lower = same & ((rate[None, :] < rate[:, None])
                        | ((rate[None, :] == rate[:, None])
                           & (tiota[None, :] < tiota[:, None])))
        n_peer = jnp.sum(same, axis=1)                 # includes self
        rank = jnp.sum(lower, axis=1)
        # exactly one median witness per job with >= 1 eligible task
        is_med = el_ok & (rank == n_peer // 2)
        med = jnp.sum(jnp.where(is_med[:, None]
                                & (job[:, None] == jiota[None, :]),
                                rate[:, None], 0.0), axis=0)  # [n_j]
        free = spec_of < 0
        job_free = jnp.sum((slot_job[:, None] == jiota[None, :])
                           & free[:, None], axis=0) > 0       # [n_j]
        straggler = (el_ok & ~s.task_cloned
                     & (2.0 * rate < med[job]) & job_free[job])

        # --- launch the slowest straggler (one per step)
        vm_live = jnp.arange(meta.n_vms) < c.n_vms
        if meta.has_failures:
            vm_live = vm_live & ~s.host_dead[
                jnp.clip(vm_host, 0, n_hosts_pad - 1)]
        launch = jnp.any(straggler) & jnp.any(vm_live)
        w = jnp.argmin(jnp.where(straggler, rate, _INF)).astype(jnp.int32)
        slot = jnp.min(jnp.where(free & (slot_job == job[w]), siota, S))
        slot = jnp.minimum(slot, S - 1)
        host_w = jnp.clip(vm_host[jnp.maximum(s.task_vm[w], 0)], 0,
                          n_hosts_pad - 1)
        off_host = vm_live & (jnp.clip(vm_host, 0, n_hosts_pad - 1)
                              != host_w)
        use = jnp.where(jnp.any(off_host), off_host, vm_live)
        if meta.has_degradation:
            undeg = use & (_host_deg_factor(c, s)[
                jnp.clip(vm_host, 0, n_hosts_pad - 1)] >= 1.0)
            use = jnp.where(jnp.any(undeg), undeg, use)
        pick = jnp.argmin(jnp.where(use, vm_load,
                                    jnp.iinfo(jnp.int32).max)
                          ).astype(jnp.int32)
        oh = (siota == slot) & launch
        return s._replace(
            spec_of=jnp.where(oh, w, spec_of),
            spec_vm=jnp.where(oh, pick, s.spec_vm),
            spec_rem=jnp.where(oh, c.task_mi[w], s.spec_rem),
            spec_start=jnp.where(oh, t, s.spec_start),
            task_cloned=s.task_cloned | ((tiota == w) & launch),
            vm_load=vm_load + ((vm_iota == pick) & launch
                               ).astype(jnp.int32),
            spec_launches=s.spec_launches + launch.astype(jnp.int32),
            spec_wasted=spec_wasted)

    return jax.lax.cond(pol["speculation"] == SPEC_ON, do_spec,
                        lambda s: s, s)


def _finished(c: EngineConsts, meta, s: SimState) -> jnp.ndarray:
    all_done = jnp.all(~c.job_valid | (s.job_out_done >= c.job_n_out))
    return all_done | s.stalled | (s.steps >= meta.max_steps)


def _make_aux(c: EngineConsts, pol) -> Dict[str, jnp.ndarray]:
    """Loop-invariant tensors hoisted out of the step body (DESIGN.md §8):
    the per-task placement hash and the per-packet legacy flow hash only
    depend on consts + the policy seed, so they are computed once before
    the while loop instead of every event."""
    n_t = c.task_job.shape[0]
    return {
        "task_hash": flow_hash_u32(jnp.arange(n_t, dtype=jnp.int32),
                                   c.task_job, pol["seed"]),
        "pkt_hash": flow_hash_u32(c.pkt_src_task + 1, c.pkt_dst_task + 1,
                                  pol["seed"]),
        # completion tolerances (also loop-invariant)
        "pkt_tol": c.pkt_bits * 1e-6 + 1.0,
        "task_tol": c.task_mi * 1e-6 + 1e-6,
    }


def _step(c: EngineConsts, meta, pol, aux, carry):
    s, cache = carry
    if meta.has_failures:
        s, cache = _apply_failures(c, meta, pol, s, cache)
    s, placed, admit_now = _admit_and_place(c, meta, pol, aux, s)
    if meta.has_ctrl:
        # migrate BEFORE the cache refresh so re-homed endpoints resolve
        # against the new placement this very step (DESIGN.md §10)
        s, cache, migrated = _maybe_migrate(c, meta, pol, s, cache)
        placed = placed | migrated
    # placement changed -> the packet endpoint/pair cache is stale
    cache = jax.lax.cond(
        placed, lambda: {**cache, **_endpoint_cache(c, meta, s)},
        lambda: cache)
    # the fused network pass: route links, active mask, channel counts and
    # effective bandwidth come out of activation ONCE per step and feed
    # rates + energy below (DESIGN.md §8)
    if meta.has_ctrl:
        install_static = static_policy_value(pol["install_mode"])
        if install_static is None or install_static == INSTALL_PROACTIVE:
            s = jax.lax.cond(
                (jnp.any(admit_now)
                 & (pol["install_mode"] == INSTALL_PROACTIVE)
                 & (pol["routing"] == ROUTE_SDN)),
                lambda s: _preinstall(c, meta, pol, aux, cache, s,
                                      admit_now),
                lambda s: s, s)
        s, links, p_active, nc, link_bw = _activate_ctrl(c, meta, pol, aux,
                                                         cache, s)
    else:
        s, links, p_active, nc, link_bw = _activate(c, meta, pol, aux,
                                                    cache, s)
    if meta.spec_slots > 0:
        # clone housekeeping + straggler launch happen AFTER activation
        # (so just-activated tasks are census-visible) and BEFORE rates
        # (so a launched clone shares its VM from this very interval)
        s = _speculate(c, meta, pol, aux, s)
    pkt_rate, task_rate, t_active, spec_rate = _rates(c, meta, pol, s,
                                                      links, p_active,
                                                      nc, link_bw)

    # earliest horizon (Eq. 4 generalized)
    dt_p = jnp.min(jnp.where(p_active & (pkt_rate > 0),
                             s.pkt_rem / pkt_rate, _INF))
    dt_t = jnp.min(jnp.where(t_active & (task_rate > 0),
                             s.task_rem / task_rate, _INF))
    future = (~s.job_admitted) & c.job_valid & (c.job_release > s.time)
    dt_r = jnp.min(jnp.where(future, c.job_release - s.time, _INF))
    dt = jnp.minimum(jnp.minimum(dt_p, dt_t), dt_r)
    if meta.has_failures:
        # fail/recover instants are rate breakpoints exactly like job
        # releases — they join the analytic min, no event heap needed
        # (DESIGN.md §7); ``fail_breaks`` is the four schedule tensors
        # pre-concatenated so this is ONE masked min (DESIGN.md §8)
        dt_f = jnp.min(jnp.where(c.fail_breaks > s.time,
                                 c.fail_breaks - s.time, _INF))
        dt = jnp.minimum(dt, dt_f)
    if meta.has_degradation:
        # gray-window edges are rate breakpoints exactly like outages
        # (DESIGN.md §13); ``deg_breaks`` pre-concatenates the four
        # schedule tensors so this is ONE masked min
        dt_d = jnp.min(jnp.where(c.deg_breaks > s.time,
                                 c.deg_breaks - s.time, _INF))
        dt = jnp.minimum(dt, dt_d)
    if meta.has_ctrl:
        # rule-install completions and migration resumes are rate
        # breakpoints exactly like failures (DESIGN.md §10): the analytic
        # min lands the clock exactly on each wake instant
        dt_c = jnp.min(jnp.where((s.pkt_state == INSTALLING)
                                 & (s.pkt_ready_t > s.time),
                                 s.pkt_ready_t - s.time, _INF))
        dt_m = jnp.min(jnp.where(s.vm_mig_until > s.time,
                                 s.vm_mig_until - s.time, _INF))
        dt = jnp.minimum(dt, jnp.minimum(dt_c, dt_m))
        # controller failover edges (primary down, election gap end,
        # primary back — DESIGN.md §13) are breakpoints too; all three
        # are inf when failover is unconfigured
        fo = jnp.stack([
            c.ctrl_fail_t,
            jnp.minimum(c.ctrl_fail_t + c.ctrl_failover_delay,
                        c.ctrl_recover_t),
            c.ctrl_recover_t])
        dt_fo = jnp.min(jnp.where(fo > s.time, fo - s.time, _INF))
        dt = jnp.minimum(dt, dt_fo)
    if meta.spec_slots > 0:
        # clone finishes join the min like task finishes
        dt_s = jnp.min(jnp.where((s.spec_of >= 0) & (spec_rate > 0),
                                 s.spec_rem / spec_rate, _INF))
        dt = jnp.minimum(dt, dt_s)
    stalled = jnp.isinf(dt)
    dt = jnp.where(stalled, 0.0, dt)

    # energy (power is constant over [t, t+dt))
    vm_safe = jnp.maximum(s.task_vm, 0)
    host_of_task = _vm_host(c, meta, s)[vm_safe]
    # MIPS-by-host via a compacted per-active-task accumulation, not a
    # task-axis scatter-add: the scatter runs EVERY step, and under a
    # vmapped cohort an XLA/CPU scatter serializes one row per lane
    # (DESIGN.md §9) — it alone cost the xl fleet ~10% batch efficiency.
    # Ascending task order is the scatter's own update order and the
    # skipped zero-adds are f32-exact (x + 0.0 == x away from -0.0/NaN,
    # and rate partial sums are finite and non-negative), so host_energy
    # stays bit-identical to the reference scatter.
    n_t_e = host_of_task.shape[0]
    hiota = jnp.arange(c.host_total_mips.shape[0], dtype=jnp.int32)
    order_e = jnp.sort(jnp.where(t_active,
                                 jnp.arange(n_t_e, dtype=jnp.int32), n_t_e))

    def mips_one(k, m):
        i = order_e[jnp.minimum(k, n_t_e - 1)]
        return m + jnp.where(hiota == host_of_task[i], task_rate[i], 0.0)

    mips_used = jax.lax.fori_loop(0, jnp.sum(t_active.astype(jnp.int32)),
                                  mips_one, jnp.zeros_like(c.host_total_mips))
    if meta.spec_slots > 0:
        # clones burn host cycles like real tasks; the slot axis is tiny
        # (n_jobs * spec_slots) so a dense one-hot contraction is cheaper
        # than extending the compacted loop
        clone_host = _vm_host(c, meta, s)[jnp.maximum(s.spec_vm, 0)]
        mips_used = mips_used + jnp.sum(
            jnp.where((s.spec_of >= 0)[:, None]
                      & (hiota[None, :] == clone_host[:, None]),
                      spec_rate[:, None], 0.0), axis=0)
    # utilization is relative to the CURRENT (possibly degraded) capacity:
    # a saturated gray host draws full power for less work (DESIGN.md §13);
    # _effective_host_mips is exactly host_total_mips when degradation is
    # off, keeping the off-switch trace unchanged
    util = jnp.clip(mips_used / jnp.maximum(_effective_host_mips(c, meta, s),
                                            1e-9), 0.0, 1.0)
    if meta.has_failures:
        util = jnp.where(s.host_dead, 0.0, util)  # dead hosts draw 0 W
    host_energy = s.host_energy + host_power(util, meta.energy) * dt
    host_busy = s.host_busy + jnp.where(util > 0, dt, 0.0)
    live_link = (nc > 0).astype(jnp.int32)
    if meta.has_failures:
        live_link = jnp.where(s.link_dead, 0, live_link)  # port is down
    # link-axis one-hot contraction, not two scatters (vmap serialization,
    # DESIGN.md §9); only the switch slice of the node axis is needed
    sw_iota = meta.n_hosts + jnp.arange(meta.n_switches, dtype=jnp.int32)
    sw_ports = jnp.sum(
        ((c.link_src[:, None] == sw_iota[None, :]).astype(jnp.int32)
         + (c.link_dst[:, None] == sw_iota[None, :]).astype(jnp.int32))
        * live_link[:, None], axis=0)
    switch_energy = s.switch_energy + switch_power(sw_ports, meta.energy) * dt

    if meta.has_failures:
        # per-job downtime: admitted, not done, and NOTHING of the job's
        # moves over [t, t+dt) — the failure-induced outage metric
        n_j = s.job_downtime.shape[0]
        prog_t = t_active & (task_rate > 0) & c.task_valid
        prog_p = p_active & (pkt_rate > 0) & c.pkt_valid
        # grouped ANY via one-hot masks, not two scatter-maxes (vmap
        # serialization, DESIGN.md §9); max over {0,1} == any
        jiota = jnp.arange(n_j, dtype=jnp.int32)
        job_prog = (
            jnp.any((jnp.maximum(c.task_job, 0)[:, None] == jiota[None, :])
                    & prog_t[:, None], axis=0)
            | jnp.any((jnp.maximum(c.pkt_job, 0)[:, None] == jiota[None, :])
                      & prog_p[:, None], axis=0)).astype(jnp.int32)
        job_live = (s.job_admitted & (s.job_out_done < c.job_n_out)
                    & c.job_valid)
        job_downtime = s.job_downtime + jnp.where(
            job_live & (job_prog == 0), dt, 0.0)
    else:
        job_downtime = s.job_downtime

    if meta.has_degradation:
        # wall-clock seconds with ANY live gray window open — the
        # degraded-exposure metric (same pass-through shape as
        # job_downtime: off-replicas in a packed sweep accumulate 0)
        any_deg = (jnp.any((c.host_slow_t <= s.time)
                           & (s.time < c.host_restore_t)
                           & (c.host_deg_factor != 1.0))
                   | jnp.any((c.link_slow_t <= s.time)
                             & (s.time < c.link_restore_t)
                             & (c.link_deg_factor != 1.0)))
        degraded_time = s.degraded_time + jnp.where(any_deg, dt, 0.0)
    else:
        degraded_time = s.degraded_time

    # advance
    time = s.time + dt
    pkt_rem = jnp.where(p_active, s.pkt_rem - pkt_rate * dt, s.pkt_rem)
    task_rem = jnp.where(t_active, s.task_rem - task_rate * dt, s.task_rem)
    p_done_now = p_active & (pkt_rem <= aux["pkt_tol"])
    t_done_now = t_active & (task_rem <= aux["task_tol"])

    pkt_state = jnp.where(p_done_now, DONE, s.pkt_state)
    pkt_finish = jnp.where(p_done_now, time, s.pkt_finish)
    task_state = jnp.where(t_done_now, DONE, s.task_state)
    task_finish = jnp.where(t_done_now, time, s.task_finish)

    if meta.has_ctrl:
        # count the primary→backup handover once, when the clock passes
        # ctrl_fail_t (a dt breakpoint, so the crossing is exact)
        crossed = (s.time <= c.ctrl_fail_t) & (time > c.ctrl_fail_t)
        ctrl_failovers = s.ctrl_failovers + crossed.astype(jnp.int32)
    else:
        ctrl_failovers = s.ctrl_failovers

    # completions feed gates + release their channels.  Only a handful of
    # packets finish per event, so this is a compacted scan over the done
    # set — pop order is a cursor-chained masked min per trip (ascending
    # packet index, same order the old argmax-chain popped; a precomputed
    # packet-axis sort runs EVERY step, done set or not, and was one of
    # the largest single per-step costs) instead of three packet-axis
    # scatters (DESIGN.md §8).  The per-trip updates are one-hot
    # compare-sums, NOT scatters: under vmap an XLA/CPU scatter serializes
    # one row per lane, and at fleet widths the three scatters per trip
    # dominated the whole step.  All updates are commutative integer adds,
    # so the carried ``nc`` stays exact (mirroring activation's bumps) —
    # bit-identical.
    n_t_pad = s.task_got.shape[0]
    n_j_pad = s.job_out_done.shape[0]
    n_p_pad = p_done_now.shape[0]
    n_done = jnp.sum(p_done_now.astype(jnp.int32))
    idx_p = jnp.arange(n_p_pad, dtype=jnp.int32)
    liota = jnp.arange(nc.shape[0], dtype=jnp.int32)
    tiota = jnp.arange(n_t_pad, dtype=jnp.int32)
    jiota = jnp.arange(n_j_pad, dtype=jnp.int32)

    def complete_one(k, carry):
        nc_c, task_got, job_out_done, cursor = carry
        i = jnp.min(jnp.where(p_done_now & (idx_p > cursor), idx_p,
                              n_p_pad))                 # k < n_done -> real
        safe = jnp.minimum(i, n_p_pad - 1)
        links_i = c.routes[jnp.maximum(s.pkt_pair[safe], 0),
                           jnp.maximum(s.pkt_cand[safe], 0)]
        nc_c = nc_c - jnp.sum((links_i[:, None] == liota[None, :])
                              .astype(jnp.int32), axis=0)
        feeds_i = c.pkt_feeds_task[safe]
        task_got = task_got + (tiota == feeds_i).astype(jnp.int32)
        jtgt = jnp.where(feeds_i < 0, jnp.maximum(c.pkt_job[safe], 0), -1)
        job_out_done = job_out_done + (jiota == jtgt).astype(jnp.int32)
        return nc_c, task_got, job_out_done, i

    nc_next, task_got, job_out_done, _ = jax.lax.fori_loop(
        0, n_done, complete_one,
        (nc, s.task_got, s.job_out_done, jnp.int32(-1)))
    newly_job_done = (job_out_done >= c.job_n_out) & \
        (s.job_out_done < c.job_n_out) & c.job_valid
    job_done_t = jnp.where(newly_job_done, time, s.job_done_t)
    # task-axis one-hot contraction, not a scatter (same vmap reason);
    # integer adds commute -> bit-identical
    vm_iota = jnp.arange(s.vm_load.shape[0], dtype=jnp.int32)
    vm_load = s.vm_load - jnp.sum(
        (vm_safe[:, None] == vm_iota[None, :])
        & t_done_now[:, None], axis=0).astype(jnp.int32)

    spec_of, spec_rem = s.spec_of, s.spec_rem
    spec_wins, spec_wasted = s.spec_wins, s.spec_wasted
    if meta.spec_slots > 0:
        # clone completions: first finish WINS the race (DESIGN.md §13).
        # A tie on the same breakpoint goes to the original, so the
        # speculation axis can only ever help a task's finish time.
        s_orig = jnp.maximum(spec_of, 0)
        s_live = spec_of >= 0
        spec_rem = jnp.where(s_live, spec_rem - spec_rate * dt, spec_rem)
        clone_done = s_live & (spec_rem <= aux["task_tol"][s_orig])
        win = clone_done & ~t_done_now[s_orig]
        # task-axis effect of the wins (one-hot, not a scatter)
        win_t = jnp.sum((s_orig[:, None] == tiota[None, :])
                        & win[:, None], axis=0) > 0
        task_state = jnp.where(win_t, DONE, task_state)
        task_finish = jnp.where(win_t, time, task_finish)
        task_rem = jnp.where(win_t, 0.0, task_rem)
        # the losing copy frees its container: the overtaken ORIGINAL's VM
        # on a win, the clone's VM on every clone finish
        vm_load = vm_load - jnp.sum(
            (vm_safe[:, None] == vm_iota[None, :])
            & win_t[:, None], axis=0).astype(jnp.int32)
        vm_load = vm_load - jnp.sum(
            (jnp.maximum(s.spec_vm, 0)[:, None] == vm_iota[None, :])
            & clone_done[:, None], axis=0).astype(jnp.int32)
        # wasted seconds: the original's whole run on a win, the clone's
        # on a photo-finish loss (cancelled clones accrue in _speculate)
        waste = jnp.where(win, time - s.task_start[s_orig],
                          time - s.spec_start)
        spec_wasted = spec_wasted + jnp.sum(
            jnp.where(clone_done, waste, 0.0))
        spec_wins = spec_wins + jnp.sum(win.astype(jnp.int32))
        spec_of = jnp.where(clone_done, -1, spec_of)

    return s._replace(
        time=time, steps=s.steps + 1, stalled=stalled,
        job_out_done=job_out_done, job_done_t=job_done_t,
        task_state=task_state, task_rem=task_rem, task_got=task_got,
        task_finish=task_finish,
        pkt_state=pkt_state, pkt_rem=pkt_rem, pkt_finish=pkt_finish,
        vm_load=vm_load, host_energy=host_energy, host_busy=host_busy,
        switch_energy=switch_energy, job_downtime=job_downtime,
        degraded_time=degraded_time, spec_of=spec_of, spec_rem=spec_rem,
        spec_wins=spec_wins, spec_wasted=spec_wasted,
        ctrl_failovers=ctrl_failovers), \
        {**cache, "nc": nc_next}


# ---------------------------------------------------------------------------
# public API
# ---------------------------------------------------------------------------


def make_packed_simulator(meta):
    """Returns ``run(consts, policy_dict, s0=None) -> SimState`` with consts
    as an ARGUMENT, so a heterogeneous-scenario sweep can vmap over consts
    and policies together (see ``repro.scenarios.sweep``, DESIGN.md §5).

    ``meta`` is a ``SimMeta`` (a legacy meta dict is coerced): only static
    shapes + scalar params shared by every replica in the batch (padded
    maxima for a packed sweep).

    ``s0`` lets a caller pass the t=0 state in as a real argument —
    ``repro.api.runners`` builds it outside the jitted loop and DONATES its
    buffers, so XLA aliases them straight into the while-loop carry instead
    of materializing a second copy (DESIGN.md §8).  ``None`` derives it
    from consts, which is always equivalent.

    The finished flag rides in the loop carry: ``_finished`` is evaluated
    once per body on the advanced state instead of once in ``cond`` and
    again in ``body``, and the body is one ``lax.cond`` on the carried
    flag — a finished replica in a vmapped batch passes its state through
    (the batching rule turns the cond into the old per-leaf select), while
    an unbatched run skips even the selects.
    """
    meta = SimMeta.coerce(meta)

    def run(consts: EngineConsts, pol: Dict[str, jnp.ndarray],
            s0: SimState | None = None) -> SimState:
        if s0 is None:
            s0 = init_state_from_consts(consts, meta.n_switches,
                                        meta.ctrl_slots, meta.spec_slots)
        aux = _make_aux(consts, pol)
        # nothing is active at t=0, so the carried channel counts start 0
        cache0 = {**_endpoint_cache(consts, meta, s0),
                  "nc": jnp.zeros(meta.n_links, jnp.int32)}

        def cond(carry):
            _, _, done = carry
            return ~done

        def body(carry):
            s, cache, done = carry
            s, cache = jax.lax.cond(
                done, lambda sc: sc,
                lambda sc: _step(consts, meta, pol, aux, sc), (s, cache))
            return s, cache, _finished(consts, meta, s)

        s_final, _, _ = jax.lax.while_loop(
            cond, body, (s0, cache0, _finished(consts, meta, s0)))
        return s_final

    return run


def make_simulator(setup: SimSetup):
    """Returns a jit-able ``run(policy_dict) -> SimState`` closure."""
    consts, meta = make_consts(setup)
    run = make_packed_simulator(meta)
    return partial(run, consts)


# --- fleet chunk stepper (DESIGN.md §9) ------------------------------------


def tree_select(done, old, new):
    """Per-lane freeze: where ``done`` (a ``[W]`` bool), keep ``old``'s
    leaves, else take ``new``'s.  The fleet chunk applies it manually after
    an UNGUARDED vmapped step — a ``lax.cond`` on a batched done flag
    lowers to a select that still executes the step for every lane, and
    its both-branch machinery is ~40x slower than the step + select
    (DESIGN.md §9).  Running ``_step`` on a finished state is safe: its
    outputs are discarded here, and the compacted scans inside get zero
    trip counts."""
    def sel(a, b):
        d = done.reshape(done.shape + (1,) * (b.ndim - done.ndim))
        return jnp.where(d, a, b)
    return jax.tree_util.tree_map(sel, old, new)


def init_fleet_carry(consts: EngineConsts, meta, width: int):
    """The t=0 chunk carry for a ``width``-lane cohort sharing one consts:
    ``(SimState, step-cache, done)`` with every leaf gaining a leading lane
    axis.  Lanes start identical — policies differ, states don't."""
    meta = SimMeta.coerce(meta)
    s0 = init_state_from_consts(consts, meta.n_switches, meta.ctrl_slots,
                                meta.spec_slots)
    cache0 = {**_endpoint_cache(consts, meta, s0),
              "nc": jnp.zeros(meta.n_links, jnp.int32)}
    done0 = _finished(consts, meta, s0)
    return jax.tree_util.tree_map(
        lambda a: jnp.broadcast_to(a, (width,) + a.shape),
        (s0, cache0, done0))


def make_fleet_chunk(meta, static_pol=None, chunk_steps: int = 32,
                     consts_axes=None):
    """Build the fleet's K-step cohort stepper (DESIGN.md §9):
    ``chunk(consts, pol, carry) -> carry`` advancing every live lane up to
    ``chunk_steps`` events, early-exiting when the whole cohort finishes.

    ``consts_axes`` (default None: one consts shared by every lane) is a
    vmap in_axes pytree over ``EngineConsts`` — the streaming ring
    (DESIGN.md §11) maps the refillable job/task/packet leaves per lane
    (axis 0) while topology/cluster leaves stay shared (None), because
    lanes retire and reload ring slots at different times.

    ``carry`` is ``(SimState, cache, done)`` with a leading lane axis on
    every leaf (see ``init_fleet_carry``); ``pol`` holds the LANE-VARYING
    policy fields as ``[W]`` arrays, while ``static_pol`` carries the
    branch-selecting fields (routing / traffic / placement) as Python ints
    closed over at trace time — the cohort scheduler groups lanes so these
    are uniform, which is what lets ``_activate`` / ``_place_batch`` /
    ``fairshare.rates`` specialize their dispatch instead of executing
    both branches of a batched ``lax.cond`` (the batch wall).

    The caller jits (and on a multi-device mesh, shard_maps) the result;
    between chunk invocations the fleet scheduler retires finished lanes,
    compacts, and refills from its pending queue, so no lane runs more
    than ``chunk_steps - 1`` wasted events past its own finish."""
    meta = SimMeta.coerce(meta)
    static_pol = dict(static_pol or {})

    def lane_step(consts, pol_lane, aux, sc):
        pol = {**pol_lane, **static_pol}
        s, cache = _step(consts, meta, pol, aux, sc)
        return s, cache, _finished(consts, meta, s)

    vstep = jax.vmap(lane_step, in_axes=(consts_axes, 0, 0, 0))

    def chunk(consts, pol, carry):
        # loop-invariant per-lane tensors hoisted OUT of the while loop,
        # mirroring the serial runner (XLA does not reliably hoist them
        # out of a vmapped while body itself)
        if consts_axes is None:
            vaux = jax.vmap(
                lambda p: _make_aux(consts, {**p, **static_pol}))(pol)
        else:
            vaux = jax.vmap(
                lambda c_, p: _make_aux(c_, {**p, **static_pol}),
                in_axes=(consts_axes, 0))(consts, pol)

        def cond(c):
            i, (_s, _cache, done) = c
            return (i < chunk_steps) & ~jnp.all(done)

        def body(c):
            i, (s, cache, done) = c
            s2, cache2, done2 = vstep(consts, pol, vaux, (s, cache))
            # freeze the STATE of finished lanes (it is the result the
            # scheduler retires); the cache needs no select — it is never
            # read into results, a finished lane's pseudo-steps leave its
            # ready set empty, and a refill resets it from the t=0 carry.
            # The chunk loop is UNBATCHED (vmap is inside vstep), so this
            # cond really branches: with a well-bucketed cohort no lane is
            # done until the tail of the chunk and the whole-state select
            # (the widest memory traffic in the loop) is skipped.
            s = jax.lax.cond(jnp.any(done),
                             lambda: tree_select(done, s, s2),
                             lambda: s2)
            return i + 1, (s, cache2, done | done2)

        return jax.lax.while_loop(cond, body, (0, carry))[1]

    return chunk


# --- deprecated shims ------------------------------------------------------
# The unified front door is ``repro.api`` (DESIGN.md §6): ``Experiment``
# dispatches single / policy-batch / packed-scenario execution through one
# compiled-runner cache, so repeated calls with an equal ``SimMeta`` reuse
# the traced program.  These wrappers keep the old spellings working and are
# proven bit-identical to the Experiment path by tests/test_api.py.


def simulate(setup: SimSetup, policy=None) -> SimState:
    """Deprecated shim: run one replica via the cached runner
    (policy: PolicyConfig, dict of scalars, or None for defaults).
    Prefer ``repro.api.Experiment(scenarios=setup, policies=policy).run()``.
    """
    from ..api import runners  # local import: api sits above core
    consts, meta = make_consts(setup)
    return runners.get_runner(meta, "single")(consts, as_policy_arrays(policy))


def simulate_batch(setup: SimSetup, pols: Dict[str, jnp.ndarray]) -> SimState:
    """Deprecated shim: vmap over a policy sweep — every dict value has a
    leading replica dim (missing registered fields broadcast their default).
    Prefer ``repro.api.Experiment``."""
    from ..api import runners
    consts, meta = make_consts(setup)
    pols = as_policy_arrays(pols)
    width = max((v.shape[0] for v in pols.values() if v.ndim), default=1)
    pols = {k: v if v.ndim else jnp.broadcast_to(v, (width,))
            for k, v in pols.items()}
    return runners.get_runner(meta, "policy_batch")(consts, pols)


def simulate_scenarios(consts: EngineConsts, meta,
                       pols: Dict[str, jnp.ndarray]) -> SimState:
    """Deprecated shim: ZIPPED batch over packed consts — every consts array
    and every policy value shares one leading replica dim R, and replica i
    runs consts[i] under pols[i].  Build consts with
    ``scenarios.sweep.pack_setups``; for the full scenario×policy cross
    product prefer ``repro.api.Experiment`` (or ``sweep_grid``), which nests
    the vmaps so consts broadcast over the policy axis."""
    from ..api import runners
    return runners.get_runner(SimMeta.coerce(meta), "zipped")(consts, pols)
