"""Generic flow-level frontend to the DES (beyond the MapReduce builder).

``flows_setup`` turns an arbitrary set of node-to-node transfers — with
optional round barriers — into a ``SimSetup`` the event engine runs.  This
is how the roofline advisor replays TPU collective schedules (ring
reduce-scatter/all-gather rounds on a torus) through the paper's network
model, and how closed-form test scenarios are written.

Rounds: packets of round r+1 activate only after EVERY round-r packet has
landed (modeled with a zero-MI barrier task per round, fed by all round-r
packets).  Endpoints are direct node ids (engine NODE_OFFSET encoding).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import numpy as np

from .energy import EnergyParams
from .engine import NODE_OFFSET
from .mapreduce import ClusterSpec, JobSpec, SimSetup
from .routing import RouteTable, build_route_table
from .topology import Topology

GBIT = 1e9


@dataclasses.dataclass(frozen=True)
class Flow:
    src: int            # node id
    dst: int            # node id
    gbits: float
    round: int = 0


def flows_cluster(topo: Topology, mips: float = 1e9) -> ClusterSpec:
    """One VM per host; compute is irrelevant (flows carry 0 MI)."""
    n = topo.n_hosts
    return ClusterSpec(
        topo=topo,
        vm_host=np.arange(n, dtype=np.int32),
        vm_total_mips=np.full(n, mips, np.float32),
        vm_core_mips=np.full(n, mips, np.float32),
        host_total_mips=np.full(n, mips, np.float32),
        storage_node=topo.n_nodes - 1 if topo.n_storage else 0,
        energy=EnergyParams(),
    )


def flows_setup(topo: Topology, flows: Sequence[Flow], *,
                k_max: int = 8,
                route_table: RouteTable | None = None) -> SimSetup:
    cluster = flows_cluster(topo)
    rt = route_table or build_route_table(topo, k_max=k_max)
    rounds = sorted({f.round for f in flows})
    r_index = {r: i for i, r in enumerate(rounds)}
    n_rounds = len(rounds)
    per_round = [sum(1 for f in flows if f.round == r) for r in rounds]

    p_job, p_phase, p_bits = [], [], []
    p_gate, p_feeds, p_src, p_dst = [], [], [], []
    for f in flows:
        ri = r_index[f.round]
        last = ri == n_rounds - 1
        p_job.append(0)
        p_phase.append(min(ri, 2))
        p_bits.append(f.gbits * GBIT)
        p_gate.append(ri - 1 if ri > 0 else -1)   # gated on prior barrier
        p_feeds.append(-1 if last else ri)        # last round = job output
        p_src.append(NODE_OFFSET + f.src)
        p_dst.append(NODE_OFFSET + f.dst)
    n_t, n_p = n_rounds, len(p_job)

    return SimSetup(
        cluster=cluster,
        route_table=rt,
        jobs=(JobSpec(submit_time=0.0, n_map=1, n_reduce=1, map_mi=0,
                      reduce_mi=0, input_gbits=0, shuffle_gbits=0,
                      output_gbits=0),),
        job_release=np.zeros(1, np.float32),
        job_total_mi=np.zeros(1, np.float32),
        job_priority=np.zeros(1, np.float32),
        job_n_out=np.asarray([per_round[-1]], np.int32),
        task_job=np.zeros(n_t, np.int32),
        task_kind=np.zeros(n_t, np.int8),
        task_mi=np.zeros(n_t, np.float32),
        task_need=np.asarray(per_round, np.int32),
        task_valid=np.ones(n_t, bool),
        pkt_job=np.asarray(p_job, np.int32),
        pkt_phase=np.asarray(p_phase, np.int8),
        pkt_bits=np.asarray(p_bits, np.float32),
        pkt_gate_task=np.asarray(p_gate, np.int32),
        pkt_feeds_task=np.asarray(p_feeds, np.int32),
        pkt_src_task=np.asarray(p_src, np.int32),
        pkt_dst_task=np.asarray(p_dst, np.int32),
        pkt_valid=np.ones(n_p, bool),
    )
