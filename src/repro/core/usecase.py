"""The paper's §5 experiment: Tables 2-3, Fig. 9 topology, 15 jobs.

Host & SAN: 8 CPUs, 30 GB, 10000 MIPS.   VM: 4 CPUs, 8 GB, 1250 MIPS/core.
Links: SAN<->core1 4 Gbps, all switch/host links 1 Gbps.
Jobs: 5 small / 5 medium / 5 big (Table 3), submitted in random order with a
1 s interval (§5.3).  16 VMs, one per host, one application master.
"""
from __future__ import annotations

from typing import List, Sequence

import numpy as np

from .energy import EnergyParams
from .mapreduce import ClusterSpec, JobSpec, SimSetup, build_setup
from .topology import paper_fat_tree

# Table 3 rows: (map MI, reduce MI, storage Gb, mappers Gb, reducers Gb, nm, nr)
TABLE3 = {
    "small": (100_000.0, 75_000.0, 200.0, 150.0, 100.0, 2, 1),
    "medium": (200_000.0, 175_000.0, 400.0, 350.0, 300.0, 4, 2),
    "big": (300_000.0, 275_000.0, 600.0, 550.0, 500.0, 6, 3),
}

VM_CORES, VM_CORE_MIPS = 4, 1250.0
HOST_CORES, HOST_MIPS = 8, 10_000.0


def paper_jobs(seed: int = 0, interval_s: float = 1.0,
               n_each: int = 5) -> List[JobSpec]:
    """15 jobs in random order, 1 s apart (paper §5.3)."""
    kinds = ["small"] * n_each + ["medium"] * n_each + ["big"] * n_each
    rng = np.random.RandomState(seed)
    rng.shuffle(kinds)
    jobs = []
    for i, kind in enumerate(kinds):
        m_mi, r_mi, st, mp, rd, nm, nr = TABLE3[kind]
        jobs.append(JobSpec(submit_time=i * interval_s, n_map=nm, n_reduce=nr,
                            map_mi=m_mi, reduce_mi=r_mi, input_gbits=st,
                            shuffle_gbits=mp, output_gbits=rd))
    return jobs


def paper_cluster(n_vms: int = 16) -> ClusterSpec:
    topo = paper_fat_tree()
    # one VM per host, round-robin (paper: "simple VM allocation policy")
    vm_host = np.arange(n_vms, dtype=np.int32) % topo.n_hosts
    return ClusterSpec(
        topo=topo,
        vm_host=vm_host,
        vm_total_mips=np.full(n_vms, VM_CORES * VM_CORE_MIPS, np.float32),
        vm_core_mips=np.full(n_vms, VM_CORE_MIPS, np.float32),
        host_total_mips=np.full(topo.n_hosts, HOST_CORES * HOST_MIPS,
                                np.float32),
        storage_node=topo.storage(0),
        energy=EnergyParams(),
    )


def paper_setup(seed: int = 0, jobs: Sequence[JobSpec] | None = None,
                n_vms: int = 16, split: int = 2) -> SimSetup:
    """split=2: each logical transfer is sent as 2 network packets (the CSV
    'size of network packets' attribute; calibrated in EXPERIMENTS.md)."""
    return build_setup(list(jobs) if jobs is not None else paper_jobs(seed),
                       paper_cluster(n_vms), split=split)
