"""Failure & recovery schedules (DESIGN.md §7).

The paper's YARN model detects host loss through the NodeManager→
ResourceManager heartbeat (§3.1.2) and re-executes the lost tasks; related
SDN work (Tiloca et al., Kreutz et al.) makes link-failure handling the
discriminating test of a controller.  Both are modeled here WITHOUT an
event heap: a failure schedule is four piecewise-constant breakpoint
tensors — ``host_fail_t``/``host_recover_t`` per host and
``link_fail_t``/``link_recover_t`` per directed link — that join the
engine's analytic ``dt`` horizon min exactly like packet finishes and job
releases do.  ``inf`` means "never": the all-``inf`` schedule is the
no-failure engine, bit-identical to a run without any schedule.

A device is DEAD on ``[fail_t, recover_t)`` (one outage per device per
run; chain runs for multi-outage studies).  Dead hosts draw 0 W and lose
their WAITING/ACTIVE tasks to re-placement; dead links carry 0 bandwidth
and kick their in-flight packets back to WAITING for re-routing.

Host-side (numpy) construction; seeded trace *generators* live in
``repro.scenarios.failures``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class FailureSchedule:
    """Deterministic outage windows for every host and directed link.

    All four arrays are float32; ``inf`` = the event never happens.  A
    finite ``recover_t`` with an ``inf`` ``fail_t`` is meaningless and
    rejected by ``validate``.
    """

    host_fail_t: np.ndarray     # f32 [n_hosts]
    host_recover_t: np.ndarray  # f32 [n_hosts]
    link_fail_t: np.ndarray     # f32 [n_links]
    link_recover_t: np.ndarray  # f32 [n_links]

    @property
    def any_failures(self) -> bool:
        return bool(np.isfinite(self.host_fail_t).any()
                    or np.isfinite(self.link_fail_t).any())

    @property
    def n_events(self) -> int:
        """Count of finite fail/recover instants (drives the engine's
        ``max_steps`` safety cap)."""
        return int(sum(np.isfinite(a).sum() for a in (
            self.host_fail_t, self.host_recover_t,
            self.link_fail_t, self.link_recover_t)))

    def instants(self) -> np.ndarray:
        """All fail/recover instants as ONE f32 tensor (``inf`` = never),
        shape ``[2*n_hosts + 2*n_links]`` — fixed by the topology, not by
        the outage count, so schedules differing only in how many outages
        they carry keep identical tensor shapes (and therefore share jit
        caches).  The engine mins over this single tensor per step instead
        of over the four device tensors separately (DESIGN.md §8)."""
        return np.concatenate([self.host_fail_t, self.host_recover_t,
                               self.link_fail_t, self.link_recover_t]
                              ).astype(np.float32)

    def validate(self, n_hosts: int, n_links: int) -> "FailureSchedule":
        assert self.host_fail_t.shape == (n_hosts,), \
            f"host_fail_t shape {self.host_fail_t.shape} != ({n_hosts},)"
        assert self.host_recover_t.shape == (n_hosts,)
        assert self.link_fail_t.shape == (n_links,), \
            f"link_fail_t shape {self.link_fail_t.shape} != ({n_links},)"
        assert self.link_recover_t.shape == (n_links,)
        for fail, rec in ((self.host_fail_t, self.host_recover_t),
                          (self.link_fail_t, self.link_recover_t)):
            assert np.all(rec >= fail), "recover_t must be >= fail_t"
            assert not np.any(np.isfinite(rec) & ~np.isfinite(fail)), \
                "finite recover_t without a finite fail_t"
        return self


def no_failures(n_hosts: int, n_links: int) -> FailureSchedule:
    """The identity schedule: nothing ever fails (all-``inf``)."""
    return FailureSchedule(
        host_fail_t=np.full(n_hosts, INF, np.float32),
        host_recover_t=np.full(n_hosts, INF, np.float32),
        link_fail_t=np.full(n_links, INF, np.float32),
        link_recover_t=np.full(n_links, INF, np.float32),
    )


def host_crash(n_hosts: int, n_links: int, host: int, at: float,
               recover_at: float = np.inf) -> FailureSchedule:
    """One host dies at ``at`` (permanently unless ``recover_at``)."""
    s = no_failures(n_hosts, n_links)
    s.host_fail_t[host] = at
    s.host_recover_t[host] = recover_at
    return s.validate(n_hosts, n_links)


def link_cut(n_hosts: int, n_links: int, links, at: float,
             recover_at: float = np.inf) -> FailureSchedule:
    """Cut the given directed link ids at ``at`` (a full-duplex cable is
    two directed links — pass both ids to sever the cable)."""
    s = no_failures(n_hosts, n_links)
    for li in np.atleast_1d(links):
        s.link_fail_t[li] = at
        s.link_recover_t[li] = recover_at
    return s.validate(n_hosts, n_links)
