"""Failure & recovery schedules (DESIGN.md §7).

The paper's YARN model detects host loss through the NodeManager→
ResourceManager heartbeat (§3.1.2) and re-executes the lost tasks; related
SDN work (Tiloca et al., Kreutz et al.) makes link-failure handling the
discriminating test of a controller.  Both are modeled here WITHOUT an
event heap: a failure schedule is four piecewise-constant breakpoint
tensors — ``host_fail_t``/``host_recover_t`` per host and
``link_fail_t``/``link_recover_t`` per directed link — that join the
engine's analytic ``dt`` horizon min exactly like packet finishes and job
releases do.  ``inf`` means "never": the all-``inf`` schedule is the
no-failure engine, bit-identical to a run without any schedule.

A device is DEAD on ``[fail_t, recover_t)`` (one outage per device per
run; chain runs for multi-outage studies).  Dead hosts draw 0 W and lose
their WAITING/ACTIVE tasks to re-placement; dead links carry 0 bandwidth
and kick their in-flight packets back to WAITING for re-routing.

Host-side (numpy) construction; seeded trace *generators* live in
``repro.scenarios.failures``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class FailureSchedule:
    """Deterministic outage windows for every host and directed link.

    All four arrays are float32; ``inf`` = the event never happens.  A
    finite ``recover_t`` with an ``inf`` ``fail_t`` is meaningless and
    rejected by ``validate``.
    """

    host_fail_t: np.ndarray     # f32 [n_hosts]
    host_recover_t: np.ndarray  # f32 [n_hosts]
    link_fail_t: np.ndarray     # f32 [n_links]
    link_recover_t: np.ndarray  # f32 [n_links]

    @property
    def any_failures(self) -> bool:
        return bool(np.isfinite(self.host_fail_t).any()
                    or np.isfinite(self.link_fail_t).any())

    @property
    def n_events(self) -> int:
        """Count of finite fail/recover instants (drives the engine's
        ``max_steps`` safety cap)."""
        return int(sum(np.isfinite(a).sum() for a in (
            self.host_fail_t, self.host_recover_t,
            self.link_fail_t, self.link_recover_t)))

    def instants(self) -> np.ndarray:
        """All fail/recover instants as ONE f32 tensor (``inf`` = never),
        shape ``[2*n_hosts + 2*n_links]`` — fixed by the topology, not by
        the outage count, so schedules differing only in how many outages
        they carry keep identical tensor shapes (and therefore share jit
        caches).  The engine mins over this single tensor per step instead
        of over the four device tensors separately (DESIGN.md §8)."""
        return np.concatenate([self.host_fail_t, self.host_recover_t,
                               self.link_fail_t, self.link_recover_t]
                              ).astype(np.float32)

    def validate(self, n_hosts: int, n_links: int) -> "FailureSchedule":
        assert self.host_fail_t.shape == (n_hosts,), \
            f"host_fail_t shape {self.host_fail_t.shape} != ({n_hosts},)"
        assert self.host_recover_t.shape == (n_hosts,)
        assert self.link_fail_t.shape == (n_links,), \
            f"link_fail_t shape {self.link_fail_t.shape} != ({n_links},)"
        assert self.link_recover_t.shape == (n_links,)
        for kind, fail, rec in (
                ("host", self.host_fail_t, self.host_recover_t),
                ("link", self.link_fail_t, self.link_recover_t)):
            # a finite window must have positive length: ``rec == fail``
            # would be a zero-length outage whose fail AND recover land on
            # the same dt breakpoint (the transition delta never fires),
            # and ``rec < fail`` is a recovery before the failure — both
            # silently passed the old ``rec >= fail`` check for the
            # degenerate equal case and are rejected loudly now
            bad = np.isfinite(fail) & (rec <= fail)
            if np.any(bad):
                ids = np.flatnonzero(bad)
                raise ValueError(
                    f"{kind} outage window(s) {ids.tolist()} have "
                    f"recover_t <= fail_t (zero/negative length): "
                    f"fail_t={fail[ids].tolist()} "
                    f"recover_t={rec[ids].tolist()}")
            assert not np.any(np.isfinite(rec) & ~np.isfinite(fail)), \
                "finite recover_t without a finite fail_t"
        return self


def no_failures(n_hosts: int, n_links: int) -> FailureSchedule:
    """The identity schedule: nothing ever fails (all-``inf``)."""
    return FailureSchedule(
        host_fail_t=np.full(n_hosts, INF, np.float32),
        host_recover_t=np.full(n_hosts, INF, np.float32),
        link_fail_t=np.full(n_links, INF, np.float32),
        link_recover_t=np.full(n_links, INF, np.float32),
    )


def host_crash(n_hosts: int, n_links: int, host: int, at: float,
               recover_at: float = np.inf) -> FailureSchedule:
    """One host dies at ``at`` (permanently unless ``recover_at``)."""
    s = no_failures(n_hosts, n_links)
    s.host_fail_t[host] = at
    s.host_recover_t[host] = recover_at
    return s.validate(n_hosts, n_links)


def link_cut(n_hosts: int, n_links: int, links, at: float,
             recover_at: float = np.inf) -> FailureSchedule:
    """Cut the given directed link ids at ``at`` (a full-duplex cable is
    two directed links — pass both ids to sever the cable)."""
    s = no_failures(n_hosts, n_links)
    for li in np.atleast_1d(links):
        s.link_fail_t[li] = at
        s.link_recover_t[li] = recover_at
    return s.validate(n_hosts, n_links)


@dataclasses.dataclass(frozen=True)
class DegradationSchedule:
    """Gray-failure windows (DESIGN.md §13): piecewise-constant rate
    MULTIPLIERS instead of binary outages.

    A host executes at ``host_factor`` x MIPS on ``[host_slow_t,
    host_restore_t)`` (the straggler model: a slow disk or an
    oversubscribed NodeManager throttles every task on the host), a
    directed link carries ``link_factor`` x bandwidth on its window (an
    oversubscribed NIC / flapping optic).  Outside the window — and
    whenever ``slow_t`` is ``inf`` or ``factor`` is exactly 1.0 — the
    device runs at full rate.  The window instants join the engine's
    analytic ``dt`` min exactly like the ``FailureSchedule`` breakpoints
    (same §7 pattern), so degraded rates stay piecewise constant between
    events and no event heap is needed.

    Unlike an outage, degradation never reverts work: tasks and packets
    keep their placement and routes and simply progress slower — that is
    what makes it GRAY.  Factors > 1 (a burst-boost window) are allowed.
    """

    host_slow_t: np.ndarray     # f32 [n_hosts]: window start (inf = never)
    host_restore_t: np.ndarray  # f32 [n_hosts]: window end
    host_factor: np.ndarray     # f32 [n_hosts]: MIPS multiplier in-window
    link_slow_t: np.ndarray     # f32 [n_links]
    link_restore_t: np.ndarray  # f32 [n_links]
    link_factor: np.ndarray     # f32 [n_links]: bandwidth multiplier

    @property
    def _live_host(self) -> np.ndarray:
        return np.isfinite(self.host_slow_t) & (self.host_factor != 1.0)

    @property
    def _live_link(self) -> np.ndarray:
        return np.isfinite(self.link_slow_t) & (self.link_factor != 1.0)

    @property
    def any_degradation(self) -> bool:
        """True iff some window can change a rate.  An all-``factor=1.0``
        (or all-``inf``) schedule is the identity: ``SimMeta``'s
        ``has_degradation`` stays False and the engine traces EXACTLY the
        pre-degradation program — same contract as ``any_failures``."""
        return bool(self._live_host.any() or self._live_link.any())

    @property
    def n_events(self) -> int:
        """Finite slow/restore instants on LIVE windows (drives the
        engine's ``max_steps`` cap like ``FailureSchedule.n_events``)."""
        lh, ll = self._live_host, self._live_link
        return int(sum(np.isfinite(a[m]).sum() for a, m in (
            (self.host_slow_t, lh), (self.host_restore_t, lh),
            (self.link_slow_t, ll), (self.link_restore_t, ll))))

    def instants(self) -> np.ndarray:
        """All LIVE slow/restore instants as ONE f32 tensor (``inf`` =
        never), shape ``[2*n_hosts + 2*n_links]`` — fixed by the topology
        like ``FailureSchedule.instants``.  Inert windows (``factor ==
        1.0``) are masked to ``inf`` so a mixed packed sweep never pays
        extra event steps for an identity lane."""
        lh, ll = self._live_host, self._live_link
        return np.concatenate([
            np.where(lh, self.host_slow_t, INF),
            np.where(lh, self.host_restore_t, INF),
            np.where(ll, self.link_slow_t, INF),
            np.where(ll, self.link_restore_t, INF),
        ]).astype(np.float32)

    def validate(self, n_hosts: int, n_links: int) -> "DegradationSchedule":
        assert self.host_slow_t.shape == (n_hosts,), \
            f"host_slow_t shape {self.host_slow_t.shape} != ({n_hosts},)"
        assert self.host_restore_t.shape == (n_hosts,)
        assert self.host_factor.shape == (n_hosts,)
        assert self.link_slow_t.shape == (n_links,), \
            f"link_slow_t shape {self.link_slow_t.shape} != ({n_links},)"
        assert self.link_restore_t.shape == (n_links,)
        assert self.link_factor.shape == (n_links,)
        for kind, slow, restore, factor in (
                ("host", self.host_slow_t, self.host_restore_t,
                 self.host_factor),
                ("link", self.link_slow_t, self.link_restore_t,
                 self.link_factor)):
            bad = np.isfinite(slow) & (restore <= slow)
            if np.any(bad):
                ids = np.flatnonzero(bad)
                raise ValueError(
                    f"{kind} degradation window(s) {ids.tolist()} have "
                    f"restore_t <= slow_t (zero/negative length)")
            if np.any(~(factor > 0.0) | ~np.isfinite(factor)):
                raise ValueError(
                    f"{kind}_factor must be finite and > 0 (a zero rate "
                    f"is an outage — use FailureSchedule)")
            assert not np.any(np.isfinite(restore) & ~np.isfinite(slow)), \
                "finite restore_t without a finite slow_t"
        return self


def no_degradation(n_hosts: int, n_links: int) -> DegradationSchedule:
    """The identity schedule: every device at factor 1.0 forever."""
    return DegradationSchedule(
        host_slow_t=np.full(n_hosts, INF, np.float32),
        host_restore_t=np.full(n_hosts, INF, np.float32),
        host_factor=np.ones(n_hosts, np.float32),
        link_slow_t=np.full(n_links, INF, np.float32),
        link_restore_t=np.full(n_links, INF, np.float32),
        link_factor=np.ones(n_links, np.float32),
    )


def host_slowdown(n_hosts: int, n_links: int, host: int, at: float,
                  factor: float,
                  restore_at: float = np.inf) -> DegradationSchedule:
    """One host runs at ``factor`` x MIPS from ``at`` (forever unless
    ``restore_at``) — the minimal straggler scenario."""
    s = no_degradation(n_hosts, n_links)
    s.host_slow_t[host] = at
    s.host_restore_t[host] = restore_at
    s.host_factor[host] = factor
    return s.validate(n_hosts, n_links)


def link_brownout(n_hosts: int, n_links: int, links, at: float,
                  factor: float,
                  restore_at: float = np.inf) -> DegradationSchedule:
    """The given directed link ids carry ``factor`` x bandwidth from
    ``at`` (pass both directions to throttle a full-duplex cable)."""
    s = no_degradation(n_hosts, n_links)
    for li in np.atleast_1d(links):
        s.link_slow_t[li] = at
        s.link_restore_t[li] = restore_at
        s.link_factor[li] = factor
    return s.validate(n_hosts, n_links)
