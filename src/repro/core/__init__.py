"""BigDataSDNSim core: vectorized DES of MapReduce x SDN x cloud (the paper)."""
from .ctrlplane import CtrlPlaneConfig, no_ctrl
from .energy import EnergyParams
from .engine import (SimState, make_packed_simulator, make_simulator,
                     simulate, simulate_batch, simulate_scenarios)
from .failures import (DegradationSchedule, FailureSchedule, host_crash,
                       host_slowdown, link_brownout, link_cut,
                       no_degradation, no_failures)
from .mapreduce import ClusterSpec, JobSpec, SimSetup, build_setup
from .policies import (INSTALL_PROACTIVE, INSTALL_REACTIVE,
                       JOBSEL_FCFS, JOBSEL_PRIORITY, JOBSEL_SJF,
                       MIG_CONGESTION, MIG_STATIC,
                       PLACE_LEAST_USED, PLACE_RANDOM, PLACE_ROUND_ROBIN,
                       RECOVERY_RESTART, RECOVERY_RESUME,
                       ROUTE_LEGACY, ROUTE_SDN, SPEC_OFF, SPEC_ON,
                       TRAFFIC_FAIRSHARE,
                       TRAFFIC_WATERFILL, PolicyConfig, PolicyField,
                       as_policy_arrays, policy_field_names, policy_fields,
                       register_policy_field)
from .report import energy_report, job_report, summarize
from .simmeta import SimMeta
from .routing import RouteTable, build_route_table
from .topology import (GBPS, Topology, canonical_tree, fat_tree, leaf_spine,
                       paper_fat_tree, torus_2d, torus_3d)
from .usecase import paper_cluster, paper_jobs, paper_setup

__all__ = [
    "EnergyParams", "SimState", "make_packed_simulator", "make_simulator",
    "simulate", "simulate_batch", "simulate_scenarios",
    "ClusterSpec", "JobSpec", "SimSetup", "build_setup", "PolicyConfig",
    "PolicyField", "SimMeta", "as_policy_arrays", "policy_field_names",
    "policy_fields", "register_policy_field",
    "FailureSchedule", "host_crash", "link_cut", "no_failures",
    "DegradationSchedule", "host_slowdown", "link_brownout",
    "no_degradation",
    "CtrlPlaneConfig", "no_ctrl",
    "ROUTE_LEGACY", "ROUTE_SDN", "TRAFFIC_FAIRSHARE", "TRAFFIC_WATERFILL",
    "PLACE_LEAST_USED", "PLACE_ROUND_ROBIN", "PLACE_RANDOM",
    "JOBSEL_FCFS", "JOBSEL_SJF", "JOBSEL_PRIORITY",
    "RECOVERY_RESTART", "RECOVERY_RESUME",
    "INSTALL_REACTIVE", "INSTALL_PROACTIVE", "MIG_STATIC", "MIG_CONGESTION",
    "SPEC_OFF", "SPEC_ON",
    "energy_report", "job_report", "summarize",
    "RouteTable", "build_route_table",
    "GBPS", "Topology", "canonical_tree", "fat_tree", "leaf_spine",
    "paper_fat_tree", "torus_2d", "torus_3d",
    "paper_cluster", "paper_jobs", "paper_setup",
]
