"""Steady-state streaming ring (DESIGN.md §11).

The engine's job/task/packet tensors are fixed-shape; every scenario so far
is a finite job list run until ``_finished``.  Streaming turns those
tensors into a RING: ``RingSpec.slots`` job slots of uniform geometry, each
owning a contiguous task block and packet block.  Completed slots are
retired at chunk boundaries and refilled from an open arrival process
(``repro.scenarios.arrivals``), so an unbounded trace runs in bounded
memory — the tensor shapes (and thus ``SimMeta`` and every compiled
program) never change while jobs flow through.

Layering (the inner ``_step`` kernel is untouched):

* ``RingSpec``          — slot geometry: the max job shape a slot can hold.
* ``slot_arrays``       — lower ONE job (or an empty pad) into its slot's
                          block of the streamed tensors, mirroring
                          ``mapreduce.build_setup``'s per-job loop exactly.
* ``ring_setup``        — a full ``SimSetup`` with every slot lowered; a
                          finite trace that fits ``slots`` makes this a
                          plain setup ``Experiment.run`` accepts, which is
                          what the bit-identity guarantee rests on.
* ``STREAM_FIELDS`` / ``stream_consts_axes`` — the ``EngineConsts`` leaves
                          a refill rewrites.  Lanes (policies) retire slots
                          at different times, so these leaves gain a
                          leading lane axis and ``make_fleet_chunk`` vmaps
                          them per-lane (``consts_axes``) while topology /
                          cluster leaves stay shared.
* ``host_stream_arrays`` / ``load_slot`` — the host-side mutable copies of
                          the streamed leaves; a refill rewrites one slot's
                          blocks in numpy and re-uploads.
* ``make_refill``       — the jitted masked state reset: refilled slots go
                          back to their t=0 state (WAITING/VOID, full
                          remaining work, no VM, NaN stamps) without
                          touching any other slot, then ``done`` is
                          recomputed against the NEW consts.

The driver on top lives in ``repro.api.stream`` (``Experiment.run_stream``).
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from .ctrlplane import CtrlPlaneConfig
from .engine import EngineConsts, _finished
from .failures import DegradationSchedule, FailureSchedule
from .mapreduce import (GBIT, KIND_MAP, KIND_REDUCE, PHASE_IN, PHASE_OUT,
                        PHASE_SHUFFLE, VOID, WAITING, ClusterSpec, JobSpec,
                        SimSetup)
from .routing import RouteTable, build_route_table
from .simmeta import SimMeta

# The EngineConsts leaves a slot refill rewrites — everything derived from
# the job list.  Topology / cluster / failure / ctrl leaves are NOT here:
# they stay lane-shared (vmap axis None) and are never touched by a refill.
STREAM_FIELDS = (
    "job_release", "job_total_mi", "job_priority", "job_n_out", "job_valid",
    "job_n_tasks",
    "task_job", "task_kind", "task_mi", "task_need", "task_valid",
    "task_rank_in_job",
    "pkt_job", "pkt_phase", "pkt_bits", "pkt_gate_task", "pkt_feeds_task",
    "pkt_src_task", "pkt_dst_task", "pkt_valid",
)


def stream_consts_axes() -> EngineConsts:
    """The ``in_axes`` pytree for ``make_fleet_chunk(consts_axes=…)``:
    axis 0 on every streamed leaf, None (lane-shared) elsewhere."""
    return EngineConsts(**{f: (0 if f in STREAM_FIELDS else None)
                           for f in EngineConsts._fields})


@dataclasses.dataclass(frozen=True)
class RingSpec:
    """Uniform slot geometry: the largest job shape a slot can hold.

    Slot ``s`` owns tasks ``[s*tasks_per_slot, (s+1)*tasks_per_slot)`` and
    packets ``[s*pkts_per_slot, (s+1)*pkts_per_slot)``; a smaller job pads
    the tail of its blocks with VOID entries, exactly like a packed sweep
    pads scenarios (DESIGN.md §5) — pad slots are inert by construction."""

    slots: int
    n_map_max: int
    n_reduce_max: int
    split: int = 1

    @property
    def tasks_per_slot(self) -> int:
        return self.n_map_max + self.n_reduce_max

    @property
    def pkts_per_slot(self) -> int:
        nm, nr = self.n_map_max, self.n_reduce_max
        return self.split * (nm + nm * nr + nr)

    def check(self, job: JobSpec) -> None:
        if job.n_map > self.n_map_max or job.n_reduce > self.n_reduce_max:
            raise ValueError(
                f"job ({job.n_map} mappers, {job.n_reduce} reducers) "
                f"exceeds the ring slot geometry "
                f"({self.n_map_max}, {self.n_reduce_max})")

    @classmethod
    def for_jobs(cls, jobs: Sequence[JobSpec], slots: int,
                 split: int = 1) -> "RingSpec":
        """Tight geometry over a job sample (e.g. the materialized trace)."""
        if not jobs:
            raise ValueError("RingSpec.for_jobs needs at least one job")
        return cls(slots=slots,
                   n_map_max=max(j.n_map for j in jobs),
                   n_reduce_max=max(j.n_reduce for j in jobs),
                   split=split)


def slot_arrays(spec: RingSpec, slot: int,
                job: Optional[JobSpec] = None) -> Dict[str, np.ndarray]:
    """Lower one job into slot ``slot``'s blocks of the streamed tensors.

    Mirrors ``build_setup``'s per-job loop exactly — mappers then reducers,
    then the T1 / T2 / T3 packet groups in the same nesting order — with
    the slot's pad tail after the valid prefix.  ``job=None`` lowers an
    empty (all-pad, ``job_valid=False``) slot.  Task references inside the
    packet arrays are GLOBAL indices (offset by the slot's task base);
    ``task_job``/``pkt_job`` carry the slot index as the job id."""
    T, P, split = spec.tasks_per_slot, spec.pkts_per_slot, spec.split
    out = {
        "job_release": np.float32(0.0),
        "job_total_mi": np.float32(0.0),
        "job_priority": np.float32(0.0),
        "job_n_out": np.int32(0),
        "job_valid": np.bool_(False),
        "job_n_tasks": np.int32(0),
        "task_job": np.full(T, -1, np.int32),
        "task_kind": np.zeros(T, np.int8),
        "task_mi": np.zeros(T, np.float32),
        "task_need": np.zeros(T, np.int32),
        "task_valid": np.zeros(T, bool),
        # rank within the slot's (single) job = local index; the pad tail's
        # value is never read (pad tasks stay VOID and are never placed)
        "task_rank_in_job": np.arange(T, dtype=np.int32),
        "pkt_job": np.full(P, -1, np.int32),
        "pkt_phase": np.zeros(P, np.int8),
        "pkt_bits": np.zeros(P, np.float32),
        "pkt_gate_task": np.full(P, -1, np.int32),
        "pkt_feeds_task": np.full(P, -1, np.int32),
        "pkt_src_task": np.full(P, -1, np.int32),
        "pkt_dst_task": np.full(P, -1, np.int32),
        "pkt_valid": np.zeros(P, bool),
    }
    if job is None:
        return out
    spec.check(job)
    nm, nr = job.n_map, job.n_reduce
    base_t = slot * T
    mappers = list(range(base_t, base_t + nm))
    reducers = list(range(base_t + nm, base_t + nm + nr))
    out["job_release"] = np.float32(job.submit_time)
    out["job_total_mi"] = np.float32(job.total_mi)
    out["job_priority"] = np.float32(job.priority)
    out["job_n_out"] = np.int32(nr * split)
    out["job_valid"] = np.bool_(True)
    out["job_n_tasks"] = np.int32(nm + nr)
    nt = nm + nr
    out["task_job"][:nt] = slot
    out["task_kind"][:nm] = KIND_MAP
    out["task_kind"][nm:nt] = KIND_REDUCE
    out["task_mi"][:nm] = job.map_mi
    out["task_mi"][nm:nt] = job.reduce_mi
    out["task_need"][:nm] = split
    out["task_need"][nm:nt] = nm * split
    out["task_valid"][:nt] = True

    p_bits: List[float] = []
    p_phase: List[int] = []
    p_gate: List[int] = []
    p_feeds: List[int] = []
    p_src: List[int] = []
    p_dst: List[int] = []
    ms_bits = job.input_gbits * GBIT / (nm * split)
    for m in mappers:
        for _ in range(split):
            p_phase.append(PHASE_IN); p_bits.append(ms_bits)
            p_gate.append(-1); p_feeds.append(m)
            p_src.append(-1); p_dst.append(m)
    sh_bits = job.shuffle_gbits * GBIT / (nm * nr * split)
    for m in mappers:
        for r in reducers:
            for _ in range(split):
                p_phase.append(PHASE_SHUFFLE); p_bits.append(sh_bits)
                p_gate.append(m); p_feeds.append(r)
                p_src.append(m); p_dst.append(r)
    out_bits = job.output_gbits * GBIT / (nr * split)
    for r in reducers:
        for _ in range(split):
            p_phase.append(PHASE_OUT); p_bits.append(out_bits)
            p_gate.append(r); p_feeds.append(-1)
            p_src.append(r); p_dst.append(-1)
    npk = len(p_bits)
    out["pkt_job"][:npk] = slot
    out["pkt_phase"][:npk] = p_phase
    out["pkt_bits"][:npk] = p_bits
    out["pkt_gate_task"][:npk] = p_gate
    out["pkt_feeds_task"][:npk] = p_feeds
    out["pkt_src_task"][:npk] = p_src
    out["pkt_dst_task"][:npk] = p_dst
    out["pkt_valid"][:npk] = True
    return out


def ring_setup(jobs: Sequence[JobSpec], cluster: ClusterSpec, spec: RingSpec,
               route_table: Optional[RouteTable] = None, k_max: int = 16,
               failures: Optional[FailureSchedule] = None,
               ctrl: Optional[CtrlPlaneConfig] = None,
               degradation: Optional[DegradationSchedule] = None,
               spec_slots: int = 0) -> SimSetup:
    """A full ring ``SimSetup``: the first ``len(jobs)`` slots loaded, the
    rest empty.  This is an ordinary setup — ``make_consts`` /
    ``Experiment.run`` accept it unchanged, which is exactly the finite-
    trace bit-identity anchor (DESIGN.md §11)."""
    if len(jobs) > spec.slots:
        raise ValueError(f"{len(jobs)} jobs exceed {spec.slots} ring slots")
    rt = route_table or build_route_table(cluster.topo, k_max=k_max)
    blocks = [slot_arrays(spec, s, jobs[s] if s < len(jobs) else None)
              for s in range(spec.slots)]

    def cat(key):
        vals = [b[key] for b in blocks]
        return (np.stack(vals) if vals[0].ndim == 0
                else np.concatenate(vals))

    return SimSetup(
        cluster=cluster,
        route_table=rt,
        failures=failures,
        ctrl=ctrl,
        degradation=degradation,
        spec_slots=int(spec_slots),
        jobs=tuple(jobs),
        job_release=cat("job_release"),
        job_total_mi=cat("job_total_mi"),
        job_priority=cat("job_priority"),
        job_n_out=cat("job_n_out"),
        task_job=cat("task_job"),
        task_kind=cat("task_kind"),
        task_mi=cat("task_mi"),
        task_need=cat("task_need"),
        task_valid=cat("task_valid"),
        pkt_job=cat("pkt_job"),
        pkt_phase=cat("pkt_phase"),
        pkt_bits=cat("pkt_bits"),
        pkt_gate_task=cat("pkt_gate_task"),
        pkt_feeds_task=cat("pkt_feeds_task"),
        pkt_src_task=cat("pkt_src_task"),
        pkt_dst_task=cat("pkt_dst_task"),
        pkt_valid=cat("pkt_valid"),
    )


def host_stream_arrays(consts: EngineConsts, width: int) -> Dict[str, np.ndarray]:
    """Mutable host copies of the streamed leaves with a leading ``[width]``
    lane axis, seeded from one (unbatched) consts — so the zero-refill
    stream re-uploads EXACTLY what ``make_consts`` produced."""
    return {f: np.repeat(np.asarray(getattr(consts, f))[None], width, axis=0)
            for f in STREAM_FIELDS}


def load_slot(host: Dict[str, np.ndarray], spec: RingSpec, lane: int,
              slot: int, job: Optional[JobSpec]) -> None:
    """Rewrite one (lane, slot)'s blocks of the host streamed arrays."""
    blk = slot_arrays(spec, slot, job)
    T, P = spec.tasks_per_slot, spec.pkts_per_slot
    for f in STREAM_FIELDS:
        v = blk[f]
        if v.ndim == 0:
            host[f][lane, slot] = v
        elif f.startswith("task_"):
            host[f][lane, slot * T:(slot + 1) * T] = v
        else:
            host[f][lane, slot * P:(slot + 1) * P] = v


def make_refill(meta):
    """The jitted streaming refill
    ``refill(consts, carry, job_m, task_m, pkt_m, lane_m) -> carry``.

    ``consts`` holds the ALREADY-REWRITTEN streamed leaves ([W, …]); the
    masks select the refilled slots' entries per lane.  Refilled entries go
    back to their t=0 state (``init_state_from_consts`` semantics) while
    every other entry — including the carried channel counts and the
    flow-table, whose stale rules for retired flows simply age out via LRU
    — passes through untouched.  ``steps`` resets on refilled lanes (the
    step budget bounds events BETWEEN refills, which a full ring's
    ``default_max_steps`` covers), the clock and ``place_counter`` run on
    continuously, and ``done`` is recomputed against the new consts.  The
    endpoint cache needs no refresh here: a refilled job's packets cannot
    activate before the job is admitted AND placed, and placement refreshes
    the cache inside ``_step`` that same event."""
    meta = SimMeta.coerce(meta)
    axes = stream_consts_axes()
    f = jnp.float32

    def lane_refill(c, s, job_m, task_m, pkt_m, lane_m):
        extra = {}
        if meta.spec_slots > 0:
            # cancel any clone still bound to a recycled job slot (a lane
            # can finish with live clones and never step again before the
            # refill, so the engine's own cleanup never sees them) and
            # re-arm the one-clone-per-task latch for the refilled tasks
            S = s.spec_of.shape[0]
            slot_job = jnp.arange(S, dtype=jnp.int32) // meta.spec_slots
            clone_m = job_m[slot_job]
            live = clone_m & (s.spec_of >= 0)
            vm_iota = jnp.arange(s.vm_load.shape[0], dtype=jnp.int32)
            extra = dict(
                spec_of=jnp.where(clone_m, -1, s.spec_of),
                spec_vm=jnp.where(clone_m, -1, s.spec_vm),
                spec_rem=jnp.where(clone_m, 0.0, s.spec_rem).astype(f),
                spec_start=jnp.where(clone_m, 0.0, s.spec_start).astype(f),
                task_cloned=jnp.where(task_m, False, s.task_cloned),
                vm_load=s.vm_load - jnp.sum(
                    (jnp.maximum(s.spec_vm, 0)[:, None]
                     == vm_iota[None, :]) & live[:, None],
                    axis=0).astype(jnp.int32),
            )
        return s._replace(
            **extra,
            steps=jnp.where(lane_m, jnp.int32(0), s.steps),
            job_admitted=jnp.where(job_m, False, s.job_admitted),
            job_admit_t=jnp.where(job_m, jnp.nan, s.job_admit_t).astype(f),
            job_out_done=jnp.where(job_m, 0, s.job_out_done),
            job_done_t=jnp.where(job_m, jnp.nan, s.job_done_t).astype(f),
            job_downtime=jnp.where(job_m, 0.0, s.job_downtime).astype(f),
            task_state=jnp.where(
                task_m, jnp.where(c.task_valid, WAITING, VOID),
                s.task_state).astype(jnp.int32),
            task_rem=jnp.where(task_m, c.task_mi, s.task_rem).astype(f),
            task_got=jnp.where(task_m, 0, s.task_got),
            task_vm=jnp.where(task_m, -1, s.task_vm),
            task_start=jnp.where(task_m, jnp.nan, s.task_start).astype(f),
            task_finish=jnp.where(task_m, jnp.nan, s.task_finish).astype(f),
            task_restarts=jnp.where(task_m, 0, s.task_restarts),
            pkt_state=jnp.where(
                pkt_m, jnp.where(c.pkt_valid, WAITING, VOID),
                s.pkt_state).astype(jnp.int32),
            pkt_rem=jnp.where(pkt_m, c.pkt_bits, s.pkt_rem).astype(f),
            pkt_pair=jnp.where(pkt_m, -1, s.pkt_pair),
            pkt_cand=jnp.where(pkt_m, -1, s.pkt_cand),
            pkt_start=jnp.where(pkt_m, jnp.nan, s.pkt_start).astype(f),
            pkt_finish=jnp.where(pkt_m, jnp.nan, s.pkt_finish).astype(f),
            pkt_reroutes=jnp.where(pkt_m, 0, s.pkt_reroutes),
            pkt_ready_t=jnp.where(pkt_m, jnp.inf, s.pkt_ready_t).astype(f),
            pkt_install_wait=jnp.where(
                pkt_m, 0.0, s.pkt_install_wait).astype(f),
        )

    vrefill = jax.vmap(lane_refill, in_axes=(axes, 0, 0, 0, 0, 0))
    vdone = jax.vmap(lambda c, s: _finished(c, meta, s), in_axes=(axes, 0))

    def refill(consts, carry, job_m, task_m, pkt_m, lane_m):
        s, cache, _done = carry
        s = vrefill(consts, s, job_m, task_m, pkt_m, lane_m)
        return s, cache, vdone(consts, s)

    return jax.jit(refill)
