"""MapReduce application model (paper §3.1.3, Fig. 7, Eqs. 1-2).

A job = nm mappers + nr reducers with the strict 5-phase pipeline:
  T1 SAN->mapper transfer   (one packet per mapper,   ms = jl/nm       Eq. 1)
  P1 map execution          (gated on its T1 packet)
  T2 mapper->reducer shuffle (one packet per (m,r),   rs = ms*f        Eq. 2)
  P2 reduce execution       (gated on ALL its T2 packets)
  T3 reducer->SAN write-back (one packet per reducer; job done when all land)

Host-side setup converts a job table into padded, fixed-shape packet/task
tensors with integer dependency gates — the whole DAG becomes index
arithmetic the event engine evaluates vectorially.
"""
from __future__ import annotations

import dataclasses
from typing import List, Sequence

import numpy as np

from .ctrlplane import CtrlPlaneConfig
from .energy import EnergyParams
from .failures import DegradationSchedule, FailureSchedule
from .routing import RouteTable, build_route_table
from .topology import Topology

GBIT = 1e9

# packet / task states.  INSTALLING (packets only, DESIGN.md §10): routed,
# waiting for its flow rules to finish installing at the controller.
WAITING, ACTIVE, DONE, VOID, INSTALLING = 0, 1, 2, 3, 4
KIND_MAP, KIND_REDUCE = 0, 1
PHASE_IN, PHASE_SHUFFLE, PHASE_OUT = 0, 1, 2


@dataclasses.dataclass(frozen=True)
class JobSpec:
    """One MapReduce job (paper Table 3 row)."""

    submit_time: float
    n_map: int
    n_reduce: int
    map_mi: float          # MI per mapper
    reduce_mi: float       # MI per reducer
    input_gbits: float     # total SAN->mappers        ("Storage" column)
    shuffle_gbits: float   # total mappers->reducers   ("Mappers" column)
    output_gbits: float    # total reducers->SAN       ("Reducers" column)
    priority: float = 0.0

    @property
    def total_mi(self) -> float:
        return self.n_map * self.map_mi + self.n_reduce * self.reduce_mi


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Hosts + VMs + SAN (paper Table 2)."""

    topo: Topology
    vm_host: np.ndarray          # int32 [n_vms]
    vm_total_mips: np.ndarray    # f32  [n_vms]
    vm_core_mips: np.ndarray     # f32  [n_vms]
    host_total_mips: np.ndarray  # f32  [n_hosts] (for utilization/energy)
    storage_node: int
    intra_bw: float = 1e12       # same-host VM-to-VM "memory bus"
    energy: EnergyParams = EnergyParams()


@dataclasses.dataclass(frozen=True)
class SimSetup:
    """Everything the jitted engine needs: static numpy tensors + sizes."""

    cluster: ClusterSpec
    route_table: RouteTable
    jobs: Sequence[JobSpec]
    # job tensors [N_J]
    job_release: np.ndarray
    job_total_mi: np.ndarray
    job_priority: np.ndarray
    job_n_out: np.ndarray
    # task tensors [N_T]
    task_job: np.ndarray
    task_kind: np.ndarray
    task_mi: np.ndarray
    task_need: np.ndarray
    task_valid: np.ndarray
    # packet tensors [N_P]
    pkt_job: np.ndarray
    pkt_phase: np.ndarray
    pkt_bits: np.ndarray
    pkt_gate_task: np.ndarray   # -1 -> gated only on job admission
    pkt_feeds_task: np.ndarray  # -1 -> job output packet
    pkt_src_task: np.ndarray    # -1 -> SAN
    pkt_dst_task: np.ndarray    # -1 -> SAN
    pkt_valid: np.ndarray
    # optional deterministic outage windows (DESIGN.md §7); None = the
    # all-inf no-failure schedule
    failures: FailureSchedule | None = None
    # optional control-plane resource model (DESIGN.md §10); None = the
    # identity instant-controller config
    ctrl: CtrlPlaneConfig | None = None
    # optional gray-failure rate-multiplier windows (DESIGN.md §13);
    # None = the identity factor-1.0 schedule
    degradation: DegradationSchedule | None = None
    # speculative-execution clone slots PER JOB (DESIGN.md §13); 0 =
    # speculation structurally off (the clone tensors are zero-length)
    spec_slots: int = 0

    @property
    def n_jobs(self) -> int:
        return int(self.job_release.shape[0])

    @property
    def n_tasks(self) -> int:
        return int(self.task_job.shape[0])

    @property
    def n_packets(self) -> int:
        return int(self.pkt_job.shape[0])


def build_setup(jobs: Sequence[JobSpec], cluster: ClusterSpec,
                route_table: RouteTable | None = None,
                k_max: int = 16, split: int = 1,
                failures: FailureSchedule | None = None,
                ctrl: CtrlPlaneConfig | None = None,
                degradation: DegradationSchedule | None = None,
                spec_slots: int = 0) -> SimSetup:
    """``split`` = network packets per logical transfer (paper: workloads
    specify "the size of network packets" in the CSV; a data block is sent as
    multiple packet objects, EACH routed by the controller — "two packets
    from the same VM can have two different routes to the same destination
    VM" §5.2).  The SDN policy stripes a transfer across equal-hop routes;
    the legacy policy pins all of a flow's packets to one random route."""
    rt = route_table or build_route_table(cluster.topo, k_max=k_max)

    t_job: List[int] = []
    t_kind: List[int] = []
    t_mi: List[float] = []
    t_need: List[int] = []
    p_job: List[int] = []
    p_phase: List[int] = []
    p_bits: List[float] = []
    p_gate: List[int] = []
    p_feeds: List[int] = []
    p_src: List[int] = []
    p_dst: List[int] = []

    assert split >= 1
    for j, job in enumerate(jobs):
        nm, nr = job.n_map, job.n_reduce
        assert nm >= 1 and nr >= 1, "a MapReduce job needs >=1 mapper & reducer"
        base_t = len(t_job)
        mappers = list(range(base_t, base_t + nm))
        reducers = list(range(base_t + nm, base_t + nm + nr))
        for _ in range(nm):
            t_job.append(j); t_kind.append(KIND_MAP)
            t_mi.append(job.map_mi); t_need.append(split)
        for _ in range(nr):
            t_job.append(j); t_kind.append(KIND_REDUCE)
            t_mi.append(job.reduce_mi); t_need.append(nm * split)
        # T1: SAN -> mapper, Eq. 1: ms = jl / nm, sent as `split` packets
        ms_bits = job.input_gbits * GBIT / (nm * split)
        for m in mappers:
            for _ in range(split):
                p_job.append(j); p_phase.append(PHASE_IN); p_bits.append(ms_bits)
                p_gate.append(-1); p_feeds.append(m)
                p_src.append(-1); p_dst.append(m)
        # T2: mapper -> reducer, Eq. 2 generalized: each mapper emits
        # shuffle_total/nm, split evenly over reducers
        sh_bits = job.shuffle_gbits * GBIT / (nm * nr * split)
        for m in mappers:
            for r in reducers:
                for _ in range(split):
                    p_job.append(j); p_phase.append(PHASE_SHUFFLE)
                    p_bits.append(sh_bits)
                    p_gate.append(m); p_feeds.append(r)
                    p_src.append(m); p_dst.append(r)
        # T3: reducer -> SAN
        out_bits = job.output_gbits * GBIT / (nr * split)
        for r in reducers:
            for _ in range(split):
                p_job.append(j); p_phase.append(PHASE_OUT)
                p_bits.append(out_bits)
                p_gate.append(r); p_feeds.append(-1)
                p_src.append(r); p_dst.append(-1)

    def pad(lst, n, fill):
        return np.asarray(lst + [fill] * (n - len(lst)))

    n_t = len(t_job)
    n_p = len(p_job)
    if failures is not None:
        failures.validate(cluster.topo.n_hosts, cluster.topo.n_links)
    if ctrl is not None:
        ctrl.validate()
    if degradation is not None:
        degradation.validate(cluster.topo.n_hosts, cluster.topo.n_links)
    if spec_slots < 0:
        raise ValueError("spec_slots must be >= 0")
    return SimSetup(
        cluster=cluster,
        route_table=rt,
        failures=failures,
        ctrl=ctrl,
        degradation=degradation,
        spec_slots=int(spec_slots),
        jobs=tuple(jobs),
        job_release=np.asarray([j.submit_time for j in jobs], np.float32),
        job_total_mi=np.asarray([j.total_mi for j in jobs], np.float32),
        job_priority=np.asarray([j.priority for j in jobs], np.float32),
        job_n_out=np.asarray([j.n_reduce * split for j in jobs], np.int32),
        task_job=pad(t_job, n_t, -1).astype(np.int32),
        task_kind=pad(t_kind, n_t, 0).astype(np.int8),
        task_mi=pad(t_mi, n_t, 0.0).astype(np.float32),
        task_need=pad(t_need, n_t, 0).astype(np.int32),
        task_valid=(pad(t_job, n_t, -1) >= 0),
        pkt_job=pad(p_job, n_p, -1).astype(np.int32),
        pkt_phase=pad(p_phase, n_p, 0).astype(np.int8),
        pkt_bits=pad(p_bits, n_p, 0.0).astype(np.float32),
        pkt_gate_task=pad(p_gate, n_p, -1).astype(np.int32),
        pkt_feeds_task=pad(p_feeds, n_p, -1).astype(np.int32),
        pkt_src_task=pad(p_src, n_p, -1).astype(np.int32),
        pkt_dst_task=pad(p_dst, n_p, -1).astype(np.int32),
        pkt_valid=(pad(p_job, n_p, -1) >= 0),
    )
