"""Result extraction (paper §4 'performance results', Eqs. 6-9).

Pure functions over the final SimState so they vmap over policy sweeps.
``repro.api.Results`` wraps these with the ``[S, P, ...]`` grid layout and
pad-job masking built in (DESIGN.md §6) — prefer it in new code.
"""
from __future__ import annotations

from typing import Dict

import jax.numpy as jnp
import numpy as np

from .engine import SimState
from .mapreduce import (DONE, KIND_MAP, KIND_REDUCE, PHASE_IN, PHASE_OUT,
                        PHASE_SHUFFLE, SimSetup)

_NEG = jnp.float32(-jnp.inf)


def _seg_max(values: jnp.ndarray, seg: jnp.ndarray, mask: jnp.ndarray,
             n: int) -> jnp.ndarray:
    v = jnp.where(mask, values, _NEG)
    out = jnp.full((n,), _NEG).at[jnp.maximum(seg, 0)].max(v)
    return jnp.where(jnp.isinf(out), jnp.nan, out)


def job_report(setup: SimSetup, s: SimState) -> Dict[str, jnp.ndarray]:
    """Per-job metrics; every array is [N_J] (vmap for batched states)."""
    return job_report_arrays(
        jnp.asarray(setup.pkt_job), jnp.asarray(setup.pkt_phase),
        jnp.asarray(setup.task_job), jnp.asarray(setup.task_kind),
        jnp.asarray(setup.job_release), s)


def job_report_consts(consts, s: SimState) -> Dict[str, jnp.ndarray]:
    """Same metrics from EngineConsts tensors — vmaps over a packed
    scenario sweep where each replica has its own (padded) job/packet
    tensors (DESIGN.md §5).  Pad jobs come out NaN; mask with
    ``consts.job_valid`` before aggregating."""
    return job_report_arrays(consts.pkt_job, consts.pkt_phase,
                             consts.task_job, consts.task_kind,
                             consts.job_release, s)


def job_report_arrays(pkt_job, pkt_phase, task_job, task_kind, job_release,
                      s: SimState) -> Dict[str, jnp.ndarray]:
    n_j = job_release.shape[0]
    pdur = s.pkt_finish - s.pkt_start
    pdone = s.pkt_state == DONE
    t1 = _seg_max(pdur, pkt_job, pdone & (pkt_phase == PHASE_IN), n_j)
    t2 = _seg_max(pdur, pkt_job, pdone & (pkt_phase == PHASE_SHUFFLE), n_j)
    t3 = _seg_max(pdur, pkt_job, pdone & (pkt_phase == PHASE_OUT), n_j)
    j_tr = t1 + t2 + t3                                   # Eq. 6

    tdur = s.task_finish - s.task_start
    tdone = s.task_state == DONE
    j_mp = _seg_max(tdur, task_job, tdone & (task_kind == KIND_MAP), n_j)   # Eq. 7
    j_rd = _seg_max(tdur, task_job, tdone & (task_kind == KIND_REDUCE), n_j)  # Eq. 8

    # failure & recovery metrics (DESIGN.md §7): 0 everywhere without a
    # failure schedule
    reexec = jnp.zeros((n_j,), jnp.int32).at[jnp.maximum(task_job, 0)].add(
        jnp.where(task_job >= 0, s.task_restarts, 0))
    reroute = jnp.zeros((n_j,), jnp.int32).at[jnp.maximum(pkt_job, 0)].add(
        jnp.where(pkt_job >= 0, s.pkt_reroutes, 0))

    # control-plane metrics (DESIGN.md §10): time packets spent parked in
    # INSTALLING waiting for flow-rule installs; 0 without a ctrl config
    install_wait = jnp.zeros((n_j,)).at[jnp.maximum(pkt_job, 0)].add(
        jnp.where(pkt_job >= 0, s.pkt_install_wait, 0.0))

    return {
        "transmission_time": j_tr,
        "t_storage_to_map": t1,
        "t_shuffle": t2,
        "t_reduce_to_storage": t3,
        "map_exec_time": j_mp,
        "reduce_exec_time": j_rd,
        "completion_eq9": j_tr + j_mp + j_rd,             # Eq. 9
        "completion_measured": s.job_done_t - job_release,
        "queue_delay": s.job_admit_t - job_release,
        "done_time": s.job_done_t,
        "task_reexecs": reexec,
        "pkt_reroutes": reroute,
        "downtime_s": s.job_downtime,
        "install_wait_s": install_wait,
    }


def energy_report(s: SimState) -> Dict[str, jnp.ndarray]:
    return {
        "host_energy_j": jnp.sum(s.host_energy, axis=-1),
        "switch_energy_j": jnp.sum(s.switch_energy, axis=-1),
        "total_energy_j": jnp.sum(s.host_energy, axis=-1)
        + jnp.sum(s.switch_energy, axis=-1),
        "makespan_s": s.time,
    }


def summarize(setup: SimSetup, s: SimState) -> Dict[str, np.ndarray]:
    """Host-side convenience: full report as numpy."""
    rep = {**job_report(setup, s), **energy_report(s)}
    rep["stalled"] = s.stalled
    rep["steps"] = s.steps
    return {k: np.asarray(v) for k, v in rep.items()}
