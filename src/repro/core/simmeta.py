"""``SimMeta`` — the typed, frozen, hashable static description of one
compiled simulation program (DESIGN.md §6).

Everything the engine needs that must be a *Python* value at trace time
(tensor shapes, scalar physics constants) lives here; everything else is
data inside ``EngineConsts``/``SimState``.  Because ``SimMeta`` is frozen
and hashable it can key the compiled-runner cache (``repro.api.runners``)
and serve as a ``jax.jit`` static argument: two setups with equal
``SimMeta`` share one traced program.

Replaces the loose ``meta: Dict[str, Any]`` the engine, report and sweep
layers used to thread around; ``__getitem__`` keeps the old ``meta["..."]``
spelling working during the migration.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

from .energy import EnergyParams


@dataclasses.dataclass(frozen=True)
class SimMeta:
    """Static shape + scalar parameters shared by every replica of a run.

    In a packed multi-scenario sweep the shape fields are the padded batch
    maxima (DESIGN.md §5); per-replica differences are data, never shape.
    """

    n_nodes: int
    n_links: int
    n_hosts: int
    n_switches: int
    n_vms: int
    intra_bw: float
    energy: EnergyParams
    max_steps: int
    # True iff some replica's failure schedule has a finite instant
    # (DESIGN.md §7).  A trace-time Python bool: with False the engine
    # traces EXACTLY the pre-failure program, so a no-failure run is
    # bit-identical to the engine without this subsystem.
    has_failures: bool = False
    # True iff some replica's control-plane config is non-identity
    # (DESIGN.md §10) — the same trace-time contract as ``has_failures``:
    # False traces EXACTLY the pre-control-plane program.
    has_ctrl: bool = False
    # static per-switch flow-table width (padded max in a packed sweep);
    # 0 when the control plane is off or uncached — the flow-table state
    # tensors then have a zero-length slot axis and are inert.
    ctrl_slots: int = 0
    # True iff some replica's degradation schedule has a live window
    # (DESIGN.md §13) — same trace-time contract as ``has_failures``:
    # False traces EXACTLY the pre-degradation program.
    has_degradation: bool = False
    # static speculative-execution clone slots PER JOB (DESIGN.md §13);
    # 0 (speculation structurally off) gives the clone state tensors a
    # zero-length axis and traces the exact pre-speculation program.
    spec_slots: int = 0

    @classmethod
    def coerce(cls, meta: "SimMeta" | Mapping[str, Any]) -> "SimMeta":
        """Accept an already-typed SimMeta or a legacy meta dict (fields
        with defaults may be absent from the dict)."""
        if isinstance(meta, cls):
            return meta
        kw = {}
        for f in dataclasses.fields(cls):
            if f.name in meta:
                kw[f.name] = meta[f.name]
            elif f.default is dataclasses.MISSING:
                raise KeyError(f.name)
        return cls(**kw)

    # legacy dict-style access (old code spelled ``meta["n_vms"]``)
    def __getitem__(self, key: str) -> Any:
        try:
            return getattr(self, key)
        except AttributeError:
            raise KeyError(key) from None

    def replace(self, **kw) -> "SimMeta":
        return dataclasses.replace(self, **kw)
