"""SDN routing, TPU-adapted.

The paper's SDN controller runs Dijkstra per packet: shortest hop count first,
then (SDN mode) maximum bottleneck bandwidth among the equal-hop routes; legacy
mode picks one equal-hop route statically at random per src/dst flow.

Dijkstra is sequential pointer-chasing — the worst fit for a systolic array.
TPU adaptation (see DESIGN.md §2):

  1. *Offline* (setup, host-side numpy): hop distances via tropical (min-plus)
     matrix squaring — the same operation the Pallas kernel
     ``repro.kernels.tropical_apsp`` implements for on-device use — then
     enumeration of up to K equal-hop candidate routes per node pair from the
     shortest-path DAG.  Works for ANY topology (paper contribution 6).
  2. *Online* (inside the jitted event loop): route choice is a vectorized
     gather + masked-min + argmax over the K candidates — the controller's
     "global network view" is the live per-link channel-count tensor.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from .topology import Topology

# ---------------------------------------------------------------------------
# offline: hop distances + candidate enumeration
# ---------------------------------------------------------------------------


def min_plus_square_np(d: np.ndarray) -> np.ndarray:
    """One tropical-semiring squaring step: d'[i,j] = min_k d[i,k] + d[k,j]."""
    return np.min(d[:, :, None] + d[None, :, :], axis=1)


def hop_distances_np(hop: np.ndarray) -> np.ndarray:
    """All-pairs hop distances by repeated min-plus squaring (O(log diam))."""
    d = hop.astype(np.float64)
    n = d.shape[0]
    steps = max(1, int(np.ceil(np.log2(max(2, n)))))
    for _ in range(steps):
        nd = min_plus_square_np(d)
        if np.array_equal(nd, d):
            break
        d = nd
    return d


@dataclasses.dataclass(frozen=True)
class RouteTable:
    """Padded candidate-route tensors for all node pairs.

    routes[p, k, h]  : link index of hop h of candidate k for pair p (-1 pad)
    n_cand[p]        : number of valid candidates for pair p (0 if unreachable
                       or src == dst)
    route_len[p, k]  : hops of candidate k
    max_hops, k_max  : static pad sizes
    truncated        : True if some pair had more equal-hop routes than k_max
    """

    routes: np.ndarray  # int32 [n_pairs, k_max, max_hops]
    n_cand: np.ndarray  # int32 [n_pairs]
    route_len: np.ndarray  # int32 [n_pairs, k_max]
    max_hops: int
    k_max: int
    n_nodes: int
    truncated: bool

    def pair(self, src: int, dst: int) -> int:
        return src * self.n_nodes + dst


def build_route_table(topo: Topology, k_max: int = 8,
                      max_hops: int | None = None) -> RouteTable:
    """Enumerate ALL equal-hop shortest routes (up to k_max) per node pair.

    An edge (u, v) lies on a shortest src->dst path iff
        dist(src, u) + 1 + dist(v, dst) == dist(src, dst)
    so the shortest-path DAG is read straight off the distance matrix and
    enumerated by DFS.  Host-side, runs once at setup.
    """
    n = topo.n_nodes
    dist = hop_distances_np(topo.hop_matrix())
    # adjacency list of directed links
    out_links: list[list[tuple[int, int]]] = [[] for _ in range(n)]
    for idx, (s, d) in enumerate(zip(topo.link_src, topo.link_dst)):
        out_links[int(s)].append((int(d), idx))

    finite = dist[np.isfinite(dist)]
    diam = int(finite.max()) if finite.size else 0
    mh = max_hops if max_hops is not None else max(1, diam)

    routes = np.full((n * n, k_max, mh), -1, dtype=np.int32)
    n_cand = np.zeros((n * n,), dtype=np.int32)
    route_len = np.zeros((n * n, k_max), dtype=np.int32)
    truncated = False

    for src in range(n):
        for dst in range(n):
            if src == dst or not np.isfinite(dist[src, dst]):
                continue
            target = dist[src, dst]
            found: list[list[int]] = []
            stack: list[tuple[int, list[int]]] = [(src, [])]
            while stack and len(found) < k_max + 1:
                node, path = stack.pop()
                if node == dst:
                    found.append(path)
                    continue
                for (nxt, lidx) in out_links[node]:
                    if dist[src, node] + 1 + dist[nxt, dst] == target:
                        stack.append((nxt, path + [lidx]))
            if len(found) > k_max:
                truncated = True
                found = found[:k_max]
            p = src * n + dst
            n_cand[p] = len(found)
            for k, f in enumerate(found):
                route_len[p, k] = len(f)
                routes[p, k, : len(f)] = f
    return RouteTable(routes=routes, n_cand=n_cand, route_len=route_len,
                      max_hops=mh, k_max=k_max, n_nodes=n, truncated=truncated)


# ---------------------------------------------------------------------------
# online: vectorized per-packet route choice (inside the event loop)
# ---------------------------------------------------------------------------

ROUTE_LEGACY = 0  # static equal-hop pick per (src,dst) flow  (paper §5.2)
ROUTE_SDN = 1     # per-packet max-bottleneck-bandwidth pick  (paper §5.2)


def candidate_bottleneck_bw(routes_k: jnp.ndarray, n_cand: jnp.ndarray,
                            link_bw: jnp.ndarray,
                            ch_count: jnp.ndarray) -> jnp.ndarray:
    """Available bottleneck bandwidth of each candidate if one more channel joins.

    routes_k : int32 [k_max, max_hops] link ids (-1 pad) for ONE pair
    returns  : f32 [k_max]  (-inf for invalid candidates)

    ``link_bw`` is the EFFECTIVE capacity: the engine zeroes dead links
    (DESIGN.md §7), so a candidate crossing an outage scores 0 and loses
    the argmax to any live route — the controller's global view includes
    link liveness for free.
    """
    links = routes_k  # [K, H]
    valid_hop = links >= 0
    safe = jnp.maximum(links, 0)
    # bandwidth this packet would see on each hop if it joined now
    hop_bw = link_bw[safe] / (ch_count[safe].astype(link_bw.dtype) + 1.0)
    hop_bw = jnp.where(valid_hop, hop_bw, jnp.inf)
    bot = jnp.min(hop_bw, axis=-1)  # [K]
    k_ids = jnp.arange(links.shape[0])
    return jnp.where(k_ids < n_cand, bot, -jnp.inf)


def sdn_route_choice(routes_k: jnp.ndarray, n_cand: jnp.ndarray,
                     link_bw: jnp.ndarray,
                     ch_count: jnp.ndarray) -> jnp.ndarray:
    """SDN pick for ONE pair: argmax of current bottleneck availability
    (Dijkstra objective #2).  Depends on the live channel counts, so the
    engine evaluates it inside the compacted ready-set scan — each
    activation sees the channels the controller just admitted."""
    bw = candidate_bottleneck_bw(routes_k, n_cand, link_bw, ch_count)
    return jnp.argmax(bw).astype(jnp.int32)


def legacy_route_choice(n_cand: jnp.ndarray,
                        flow_hash: jnp.ndarray) -> jnp.ndarray:
    """Legacy pick: deterministic hash of the flow id over the equal-hop
    set — fixed for the whole flow regardless of load.  Needs no channel
    feedback, so it vectorizes over any batch of pairs (DESIGN.md §8)."""
    return jnp.where(n_cand > 0, flow_hash % jnp.maximum(n_cand, 1),
                     0).astype(jnp.int32)


def choose_route(policy: jnp.ndarray, routes_k: jnp.ndarray,
                 n_cand: jnp.ndarray, link_bw: jnp.ndarray,
                 ch_count: jnp.ndarray, flow_hash: jnp.ndarray) -> jnp.ndarray:
    """Pick a candidate index for ONE pair per the active routing policy
    (see ``sdn_route_choice`` / ``legacy_route_choice``)."""
    return jnp.where(policy == ROUTE_SDN,
                     sdn_route_choice(routes_k, n_cand, link_bw, ch_count),
                     legacy_route_choice(n_cand, flow_hash)).astype(jnp.int32)


def flow_hash_u32(a: jnp.ndarray, b: jnp.ndarray, seed: jnp.ndarray) -> jnp.ndarray:
    """Counter-based integer hash (vmap-safe legacy 'random' route pick)."""
    x = (a.astype(jnp.uint32) * jnp.uint32(0x9E3779B1)
         ^ b.astype(jnp.uint32) * jnp.uint32(0x85EBCA77)
         ^ seed.astype(jnp.uint32) * jnp.uint32(0xC2B2AE3D))
    x = (x ^ (x >> 15)) * jnp.uint32(0x2C1B3C6D)
    x = (x ^ (x >> 12)) * jnp.uint32(0x297A2D39)
    x = x ^ (x >> 15)
    return x.astype(jnp.int32) & jnp.int32(0x7FFFFFFF)


# jnp APSP (used by tests & the roofline advisor for on-device distances; the
# Pallas kernel in repro.kernels.tropical_apsp is the TPU fast path)
def hop_distances_jnp(hop: jnp.ndarray, steps: int | None = None) -> jnp.ndarray:
    n = hop.shape[0]
    steps = steps if steps is not None else max(1, int(np.ceil(np.log2(max(2, n)))))

    def body(_, d):
        return jnp.min(d[:, :, None] + d[None, :, :], axis=1)

    return jax.lax.fori_loop(0, steps, body, hop)
