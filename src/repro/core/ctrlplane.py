"""SDN control-plane model: flow-rule install latency, controller service
capacity, flow-table caching and migrate-on-congestion (DESIGN.md §10).

The paper's controller is an instant oracle — routing decisions are free,
flow rules appear with zero latency, capacity is infinite — which
systematically flatters SDN in the legacy-vs-SDN comparisons (Figs.
11-13).  Real controller evaluations (the OMNeT++/INET SDN study,
arXiv:1609.04554) show rule-install latency and flow-table churn dominate
SDN behavior under load.  ``CtrlPlaneConfig`` makes both first-class
simulated resources, using the exact structural pattern of
``FailureSchedule`` (DESIGN.md §7): plain host-side scalars that lower to
breakpoint instants joining the engine's analytic ``dt`` min — no event
heap, and the identity config traces the EXACT pre-control-plane program
(``SimMeta.has_ctrl`` mirrors ``has_failures``).

Only ``routing=sdn`` packets talk to the controller; the legacy
static-hash path needs no flow-mod round trip.  That asymmetry is the
point: under high install latency or tiny flow tables, legacy routing can
BEAT SDN on makespan (``benchmarks/ctrl_sweep.py``).
"""
from __future__ import annotations

import dataclasses

import numpy as np

INF = float(np.inf)


@dataclasses.dataclass(frozen=True)
class CtrlPlaneConfig:
    """Control-plane resource parameters (DESIGN.md §10).

    The all-default instance is the IDENTITY config — zero install
    latency, infinite controller rate, no flow-table bound, migration
    disabled — and is treated exactly like an absent config
    (``any_ctrl`` False, ``SimMeta.has_ctrl`` False).
    """

    # flow-rule installation (reactive path): every missing rule on an
    # activating SDN packet's route costs one controller service slot plus
    # this propagation latency before the packet may transmit
    install_latency: float = 0.0   # s per flow-mod batch
    ctrl_rate: float = INF         # rule installs per second (inf = free)
    # per-switch flow-table capacity (LRU-evicted); 0 = no caching when a
    # finite latency/rate is set — every activation re-installs its rules
    table_slots: int = 0
    # migrate-on-congestion dynamic placement (S-CORE direction): a VM
    # whose aggregate route-hop cost over active packets exceeds the
    # threshold re-homes to the cost-minimizing live host
    mig_threshold: float = INF     # inf = migration can never trigger
    mig_cost: float = 0.0          # s of compute pause per migration
    mig_cooldown: float = 0.0      # s after a migration before the next
    mig_limit: int = 8             # total migrations per run (step bound)
    # controller failover (DESIGN.md §13): the PRIMARY controller is down
    # on [ctrl_fail_t, ctrl_recover_t).  SDN rule requests arriving inside
    # the first ``failover_delay`` seconds of the outage PARK until the
    # backup finishes taking over (leader election + state sync); after
    # that the backup serves with its own rate/latency until the primary
    # recovers.  ``inf`` fail_t = failover can never happen (the default
    # config is unchanged).  Legacy routing never consults the controller,
    # so it rides through the outage untouched — the Kreutz et al.
    # availability asymmetry in one knob.
    ctrl_fail_t: float = INF       # s: primary outage start (inf = never)
    ctrl_recover_t: float = INF    # s: primary back (inf = down for good)
    failover_delay: float = 0.0    # s: leader-election gap, requests park
    backup_rate: float = INF       # backup rule installs per second
    backup_latency: float = 0.0    # backup flow-mod latency (s)

    @property
    def any_ctrl(self) -> bool:
        """True iff this config changes anything: some control-plane
        resource is finite.  False (the identity) keeps
        ``SimMeta.has_ctrl`` off, so the engine traces the exact
        pre-control-plane program — same contract as
        ``FailureSchedule.any_failures``."""
        return bool(self.install_latency > 0.0
                    or np.isfinite(self.ctrl_rate)
                    or self.table_slots > 0
                    or np.isfinite(self.mig_threshold)
                    or np.isfinite(self.ctrl_fail_t))

    def validate(self) -> "CtrlPlaneConfig":
        checks = (
            (self.install_latency >= 0.0, "install_latency must be >= 0"),
            (self.ctrl_rate > 0.0, "ctrl_rate must be > 0 (inf = free)"),
            (self.table_slots >= 0, "table_slots must be >= 0"),
            (self.mig_threshold > 0.0, "mig_threshold must be > 0"),
            (self.mig_cost >= 0.0, "mig_cost must be >= 0"),
            (self.mig_cooldown >= 0.0, "mig_cooldown must be >= 0"),
            (self.mig_limit >= 0, "mig_limit must be >= 0"),
            (self.ctrl_fail_t >= 0.0, "ctrl_fail_t must be >= 0"),
            (not np.isfinite(self.ctrl_fail_t)
             or self.ctrl_recover_t > self.ctrl_fail_t,
             "ctrl_recover_t must be > ctrl_fail_t (zero/negative-length "
             "controller outage window)"),
            (np.isfinite(self.ctrl_fail_t)
             or not np.isfinite(self.ctrl_recover_t),
             "finite ctrl_recover_t without a finite ctrl_fail_t"),
            (self.failover_delay >= 0.0, "failover_delay must be >= 0"),
            (self.backup_rate > 0.0, "backup_rate must be > 0"),
            (self.backup_latency >= 0.0, "backup_latency must be >= 0"),
        )
        for ok, msg in checks:
            if not ok:
                raise ValueError(msg)
        return self


def no_ctrl() -> CtrlPlaneConfig:
    """The identity config: an instant, infinite-capacity controller."""
    return CtrlPlaneConfig()
