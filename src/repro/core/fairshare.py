"""Channel bandwidth allocation.

Paper Eq. 3 (fair share): every channel crossing link i gets l_bw(i)/nc(i);
a channel's rate is the minimum share along its route.  This is what
CloudSimSDN implements and what the paper's use-case uses.

Beyond paper: progressive-filling **max-min water-filling**, which is
Pareto-optimal (Eq. 3 can leave residual capacity on non-bottleneck links).
Offered as TRAFFIC_WATERFILL, used in the §Perf iterations of the advisor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

TRAFFIC_FAIRSHARE = 0  # paper Eq. 3
TRAFFIC_WATERFILL = 1  # beyond-paper max-min fairness


def channel_counts(route_links: jnp.ndarray, active: jnp.ndarray,
                   n_links: int) -> jnp.ndarray:
    """nc(i): number of active channels crossing each directed link.

    route_links: int32 [N, H] link ids (-1 pad); active: bool [N]
    """
    mask = (route_links >= 0) & active[:, None]
    safe = jnp.maximum(route_links, 0)
    contrib = mask.astype(jnp.int32)
    return jnp.zeros((n_links,), jnp.int32).at[safe.reshape(-1)].add(
        contrib.reshape(-1))


def eq3_rates(route_links: jnp.ndarray, active: jnp.ndarray,
              link_bw: jnp.ndarray, intra_bw: float,
              nc: jnp.ndarray | None = None) -> jnp.ndarray:
    """Paper Eq. 3 rate for every packet (0 for inactive).

    Packets with an empty route (src host == dst host) move at ``intra_bw``.
    ``nc`` takes the per-link channel counts precomputed by the engine's
    fused network pass (DESIGN.md §8); ``None`` recomputes them here.
    """
    if nc is None:
        nc = channel_counts(route_links, active, link_bw.shape[0])
    valid = route_links >= 0
    safe = jnp.maximum(route_links, 0)
    # per-LINK share first (tiny link axis), then one gather onto the
    # packet axis — same float op on the same operands as dividing after
    # the gather, one packet-scale op cheaper (DESIGN.md §8)
    share_l = link_bw / jnp.maximum(nc, 1).astype(link_bw.dtype)
    share = jnp.where(valid, share_l[safe], jnp.inf)
    bot = jnp.min(share, axis=-1)
    bot = jnp.where(jnp.isinf(bot), jnp.asarray(intra_bw, link_bw.dtype), bot)
    return jnp.where(active, bot, 0.0)


def waterfill_rates(route_links: jnp.ndarray, active: jnp.ndarray,
                    link_bw: jnp.ndarray, intra_bw: float,
                    n_iter: int | None = None) -> jnp.ndarray:
    """Progressive-filling max-min fair rates.

    Each iteration freezes every flow whose bottleneck link is globally
    saturated at the current fill level; at most n_links iterations needed.
    Fixed trip count for jit; early iterations simply become no-ops.
    """
    n_links = link_bw.shape[0]
    n_iter = n_iter if n_iter is not None else min(n_links, 32)
    valid = route_links >= 0
    safe = jnp.maximum(route_links, 0)

    def fill_level(alloc, frozen, live):
        """Per-flow fill level: min over the route of (link residual after
        frozen allocations) / (live flows on the link)."""
        used = jnp.zeros((n_links,), link_bw.dtype).at[safe.reshape(-1)].add(
            jnp.where(valid & frozen[:, None], alloc[:, None],
                      0.0).reshape(-1))
        resid = jnp.maximum(link_bw - used, 0.0)
        n_live = jnp.zeros((n_links,), jnp.int32).at[safe.reshape(-1)].add(
            (valid & live[:, None]).astype(jnp.int32).reshape(-1))
        share = resid / jnp.maximum(n_live, 1).astype(link_bw.dtype)
        share = jnp.where(n_live > 0, share, jnp.inf)
        return jnp.min(jnp.where(valid, share[safe], jnp.inf), axis=-1)

    def body(_, carry):
        alloc, frozen = carry
        live = active & ~frozen
        level = fill_level(alloc, frozen, live)  # [N]
        # global fill step: freeze flows bottlenecked at the minimum level
        glob = jnp.min(jnp.where(live, level, jnp.inf))
        glob = jnp.where(jnp.isinf(glob), 0.0, glob)
        hit = live & (level <= glob * (1 + 1e-6))
        alloc = jnp.where(hit, glob, alloc)
        frozen = frozen | hit
        return alloc, frozen

    alloc0 = jnp.zeros(route_links.shape[0], link_bw.dtype)
    frozen0 = jnp.zeros(route_links.shape[0], bool)
    alloc, frozen = jax.lax.fori_loop(0, n_iter, body, (alloc0, frozen0))
    # any still-unfrozen live flow (iter cap hit) gets its CURRENT fill
    # level: each link then carries at most n_live * (resid/n_live) = resid
    # on top of the frozen allocations — never oversubscribed.  (The old
    # fallback handed out Eq. 3 rates computed against the FULL link
    # capacity, stacking on top of frozen water-fill allocations and
    # exceeding shared links.)
    live = active & ~frozen
    alloc = jnp.where(live, fill_level(alloc, frozen, live), alloc)
    # intra-host flows
    empty = ~jnp.any(valid, axis=-1)
    alloc = jnp.where(active & empty, jnp.asarray(intra_bw, link_bw.dtype), alloc)
    return jnp.where(active, alloc, 0.0)


def rates(policy: jnp.ndarray, route_links: jnp.ndarray, active: jnp.ndarray,
          link_bw: jnp.ndarray, intra_bw: float,
          nc: jnp.ndarray | None = None) -> jnp.ndarray:
    """Dispatch on traffic policy (vmap-safe lax.cond).

    ``nc`` is the optional precomputed channel-count tensor for the Eq. 3
    branch (water-filling recomputes per-link live counts each fill
    iteration, so it has no use for a one-shot count).

    A host-static ``policy`` (a plain Python/numpy int — fleet cohorts,
    DESIGN.md §9) resolves the branch at trace time: under ``vmap`` a
    ``lax.cond`` on a batched predicate executes BOTH branches, and the
    32-iteration water-fill loop would tax every Eq.-3 step."""
    if isinstance(policy, (bool, int, np.integer)) or (
            isinstance(policy, np.ndarray) and policy.ndim == 0):
        if int(policy) == TRAFFIC_WATERFILL:
            return waterfill_rates(route_links, active, link_bw, intra_bw)
        return eq3_rates(route_links, active, link_bw, intra_bw, nc=nc)
    return jax.lax.cond(
        policy == TRAFFIC_WATERFILL,
        lambda: waterfill_rates(route_links, active, link_bw, intra_bw),
        lambda: eq3_rates(route_links, active, link_bw, intra_bw, nc=nc),
    )
