"""Energy model (paper Fig. 13).

Hosts: linear-utilization model P = P_idle + u * (P_peak - P_idle) while any
task runs on the host, 0 W otherwise ("idle-mode ... is activated" — §5.3).
Switches: P = P_static + n_active_ports * P_port while any channel crosses the
switch, 0 W otherwise.  Power is piecewise constant between events, so energy
is an exact power*dt accumulation inside the event loop.

The paper does not publish its constants; defaults follow the CloudSimSDN
lineage (HP ProLiant-class hosts, commodity ToR switches).  The validated
quantity is the *relative* SDN-vs-legacy saving.
"""
from __future__ import annotations

import dataclasses

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class EnergyParams:
    host_idle_w: float = 150.0
    host_peak_w: float = 250.0
    switch_static_w: float = 100.0
    switch_port_w: float = 10.0


def host_power(util: jnp.ndarray, p: EnergyParams) -> jnp.ndarray:
    """util in [0,1] per host; 0 W when fully idle."""
    busy = util > 0
    pw = p.host_idle_w + util * (p.host_peak_w - p.host_idle_w)
    return jnp.where(busy, pw, 0.0)


def switch_power(active_ports: jnp.ndarray, p: EnergyParams) -> jnp.ndarray:
    """active_ports: int per switch (directed links with >=1 channel)."""
    busy = active_ports > 0
    pw = p.switch_static_w + active_ports.astype(jnp.float32) * p.switch_port_w
    return jnp.where(busy, pw, 0.0)
