"""Network topology as fixed-shape tensors.

The paper models a cloud data center as hosts + switches + a SAN connected by
bidirectional links (Fig. 9).  We represent a topology as:

  * ``n_nodes`` nodes (hosts first, then switches, then storage nodes),
  * ``n_links`` *directed* link slots (each undirected cable = 2 directed links),
  * ``link_src/link_dst``  int32[n_links] endpoints,
  * ``link_bw``            f32[n_links] capacity (bits/s),
  * ``adj_hop``            f32[n_nodes, n_nodes] 1/inf adjacency (tropical weights).

Directed links let us model full-duplex cables exactly as CloudSimSDN does
(a SAN->mapper flow and a reducer->SAN flow never share capacity).

Builders are host-side (numpy) — topology construction is setup, not sim.
"""
from __future__ import annotations

import dataclasses
from typing import Dict, List, Tuple

import numpy as np

INF = np.float32(np.inf)


@dataclasses.dataclass(frozen=True)
class Topology:
    """Immutable tensor description of a data-center network."""

    n_hosts: int
    n_switches: int
    n_storage: int
    link_src: np.ndarray  # int32[n_links]
    link_dst: np.ndarray  # int32[n_links]
    link_bw: np.ndarray  # f32[n_links] bits/sec
    names: Tuple[str, ...] = ()

    @property
    def n_nodes(self) -> int:
        return self.n_hosts + self.n_switches + self.n_storage

    @property
    def n_links(self) -> int:
        return int(self.link_src.shape[0])

    # node-id helpers ------------------------------------------------------
    def host(self, i: int) -> int:
        return i

    def switch(self, i: int) -> int:
        return self.n_hosts + i

    def storage(self, i: int = 0) -> int:
        return self.n_hosts + self.n_switches + i

    def is_switch(self, node: np.ndarray) -> np.ndarray:
        return (node >= self.n_hosts) & (node < self.n_hosts + self.n_switches)

    # derived tensors ------------------------------------------------------
    def hop_matrix(self) -> np.ndarray:
        """Tropical-semiring adjacency: 1 hop per link, inf where unconnected."""
        n = self.n_nodes
        m = np.full((n, n), INF, dtype=np.float32)
        np.fill_diagonal(m, 0.0)
        m[self.link_src, self.link_dst] = 1.0
        return m

    def bw_matrix(self) -> np.ndarray:
        """Dense [n,n] bandwidth lookup (0 where no link)."""
        n = self.n_nodes
        m = np.zeros((n, n), dtype=np.float32)
        m[self.link_src, self.link_dst] = self.link_bw
        return m

    def link_index(self) -> Dict[Tuple[int, int], int]:
        return {
            (int(s), int(d)): i
            for i, (s, d) in enumerate(zip(self.link_src, self.link_dst))
        }

    def cable_pairs(self) -> List[Tuple[int, int]]:
        """Directed-link id pairs forming one full-duplex cable.

        ``_build`` emits the two directions of every undirected edge
        adjacently, so pairs are ``(2i, 2i+1)`` when that layout holds
        (verified here); failure generators cut cables, not directions
        (DESIGN.md §7).  A hand-built topology without the layout gets
        reverse-lookup pairing instead."""
        pairs: List[Tuple[int, int]] = []
        n = self.n_links
        adjacent = (n % 2 == 0 and all(
            self.link_src[2 * i] == self.link_dst[2 * i + 1]
            and self.link_dst[2 * i] == self.link_src[2 * i + 1]
            for i in range(n // 2)))
        if adjacent:
            return [(2 * i, 2 * i + 1) for i in range(n // 2)]
        idx = self.link_index()
        seen = set()
        for i, (s, d) in enumerate(zip(self.link_src, self.link_dst)):
            if i in seen:
                continue
            j = idx.get((int(d), int(s)), i)
            seen.update((i, j))
            pairs.append((i, j))
        return pairs

    def links_touching(self, node: int) -> List[int]:
        """Directed link ids with ``node`` as an endpoint (both directions
        — what a NIC/port failure at that node takes down)."""
        return [i for i, (s, d) in enumerate(zip(self.link_src,
                                                 self.link_dst))
                if node in (int(s), int(d))]


def _build(edges: List[Tuple[int, int, float]], n_hosts: int, n_switches: int,
           n_storage: int, names: Tuple[str, ...] = ()) -> Topology:
    """Expand undirected (u, v, bw) edges into directed link tensors."""
    src, dst, bw = [], [], []
    for u, v, b in edges:
        src += [u, v]
        dst += [v, u]
        bw += [b, b]
    return Topology(
        n_hosts=n_hosts,
        n_switches=n_switches,
        n_storage=n_storage,
        link_src=np.asarray(src, np.int32),
        link_dst=np.asarray(dst, np.int32),
        link_bw=np.asarray(bw, np.float32),
        names=names,
    )


GBPS = 1e9  # bits per second


def paper_fat_tree(core_bw: float = 1 * GBPS,
                   agg_bw: float = 1 * GBPS,
                   edge_bw: float = 1 * GBPS,
                   san_bw: float = 4 * GBPS,
                   core_parallel: int = 2) -> Topology:
    """The paper's Fig. 9 three-tier topology.

    4 core switches (2 pairs), 8 aggregation, 8 edge, 16 hosts, 1 SAN.
    - SAN connects to core switch 0 ("core1") at 4 Gbps.
    - §5.1: "the first pair of core switches (L1) is connected to four odd
      switches of the child layer (L2) by TWO links, configured with a
      bandwidth of 1 Gbps each, and vice versa to the others" — every
      core<->agg attachment is ``core_parallel`` PARALLEL 1 Gbps cables.
      Parallel cables are distinct equal-hop routes ("same number of links
      but different bandwidths", §5.3) — this is exactly the diversity the
      paper's SDN controller exploits, including on SAN->mapper paths.
    - Core pair A serves agg {0,2,4,6}, pair B serves agg {1,3,5,7}.
    - Each aggregation switch feeds 2 edge switches, each edge feeds 2 hosts.
    """
    n_hosts, n_sw, n_storage = 16, 4 + 8 + 8, 1
    H = lambda i: i
    CORE = lambda i: 16 + i
    AGG = lambda i: 16 + 4 + i
    EDGE = lambda i: 16 + 4 + 8 + i
    SAN = 16 + 20

    edges: List[Tuple[int, int, float]] = []
    # SAN -> core1
    edges.append((SAN, CORE(0), san_bw))
    # core pairs to aggregation: pair {0,1} <-> even agg, pair {2,3} <-> odd agg
    for a in range(8):
        pair = (0, 1) if a % 2 == 0 else (2, 3)
        for c in pair:
            for _ in range(core_parallel):
                edges.append((CORE(c), AGG(a), core_bw))
    # aggregation a serves edges 2a, 2a+1?  8 agg, 8 edge: group agg in pairs
    # per pod: pod p has agg {2p, 2p+1} and edge {2p, 2p+1}, full bipartite.
    for p in range(4):
        for a in (2 * p, 2 * p + 1):
            for e in (2 * p, 2 * p + 1):
                edges.append((AGG(a), EDGE(e), agg_bw))
    # each edge switch -> 2 hosts
    for e in range(8):
        for h in (2 * e, 2 * e + 1):
            edges.append((EDGE(e), H(h), edge_bw))

    names = tuple(
        [f"host{i}" for i in range(16)]
        + [f"core{i}" for i in range(4)]
        + [f"agg{i}" for i in range(8)]
        + [f"edge{i}" for i in range(8)]
        + ["san0"]
    )
    return _build(edges, n_hosts, n_sw, n_storage, names)


def fat_tree(k: int, bw: float = GBPS, san_bw: float | None = None) -> Topology:
    """Generic k-ary fat-tree (k even): (k/2)^2 core, k pods of k/2+k/2 switches,
    (k^3)/4 hosts, plus one SAN on core switch 0."""
    assert k % 2 == 0
    half = k // 2
    n_hosts = k * half * half
    n_core = half * half
    n_agg = k * half
    n_edge = k * half
    n_sw = n_core + n_agg + n_edge
    H = lambda i: i
    CORE = lambda i: n_hosts + i
    AGG = lambda p, i: n_hosts + n_core + p * half + i
    EDGE = lambda p, i: n_hosts + n_core + n_agg + p * half + i
    SAN = n_hosts + n_sw

    edges: List[Tuple[int, int, float]] = []
    edges.append((SAN, CORE(0), san_bw if san_bw is not None else 4 * bw))
    for p in range(k):
        for a in range(half):
            # agg (p,a) connects to core group a*half .. a*half+half-1
            for c in range(half):
                edges.append((AGG(p, a), CORE(a * half + c), bw))
            for e in range(half):
                edges.append((AGG(p, a), EDGE(p, e), bw))
        for e in range(half):
            for h in range(half):
                edges.append((EDGE(p, e), H(p * half * half + e * half + h), bw))
    return _build(edges, n_hosts, n_sw, 1)


def leaf_spine(n_spine: int = 4, n_leaf: int = 4, hosts_per_leaf: int = 4,
               host_bw: float = GBPS, fabric_bw: float = GBPS,
               san_bw: float | None = None) -> Topology:
    """Two-tier leaf-spine (Clos) fabric.

    Every leaf connects to every spine (``fabric_bw``), every host hangs off
    one leaf (``host_bw``), and the SAN attaches to spine 0.  Any inter-leaf
    host pair therefore has exactly ``n_spine`` equal-hop routes — the route
    diversity the SDN controller load-balances over (DESIGN.md §5).
    """
    assert n_spine >= 1 and n_leaf >= 1 and hosts_per_leaf >= 1
    n_hosts = n_leaf * hosts_per_leaf
    n_sw = n_spine + n_leaf
    H = lambda i: i
    SPINE = lambda i: n_hosts + i
    LEAF = lambda i: n_hosts + n_spine + i
    SAN = n_hosts + n_sw

    edges: List[Tuple[int, int, float]] = []
    edges.append((SAN, SPINE(0), san_bw if san_bw is not None else 4 * fabric_bw))
    for l in range(n_leaf):
        for s in range(n_spine):
            edges.append((LEAF(l), SPINE(s), fabric_bw))
        for h in range(hosts_per_leaf):
            edges.append((LEAF(l), H(l * hosts_per_leaf + h), host_bw))

    names = tuple(
        [f"host{i}" for i in range(n_hosts)]
        + [f"spine{i}" for i in range(n_spine)]
        + [f"leaf{i}" for i in range(n_leaf)]
        + ["san0"]
    )
    return _build(edges, n_hosts, n_sw, 1, names)


def canonical_tree(depth: int = 2, fanout: int = 2, hosts_per_edge: int = 2,
                   bw: float = GBPS, root_bw_mult: float = 1.0,
                   san_bw: float | None = None) -> Topology:
    """Canonical (single-rooted) switch tree, the classic data-center baseline.

    ``depth`` switch levels: level 0 is one root, level d has ``fanout**d``
    switches; the ``fanout**(depth-1)`` bottom switches are edge switches with
    ``hosts_per_edge`` hosts each.  The SAN attaches to the root.  Every node
    pair has exactly ONE route (no path diversity) — the degenerate case
    against which fat-tree/leaf-spine SDN gains are measured.  Links touching
    the root carry ``bw * root_bw_mult`` to model thicker trunks.
    """
    assert depth >= 1 and fanout >= 1 and hosts_per_edge >= 1
    level_size = [fanout ** d for d in range(depth)]
    n_sw = sum(level_size)
    n_edge = level_size[-1]
    n_hosts = n_edge * hosts_per_edge
    level_base = [n_hosts + sum(level_size[:d]) for d in range(depth)]
    SW = lambda d, i: level_base[d] + i
    SAN = n_hosts + n_sw

    edges: List[Tuple[int, int, float]] = []
    edges.append((SAN, SW(0, 0), san_bw if san_bw is not None else 4 * bw))
    for d in range(1, depth):
        level_bw = bw * (root_bw_mult if d == 1 else 1.0)
        for i in range(level_size[d]):
            edges.append((SW(d - 1, i // fanout), SW(d, i), level_bw))
    edge_bw = bw * (root_bw_mult if depth == 1 else 1.0)
    for e in range(n_edge):
        for h in range(hosts_per_edge):
            edges.append((SW(depth - 1, e), e * hosts_per_edge + h, edge_bw))

    names = tuple(
        [f"host{i}" for i in range(n_hosts)]
        + [f"sw{d}_{i}" for d in range(depth) for i in range(level_size[d])]
        + ["san0"]
    )
    return _build(edges, n_hosts, n_sw, 1, names)


def torus_2d(nx: int, ny: int, bw: float = GBPS) -> Topology:
    """2-D torus of `hosts` (TPU-pod ICI abstraction for the roofline advisor).

    Every node is a host (chip); links are the ±x/±y ICI cables.
    """
    n = nx * ny
    idx = lambda x, y: (x % nx) * ny + (y % ny)
    edges: List[Tuple[int, int, float]] = []
    for x in range(nx):
        for y in range(ny):
            if nx > 1 and (nx > 2 or x == 0):  # avoid double edge when nx==2
                edges.append((idx(x, y), idx(x + 1, y), bw))
            if ny > 1 and (ny > 2 or y == 0):
                edges.append((idx(x, y), idx(x, y + 1), bw))
    return _build(edges, n, 0, 0)


def torus_3d(nx: int, ny: int, nz: int, bw: float = GBPS) -> Topology:
    n = nx * ny * nz
    idx = lambda x, y, z: ((x % nx) * ny + (y % ny)) * nz + (z % nz)
    edges: List[Tuple[int, int, float]] = []
    for x in range(nx):
        for y in range(ny):
            for z in range(nz):
                if nx > 1 and (nx > 2 or x == 0):
                    edges.append((idx(x, y, z), idx(x + 1, y, z), bw))
                if ny > 1 and (ny > 2 or y == 0):
                    edges.append((idx(x, y, z), idx(x, y + 1, z), bw))
                if nz > 1 and (nz > 2 or z == 0):
                    edges.append((idx(x, y, z), idx(x, y, z + 1), bw))
    return _build(edges, n, 0, 0)
