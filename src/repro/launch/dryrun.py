import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines: jax locks the device count on first init.
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

For each cell this builds the exact step function a production run jits
(train_step / prefill / decode_step), with the sharding rules from
repro.sharding, lowers it against ShapeDtypeStruct inputs (no allocation),
compiles, and records memory_analysis / cost_analysis / collective stats
for the roofline table.

Roofline counts are DEPTH-EXTRAPOLATED: XLA cost analysis counts a
lax.scan body once, so each cell also compiles depth-1 and depth-2
variants; per-layer counts = (depth2 - depth1), total = outside +
per-layer x L.  The FULL-depth compile still proves sharding + memory.

Usage:
  python -m repro.launch.dryrun --arch qwen3-4b --shape train_4k
  python -m repro.launch.dryrun --all --multi-pod both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import sys
import time
import traceback
from functools import partial
from typing import Any, Dict, Tuple

import jax
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, SHAPES, get_config, shape_applies
from repro.models import get_model
from repro.models.registry import decode_input_specs, prefill_input_specs, \
    train_input_specs
from repro.roofline import analyze_raw, count_active_params, count_params
from repro.roofline.terms import peak_memory, raw_counts
from repro.sharding import batch_specs, cache_specs_tree, param_specs
from repro.train import AdamWConfig, make_train_step
from repro.train import optim
from .mesh import make_production_mesh, mesh_chips


def _named(mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))


def _opt_specs(pspecs, params_sds, mesh):
    from repro.sharding.rules import opt_state_specs
    mspecs = opt_state_specs(params_sds, mesh)   # ZeRO: +data-axis shard
    err = jax.tree_util.tree_map(lambda _: P(), params_sds)
    return optim.OptState(step=P(), mu=mspecs, nu=mspecs, err=err)


def depth_units(cfg) -> int:
    if cfg.family == "hybrid":
        return cfg.n_layers // cfg.attn_every
    return cfg.n_layers


def with_units(cfg, u: int):
    if cfg.family == "hybrid":
        return dataclasses.replace(cfg, n_layers=u * cfg.attn_every)
    if cfg.family == "audio":
        return dataclasses.replace(cfg, n_layers=u, n_enc_layers=u)
    return dataclasses.replace(cfg, n_layers=u)


def lower_one(cfg, shape, mesh, *, backend: str, remat: bool,
              microbatch: int):
    """Lower + compile one step function for one cfg/shape/mesh."""
    if shape.kind == "decode" and cfg.fsdp:
        # decode steps amortize ZERO weight traffic per token: run them
        # Megatron-TP (weights stay sharded; tiny activations all-reduce)
        cfg = dataclasses.replace(cfg, fsdp=False)
    api = get_model(cfg)
    key = jax.random.PRNGKey(0)
    params_sds = jax.eval_shape(api.init, key)
    pspecs = param_specs(params_sds, mesh)
    p_sh = _named(mesh, pspecs)

    if shape.kind == "train":
        batch_sds = train_input_specs(cfg, shape.global_batch, shape.seq_len)
        ocfg = AdamWConfig()
        opt_sds = jax.eval_shape(partial(optim.init, ocfg), params_sds)
        o_sh = _named(mesh, _opt_specs(pspecs, params_sds, mesh))
        b_sh = _named(mesh, batch_specs(batch_sds, mesh))
        step = make_train_step(api, ocfg, backend=backend, remat=remat,
                               microbatch=microbatch)
        with jax.set_mesh(mesh):
            lowered = jax.jit(step, in_shardings=(p_sh, o_sh, b_sh),
                              out_shardings=(p_sh, o_sh, None),
                              donate_argnums=(0, 1)
                              ).lower(params_sds, opt_sds, batch_sds)
    elif shape.kind == "prefill":
        batch_sds = prefill_input_specs(cfg, shape.global_batch,
                                        shape.seq_len)
        cache_sds = jax.eval_shape(
            lambda: api.init_cache(shape.global_batch, shape.seq_len))
        c_sh = _named(mesh, cache_specs_tree(cache_sds, mesh))
        b_sh = _named(mesh, batch_specs(batch_sds, mesh))

        def prefill_step(params, batch, cache):
            return api.prefill(params, batch, cache, backend=backend)

        with jax.set_mesh(mesh):
            lowered = jax.jit(prefill_step,
                              in_shardings=(p_sh, b_sh, c_sh),
                              out_shardings=(None, c_sh),
                              donate_argnums=(2,)
                              ).lower(params_sds, batch_sds, cache_sds)
    else:  # decode
        cache_sds = jax.eval_shape(
            lambda: api.init_cache(shape.global_batch, shape.seq_len))
        c_sh = _named(mesh, cache_specs_tree(cache_sds, mesh))
        extra_sds = decode_input_specs(cfg, shape.global_batch)
        e_sh = _named(mesh, batch_specs(extra_sds, mesh))
        if cfg.family == "vlm":
            def decode(params, extra, cache):
                return api.decode_step(params, None, cache,
                                       batch_extra=extra)
        else:
            def decode(params, extra, cache):
                return api.decode_step(params, extra["tokens"], cache)
        with jax.set_mesh(mesh):
            lowered = jax.jit(decode, in_shardings=(p_sh, e_sh, c_sh),
                              out_shardings=(None, c_sh),
                              donate_argnums=(2,)
                              ).lower(params_sds, extra_sds, cache_sds)
    return lowered.compile()


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               backend: str = "chunked", remat: bool = True,
               microbatch: int = 0, mesh=None,
               extrapolate: bool = True,
               cfg_override=None) -> Tuple[Any, Dict[str, Any]]:
    """Compile the full cell + depth-extrapolated roofline counts."""
    cfg = cfg_override or get_config(arch)
    shape = SHAPES[shape_name]
    ok, why = shape_applies(cfg, shape_name)
    if not ok:
        raise ValueError(f"N/A cell: {why}")
    mesh = mesh or make_production_mesh(multi_pod=multi_pod)
    chips = mesh_chips(mesh)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    kw = dict(backend=backend, remat=remat, microbatch=microbatch)

    t0 = time.time()
    compiled = lower_one(cfg, shape, mesh, **kw)
    t_compile = time.time() - t0

    units = depth_units(cfg)
    if extrapolate and units > 2:
        from repro.util import unrolled_counting
        with unrolled_counting():
            c1 = lower_one(with_units(cfg, 1), shape, mesh, **kw)
            c2 = lower_one(with_units(cfg, 2), shape, mesh, **kw)
        r1 = raw_counts(c1, chips=chips)
        r2 = raw_counts(c2, chips=chips)
        per = {k: max(0.0, r2[k] - r1[k])
               for k in ("flops", "bytes", "wire_bytes")}
        outside = {k: max(0.0, r1[k] - per[k])
                   for k in ("flops", "bytes", "wire_bytes")}
        tot = {k: outside[k] + per[k] * units
               for k in ("flops", "bytes", "wire_bytes")}
        counts = raw_counts(compiled, chips=chips)["counts"]
        extrap = True
        del c1, c2
    else:
        rc = raw_counts(compiled, chips=chips)
        tot = {k: rc[k] for k in ("flops", "bytes", "wire_bytes")}
        counts = rc["counts"]
        extrap = False

    api = get_model(cfg)
    params_sds = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    params_n = count_params(params_sds)
    active_n = count_active_params(params_sds, cfg)
    from repro.roofline.terms import model_flops_cell
    mf = model_flops_cell(cfg, shape, active_n)
    rep = analyze_raw(flops=tot["flops"], byts=tot["bytes"],
                      wire=tot["wire_bytes"], counts=counts,
                      arch=arch, shape=shape_name, mesh_name=mesh_name,
                      chips=chips, model_flops=mf,
                      peak_bytes=peak_memory(compiled))
    mem = compiled.memory_analysis()
    info = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "chips": chips, "params": params_n, "active_params": active_n,
        "t_compile_s": round(t_compile, 2),
        "depth_extrapolated": extrap,
        "backend": backend, "remat": remat, "microbatch": microbatch,
        "memory": {
            "argument_gib": getattr(mem, "argument_size_in_bytes", 0) / 2**30,
            "output_gib": getattr(mem, "output_size_in_bytes", 0) / 2**30,
            "temp_gib": getattr(mem, "temp_size_in_bytes", 0) / 2**30,
            "alias_gib": getattr(mem, "alias_size_in_bytes", 0) / 2**30,
        },
        "roofline": rep.row(),
    }
    return compiled, info


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS)
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", choices=["off", "on", "both"],
                    default="off")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--backend", default="chunked")
    ap.add_argument("--remat", type=int, default=1)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args(argv)

    cells = ([(a, s) for a in ARCH_IDS for s in SHAPES] if args.all
             else [(args.arch, args.shape)])
    pods = {"off": [False], "on": [True], "both": [False, True]}[
        args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for arch, shape in cells:
        cfg = get_config(arch)
        ok, why = shape_applies(cfg, shape)
        for mp in pods:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
            path = os.path.join(args.out, tag + ".json")
            if args.skip_existing and os.path.exists(path):
                try:
                    st = json.load(open(path)).get("status")
                except Exception:
                    st = None
                if st in ("ok", "n/a"):
                    print(f"[skip] {tag}", flush=True)
                    continue
            if not ok:
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "status": "n/a", "reason": why}, f, indent=1)
                print(f"[n/a ] {tag}: {why}")
                continue
            try:
                # default microbatching keeps train cells inside 16 GB HBM
                # (see EXPERIMENTS.md §Dry-run): MoE capacity buffers scale
                # with global tokens-per-microstep, dense remat with
                # tokens-per-chip.
                microbatch = args.microbatch
                # (EP MoE keeps dispatch buffers local-token-sized, so MoE
                # train cells no longer need microbatching — see moe_ep.py)
                compiled, info = lower_cell(
                    arch, shape, multi_pod=mp, backend=args.backend,
                    remat=bool(args.remat), microbatch=microbatch,
                    extrapolate=not args.no_extrapolate)
                info["status"] = "ok"
                with open(path, "w") as f:
                    json.dump(info, f, indent=1, default=str)
                r = info["roofline"]
                print(f"[ok  ] {tag}: compile={info['t_compile_s']}s "
                      f"dom={r['dominant']} c/m/coll="
                      f"{r['compute_s']:.3f}/{r['memory_s']:.3f}/"
                      f"{r['collective_s']:.3f}s "
                      f"useful={r['useful_ratio']:.2f} "
                      f"mem={info['memory']['temp_gib']:.2f}GiB/chip",
                      flush=True)
                del compiled
            except Exception as e:  # noqa: BLE001 — report into the table
                failures += 1
                with open(path, "w") as f:
                    json.dump({"arch": arch, "shape": shape,
                               "mesh": "2x16x16" if mp else "16x16",
                               "status": "fail",
                               "error": traceback.format_exc()}, f, indent=1)
                print(f"[FAIL] {tag}: {type(e).__name__}: {e}", flush=True)
    print(f"dry-run done, {failures} failures")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
