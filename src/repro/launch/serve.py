"""Production serving launcher (continuous batching).

  PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b --smoke \
      --requests 16 --slots 4
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.models import get_model
from repro.serve import Request, ServeLoop


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=16)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-len", type=int, default=256)
    ap.add_argument("--max-new", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    loop = ServeLoop(api, params, slots=args.slots, max_len=args.max_len)
    rng = np.random.RandomState(0)
    for r in range(args.requests):
        loop.submit(Request(
            rid=r,
            prompt=rng.randint(1, cfg.vocab,
                               int(rng.randint(4, 32))).astype(np.int32),
            max_new=args.max_new))
    t0 = time.time()
    results = loop.run()
    dt = time.time() - t0
    tokens = sum(len(r.tokens) for r in results)
    print(f"[serve] {len(results)} requests, {tokens} tokens, "
          f"{tokens / dt:.1f} tok/s ({args.slots} slots)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
