"""Production mesh builders (a FUNCTION, not module state — importing this
never touches jax device initialization)."""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    return jax.make_mesh(tuple(shape), tuple(axes))


def mesh_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
