"""Production training launcher.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
      --steps 200 --batch 8 --seq 256 --smoke

On a real TPU slice this process runs once per host (jax.distributed
initializes from the environment); on this CPU container ``--smoke`` uses
the reduced config on one device.  The loop is the fault-tolerant
TrainDriver: deterministic data, periodic atomic checkpoints, crash
restart, straggler monitoring.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_smoke_config
from repro.data import TokenPipeline
from repro.ft import FailurePlan, TrainDriver
from repro.models import get_model
from repro.train import AdamWConfig, make_train_step
from repro.train import init as opt_init


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_IDS, default="qwen3-4b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=256)
    ap.add_argument("--microbatch", type=int, default=0)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--ckpt-dir", default="experiments/ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--crash-at", type=int, default=-1)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    if cfg.family in ("vlm", "audio") and not args.smoke:
        raise SystemExit("frontend-stub families train via the dry-run "
                         "path; use --smoke for a CPU run")
    api = get_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    n = sum(int(np.prod(l.shape))
            for l in jax.tree_util.tree_leaves(params))
    print(f"[train] arch={cfg.name} params={n / 1e6:.1f}M "
          f"batch={args.batch}x{args.seq}")

    ocfg = AdamWConfig(lr_peak=args.lr, total_steps=args.steps,
                       warmup_steps=max(1, args.steps // 20),
                       compress=args.compress_grads)
    opt = opt_init(ocfg, params)
    step = jax.jit(make_train_step(api, ocfg, microbatch=args.microbatch),
                   donate_argnums=(0, 1))
    pipe = TokenPipeline(vocab=cfg.vocab, batch=args.batch, seq=args.seq,
                         n_hosts=jax.process_count(),
                         host_id=jax.process_index())

    def batch_fn(s):
        b = pipe.batch_at(s)
        if cfg.family == "audio":
            b["enc_embeds"] = np.zeros(
                (args.batch, cfg.enc_seq, cfg.d_model), np.float32)
        return {k: jnp.asarray(v) for k, v in b.items()}

    plan = FailurePlan(at_steps={args.crash_at: "crash"}
                       if args.crash_at >= 0 else {})
    drv = TrainDriver(step_fn=step, batch_fn=batch_fn,
                      ckpt_dir=args.ckpt_dir, ckpt_every=args.ckpt_every,
                      failure_plan=plan)
    t0 = time.time()
    params, opt, info = drv.run(params, opt, args.steps)
    hist = info["history"]
    if hist:
        print(f"[train] {len(hist)} steps in {time.time() - t0:.0f}s, "
              f"loss {hist[0]['loss']:.3f} -> {hist[-1]['loss']:.3f}, "
              f"restarts={info['restarts']}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
