"""Atomic checkpoint save/restore with elastic re-shard on resume.

Layout: ``<dir>/step_<N>/`` holding ``arrays.npz`` (flattened pytree
leaves keyed by path) + ``manifest.json`` (step, tree structure, dtypes,
pipeline cursor, config fingerprint).  Writes go to ``.tmp-...`` then
``os.replace`` — a crashed writer never corrupts the latest checkpoint
(the restart path always loads the newest COMPLETE manifest).

``restore`` device_puts every leaf with the *target* sharding, so a run
restarted on a different mesh (elastic down/up-scale) re-shards
transparently.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

_SEP = "/"


def _flatten(tree: Any) -> Dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = np.asarray(leaf)
        if arr.dtype.name == "bfloat16":  # npz has no native bf16: widen
            arr = arr.astype(np.float32)  # (bf16 -> f32 -> bf16 is exact)
        flat[key] = arr
    return flat


def save(ckpt_dir: str, step: int, tree: Any,
         extra: Optional[Dict[str, Any]] = None) -> str:
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(prefix=".tmp-", dir=ckpt_dir)
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {
            "step": step,
            "keys": sorted(flat),
            "dtypes": {k: str(v.dtype) for k, v in flat.items()},
            "shapes": {k: list(v.shape) for k, v in flat.items()},
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    return final


def latest_step(ckpt_dir: str) -> Optional[int]:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and os.path.exists(
                os.path.join(ckpt_dir, name, "manifest.json")):
            steps.append(int(name.split("_")[1]))
    return max(steps) if steps else None


def restore(ckpt_dir: str, like: Any, *, step: Optional[int] = None,
            shardings: Any = None) -> Tuple[Any, Dict[str, Any]]:
    """Load into the structure of ``like``; optional target shardings
    (matching pytree of jax.sharding.Sharding) re-shard on load."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoint under {ckpt_dir}")
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    arrays = np.load(os.path.join(d, "arrays.npz"))

    flat_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_flat = (jax.tree_util.tree_flatten(shardings)[0]
                  if shardings is not None else [None] * len(flat_like))
    leaves = []
    for (path, leaf), sh in zip(flat_like, shard_flat):
        key = _SEP.join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path)
        arr = arrays[key]
        assert tuple(arr.shape) == tuple(leaf.shape), \
            f"{key}: ckpt {arr.shape} vs target {leaf.shape}"
        val = jnp.asarray(arr, dtype=leaf.dtype)
        if sh is not None:
            val = jax.device_put(val, sh)
        leaves.append(val)
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["extra"]
