from . import ckpt
from .ckpt import latest_step, restore, save

__all__ = ["ckpt", "save", "restore", "latest_step"]
