from .scheduler import Request, Result, ServeLoop

__all__ = ["Request", "Result", "ServeLoop"]
