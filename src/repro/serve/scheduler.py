"""Continuous-batching serve loop (single-host reference implementation).

Requests enter a FIFO; a fixed pool of B slots holds active sequences.
Each tick: (1) free slots are refilled by prefilling queued prompts into
the slot's cache rows, (2) one decode step advances every active slot,
(3) finished rows (EOS or budget) are emitted.  The jitted hot path is the
batched decode step; prefill is jitted per prompt-length bucket.

This is the host-side analogue of the paper's ResourceManager admission
queue (FCFS reservation), applied to serving slots instead of VMs.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Deque, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.registry import ModelApi


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [S] int32
    max_new: int = 16
    eos_id: int = -2            # -2: never (synthetic workloads)


@dataclasses.dataclass
class Result:
    rid: int
    tokens: List[int]
    prefill_len: int
    decode_steps: int


class ServeLoop:
    def __init__(self, api: ModelApi, params, *, slots: int = 4,
                 max_len: int = 256, bucket: int = 32):
        self.api = api
        self.params = params
        self.slots = slots
        self.max_len = max_len
        self.bucket = bucket
        self.queue: Deque[Request] = deque()
        self.active: Dict[int, dict] = {}          # slot -> request state
        self.free = list(range(slots))
        self.cache = api.init_cache(slots, max_len)
        self._decode = jax.jit(
            lambda p, t, c: api.decode_step(p, t, c))
        self._prefill_1 = {}

    # -- admission ---------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _bucketed(self, n: int) -> int:
        return max(self.bucket, -(-n // self.bucket) * self.bucket)

    def _prefill_fn(self, plen: int):
        if plen not in self._prefill_1:
            api = self.api

            def fn(params, batch, cache):
                return api.prefill(params, batch, cache)

            self._prefill_1[plen] = jax.jit(fn)
        return self._prefill_1[plen]

    def _admit(self):
        while self.free and self.queue:
            slot = self.free.pop()
            req = self.queue.popleft()
            plen = self._bucketed(len(req.prompt))
            prompt = np.full((plen,), 0, np.int32)
            prompt[-len(req.prompt):] = req.prompt
            # per-slot prefill into a fresh single-row cache, then splice
            row_cache = self.api.init_cache(1, self.max_len)
            logits, row_cache = self._prefill_fn(plen)(
                self.params, {"tokens": jnp.asarray(prompt[None])},
                row_cache)
            self.cache = jax.tree_util.tree_map(
                lambda full, row: full.at[:, slot:slot + 1].set(row)
                if full.ndim >= 2 else full.at[slot].set(row[0]),
                self.cache, row_cache)
            tok = int(jnp.argmax(logits[0, -1]))
            self.active[slot] = {"req": req, "tokens": [tok], "steps": 0,
                                 "plen": plen}

    # -- one tick ----------------------------------------------------------
    def tick(self) -> List[Result]:
        self._admit()
        if not self.active:
            return []
        tokens = np.zeros((self.slots, 1), np.int32)
        for slot, st in self.active.items():
            tokens[slot, 0] = st["tokens"][-1]
        logits, self.cache = self._decode(self.params,
                                          jnp.asarray(tokens), self.cache)
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        done: List[Result] = []
        for slot in list(self.active):
            st = self.active[slot]
            st["steps"] += 1
            st["tokens"].append(int(nxt[slot]))
            req = st["req"]
            if (st["steps"] >= req.max_new
                    or int(nxt[slot]) == req.eos_id):
                done.append(Result(req.rid, st["tokens"], st["plen"],
                                   st["steps"]))
                del self.active[slot]
                self.free.append(slot)
        return done

    def run(self, until_empty: bool = True, max_ticks: int = 10_000
            ) -> List[Result]:
        out: List[Result] = []
        ticks = 0
        while (self.queue or self.active) and ticks < max_ticks:
            out.extend(self.tick())
            ticks += 1
        return out
