"""Attention backends: naive, chunked (flash-style online softmax), decode.

All take q [B,S,H,Dh], k/v [B,Skv,KV,Dh] with GQA (H = G*KV).  The chunked
backend is the memory-safe default for long sequences; the Pallas kernel in
``repro.kernels.flash_attention`` is the TPU fast path and is numerically
validated against ``naive`` (its ref.py re-exports it).
"""
from __future__ import annotations


import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_hint

NEG_INF = -1e30


def _expand_kv(k: jnp.ndarray, n_heads: int) -> jnp.ndarray:
    """[B,S,KV,Dh] -> [B,S,H,Dh] by repeating each kv head G times."""
    b, s, kv, dh = k.shape
    g = n_heads // kv
    return jnp.repeat(k, g, axis=2) if g > 1 else k


def naive_attention(q, k, v, *, causal: bool = True,
                    q_offset: int | jnp.ndarray = 0) -> jnp.ndarray:
    """Reference full-materialization attention (oracle for kernels)."""
    h = q.shape[2]
    k, v = _expand_kv(k, h), _expand_kv(v, h)
    scale = 1.0 / jnp.sqrt(q.shape[-1]).astype(jnp.float32)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k,
                        preferred_element_type=jnp.float32) * scale
    if causal:
        sq, sk = q.shape[1], k.shape[1]
        qpos = jnp.arange(sq)[:, None] + q_offset
        kpos = jnp.arange(sk)[None, :]
        logits = jnp.where(qpos >= kpos, logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhqk,bkhd->bqhd", w.astype(v.dtype), v)


def chunked_attention(q, k, v, *, causal: bool = True,
                      q_offset: int | jnp.ndarray = 0,
                      block_k: int = 512) -> jnp.ndarray:
    """Flash-style attention: scan over KV blocks with running (m, l, acc).

    Never materializes the [S,S] score matrix (O(S·block_k) memory) and
    keeps kv heads GROUPED — no jnp.repeat expansion of K/V (a 4.3 GB/chip
    transient for the 72B decode cells).
    """
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    # gathered-KV attention: under sequence parallelism q stays S-sharded
    # while k/v are gathered once per layer; pinning them also stops GSPMD
    # from splitting the contraction over an idle axis (huge partial-sum
    # all-reduces of the [B,KV,G,Sq,bk] logits otherwise).
    k = shard_hint(k, ("pod", "data"), None, None, None)
    v = shard_hint(v, ("pod", "data"), None, None, None)
    qg = q.reshape(b, sq, kv, g, dh)
    nblk = -(-skv // block_k)
    pad = nblk * block_k - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    kb = k.reshape(b, nblk, block_k, kv, dh).transpose(1, 0, 2, 3, 4)
    vb = v.reshape(b, nblk, block_k, kv, dh).transpose(1, 0, 2, 3, 4)
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    qpos = jnp.arange(sq)[:, None] + q_offset                     # [Sq, 1]

    def body(carry, blk):
        m, l, acc, kidx = carry                  # m,l: [B,KV,G,Sq]
        kblk, vblk = blk                         # [B,bk,KV,Dh]
        logits = jnp.einsum("bqngd,bknd->bngqk", qg, kblk,
                            preferred_element_type=jnp.float32) * scale
        kpos = kidx * block_k + jnp.arange(block_k)[None, :]      # [1, bk]
        mask = kpos <= (skv - 1)                                  # pad mask
        if causal:
            mask = mask & (qpos >= kpos)
        logits = jnp.where(mask[None, None, None], logits, NEG_INF)
        m_blk = jnp.max(logits, axis=-1)                          # [B,KV,G,Sq]
        m_new = jnp.maximum(m, m_blk)
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])                    # [B,KV,G,Sq,bk]
        l = l * alpha + jnp.sum(p, axis=-1)
        pv = jnp.einsum("bngqk,bknd->bngqd", p.astype(vblk.dtype), vblk)
        acc = acc * alpha[..., None] + pv.astype(jnp.float32)
        return (m_new, l, acc, kidx + 1), None

    m0 = jnp.full((b, kv, g, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, sq), jnp.float32)
    acc0 = jnp.zeros((b, kv, g, sq, dh), jnp.float32)
    from repro.util import scan as _scan
    (m, l, acc, _), _ = _scan(body, (m0, l0, acc0, jnp.int32(0)), (kb, vb))
    out = acc / jnp.maximum(l, 1e-30)[..., None]   # [B,KV,G,Sq,Dh]
    return out.transpose(0, 3, 1, 2, 4).reshape(b, sq, h, dh).astype(q.dtype)


def decode_attention(q, k_cache, v_cache, cache_len) -> jnp.ndarray:
    """Single-step decode: q [B,1,H,Dh] vs cache [B,Smax,KV,Dh].

    ``cache_len`` [B] or scalar = number of valid cache entries (the new
    token's k/v must already be written at position cache_len-1).
    Grouped-head form: K/V are never expanded to H heads.
    """
    b, _, h, dh = q.shape
    kv = k_cache.shape[2]
    g = h // kv
    qg = q.reshape(b, kv, g, dh)
    # match the cache's Dh sharding: resharding q costs ~MBs, while GSPMD's
    # alternative (remat the 32k-context cache to head sharding) costs GBs
    # per layer ("Involuntary full rematerialization" warning).
    qg = shard_hint(qg, None, None, None, "model")
    scale = 1.0 / jnp.sqrt(dh).astype(jnp.float32)
    logits = jnp.einsum("bngd,bsnd->bngs", qg, k_cache,
                        preferred_element_type=jnp.float32) * scale
    kpos = jnp.arange(k_cache.shape[1])
    valid = kpos[None, :] < jnp.reshape(jnp.asarray(cache_len), (-1, 1))
    logits = jnp.where(valid[:, None, None, :], logits, NEG_INF)
    w = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bngs,bsnd->bngd", w.astype(v_cache.dtype), v_cache)
    return out.reshape(b, 1, h, dh)


def attention(q, k, v, *, causal: bool = True, q_offset=0,
              backend: str = "chunked", block_k: int = 512) -> jnp.ndarray:
    if backend == "naive":
        return naive_attention(q, k, v, causal=causal, q_offset=q_offset)
    if backend == "chunked":
        return chunked_attention(q, k, v, causal=causal, q_offset=q_offset,
                                 block_k=block_k)
    if backend == "pallas":
        from repro.kernels.flash_attention import ops as fa_ops
        return fa_ops.flash_attention(q, k, v, causal=causal,
                                      q_offset=q_offset)
    raise ValueError(f"unknown attention backend {backend!r}")
