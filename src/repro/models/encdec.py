"""Whisper-style encoder-decoder backbone (audio family).

The conv/log-mel frontend is a STUB per the assignment: ``input_specs``
supplies precomputed frame embeddings ``enc_embeds`` [B, enc_seq, D].
Encoder: bidirectional self-attention; decoder: causal self-attention +
cross-attention over the encoder output.  Decode keeps a KV cache for the
decoder self-attention plus the (static) encoder K/V.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import activation_hint, fsdp_params, shard_hint

from repro.util import scan as uscan

from . import attention as attn_mod
from .layers import (ModelConfig, Params, apply_rope, attn_init, embed_apply,
                     embed_init, mlp_apply, mlp_init, out_project,
                     qkv_project, rmsnorm_apply, rmsnorm_init, stack_params,
                     unembed_apply, unembed_init)
from .transformer import _positions


def encdec_init(key, cfg: ModelConfig) -> Params:
    n_enc = cfg.n_enc_layers or cfg.n_layers
    ks = jax.random.split(key, n_enc + 3 * cfg.n_layers + 3)
    enc = [{
        "ln1": rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attn_init(ks[i], cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg.dtype),
        "mlp": mlp_init(ks[n_enc + i], cfg),
    } for i in range(n_enc)]
    dec = [{
        "ln1": rmsnorm_init(cfg.d_model, cfg.dtype),
        "self_attn": attn_init(ks[2 * n_enc + 3 * i], cfg),
        "ln_x": rmsnorm_init(cfg.d_model, cfg.dtype),
        "cross_attn": attn_init(ks[2 * n_enc + 3 * i + 1], cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg.dtype),
        "mlp": mlp_init(ks[2 * n_enc + 3 * i + 2], cfg),
    } for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(ks[-3], cfg),
        "enc_layers": stack_params(enc),
        "enc_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "dec_layers": stack_params(dec),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "unembed": unembed_init(ks[-2], cfg),
    }


def encode(params: Params, enc_embeds: jnp.ndarray, cfg: ModelConfig,
           *, backend: str = "chunked", remat: bool = True) -> jnp.ndarray:
    x = enc_embeds.astype(cfg.dtype)
    batch = {"embeds": x}


    def one(x, lp):
        lp = {**lp, "attn": fsdp_params(lp["attn"], cfg),
              "mlp": fsdp_params(lp["mlp"], cfg)}
        h = rmsnorm_apply(lp["ln1"], x)
        q, k, v = qkv_project(lp["attn"], h, cfg)
        pos = _positions(batch, q.shape[1], 0)
        q, k = apply_rope(q, pos, cfg.rope_theta), apply_rope(k, pos, cfg.rope_theta)
        o = attn_mod.attention(q, k, v, causal=False, backend=backend)
        x = x + out_project(lp["attn"], o)
        x = x + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], x))
        return activation_hint(x), None

    f = jax.checkpoint(one, prevent_cse=False) if remat else one
    x, _ = uscan(f, x, params["enc_layers"])
    return rmsnorm_apply(params["enc_norm"], x)


def _dec_layer(lp, x, enc_out, cfg, batch, offset, *, backend):
    lp = {**lp, "self_attn": fsdp_params(lp["self_attn"], cfg),
          "cross_attn": fsdp_params(lp["cross_attn"], cfg),
          "mlp": fsdp_params(lp["mlp"], cfg)}
    h = rmsnorm_apply(lp["ln1"], x)
    q, k, v = qkv_project(lp["self_attn"], h, cfg)
    pos = _positions(batch, q.shape[1], offset)
    q, k = apply_rope(q, pos, cfg.rope_theta), apply_rope(k, pos, cfg.rope_theta)
    o = attn_mod.attention(q, k, v, causal=True, q_offset=offset,
                           backend=backend)
    x = x + out_project(lp["self_attn"], o)
    h = rmsnorm_apply(lp["ln_x"], x)
    q, k, v = qkv_project(lp["cross_attn"], h, cfg, kv_x=enc_out)
    o = attn_mod.attention(q, k, v, causal=False, backend=backend)
    x = x + out_project(lp["cross_attn"], o)
    x = x + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], x))
    return x


def encdec_apply(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig, *, backend: str = "chunked",
                 remat: bool = True, logits: bool = True
                 ) -> Dict[str, jnp.ndarray]:
    """batch: enc_embeds [B,Se,D] + tokens [B,Sd]."""
    enc_out = encode(params, batch["enc_embeds"], cfg, backend=backend,
                     remat=remat)
    x = embed_apply(params["embed"], batch["tokens"])


    def one(x, lp):
        x = _dec_layer(lp, x, enc_out, cfg, batch, 0, backend=backend)
        return activation_hint(x), None

    f = jax.checkpoint(one, prevent_cse=False) if remat else one
    x, _ = uscan(f, x, params["dec_layers"])
    x = rmsnorm_apply(params["final_norm"], x)
    out = {"hidden": x, "aux_loss": jnp.float32(0.0)}
    if logits:
        out["logits"] = unembed_apply(params["unembed"], params["embed"],
                                      x, cfg)
    return out


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------


def encdec_init_cache(cfg: ModelConfig, batch_size: int,
                      max_len: int) -> Params:
    kv = (cfg.n_layers, batch_size, max_len, cfg.n_kv, cfg.d_head)
    enc_kv = (cfg.n_layers, batch_size, cfg.enc_seq, cfg.n_kv, cfg.d_head)
    return {
        "k": jnp.zeros(kv, cfg.dtype), "v": jnp.zeros(kv, cfg.dtype),
        "enc_k": jnp.zeros(enc_kv, cfg.dtype),
        "enc_v": jnp.zeros(enc_kv, cfg.dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def encdec_prefill(params: Params, batch: Dict[str, jnp.ndarray],
                   cfg: ModelConfig, cache: Params, *,
                   backend: str = "chunked") -> Tuple[jnp.ndarray, Params]:
    """Encode audio, precompute cross K/V, run decoder prompt."""
    enc_out = encode(params, batch["enc_embeds"], cfg, backend=backend,
                     remat=False)
    x = embed_apply(params["embed"], batch["tokens"])
    s = x.shape[1]

    def one(x, scanned):
        lp, kc, vc, ekc, evc = scanned
        # precompute encoder K/V for this layer's cross-attention
        _, ek, ev = qkv_project(lp["cross_attn"], enc_out, cfg, kv_x=enc_out)
        h = rmsnorm_apply(lp["ln1"], x)
        q, k, v = qkv_project(lp["self_attn"], h, cfg)
        pos = _positions(batch, q.shape[1], 0)
        q2 = apply_rope(q, pos, cfg.rope_theta)
        k2 = apply_rope(k, pos, cfg.rope_theta)
        k2w = shard_hint(k2, ("pod", "data"), None, None, "model")
        vw = shard_hint(v, ("pod", "data"), None, None, "model")
        kc = jax.lax.dynamic_update_slice_in_dim(kc, k2w.astype(kc.dtype), 0, 1)
        vc = jax.lax.dynamic_update_slice_in_dim(vc, vw.astype(vc.dtype), 0, 1)
        o = attn_mod.attention(q2, k2, v, causal=True, backend=backend)
        x = x + out_project(lp["self_attn"], o)
        hq = rmsnorm_apply(lp["ln_x"], x)
        qx, _, _ = qkv_project(lp["cross_attn"], hq, cfg)
        o = attn_mod.attention(qx, ek, ev, causal=False, backend=backend)
        x = x + out_project(lp["cross_attn"], o)
        x = x + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], x))
        return x, (kc, vc, ek.astype(ekc.dtype), ev.astype(evc.dtype))

    x, (k_new, v_new, ek, ev) = uscan(
        one, x, (params["dec_layers"], cache["k"], cache["v"],
                 cache["enc_k"], cache["enc_v"]))
    x = rmsnorm_apply(params["final_norm"], x[:, -1:])
    logits = unembed_apply(params["unembed"], params["embed"], x, cfg)
    return logits, {"k": k_new, "v": v_new, "enc_k": ek, "enc_v": ev,
                    "len": jnp.full_like(cache["len"], s)}


def encdec_decode_step(params: Params, tokens: jnp.ndarray, cache: Params,
                       cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    x = embed_apply(params["embed"], tokens)
    pos = cache["len"]
    batch = {"tokens": tokens}

    def one(x, scanned):
        lp, kc, vc, ekc, evc = scanned
        h = rmsnorm_apply(lp["ln1"], x)
        q, k, v = qkv_project(lp["self_attn"], h, cfg)
        ppos = _positions(batch, 1, pos)
        q = apply_rope(q, ppos, cfg.rope_theta)
        k = apply_rope(k, ppos, cfg.rope_theta)
        b = k.shape[0]
        k = shard_hint(k, ("pod", "data"), None, None, "model")
        v = shard_hint(v, ("pod", "data"), None, None, "model")
        idx = jnp.reshape(pos, (b, 1))
        kc = kc.at[jnp.arange(b)[:, None], idx].set(k.astype(kc.dtype))
        vc = vc.at[jnp.arange(b)[:, None], idx].set(v.astype(vc.dtype))
        o = attn_mod.decode_attention(q, kc, vc, pos + 1)
        x = x + out_project(lp["self_attn"], o)
        hq = rmsnorm_apply(lp["ln_x"], x)
        qx, _, _ = qkv_project(lp["cross_attn"], hq, cfg)
        o = attn_mod.decode_attention(qx, ekc, evc,
                                      jnp.full((b,), ekc.shape[1]))
        x = x + out_project(lp["cross_attn"], o)
        x = x + mlp_apply(lp["mlp"], rmsnorm_apply(lp["ln2"], x))
        return x, (kc, vc)

    x, (k_new, v_new) = uscan(
        one, x, (params["dec_layers"], cache["k"], cache["v"],
                 cache["enc_k"], cache["enc_v"]))
    x = rmsnorm_apply(params["final_norm"], x)
    logits = unembed_apply(params["unembed"], params["embed"], x, cfg)
    return logits, {"k": k_new, "v": v_new, "enc_k": cache["enc_k"],
                    "enc_v": cache["enc_v"], "len": cache["len"] + 1}
