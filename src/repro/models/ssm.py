"""Mamba1 selective-state-space block (falcon-mamba / jamba mamba layers).

Training path: chunked parallel scan — sequence is split into chunks;
within a chunk the recurrence h_t = a_t*h_{t-1} + b_t is solved with
``jax.lax.associative_scan`` (vectorized, MXU-friendly); chunks are chained
with a small sequential ``lax.scan``.  Memory is O(B·Q·D_in·N) for chunk Q
instead of O(B·S·D_in·N).  The Pallas kernel in
``repro.kernels.selective_scan`` implements the same chunking on-TPU.

Decode path: O(1) per token — the SSM state [B, D_in, N] plus a conv ring
buffer IS the "KV cache" (why this family runs long_500k).
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from .layers import ModelConfig, Params, _dense_init


def dt_rank(cfg: ModelConfig) -> int:
    return max(1, -(-cfg.d_model // 16))


def mamba_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 8)
    d, di, n = cfg.d_model, cfg.d_inner, cfg.ssm_state
    r = dt_rank(cfg)
    # S4D-real initialization for A
    a = jnp.tile(jnp.arange(1, n + 1, dtype=jnp.float32)[None, :], (di, 1))
    dt_bias = jnp.log(jnp.exp(
        jnp.exp(jax.random.uniform(ks[6], (di,), jnp.float32,
                                   jnp.log(1e-3), jnp.log(1e-1)))) - 1.0 + 1e-9)
    return {
        "in_x": _dense_init(ks[0], d, di, cfg.dtype),
        "in_z": _dense_init(ks[1], d, di, cfg.dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.ssm_conv, di), jnp.float32)
                   * 0.1).astype(cfg.dtype),
        "conv_b": jnp.zeros((di,), cfg.dtype),
        "x_proj": _dense_init(ks[3], di, r + 2 * n, cfg.dtype),
        "dt_proj": _dense_init(ks[4], r, di, jnp.float32,
                               scale=r ** -0.5),
        "dt_bias": dt_bias,
        "a_log": jnp.log(a),
        "d_skip": jnp.ones((di,), jnp.float32),
        "out": _dense_init(ks[5], di, d, cfg.dtype),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                 init_state: jnp.ndarray | None = None) -> jnp.ndarray:
    """Depthwise causal conv along S. x: [B,S,Di]; w: [K,Di]."""
    k = w.shape[0]
    if init_state is None:
        xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        xp = jnp.concatenate([init_state.astype(x.dtype), x], axis=1)
    out = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
              for i in range(k))
    return out + b[None, None, :]


def _ssm_params(p: Params, x: jnp.ndarray, cfg: ModelConfig):
    """x: [B,S,Di] (post-conv, post-silu) -> dt, B, C tensors."""
    n, r = cfg.ssm_state, dt_rank(cfg)
    proj = x @ p["x_proj"]                                      # [B,S,r+2n]
    dt_in, bc = proj[..., :r], proj[..., r:]
    bmat, cmat = bc[..., :n], bc[..., n:]                       # [B,S,N]
    dt = jax.nn.softplus(dt_in.astype(jnp.float32) @ p["dt_proj"]
                         + p["dt_bias"])                        # [B,S,Di]
    return dt, bmat.astype(jnp.float32), cmat.astype(jnp.float32)


def _scan_chunked(a: jnp.ndarray, b: jnp.ndarray, h0: jnp.ndarray,
                  chunk: int) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Solve h_t = a_t h_{t-1} + b_t.  a,b: [B,S,Di,N]; h0: [B,Di,N].

    Returns (h [B,S,Di,N], h_last).  Chunked: sequential over S/chunk,
    parallel (associative_scan) within a chunk.
    """
    bsz, s, di, n = a.shape
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s
    if pad:
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad), (0, 0), (0, 0)))
    a = a.reshape(bsz, nchunk, chunk, di, n).transpose(1, 0, 2, 3, 4)
    b = b.reshape(bsz, nchunk, chunk, di, n).transpose(1, 0, 2, 3, 4)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def chunk_body(h, ab):
        ac, bc = ab                                              # [B,Q,Di,N]
        a_cum, b_cum = jax.lax.associative_scan(combine, (ac, bc), axis=1)
        h_chunk = a_cum * h[:, None] + b_cum                     # [B,Q,Di,N]
        return h_chunk[:, -1], h_chunk

    from repro.util import scan as _scan
    h_last, h = _scan(chunk_body, h0, (a, b))
    h = h.transpose(1, 0, 2, 3, 4).reshape(bsz, nchunk * chunk, di, n)
    return h[:, :s], h_last


def _fused_scan(dt, bmat, cmat, xc, a_neg, h0, chunk: int):
    """Chunked scan with IN-BODY discretization and output projection.

    Never materializes [B,S,Di,N] — only per-chunk [B,Q,Di,N] tensors —
    matching the Pallas kernel's VMEM-resident formulation.  Returns
    (y [B,S,Di] f32, h_last [B,Di,N])."""
    bsz, s, di = dt.shape
    nchunk = -(-s // chunk)
    pad = nchunk * chunk - s

    def pad3(v):
        return jnp.pad(v, ((0, 0), (0, pad), (0, 0))) if pad else v

    def chunks(v):
        return pad3(v).reshape(bsz, nchunk, chunk, v.shape[-1]) \
            .transpose(1, 0, 2, 3)

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    def body(h, inp):
        dt_c, b_c, c_c, xc_c = inp                # [B,Q,Di],[B,Q,N],...
        a_t = jnp.exp(dt_c[..., None] * a_neg[None, None])
        b_t = (dt_c * xc_c)[..., None] * b_c[:, :, None, :]
        a_cum, b_cum = jax.lax.associative_scan(combine, (a_t, b_t), axis=1)
        h_chunk = a_cum * h[:, None] + b_cum       # [B,Q,Di,N]
        y_c = jnp.einsum("bqdn,bqn->bqd", h_chunk, c_c)
        return h_chunk[:, -1], y_c

    from repro.util import scan as _scan
    h_last, y = _scan(body, h0,
                      (chunks(dt), chunks(bmat), chunks(cmat),
                       chunks(xc.astype(jnp.float32))))
    y = y.transpose(1, 0, 2, 3).reshape(bsz, nchunk * chunk, di)
    return y[:, :s], h_last


def mamba_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                chunk: int = 128) -> jnp.ndarray:
    """Full-sequence forward. x: [B,S,D] -> [B,S,D]."""
    xi = x @ p["in_x"]                                           # [B,S,Di]
    z = x @ p["in_z"]
    xc = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
    dt, bmat, cmat = _ssm_params(p, xc, cfg)
    h0 = jnp.zeros((x.shape[0], cfg.d_inner, cfg.ssm_state), jnp.float32)
    a_neg = -jnp.exp(p["a_log"])
    y, _ = _fused_scan(dt, bmat, cmat, xc, a_neg, h0, chunk)
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    return y @ p["out"]


# ---------------------------------------------------------------------------
# decode (stateful, O(1)/token)
# ---------------------------------------------------------------------------


def mamba_cache_init(cfg: ModelConfig, batch: int) -> Params:
    return {
        "h": jnp.zeros((batch, cfg.d_inner, cfg.ssm_state), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, cfg.d_inner),
                          jnp.float32),
    }


def mamba_decode_step(p: Params, x: jnp.ndarray, cache: Params,
                      cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    """x: [B,1,D]; cache: {'h','conv'} -> (y [B,1,D], new cache)."""
    xi = x @ p["in_x"]                                           # [B,1,Di]
    z = x @ p["in_z"]
    conv_in = jnp.concatenate([cache["conv"].astype(xi.dtype), xi], axis=1)
    k = p["conv_w"].shape[0]
    xc = sum(conv_in[:, i:i + 1, :] * p["conv_w"][i][None, None, :]
             for i in range(k)) + p["conv_b"][None, None, :]
    xc = jax.nn.silu(xc)                                         # [B,1,Di]
    dt, bmat, cmat = _ssm_params(p, xc, cfg)
    a_t = jnp.exp(dt[..., None] * (-jnp.exp(p["a_log"]))[None, None])
    b_t = (dt * xc.astype(jnp.float32))[..., None] * bmat[:, :, None, :]
    h = a_t[:, 0] * cache["h"] + b_t[:, 0]                       # [B,Di,N]
    y = jnp.einsum("bdn,bn->bd", h, cmat[:, 0])[:, None, :]
    y = y + xc.astype(jnp.float32) * p["d_skip"]
    y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
    new_cache = {"h": h, "conv": conv_in[:, 1:].astype(jnp.float32)}
    return y @ p["out"], new_cache
