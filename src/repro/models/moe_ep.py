"""Expert-parallel MoE via shard_map + explicit all-to-all (hillclimb H1).

The GSPMD-mediated dispatch in ``moe.moe_apply`` builds a GLOBAL-capacity
[E, C, D] buffer and lets the partitioner move it — measured at 123 s of
collective time for qwen3-moe × train_4k.  Real EP moves only the tokens:

  per device: route local tokens -> per-destination-shard send buffers
  -> all_to_all over 'model' -> local expert FFN -> all_to_all back
  -> combine with gates.

Wire per chip per layer = 2 x t_loc·k·D·bytes (there and back), fwd;
the transpose of all_to_all is all_to_all, so backward costs the same.

Requirements: ambient mesh with a 'model' axis, E % model_size == 0, and
the token batch divisible by the full mesh (the train_4k layout).  The
caller falls back to the dense path otherwise.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from .layers import ModelConfig, Params


def _mesh_info():
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return None
        return mesh
    except Exception:
        return None


def ep_applicable(cfg: ModelConfig, x: jnp.ndarray) -> bool:
    mesh = _mesh_info()
    if mesh is None or "model" not in mesh.axis_names:
        return False
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    m = sizes["model"]
    full = 1
    for s in mesh.axis_sizes:
        full *= s
    return (cfg.n_experts % m == 0 and m > 1
            and x.shape[0] % full == 0 and x.shape[0] >= full)


def _rank_by(dest: jnp.ndarray, n_bins: int, cap: int):
    """Sort-based rank of each element within its destination bin."""
    n = dest.shape[0]
    counts = jnp.zeros((n_bins,), jnp.int32).at[dest].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])
    order = jnp.argsort(dest, stable=True)
    rank_sorted = jnp.arange(n, dtype=jnp.int32) - offsets[dest[order]]
    rank = jnp.zeros((n,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap
    slot = dest * cap + jnp.where(keep, rank, 0)
    return slot, keep


def moe_apply_ep(p: Params, x: jnp.ndarray, cfg: ModelConfig
                 ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B,S,D] (batch divides the whole mesh) -> (out, aux)."""
    mesh = _mesh_info()
    axes = tuple(mesh.axis_names)
    sizes = dict(zip(mesh.axis_names, mesh.axis_sizes))
    m = sizes["model"]
    e, k, d = cfg.n_experts, cfg.top_k, cfg.d_model
    e_loc = e // m
    b, s, _ = x.shape

    x_spec = P(axes, None, None)
    w_spec = P("model", None, None)
    r_spec = P(None, None)

    def inner(xs, router, wi, wg, wo):
        # xs: [b_loc, S, D]; wi/wg/wo: [E_loc, ...]; router: [D, E]
        t_loc = xs.shape[0] * xs.shape[1]
        xt = xs.reshape(t_loc, d)
        logits = xt.astype(jnp.float32) @ router              # [t, E]
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert = jax.lax.top_k(probs, k)                # [t, k]
        gate = gate / jnp.maximum(
            jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
        me = jnp.mean(probs, axis=0)
        ce = jnp.zeros((e,), jnp.float32).at[expert.reshape(-1)].add(
            1.0) / (t_loc * k)
        aux = e * jnp.sum(me * ce)
        aux = jax.lax.pmean(aux, axes)

        flat_e = expert.reshape(-1)                           # [t*k]
        dest = flat_e // e_loc                                # model shard
        cap_send = max(8, -(-int(t_loc * k * cfg.capacity_factor / m)
                            // 8) * 8)
        slot, keep = _rank_by(dest, m, cap_send)
        tok_idx = jnp.repeat(jnp.arange(t_loc), k)
        dump = m * cap_send                    # +1 overflow slot
        slot_s = jnp.where(keep, slot, dump)

        send = jnp.zeros((m * cap_send + 1, d), xs.dtype)
        send = send.at[slot_s].add(jnp.where(keep[:, None], xt[tok_idx], 0))
        send_le = jnp.zeros((m * cap_send + 1,), jnp.int32).at[slot_s].max(
            jnp.where(keep, flat_e % e_loc, 0))
        send = send[:dump].reshape(m, cap_send, d)
        send_le = send_le[:dump].reshape(m, cap_send)

        # dispatch all-to-all over the expert axis
        recv = jax.lax.all_to_all(send, "model", split_axis=0,
                                  concat_axis=0, tiled=True)
        recv_le = jax.lax.all_to_all(send_le[..., None], "model",
                                     split_axis=0, concat_axis=0,
                                     tiled=True)[..., 0]
        rt = recv.reshape(m * cap_send, d)                    # local tokens
        rle = recv_le.reshape(m * cap_send)

        # second-stage bucket by local expert
        cap2 = max(8, -(-int(m * cap_send * 1.0 / e_loc) // 8) * 8) * 2
        slot2, keep2 = _rank_by(rle, e_loc, cap2)
        dump2 = e_loc * cap2
        slot2_s = jnp.where(keep2, slot2, dump2)
        buf = jnp.zeros((e_loc * cap2 + 1, d), xs.dtype)
        buf = buf.at[slot2_s].add(jnp.where(keep2[:, None], rt, 0))
        buf = buf[:dump2].reshape(e_loc, cap2, d)

        hg = jnp.einsum("ecd,edf->ecf", buf, wg,
                        preferred_element_type=jnp.float32)
        hi = jnp.einsum("ecd,edf->ecf", buf, wi,
                        preferred_element_type=jnp.float32)
        hh = (jax.nn.silu(hg) * hi).astype(xs.dtype)
        yb = jnp.einsum("ecf,efd->ecd", hh, wo,
                        preferred_element_type=jnp.float32
                        ).astype(xs.dtype)

        # un-bucket, return all-to-all, combine
        y_rt = yb.reshape(e_loc * cap2, d)[jnp.minimum(slot2, dump2 - 1)]
        y_rt = jnp.where(keep2[:, None], y_rt, 0)             # [m*cs, D]
        y_send = y_rt.reshape(m, cap_send, d)
        y_back = jax.lax.all_to_all(y_send, "model", split_axis=0,
                                    concat_axis=0, tiled=True)
        y_flat = jnp.where(
            keep[:, None],
            y_back.reshape(m * cap_send, d)[jnp.minimum(slot, dump - 1)],
            0)                                                # [t*k, D]
        w = jnp.where(keep, gate.reshape(-1), 0.0)[:, None]
        out = jnp.zeros((t_loc, d), jnp.float32).at[tok_idx].add(
            y_flat.astype(jnp.float32) * w)
        return out.reshape(xs.shape).astype(xs.dtype), aux

    out, aux = jax.shard_map(
        inner, mesh=mesh,
        in_specs=(x_spec, r_spec, w_spec, w_spec, w_spec),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["wi"], p["wg"], p["wo"])
    return out, aux
