"""Shared neural layers for the model zoo (pure JAX, init/apply style).

Params are nested dicts of jnp arrays; every ``*_init`` returns a pytree and
every ``*_apply`` is a pure function of (params, inputs).  Layer stacks are
built as *stacked* pytrees ([L, ...] leading axis) and consumed with
``jax.lax.scan`` so compile time is O(1) in depth.

Conventions: activations are ``[B, S, D]``; attention heads are packed as
``[B, S, H, Dh]``; all matmuls accumulate in f32 (``preferred_element_type``)
regardless of the bf16/f32 param dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

Params = Dict[str, Any]


# ---------------------------------------------------------------------------
# config
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One description covering every assigned architecture family."""

    name: str = "model"
    family: str = "dense"  # dense | moe | ssm | hybrid | vlm | audio
    n_layers: int = 2
    d_model: int = 128
    n_heads: int = 4
    n_kv: int = 2
    d_head: int = 32
    d_ff: int = 256
    vocab: int = 1024
    qk_norm: bool = False
    rope_theta: float = 1e6
    mrope: bool = False            # Qwen2-VL multimodal RoPE (3 position axes)
    tie_embeddings: bool = False
    # MoE
    n_experts: int = 0
    top_k: int = 0
    d_ff_expert: int = 0
    moe_every: int = 1             # MoE MLP every k-th layer (1 = all layers)
    capacity_factor: float = 1.25
    # SSM (Mamba1)
    ssm_state: int = 16
    ssm_conv: int = 4
    ssm_expand: int = 2
    # hybrid (Jamba): attention layer every `attn_every` layers
    attn_every: int = 0            # 0 = not hybrid
    # enc-dec (Whisper): encoder config
    n_enc_layers: int = 0
    enc_seq: int = 1500            # whisper: 30 s audio -> 1500 frames
    # frontend stubs
    frontend: str = "token"        # token | embed (precomputed frame/patch)
    dtype: Any = jnp.bfloat16
    # sharding mode: True = FSDP/ZeRO-3 (gather weights at use, cheap for
    # high tokens/device), False = Megatron-TP (all-reduce activations)
    fsdp: bool = True

    @property
    def d_inner(self) -> int:      # mamba inner width
        return self.ssm_expand * self.d_model

    @property
    def is_moe_arch(self) -> bool:
        return self.n_experts > 0

    def moe_layer(self, layer_idx: int) -> bool:
        return self.is_moe_arch and (layer_idx % self.moe_every == self.moe_every - 1)


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def _dense_init(key, d_in: int, d_out: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else (1.0 / jnp.sqrt(d_in))
    return (jax.random.normal(key, (d_in, d_out), jnp.float32) * scale).astype(dtype)


def rmsnorm_init(d: int, dtype) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(p: Params, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * p["scale"].astype(jnp.float32)).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (standard + M-RoPE)
# ---------------------------------------------------------------------------


def rope_freqs(d_head: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, d_head, 2, dtype=jnp.float32) / d_head))


def apply_rope(x: jnp.ndarray, pos: jnp.ndarray, theta: float) -> jnp.ndarray:
    """x: [B, S, H, Dh]; pos: [B, S] int32."""
    freqs = rope_freqs(x.shape[-1], theta)                    # [Dh/2]
    ang = pos[..., None].astype(jnp.float32) * freqs          # [B, S, Dh/2]
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, pos3: jnp.ndarray, theta: float,
                sections: Tuple[int, int, int] = (1, 1, 2)) -> jnp.ndarray:
    """Qwen2-VL M-RoPE: pos3 [B, S, 3] (t, h, w); frequency channels are
    partitioned between the three axes in `sections` proportion."""
    dh = x.shape[-1]
    half = dh // 2
    tot = sum(sections)
    n_t = half * sections[0] // tot
    n_h = half * sections[1] // tot
    freqs = rope_freqs(dh, theta)                              # [half]
    axis_of = jnp.concatenate([
        jnp.zeros((n_t,), jnp.int32),
        jnp.ones((n_h,), jnp.int32),
        jnp.full((half - n_t - n_h,), 2, jnp.int32),
    ])
    pos = jnp.take_along_axis(
        pos3.astype(jnp.float32),
        jnp.broadcast_to(axis_of[None, None, :], pos3.shape[:2] + (half,)),
        axis=-1)                                               # [B, S, half]
    ang = pos * freqs
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention projections
# ---------------------------------------------------------------------------


def attn_init(key, cfg: ModelConfig, cross: bool = False) -> Params:
    ks = jax.random.split(key, 6)
    d, h, kv, dh = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.d_head
    p = {
        "wq": _dense_init(ks[0], d, h * dh, cfg.dtype),
        "wk": _dense_init(ks[1], d, kv * dh, cfg.dtype),
        "wv": _dense_init(ks[2], d, kv * dh, cfg.dtype),
        "wo": _dense_init(ks[3], h * dh, d, cfg.dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = rmsnorm_init(dh, cfg.dtype)
        p["k_norm"] = rmsnorm_init(dh, cfg.dtype)
    return p


def qkv_project(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                kv_x: Optional[jnp.ndarray] = None):
    """Returns q [B,S,H,Dh], k/v [B,Skv,KV,Dh] (pre-RoPE, post-qk-norm)."""
    b, s, _ = x.shape
    kv_x = x if kv_x is None else kv_x
    skv = kv_x.shape[1]
    q = (x @ p["wq"]).reshape(b, s, cfg.n_heads, cfg.d_head)
    k = (kv_x @ p["wk"]).reshape(b, skv, cfg.n_kv, cfg.d_head)
    v = (kv_x @ p["wv"]).reshape(b, skv, cfg.n_kv, cfg.d_head)
    if cfg.qk_norm:
        q = rmsnorm_apply(p["q_norm"], q)
        k = rmsnorm_apply(p["k_norm"], k)
    return q, k, v


def out_project(p: Params, attn: jnp.ndarray) -> jnp.ndarray:
    b, s, h, dh = attn.shape
    return attn.reshape(b, s, h * dh) @ p["wo"]


# ---------------------------------------------------------------------------
# SwiGLU MLP
# ---------------------------------------------------------------------------


def mlp_init(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> Params:
    ks = jax.random.split(key, 3)
    d, f = cfg.d_model, d_ff or cfg.d_ff
    return {
        "wi": _dense_init(ks[0], d, f, cfg.dtype),
        "wg": _dense_init(ks[1], d, f, cfg.dtype),
        "wo": _dense_init(ks[2], f, d, cfg.dtype),
    }


def mlp_apply(p: Params, x: jnp.ndarray) -> jnp.ndarray:
    h = jax.nn.silu(x @ p["wg"]) * (x @ p["wi"])
    return h @ p["wo"]


# ---------------------------------------------------------------------------
# embeddings / unembedding
# ---------------------------------------------------------------------------


def embed_init(key, cfg: ModelConfig) -> Params:
    p = {"tok": (jax.random.normal(key, (cfg.vocab, cfg.d_model), jnp.float32)
                 * 0.02).astype(cfg.dtype)}
    return p


def embed_apply(p: Params, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(p["tok"], tokens, axis=0)


def unembed_init(key, cfg: ModelConfig) -> Params:
    if cfg.tie_embeddings:
        return {}
    return {"w": _dense_init(key, cfg.d_model, cfg.vocab, cfg.dtype, scale=0.02)}


def unembed_apply(p: Params, embed: Params, x: jnp.ndarray,
                  cfg: ModelConfig) -> jnp.ndarray:
    from repro.sharding.rules import shard_hint  # lazy: avoid cycle
    w = embed["tok"].T if cfg.tie_embeddings else p["w"]
    logits = jnp.einsum("bsd,dv->bsv", x, w,
                        preferred_element_type=jnp.float32)
    # [B,S,V] f32 is the single largest activation: keep it sharded.
    # FSDP mode: batch over every axis (vocab local); TP mode would put
    # vocab over 'model' instead.  shard_hint trims axes that don't divide.
    if getattr(cfg, "fsdp", True):
        return shard_hint(logits, ("pod", "data", "model"), None, None)
    return shard_hint(logits, ("pod", "data"), None, "model")


def stack_params(per_layer: list) -> Params:
    """[{...}, {...}] -> {...: [L, ...]} for lax.scan consumption."""
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_layer)
