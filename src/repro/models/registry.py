"""Model registry: family -> (init, apply, cache, prefill, decode) API.

``get_model(cfg)`` returns a ``ModelApi`` whose members close over the
config; ``input_specs(cfg, shape)`` builds the ShapeDtypeStruct stand-ins
used by smoke tests (with real arrays) and the multi-pod dry-run (with
abstract shapes, no allocation).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict

import jax
import jax.numpy as jnp

from . import encdec, hybrid, mamba_lm, transformer
from .layers import ModelConfig


@dataclasses.dataclass(frozen=True)
class ModelApi:
    cfg: ModelConfig
    init: Callable[..., Any]
    apply: Callable[..., Dict[str, jnp.ndarray]]
    init_cache: Callable[..., Any]
    prefill: Callable[..., Any]
    decode_step: Callable[..., Any]


_FAMILY_MODULES = {
    "dense": transformer, "moe": transformer, "vlm": transformer,
    "ssm": mamba_lm, "hybrid": hybrid, "audio": encdec,
}
_FNS = {
    transformer: ("lm_init", "lm_apply", "lm_init_cache", "lm_prefill",
                  "lm_decode_step"),
    mamba_lm: ("ssm_lm_init", "ssm_lm_apply", "ssm_lm_init_cache",
               "ssm_lm_prefill", "ssm_lm_decode_step"),
    hybrid: ("hybrid_init", "hybrid_apply", "hybrid_init_cache",
             "hybrid_prefill", "hybrid_decode_step"),
    encdec: ("encdec_init", "encdec_apply", "encdec_init_cache",
             "encdec_prefill", "encdec_decode_step"),
}


def get_model(cfg: ModelConfig) -> ModelApi:
    mod = _FAMILY_MODULES.get(cfg.family)
    if mod is None:
        raise ValueError(f"unknown family {cfg.family!r}")
    f_init, f_apply, f_cache, f_prefill, f_decode = \
        (getattr(mod, n) for n in _FNS[mod])
    return ModelApi(
        cfg=cfg,
        init=lambda key: f_init(key, cfg),
        apply=lambda params, batch, **kw: f_apply(params, batch, cfg, **kw),
        init_cache=lambda batch, max_len=0: f_cache(cfg, batch, max_len),
        prefill=lambda params, batch, cache, **kw:
            f_prefill(params, batch, cfg, cache, **kw),
        decode_step=lambda params, tokens, cache, **kw:
            f_decode(params, tokens, cache, cfg, **kw),
    )


# ---------------------------------------------------------------------------
# input specs per (config, shape)
# ---------------------------------------------------------------------------

I32 = jnp.int32


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_input_specs(cfg: ModelConfig, batch: int, seq: int
                      ) -> Dict[str, jax.ShapeDtypeStruct]:
    if cfg.family == "vlm" or cfg.frontend == "embed" and cfg.family != "audio":
        return {
            "embeds": _sds((batch, seq, cfg.d_model), cfg.dtype),
            "pos3": _sds((batch, seq, 3), I32),
            "labels": _sds((batch, seq), I32),
        }
    if cfg.family == "audio":
        return {
            "enc_embeds": _sds((batch, cfg.enc_seq, cfg.d_model), cfg.dtype),
            "tokens": _sds((batch, seq), I32),
            "labels": _sds((batch, seq), I32),
        }
    return {
        "tokens": _sds((batch, seq), I32),
        "labels": _sds((batch, seq), I32),
    }


def prefill_input_specs(cfg: ModelConfig, batch: int, seq: int):
    specs = train_input_specs(cfg, batch, seq)
    specs.pop("labels")
    return specs


def cache_specs(cfg: ModelConfig, batch: int, max_len: int):
    """ShapeDtypeStructs of the decode cache (no allocation)."""
    api = get_model(cfg)
    return jax.eval_shape(lambda: api.init_cache(batch, max_len))


def decode_input_specs(cfg: ModelConfig, batch: int):
    if cfg.family == "vlm":
        return {"embeds": _sds((batch, 1, cfg.d_model), cfg.dtype),
                "pos3": _sds((batch, 1, 3), I32)}
    return {"tokens": _sds((batch, 1), I32)}
