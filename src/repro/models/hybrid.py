"""Jamba-style hybrid: Mamba + attention interleaved 1:7, MoE every 2 layers.

The layer sequence has period ``attn_every`` (one attention layer per
period, position attn_every-1; all others Mamba).  MoE replaces the MLP on
every ``moe_every``-th layer.  Because the period structure is static, we
stack params PER PERIOD and ``lax.scan`` over periods — uniform pytrees,
O(1)-in-depth compile, heterogeneous layers inside the (unrolled) period.

Decode carries BOTH cache kinds: SSM state for mamba layers (O(1)) and a KV
cache for the few attention layers — why jamba runs long_500k.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import activation_hint, fsdp_params, shard_hint

from repro.util import scan as uscan

from . import attention as attn_mod
from .layers import (ModelConfig, Params, apply_rope, attn_init, embed_apply,
                     embed_init, mlp_apply, mlp_init, out_project,
                     qkv_project, rmsnorm_apply, rmsnorm_init, stack_params,
                     unembed_apply, unembed_init)
from .moe import moe_apply, moe_init
from .ssm import mamba_apply, mamba_decode_step, mamba_init
from .transformer import _positions


def _is_attn(cfg: ModelConfig, layer: int) -> bool:
    return layer % cfg.attn_every == cfg.attn_every - 1


def n_periods(cfg: ModelConfig) -> int:
    assert cfg.n_layers % cfg.attn_every == 0
    return cfg.n_layers // cfg.attn_every


def hybrid_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, cfg.n_layers * 2 + 3)
    periods = []
    for p0 in range(n_periods(cfg)):
        period = []
        for i in range(cfg.attn_every):
            layer = p0 * cfg.attn_every + i
            kk = ks[layer]
            lp: Params = {"ln1": rmsnorm_init(cfg.d_model, cfg.dtype),
                          "ln2": rmsnorm_init(cfg.d_model, cfg.dtype)}
            if _is_attn(cfg, layer):
                lp["attn"] = attn_init(kk, cfg)
            else:
                lp["mamba"] = mamba_init(kk, cfg)
            if cfg.moe_layer(layer):
                lp["moe"] = moe_init(ks[cfg.n_layers + layer], cfg)
            else:
                lp["mlp"] = mlp_init(ks[cfg.n_layers + layer], cfg)
            period.append(lp)
        periods.append(period)
    # stack over periods: each of the `attn_every` slots becomes [P, ...]
    stacked = [stack_params([periods[p][i] for p in range(n_periods(cfg))])
               for i in range(cfg.attn_every)]
    return {
        "embed": embed_init(ks[-3], cfg),
        "period": tuple(stacked),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "unembed": unembed_init(ks[-2], cfg),
    }


def _mixer(lp: Params, x, cfg: ModelConfig, batch, offset, *, backend):
    h = rmsnorm_apply(lp["ln1"], x)
    if "attn" in lp:
        lp = {**lp, "attn": fsdp_params(lp["attn"], cfg)}
        q, k, v = qkv_project(lp["attn"], h, cfg)
        pos = _positions(batch, q.shape[1], offset)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
        o = attn_mod.attention(q, k, v, causal=True, q_offset=offset,
                               backend=backend)
        x = x + out_project(lp["attn"], o)
    else:
        x = x + mamba_apply(fsdp_params(lp["mamba"], cfg), h, cfg)
    h = rmsnorm_apply(lp["ln2"], x)
    if "moe" in lp:
        m, aux = moe_apply(lp["moe"], h, cfg)
    else:
        m, aux = mlp_apply(fsdp_params(lp["mlp"], cfg), h), jnp.float32(0.0)
    return x + m, aux


def hybrid_apply(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig, *, backend: str = "chunked",
                 remat: bool = True, logits: bool = True
                 ) -> Dict[str, jnp.ndarray]:
    x = embed_apply(params["embed"], batch["tokens"])


    def one_layer(x, lp):
        x, a = _mixer(lp, x, cfg, batch, 0, backend=backend)
        return activation_hint(x), a

    # remat PER LAYER inside the period: checkpointing the whole 8-layer
    # period kept every layer's chunk-scan internals live (201 GiB/chip
    # measured on jamba train_4k)
    layer_f = jax.checkpoint(one_layer, prevent_cse=False)         if remat else one_layer

    def period_fn(carry, slot_params):
        x, aux = carry
        for i in range(cfg.attn_every):
            x, a = layer_f(x, slot_params[i])
            aux = aux + a
        return (x, aux), None

    f = period_fn
    (x, aux), _ = uscan(f, (x, jnp.float32(0.0)), params["period"])
    x = rmsnorm_apply(params["final_norm"], x)
    out = {"hidden": x, "aux_loss": aux / cfg.n_layers}
    if logits:
        out["logits"] = unembed_apply(params["unembed"], params["embed"],
                                      x, cfg)
    return out


# ---------------------------------------------------------------------------
# serve
# ---------------------------------------------------------------------------


def hybrid_init_cache(cfg: ModelConfig, batch_size: int,
                      max_len: int) -> Params:
    np_ = n_periods(cfg)
    kv = (np_, batch_size, max_len, cfg.n_kv, cfg.d_head)
    mamba_slots = [i for i in range(cfg.attn_every)
                   if not _is_attn(cfg, i)]
    return {
        "k": jnp.zeros(kv, cfg.dtype),
        "v": jnp.zeros(kv, cfg.dtype),
        "ssm": {f"slot{i}": {
            "h": jnp.zeros((np_, batch_size, cfg.d_inner, cfg.ssm_state),
                           jnp.float32),
            "conv": jnp.zeros((np_, batch_size, cfg.ssm_conv - 1,
                               cfg.d_inner), jnp.float32)}
            for i in mamba_slots},
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def hybrid_prefill(params: Params, batch: Dict[str, jnp.ndarray],
                   cfg: ModelConfig, cache: Params, *,
                   backend: str = "chunked") -> Tuple[jnp.ndarray, Params]:
    """Full-prompt forward filling both cache kinds (KV + SSM state)."""
    from .ssm import _causal_conv, _fused_scan, _ssm_params

    x = embed_apply(params["embed"], batch["tokens"])
    s = x.shape[1]

    def period_fn(x, scanned):
        slot_params, kc, vc, ssm = scanned
        new_ssm = {}
        for i in range(cfg.attn_every):
            lp = slot_params[i]
            h = rmsnorm_apply(lp["ln1"], x)
            if "attn" in lp:
                q, k, v = qkv_project(lp["attn"], h, cfg)
                pos = _positions(batch, q.shape[1], 0)
                q = apply_rope(q, pos, cfg.rope_theta)
                k = apply_rope(k, pos, cfg.rope_theta)
                kw_ = shard_hint(k, ("pod", "data"), None, None, "model")
                vw_ = shard_hint(v, ("pod", "data"), None, None, "model")
                kc = jax.lax.dynamic_update_slice_in_dim(
                    kc, kw_.astype(kc.dtype), 0, 1)
                vc = jax.lax.dynamic_update_slice_in_dim(
                    vc, vw_.astype(vc.dtype), 0, 1)
                o = attn_mod.attention(q, k, v, causal=True, backend=backend)
                x = x + out_project(lp["attn"], o)
            else:
                p = lp["mamba"]
                xi = h @ p["in_x"]
                z = h @ p["in_z"]
                xc = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
                dt, bmat, cmat = _ssm_params(p, xc, cfg)
                h0 = jnp.zeros((x.shape[0], cfg.d_inner, cfg.ssm_state),
                               jnp.float32)
                y, h_last = _fused_scan(dt, bmat, cmat, xc,
                                        -jnp.exp(p["a_log"]), h0, 128)
                y = y + xc.astype(jnp.float32) * p["d_skip"]
                y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
                x = x + y @ p["out"]
                kconv = p["conv_w"].shape[0]
                new_ssm[f"slot{i}"] = {
                    "h": h_last,
                    "conv": xi[:, s - (kconv - 1):, :].astype(jnp.float32)}
            h2 = rmsnorm_apply(lp["ln2"], x)
            if "moe" in lp:
                m, _ = moe_apply(lp["moe"], h2, cfg)
            else:
                m = mlp_apply(lp["mlp"], h2)
            x = x + m
        return x, (kc, vc, new_ssm)

    x, (k_new, v_new, ssm_new) = uscan(
        period_fn, x, (params["period"], cache["k"], cache["v"],
                       cache["ssm"]))
    x = rmsnorm_apply(params["final_norm"], x[:, -1:])
    logits = unembed_apply(params["unembed"], params["embed"], x, cfg)
    return logits, {"k": k_new, "v": v_new, "ssm": ssm_new,
                    "len": jnp.full_like(cache["len"], s)}


def hybrid_decode_step(params: Params, tokens: jnp.ndarray, cache: Params,
                       cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    x = embed_apply(params["embed"], tokens)
    pos = cache["len"]
    batch = {"tokens": tokens}

    def period_fn(x, scanned):
        slot_params, kc, vc, ssm = scanned
        new_ssm = {}
        for i in range(cfg.attn_every):
            lp = slot_params[i]
            h = rmsnorm_apply(lp["ln1"], x)
            if "attn" in lp:
                q, k, v = qkv_project(lp["attn"], h, cfg)
                ppos = _positions(batch, 1, pos)
                q = apply_rope(q, ppos, cfg.rope_theta)
                k = apply_rope(k, ppos, cfg.rope_theta)
                b = k.shape[0]
                k = shard_hint(k, ("pod", "data"), None, None, "model")
                v = shard_hint(v, ("pod", "data"), None, None, "model")
                idx = jnp.reshape(pos, (b, 1))
                kc = kc.at[jnp.arange(b)[:, None], idx].set(k.astype(kc.dtype))
                vc = vc.at[jnp.arange(b)[:, None], idx].set(v.astype(vc.dtype))
                o = attn_mod.decode_attention(q, kc, vc, pos + 1)
                x = x + out_project(lp["attn"], o)
            else:
                y, ns = mamba_decode_step(lp["mamba"], h, ssm[f"slot{i}"], cfg)
                new_ssm[f"slot{i}"] = ns
                x = x + y
            h = rmsnorm_apply(lp["ln2"], x)
            if "moe" in lp:
                m, _ = moe_apply(lp["moe"], h, cfg)
            else:
                m = mlp_apply(lp["mlp"], h)
            x = x + m
        return x, (kc, vc, new_ssm)

    x, (k_new, v_new, ssm_new) = uscan(
        period_fn, x, (params["period"], cache["k"], cache["v"],
                       cache["ssm"]))
    x = rmsnorm_apply(params["final_norm"], x)
    logits = unembed_apply(params["unembed"], params["embed"], x, cfg)
    return logits, {"k": k_new, "v": v_new, "ssm": ssm_new,
                    "len": cache["len"] + 1}
