"""Pure-JAX model zoo: dense/MoE/SSM/hybrid/VLM/enc-dec LM backbones."""
from .layers import ModelConfig
from .registry import (ModelApi, decode_input_specs, get_model,
                       prefill_input_specs, train_input_specs)

__all__ = ["ModelConfig", "ModelApi", "get_model", "train_input_specs",
           "prefill_input_specs", "decode_input_specs"]
