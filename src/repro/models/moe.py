"""Mixture-of-Experts layer: top-k router + capacity-bucketed dispatch.

Dispatch is sort-free gather/scatter into an ``[E, C, D]`` capacity buffer
(C = ceil(T·k/E · capacity_factor)) so the expert FFN is one dense
``[E,C,D] x [E,D,F]`` einsum — EP-shardable on the expert axis and O(T·k·D)
memory, unlike the GShard one-hot-einsum which materializes [T,E,C].

Tokens overflowing an expert's capacity are dropped (standard capacity-drop
semantics); the router keeps an aux load-balancing loss.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import shard_hint

from .layers import ModelConfig, Params, _dense_init


def moe_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    d, f, e = cfg.d_model, cfg.d_ff_expert, cfg.n_experts
    scale = 1.0 / jnp.sqrt(d)

    def experts(k, d_in, d_out):
        return (jax.random.normal(k, (e, d_in, d_out), jnp.float32)
                * (1.0 / jnp.sqrt(d_in))).astype(cfg.dtype)

    return {
        "router": _dense_init(ks[0], d, e, jnp.float32, scale),
        "wi": experts(ks[1], d, f),
        "wg": experts(ks[2], d, f),
        "wo": experts(ks[3], f, d),
    }


def _capacity(n_tokens: int, cfg: ModelConfig) -> int:
    c = int(n_tokens * cfg.top_k * cfg.capacity_factor / cfg.n_experts) + 1
    return max(8, -(-c // 8) * 8)  # round up to 8 for TPU-friendly shapes


def moe_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig
              ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """x: [B, S, D] -> (out [B, S, D], aux_loss scalar).

    Delegates to the shard_map expert-parallel path (explicit all-to-all
    dispatch, moe_ep.py) whenever the mesh/batch allow it; the dense
    GSPMD path below is the fallback (single device, TP decode, uneven
    batches)."""
    from .moe_ep import ep_applicable, moe_apply_ep
    if ep_applicable(cfg, x):
        return moe_apply_ep(p, x, cfg)
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(t, cfg)
    xt = x.reshape(t, d)

    logits = (xt.astype(jnp.float32) @ p["router"])               # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert = jax.lax.top_k(probs, k)                        # [T, k]
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # aux load-balance loss (Switch-style): E * sum_e f_e * P_e
    me = jnp.mean(probs, axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[expert.reshape(-1)].add(
        jnp.ones((t * k,), jnp.float32)) / (t * k)
    aux = e * jnp.sum(me * ce)

    # slot assignment: position of each (token, choice) within its expert,
    # via a stable sort (O(n log n)) — NOT the GShard one-hot cumsum,
    # whose reduce-window lowering costs O(n^2·E) in the XLA cost model.
    flat_e = expert.reshape(-1)                                   # [T*k]
    counts = jnp.zeros((e,), jnp.int32).at[flat_e].add(1)
    offsets = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                               jnp.cumsum(counts)[:-1]])          # [E] excl.
    order = jnp.argsort(flat_e, stable=True)                      # [T*k]
    sorted_e = flat_e[order]
    rank_sorted = jnp.arange(t * k, dtype=jnp.int32) - offsets[sorted_e]
    rank = jnp.zeros((t * k,), jnp.int32).at[order].set(rank_sorted)
    keep = rank < cap
    slot = flat_e * cap + jnp.where(keep, rank, 0)                # [T*k]

    # dispatch: scatter tokens into [E*C, D]
    tok_idx = jnp.repeat(jnp.arange(t), k)
    buf = jnp.zeros((e * cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, slot, e * cap - 1)].add(
        jnp.where(keep[:, None], xt[tok_idx], 0))
    buf = buf.reshape(e, cap, d)
    # EP: capacity buffers live expert-sharded on 'model'; the scatter
    # above is the (GSPMD-mediated) dispatch all-to-all
    buf = shard_hint(buf, "model", None, None)

    # expert FFN (one einsum pair; EP: shard axis 0)
    hg = jnp.einsum("ecd,edf->ecf", buf, p["wg"],
                    preferred_element_type=jnp.float32)
    hi = jnp.einsum("ecd,edf->ecf", buf, p["wi"],
                    preferred_element_type=jnp.float32)
    h = (jax.nn.silu(hg) * hi).astype(x.dtype)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"],
                         preferred_element_type=jnp.float32)
    out_buf = shard_hint(out_buf, "model", None, None)

    # combine: gather back each kept assignment, weight by its gate
    gathered = out_buf.reshape(e * cap, d)[slot]                  # [T*k, D]
    w = jnp.where(keep, gate.reshape(-1), 0.0)[:, None]
    out = jnp.zeros((t, d), jnp.float32).at[tok_idx].add(gathered * w)
    return out.reshape(b, s, d).astype(x.dtype), aux
