"""Decoder-only transformer LM (covers dense, moe and vlm families).

Layers are stacked pytrees consumed by ``lax.scan`` (O(1) compile time in
depth) with optional per-layer ``jax.checkpoint`` (remat).  Three entry
points per the serving split:

  * ``lm_apply``      — full-sequence training forward -> logits
  * ``lm_prefill``    — forward that also fills a KV cache
  * ``lm_decode_step``— one-token step against the cache

Input is either ``tokens`` [B,S] (LM) or ``embeds`` [B,S,D] (+ ``pos3``
[B,S,3] for M-RoPE) for the VLM/audio stub frontends.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import activation_hint, fsdp_params, shard_hint

from repro.util import scan as uscan

from . import attention as attn_mod
from .layers import (ModelConfig, Params, apply_mrope, apply_rope, attn_init,
                     embed_apply, embed_init, mlp_apply, mlp_init,
                     out_project, qkv_project, rmsnorm_apply, rmsnorm_init,
                     stack_params, unembed_apply, unembed_init)
from .moe import moe_apply, moe_init


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------


def layer_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, 4)
    p = {
        "ln1": rmsnorm_init(cfg.d_model, cfg.dtype),
        "attn": attn_init(ks[0], cfg),
        "ln2": rmsnorm_init(cfg.d_model, cfg.dtype),
    }
    if cfg.family == "moe" or (cfg.is_moe_arch and cfg.moe_every == 1):
        p["moe"] = moe_init(ks[1], cfg)
    else:
        p["mlp"] = mlp_init(ks[1], cfg)
    return p


def lm_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = [layer_init(ks[i], cfg) for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(ks[-3], cfg),
        "layers": stack_params(layers),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "unembed": unembed_init(ks[-2], cfg),
    }


# ---------------------------------------------------------------------------
# one layer
# ---------------------------------------------------------------------------


def _positions(batch: Dict[str, jnp.ndarray], s: int, offset) -> jnp.ndarray:
    return jnp.arange(s)[None, :] + jnp.reshape(jnp.asarray(offset), (-1, 1))


def _rope(cfg: ModelConfig, q, k, batch, offset):
    if cfg.mrope and "pos3" in batch:
        q = apply_mrope(q, batch["pos3"], cfg.rope_theta)
        k = apply_mrope(k, batch["pos3"], cfg.rope_theta)
    else:
        pos = _positions(batch, q.shape[1], offset)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    return q, k


def layer_apply(p: Params, x: jnp.ndarray, cfg: ModelConfig,
                batch: Dict[str, jnp.ndarray], *, backend: str = "chunked",
                causal: bool = True, offset=0
                ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Returns (x_out, aux_loss)."""
    attn_p = fsdp_params(p["attn"], cfg)
    h = rmsnorm_apply(p["ln1"], x)
    q, k, v = qkv_project(attn_p, h, cfg)
    q, k = _rope(cfg, q, k, batch, offset)
    o = attn_mod.attention(q, k, v, causal=causal, q_offset=offset,
                           backend=backend)
    x = x + out_project(attn_p, o)
    h = rmsnorm_apply(p["ln2"], x)
    if "moe" in p:
        m, aux = moe_apply(p["moe"], h, cfg)   # experts stay EP-sharded
    else:
        m, aux = mlp_apply(fsdp_params(p["mlp"], cfg), h), jnp.float32(0.0)
    return x + m, aux


# ---------------------------------------------------------------------------
# full-sequence forward (train)
# ---------------------------------------------------------------------------


def lm_apply(params: Params, batch: Dict[str, jnp.ndarray], cfg: ModelConfig,
             *, backend: str = "chunked", remat: bool = True,
             logits: bool = True) -> Dict[str, jnp.ndarray]:
    x = (embed_apply(params["embed"], batch["tokens"])
         if "tokens" in batch else batch["embeds"].astype(cfg.dtype))


    def one(carry, lp):
        x, aux = carry
        x, a = layer_apply(lp, x, cfg, batch, backend=backend)
        # FSDP: activations stay batch-sharded; GSPMD then all-gathers the
        # (model-sharded) weights per layer instead of all-reducing
        # activation partial sums (TP) — see DESIGN.md perf notes.
        x = activation_hint(x)
        return (x, aux + a), None

    f = jax.checkpoint(one, prevent_cse=False) if remat else one
    (x, aux), _ = uscan(f, (x, jnp.float32(0.0)), params["layers"])
    x = rmsnorm_apply(params["final_norm"], x)
    out = {"hidden": x, "aux_loss": aux / cfg.n_layers}
    if logits:
        out["logits"] = unembed_apply(params["unembed"], params["embed"],
                                      x, cfg)
    return out


# ---------------------------------------------------------------------------
# serve: KV cache prefill / decode
# ---------------------------------------------------------------------------


def lm_init_cache(cfg: ModelConfig, batch_size: int, max_len: int,
                  dtype=None) -> Params:
    dtype = dtype or cfg.dtype
    shape = (cfg.n_layers, batch_size, max_len, cfg.n_kv, cfg.d_head)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def _cached_layer(p, kv_cache_layer, x, cfg, batch, offset, cache_len,
                  *, backend):
    """One layer for prefill (writes cache) or decode (reads+writes)."""
    kc, vc = kv_cache_layer
    attn_p = fsdp_params(p["attn"], cfg)
    h = rmsnorm_apply(p["ln1"], x)
    q, k, v = qkv_project(attn_p, h, cfg)
    q, k = _rope(cfg, q, k, batch, offset)
    s = x.shape[1]
    # write k/v in the CACHE's layout (batch over data, Dh over 'model'):
    # resharding the [B,S,KV,Dh] update is MBs; letting GSPMD reshard the
    # [L,B,Smax,KV,Dh] cache instead is GBs per layer.
    kw_ = shard_hint(k, ("pod", "data"), None, None, "model")
    vw_ = shard_hint(v, ("pod", "data"), None, None, "model")
    kc = jax.lax.dynamic_update_slice_in_dim(kc, kw_.astype(kc.dtype),
                                             offset, axis=1) \
        if isinstance(offset, int) else _scatter_kv(kc, kw_, offset)
    vc = jax.lax.dynamic_update_slice_in_dim(vc, vw_.astype(vc.dtype),
                                             offset, axis=1) \
        if isinstance(offset, int) else _scatter_kv(vc, vw_, offset)
    if s == 1:
        o = attn_mod.decode_attention(q, kc, vc, cache_len)
    else:
        o = attn_mod.attention(q, k, v, causal=True, q_offset=offset,
                               backend=backend)
    x = x + out_project(attn_p, o)
    h = rmsnorm_apply(p["ln2"], x)
    if "moe" in p:
        m, _ = moe_apply(p["moe"], h, cfg)
    else:
        m = mlp_apply(fsdp_params(p["mlp"], cfg), h)
    return x + m, (kc, vc)


def _scatter_kv(cache, new, pos):
    """Per-batch-row scatter at positions `pos` [B] (ragged decode)."""
    b = new.shape[0]
    idx = jnp.reshape(pos, (b, 1))
    return cache.at[jnp.arange(b)[:, None], idx].set(
        new.astype(cache.dtype))


def lm_prefill(params: Params, batch: Dict[str, jnp.ndarray],
               cfg: ModelConfig, cache: Params, *,
               backend: str = "chunked") -> Tuple[jnp.ndarray, Params]:
    """Full-prompt forward; fills cache[: , :S]; returns last-pos logits."""
    x = (embed_apply(params["embed"], batch["tokens"])
         if "tokens" in batch else batch["embeds"].astype(cfg.dtype))
    s = x.shape[1]


    def one(x, lp_kv):
        lp, kc, vc = lp_kv
        x, (kc, vc) = _cached_layer(lp, (kc, vc), x, cfg, batch, 0,
                                    None, backend=backend)
        return activation_hint(x), (kc, vc)

    x, (k_new, v_new) = uscan(
        one, x, (params["layers"], cache["k"], cache["v"]))
    x = rmsnorm_apply(params["final_norm"], x[:, -1:])
    logits = unembed_apply(params["unembed"], params["embed"], x, cfg)
    new_cache = {"k": k_new, "v": v_new,
                 "len": jnp.full_like(cache["len"], s)}
    return logits, new_cache


def lm_decode_step(params: Params, tokens: jnp.ndarray, cache: Params,
                   cfg: ModelConfig,
                   batch_extra: Optional[Dict[str, jnp.ndarray]] = None
                   ) -> Tuple[jnp.ndarray, Params]:
    """tokens [B,1] (or embeds [B,1,D] under key 'embeds' in batch_extra)."""
    batch = dict(batch_extra or {})
    if tokens is not None:
        batch["tokens"] = tokens
    x = (embed_apply(params["embed"], batch["tokens"])
         if "tokens" in batch else batch["embeds"].astype(cfg.dtype))
    pos = cache["len"]                                           # [B]

    # decode positions: RoPE offset = current length (per row)
    def one_fixed(x, lp_kv):
        lp, kc, vc = lp_kv
        x, (kc, vc) = _cached_layer(lp, (kc, vc), x, cfg, batch,
                                    pos, pos + 1, backend="naive")
        return x, (kc, vc)

    x, (k_new, v_new) = uscan(
        one_fixed, x, (params["layers"], cache["k"], cache["v"]))
    x = rmsnorm_apply(params["final_norm"], x)
    logits = unembed_apply(params["unembed"], params["embed"], x, cfg)
    return logits, {"k": k_new, "v": v_new, "len": cache["len"] + 1}
