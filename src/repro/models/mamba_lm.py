"""Falcon-Mamba-style attention-free LM: embed + N mamba blocks + head.

Mamba1 layers have no separate MLP — the block IS the layer (as in
falcon-mamba / mamba1).  Decode state is O(1) per token, so this family
runs the ``long_500k`` shape.
"""
from __future__ import annotations

from typing import Dict, Tuple

import jax
import jax.numpy as jnp

from repro.sharding.rules import activation_hint, fsdp_params

from repro.util import scan as uscan

from .layers import (ModelConfig, Params, embed_apply, embed_init,
                     rmsnorm_apply, rmsnorm_init, stack_params,
                     unembed_apply, unembed_init)
from .ssm import mamba_apply, mamba_cache_init, mamba_decode_step, mamba_init


def ssm_lm_init(key, cfg: ModelConfig) -> Params:
    ks = jax.random.split(key, cfg.n_layers + 3)
    layers = [{
        "ln": rmsnorm_init(cfg.d_model, cfg.dtype),
        "mamba": mamba_init(ks[i], cfg),
    } for i in range(cfg.n_layers)]
    return {
        "embed": embed_init(ks[-3], cfg),
        "layers": stack_params(layers),
        "final_norm": rmsnorm_init(cfg.d_model, cfg.dtype),
        "unembed": unembed_init(ks[-2], cfg),
    }


def ssm_lm_apply(params: Params, batch: Dict[str, jnp.ndarray],
                 cfg: ModelConfig, *, backend: str = "chunked",
                 remat: bool = True, logits: bool = True
                 ) -> Dict[str, jnp.ndarray]:
    x = embed_apply(params["embed"], batch["tokens"])



    def one(x, lp):
        x = x + mamba_apply(fsdp_params(lp["mamba"], cfg),
                            rmsnorm_apply(lp["ln"], x), cfg)
        return activation_hint(x), None

    f = jax.checkpoint(one, prevent_cse=False) if remat else one
    x, _ = uscan(f, x, params["layers"])
    x = rmsnorm_apply(params["final_norm"], x)
    out = {"hidden": x, "aux_loss": jnp.float32(0.0)}
    if logits:
        out["logits"] = unembed_apply(params["unembed"], params["embed"],
                                      x, cfg)
    return out


def ssm_lm_init_cache(cfg: ModelConfig, batch_size: int,
                      max_len: int = 0) -> Params:
    per = mamba_cache_init(cfg, batch_size)
    return {
        "h": jnp.zeros((cfg.n_layers,) + per["h"].shape, jnp.float32),
        "conv": jnp.zeros((cfg.n_layers,) + per["conv"].shape, jnp.float32),
        "len": jnp.zeros((batch_size,), jnp.int32),
    }


def ssm_lm_prefill(params: Params, batch: Dict[str, jnp.ndarray],
                   cfg: ModelConfig, cache: Params, *,
                   backend: str = "chunked") -> Tuple[jnp.ndarray, Params]:
    """Run the prompt through scan-over-tokens per layer, keeping final state.

    For SSM, prefill = full forward while retaining (h, conv) at the end of
    the prompt; we reuse the chunked scan and extract the final state.
    """
    from .ssm import _causal_conv, _fused_scan, _ssm_params

    x = embed_apply(params["embed"], batch["tokens"])
    s = x.shape[1]

    def one(x, lp_cache):
        lp = lp_cache
        h_in = rmsnorm_apply(lp["ln"], x)
        p = lp["mamba"]
        xi = h_in @ p["in_x"]
        z = h_in @ p["in_z"]
        xc = jax.nn.silu(_causal_conv(xi, p["conv_w"], p["conv_b"]))
        dt, bmat, cmat = _ssm_params(p, xc, cfg)
        h0 = jnp.zeros((x.shape[0], cfg.d_inner, cfg.ssm_state), jnp.float32)
        y, h_last = _fused_scan(dt, bmat, cmat, xc,
                                -jnp.exp(p["a_log"]), h0, 128)
        y = y + xc.astype(jnp.float32) * p["d_skip"]
        y = (y * jax.nn.silu(z.astype(jnp.float32))).astype(x.dtype)
        x = x + y @ p["out"]
        k = p["conv_w"].shape[0]
        conv_state = xi[:, s - (k - 1):, :].astype(jnp.float32)
        return x, (h_last, conv_state)

    x, (h_new, conv_new) = uscan(one, x, params["layers"])
    x = rmsnorm_apply(params["final_norm"], x[:, -1:])
    logits = unembed_apply(params["unembed"], params["embed"], x, cfg)
    return logits, {"h": h_new, "conv": conv_new,
                    "len": jnp.full_like(cache["len"], s)}


def ssm_lm_decode_step(params: Params, tokens: jnp.ndarray, cache: Params,
                       cfg: ModelConfig) -> Tuple[jnp.ndarray, Params]:
    x = embed_apply(params["embed"], tokens)

    def one(x, lp_state):
        lp, h, conv = lp_state
        y, ns = mamba_decode_step(lp["mamba"], rmsnorm_apply(lp["ln"], x),
                                  {"h": h, "conv": conv}, cfg)
        return x + y, (ns["h"], ns["conv"])

    x, (h_new, conv_new) = uscan(
        one, x, (params["layers"], cache["h"], cache["conv"]))
    x = rmsnorm_apply(params["final_norm"], x)
    logits = unembed_apply(params["unembed"], params["embed"], x, cfg)
    return logits, {"h": h_new, "conv": conv_new, "len": cache["len"] + 1}
