"""moonshot-v1-16b-a3b [moe]: 48L d_model=2048 16H (kv=16, MHA) expert
d_ff=1408 vocab=163840, MoE 64 experts top-6 (kimi/moonlight lineage).

[hf:moonshotai/Moonlight-16B-A3B; hf-verified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv=16, d_head=128,
    d_ff=1408, vocab=163840, rope_theta=5e4,
    n_experts=64, top_k=6, d_ff_expert=1408, moe_every=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="moonshot-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
        d_ff=64, vocab=256, n_experts=8, top_k=2, d_ff_expert=64)
