"""jamba-v0.1-52b [hybrid]: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=65536, Mamba:attention 7:1 interleave (attn_every=8 -> 4 attention
layers), MoE 16 experts top-2 on every 2nd layer.  Runs long_500k.

[arXiv:2403.19887; hf-verified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="jamba-v0.1-52b", family="hybrid",
    n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_head=128,
    d_ff=14336, vocab=65536, attn_every=8,
    n_experts=16, top_k=2, d_ff_expert=14336, moe_every=2,
    ssm_state=16, ssm_conv=4, ssm_expand=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="jamba-smoke", family="hybrid",
        n_layers=4, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, attn_every=2,
        n_experts=4, top_k=2, d_ff_expert=128, moe_every=2,
        ssm_state=4, ssm_conv=4, ssm_expand=2)
