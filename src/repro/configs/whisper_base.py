"""whisper-base [audio]: 6L d_model=512 8H d_ff=2048 vocab=51865, enc-dec.

Conv/log-mel frontend is a STUB: input_specs supplies precomputed frame
embeddings [B, 1500, 512].  long_500k skipped (full attention).

[arXiv:2212.04356; unverified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv=8, d_head=64,
    d_ff=2048, vocab=51865, n_enc_layers=6, enc_seq=1500,
    frontend="embed",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-smoke", family="audio",
        n_layers=2, d_model=64, n_heads=4, n_kv=4, d_head=16,
        d_ff=128, vocab=256, n_enc_layers=2, enc_seq=16,
        frontend="embed")
