"""granite-3-2b [dense]: 40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.

[hf:ibm-granite/granite-3.0-2b-base; hf-verified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b", family="dense",
    n_layers=40, d_model=2048, n_heads=32, n_kv=8, d_head=64,
    d_ff=8192, vocab=49155, rope_theta=1e7, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="granite-3-2b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, tie_embeddings=True)
