"""qwen3-moe-30b-a3b [moe]: 48L d_model=2048 32H (GQA kv=4) expert d_ff=768
vocab=151936, MoE 128 experts top-8, qk_norm.

[hf:Qwen/Qwen3-30B-A3B; hf-verified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-moe-30b-a3b", family="moe",
    n_layers=48, d_model=2048, n_heads=32, n_kv=4, d_head=128,
    d_ff=768, vocab=151936, qk_norm=True, rope_theta=1e6,
    n_experts=128, top_k=8, d_ff_expert=768, moe_every=1,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-moe-smoke", family="moe",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=32, vocab=256, qk_norm=True,
        n_experts=8, top_k=2, d_ff_expert=32)
