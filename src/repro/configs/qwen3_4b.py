"""qwen3-4b [dense]: 36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936.

qk_norm + GQA, head_dim=128 (Qwen3 decouples head_dim from d_model/n_heads).
[hf:Qwen/Qwen3-8B family; hf-verified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b", family="dense",
    n_layers=36, d_model=2560, n_heads=32, n_kv=8, d_head=128,
    d_ff=9728, vocab=151936, qk_norm=True, rope_theta=1e6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-4b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, qk_norm=True)
