"""falcon-mamba-7b [ssm]: 64L d_model=4096 attention-free vocab=65024,
ssm_state=16 (mamba1 architecture). Runs long_500k (O(1) decode state).

[arXiv:2410.05355; unverified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="falcon-mamba-7b", family="ssm",
    n_layers=64, d_model=4096, n_heads=0, n_kv=0, d_head=0,
    d_ff=0, vocab=65024, ssm_state=16, ssm_conv=4, ssm_expand=2,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="falcon-mamba-smoke", family="ssm",
        n_layers=2, d_model=64, n_heads=0, n_kv=0, d_head=0,
        d_ff=0, vocab=256, ssm_state=4, ssm_conv=4, ssm_expand=2)
