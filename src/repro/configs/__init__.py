"""Assigned-architecture configs (``--arch <id>``) + shape grid.

Every module defines ``CONFIG`` (the exact published dims) and
``smoke_config()`` (a reduced same-family config for CPU tests).
``SHAPES`` is the assignment's shared shape grid; ``shape_applies``
encodes the long_500k / decode skips per family.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, List, Tuple

from repro.models.layers import ModelConfig

ARCH_IDS: Tuple[str, ...] = (
    "qwen3-4b", "yi-6b", "granite-3-2b", "llama3.2-3b",
    "moonshot-v1-16b-a3b", "qwen3-moe-30b-a3b", "falcon-mamba-7b",
    "qwen2-vl-72b", "whisper-base", "jamba-v0.1-52b",
)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

# long_500k needs sub-quadratic attention: only SSM/hybrid run it
LONG_OK_FAMILIES = ("ssm", "hybrid")


def _modname(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_modname(arch_id)}")
    return mod.CONFIG


def get_smoke_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_modname(arch_id)}")
    return mod.smoke_config()


def shape_applies(cfg: ModelConfig, shape: str) -> Tuple[bool, str]:
    """(applies?, reason-if-not)."""
    if shape == "long_500k" and cfg.family not in LONG_OK_FAMILIES:
        return False, ("524k dense attention is the quadratic case the "
                       "assignment says to skip (full-attention family)")
    return True, ""


def all_cells() -> List[Tuple[str, str]]:
    """The 40 (arch, shape) cells; skipped cells still appear (marked N/A
    downstream via shape_applies)."""
    return [(a, s) for a in ARCH_IDS for s in SHAPES]
