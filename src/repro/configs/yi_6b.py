"""yi-6b [dense]: 32L d_model=4096 32H (GQA kv=4) d_ff=11008 vocab=64000.

llama-architecture GQA. [arXiv:2403.04652; hf-verified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b", family="dense",
    n_layers=32, d_model=4096, n_heads=32, n_kv=4, d_head=128,
    d_ff=11008, vocab=64000, rope_theta=5e6,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="yi-6b-smoke", family="dense",
        n_layers=2, d_model=64, n_heads=4, n_kv=1, d_head=16,
        d_ff=128, vocab=256)
