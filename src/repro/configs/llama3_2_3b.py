"""llama3.2-3b [dense]: 28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.

[hf:meta-llama/Llama-3.2-3B; unverified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b", family="dense",
    n_layers=28, d_model=3072, n_heads=24, n_kv=8, d_head=128,
    d_ff=8192, vocab=128256, rope_theta=5e5, tie_embeddings=True,
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="llama3.2-3b-smoke", family="dense",
        n_layers=2, d_model=48, n_heads=3, n_kv=1, d_head=16,
        d_ff=96, vocab=256, tie_embeddings=True)
