"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064, M-RoPE.  Backbone only: the vision frontend is a stub —
input_specs supplies pre-merged text+vision embeddings [B,S,D] plus
3x[B,S] M-RoPE position ids.

[arXiv:2409.12191; hf-verified tier]
"""
from repro.models.layers import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-vl-72b", family="vlm",
    n_layers=80, d_model=8192, n_heads=64, n_kv=8, d_head=128,
    d_ff=29568, vocab=152064, mrope=True, rope_theta=1e6,
    frontend="embed",
)


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-smoke", family="vlm",
        n_layers=2, d_model=64, n_heads=4, n_kv=2, d_head=16,
        d_ff=128, vocab=256, mrope=True, frontend="embed")
