"""Public GQA flash-attention wrapper over the Pallas kernel.

Accepts the model zoo layout q [B,S,H,Dh], k/v [B,Skv,KV,Dh]; expands kv
heads, folds (B, H) into the kernel's grid dim, unfolds the result.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import flash_attention_bhsd


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("causal", "q_offset", "bq", "bk",
                                   "interpret"))
def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, q_offset: int = 0,
                    bq: int = 128, bk: int = 128,
                    interpret: bool | None = None) -> jnp.ndarray:
    interpret = _default_interpret() if interpret is None else interpret
    b, sq, h, dh = q.shape
    skv, kv = k.shape[1], k.shape[2]
    g = h // kv
    kx = jnp.repeat(k, g, axis=2) if g > 1 else k
    vx = jnp.repeat(v, g, axis=2) if g > 1 else v
    qf = q.transpose(0, 2, 1, 3).reshape(b * h, sq, dh)
    kf = kx.transpose(0, 2, 1, 3).reshape(b * h, skv, dh)
    vf = vx.transpose(0, 2, 1, 3).reshape(b * h, skv, dh)
    of = flash_attention_bhsd(qf, kf, vf, causal=causal, q_offset=q_offset,
                              bq=bq, bk=bk, interpret=interpret)
    return of.reshape(b, h, sq, dh).transpose(0, 2, 1, 3)
