"""Oracle: the model zoo's naive full-materialization attention."""
from repro.models.attention import naive_attention  # noqa: F401
