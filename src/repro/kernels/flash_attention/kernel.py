"""Blockwise (flash) causal attention Pallas kernel.

Grid: (batch*q_heads, num_q_blocks, num_k_blocks) — k innermost so the
online-softmax state (m, l, acc) persists in VMEM scratch across the k
sweep of one q block.  Causality skips fully-masked k blocks with
``pl.when`` (no MXU work past the diagonal).

Tiling: q block (bq, dh), k/v blocks (bk, dh); with dh=128 and bq=bk=128
both matmuls are MXU-aligned.  GQA is handled by the wrapper (ops.py)
mapping q-head -> kv-head before the call, so the kernel sees matched
head streams.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
               scale: float, bq: int, bk: int, causal: bool,
               q_offset: int, n_kblocks: int, skv: int):
    kb = pl.program_id(2)

    @pl.when(kb == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    qb = pl.program_id(1)
    q_start = qb * bq + q_offset            # absolute position of q row 0
    k_start = kb * bk

    # skip k blocks that lie entirely above the causal diagonal
    run = (k_start <= q_start + bq - 1) if causal else (kb >= 0)

    @pl.when(run)
    def _body():
        q = q_ref[0].astype(jnp.float32)            # [bq, dh]
        k = k_ref[0].astype(jnp.float32)            # [bk, dh]
        v = v_ref[0].astype(jnp.float32)            # [bk, dh]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = kpos < skv                           # kv padding
        if causal:
            qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            mask = mask & (qpos >= kpos)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_scr[...]                          # [bq, 1]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1, keepdims=True))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new)                       # [bq, bk]
        l_scr[...] = l_scr[...] * alpha + jnp.sum(p, axis=1, keepdims=True)
        m_scr[...] = m_new
        acc_scr[...] = acc_scr[...] * alpha + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(kb == n_kblocks - 1)
    def _finalize():
        o_ref[0] = (acc_scr[...]
                    / jnp.maximum(l_scr[...], 1e-30)).astype(o_ref.dtype)


@partial(jax.jit,
         static_argnames=("causal", "bq", "bk", "interpret", "q_offset"))
def flash_attention_bhsd(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                         causal: bool = True, q_offset: int = 0,
                         bq: int = 128, bk: int = 128,
                         interpret: bool = False) -> jnp.ndarray:
    """q: [BH, Sq, Dh]; k/v: [BH, Skv, Dh] (kv already expanded per q head).

    Returns [BH, Sq, Dh].  Sequence dims padded to block multiples inside.
    """
    bh, sq, dh = q.shape
    skv = k.shape[1]
    bq_, bk_ = min(bq, sq), min(bk, skv)
    sqp, skp = -(-sq // bq_) * bq_, -(-skv // bk_) * bk_
    qp = jnp.pad(q, ((0, 0), (0, sqp - sq), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, skp - skv), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, skp - skv), (0, 0)))
    n_kblocks = skp // bk_
    grid = (bh, sqp // bq_, n_kblocks)
    out = pl.pallas_call(
        partial(_fa_kernel, scale=dh ** -0.5, bq=bq_, bk=bk_, causal=causal,
                q_offset=q_offset, n_kblocks=n_kblocks, skv=skv),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bq_, dh), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk_, dh), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk_, dh), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, dh), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, sqp, dh), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, 1), jnp.float32),    # m
            pltpu.VMEM((bq_, 1), jnp.float32),    # l
            pltpu.VMEM((bq_, dh), jnp.float32),   # acc
        ],
        interpret=interpret,
    )(qp, kp, vp)
    return out[:, :sq]
