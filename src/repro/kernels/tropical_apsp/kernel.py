"""Tropical (min-plus) matmul Pallas kernel — the SDN controller's APSP.

Dijkstra's relaxation is sequential pointer-chasing; on TPU we recast
all-pairs shortest paths as log2(diameter) squarings in the (min, +)
semiring:  D'[i,j] = min_k D[i,k] + D[k,j].

One squaring is a dense "matmul" with (+ -> min, * -> +): perfectly
systolic-shaped, tiled exactly like an MXU matmul.  BlockSpec tiles
(bm, bk) x (bk, bn) operand blocks into VMEM; the K-axis is the innermost
grid dim so the output block stays resident while partial mins accumulate.

TPU lowering note: min-plus contractions run on the VPU (vector min/add),
not the MXU — but the tiling/data-movement pattern (and roofline) is that
of a matmul, so the same block shapes apply (multiples of 8x128 lanes).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BIG = 3.4e38  # stand-in for +inf (python float so the kernel body does not
              # capture a traced constant; finite BIG is fastmath-robust)


def _minplus_kernel(x_ref, y_ref, o_ref):
    """One (bm, bn) output block; K-grid accumulates mins in-place."""
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.full_like(o_ref, BIG)

    x = x_ref[...]                       # [bm, bk]
    y = y_ref[...]                       # [bk, bn]
    # broadcast-add then reduce-min over k: [bm, bk, bn] -> [bm, bn]
    s = x[:, :, None] + y[None, :, :]
    o_ref[...] = jnp.minimum(o_ref[...], jnp.min(s, axis=1))


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def minplus_matmul(x: jnp.ndarray, y: jnp.ndarray, *, bm: int = 128,
                   bn: int = 128, bk: int = 128,
                   interpret: bool = False) -> jnp.ndarray:
    """Z[i,j] = min_k X[i,k] + Y[k,j].  Pads to block multiples with BIG."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    mp, np_, kp = -(-m // bm) * bm, -(-n // bn) * bn, -(-k // bk) * bk
    xp = jnp.pad(x, ((0, mp - m), (0, kp - k)), constant_values=BIG)
    yp = jnp.pad(y, ((0, kp - k), (0, np_ - n)), constant_values=BIG)
    grid = (mp // bm, np_ // bn, kp // bk)
    out = pl.pallas_call(
        _minplus_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=interpret,
    )(xp.astype(jnp.float32), yp.astype(jnp.float32))
    return out[:m, :n]
