"""Pure-jnp oracle for the min-plus matmul / APSP."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np


def minplus_matmul_ref(x: jnp.ndarray, y: jnp.ndarray) -> jnp.ndarray:
    return jnp.min(x[:, :, None].astype(jnp.float32)
                   + y[None, :, :].astype(jnp.float32), axis=1)


def apsp_ref(adj: jnp.ndarray, steps: int | None = None) -> jnp.ndarray:
    """All-pairs shortest paths by repeated squaring (pure jnp)."""
    n = adj.shape[0]
    steps = steps if steps is not None else max(1, int(np.ceil(np.log2(n))))
    d = adj.astype(jnp.float32)
    for _ in range(steps):
        d = minplus_matmul_ref(d, d)
    return d
