"""Jit'd public wrapper: APSP via Pallas min-plus squaring.

On CPU (this container) the kernel runs in interpret mode; on TPU set
``interpret=False`` (default picks by backend).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from .kernel import minplus_matmul


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("steps", "interpret", "block"))
def apsp(adj: jnp.ndarray, *, steps: int | None = None,
         interpret: bool | None = None, block: int = 128) -> jnp.ndarray:
    """Tropical-semiring all-pairs shortest paths.

    adj: [n, n] edge weights (inf = no edge, 0 diagonal).
    """
    interpret = _default_interpret() if interpret is None else interpret
    n = adj.shape[0]
    steps = steps if steps is not None else max(1, int(np.ceil(np.log2(n))))
    d = adj.astype(jnp.float32)
    for _ in range(steps):
        d = minplus_matmul(d, d, bm=block, bn=block, bk=block,
                           interpret=interpret)
    return d
