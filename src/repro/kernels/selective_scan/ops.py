"""Jit'd wrapper for the selective-scan kernel (interpret on CPU)."""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from .kernel import selective_scan as _kernel


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


@partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def selective_scan(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, *,
                   chunk: int = 128, bd: int = 256,
                   interpret: bool | None = None) -> jnp.ndarray:
    interpret = _default_interpret() if interpret is None else interpret
    return _kernel(a, b, c, chunk=chunk, bd=bd, interpret=interpret)
