"""Chunked Mamba1 selective-scan Pallas kernel.

Solves h_t = a_t ⊙ h_{t-1} + b_t over the sequence, then y_t = C_t·h_t.

Grid: (B, D_blocks, S_chunks) with the chunk axis innermost — TPU grids
execute sequentially, so the inter-chunk carry h lives in VMEM scratch and
flows across grid steps (the same trick flash attention uses for its
online-softmax state).  Within a chunk the recurrence is solved with an
associative scan over the time axis — log2(Q) vectorized steps instead of
Q sequential ones.

Block shapes: a/b tiles [Q, bd, N] where bd (d_inner block) is a multiple
of 8 lanes and N=16 keeps the minor dim dense; y tile [Q, bd].
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _scan_kernel(a_ref, b_ref, c_ref, y_ref, h_scr, *, n_chunks: int):
    cb = pl.program_id(2)

    @pl.when(cb == 0)
    def _init():
        h_scr[...] = jnp.zeros_like(h_scr)

    a = a_ref[0]                      # [Q, bd, N]
    b = b_ref[0]                      # [Q, bd, N]
    c = c_ref[0]                      # [Q, N]

    def combine(l, r):
        al, bl = l
        ar, br = r
        return al * ar, bl * ar + br

    a_cum, b_cum = jax.lax.associative_scan(combine, (a, b), axis=0)
    h = a_cum * h_scr[...][None] + b_cum                  # [Q, bd, N]
    h_scr[...] = h[-1]
    # y[q, d] = sum_n h[q, d, n] * c[q, n]
    y_ref[0] = jnp.sum(h * c[:, None, :], axis=-1).astype(y_ref.dtype)


@partial(jax.jit, static_argnames=("chunk", "bd", "interpret"))
def selective_scan(a: jnp.ndarray, b: jnp.ndarray, c: jnp.ndarray, *,
                   chunk: int = 128, bd: int = 256,
                   interpret: bool = False) -> jnp.ndarray:
    """a, b: [B, S, D, N] f32; c: [B, S, N] f32 -> y [B, S, D].

    (a = exp(dt·A) discretized decay, b = dt·B_t·x_t, c = C_t.)
    """
    bsz, s, d, n = a.shape
    chunk = min(chunk, s)
    bd = min(bd, d)
    sp = -(-s // chunk) * chunk
    dp = -(-d // bd) * bd
    pad_s, pad_d = sp - s, dp - d
    if pad_s or pad_d:
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_d), (0, 0)),
                    constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_d), (0, 0)))
        c = jnp.pad(c, ((0, 0), (0, pad_s), (0, 0)))
    n_chunks = sp // chunk
    grid = (bsz, dp // bd, n_chunks)
    y = pl.pallas_call(
        partial(_scan_kernel, n_chunks=n_chunks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, chunk, bd, n), lambda bi, di, ci: (bi, ci, di, 0)),
            pl.BlockSpec((1, chunk, bd, n), lambda bi, di, ci: (bi, ci, di, 0)),
            pl.BlockSpec((1, chunk, n), lambda bi, di, ci: (bi, ci, 0)),
        ],
        out_specs=pl.BlockSpec((1, chunk, bd),
                               lambda bi, di, ci: (bi, ci, di)),
        out_shape=jax.ShapeDtypeStruct((bsz, sp, dp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bd, n), jnp.float32)],
        interpret=interpret,
    )(a.astype(jnp.float32), b.astype(jnp.float32), c.astype(jnp.float32))
    return y[:, :s, :d]
