"""Pure-jnp oracle for the chunked selective scan."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def selective_scan_ref(a: jnp.ndarray, b: jnp.ndarray,
                       c: jnp.ndarray) -> jnp.ndarray:
    """Sequential-scan reference.  a,b: [B,S,D,N]; c: [B,S,N] -> [B,S,D]."""
    def step(h, ab):
        at, bt, ct = ab
        h = at * h + bt                               # [B, D, N]
        y = jnp.sum(h * ct[:, None, :], axis=-1)      # [B, D]
        return h, y

    bsz, s, d, n = a.shape
    h0 = jnp.zeros((bsz, d, n), jnp.float32)
    _, ys = jax.lax.scan(
        step, h0, (a.transpose(1, 0, 2, 3).astype(jnp.float32),
                   b.transpose(1, 0, 2, 3).astype(jnp.float32),
                   c.transpose(1, 0, 2).astype(jnp.float32)))
    return ys.transpose(1, 0, 2)                      # [B, S, D]
