"""BigDataSDNSim reproduction as a jax tensor program.

Importing any ``repro`` submodule first installs the jax API-compat shims
(``repro.compat``) so the codebase runs unmodified on both jax 0.4.x and
current jax.
"""
from . import compat  # noqa: F401  (side effect: jax API shims)
