"""Next-token cross-entropy with z-loss and padding mask."""
from __future__ import annotations

import jax
import jax.numpy as jnp

PAD_ID = -1


def lm_loss(logits: jnp.ndarray, labels: jnp.ndarray, *,
            z_loss: float = 1e-4, aux_loss: jnp.ndarray | float = 0.0,
            aux_weight: float = 1e-2):
    """logits [B,S,V] f32; labels [B,S] int32 (PAD_ID = ignore)."""
    logits = logits.astype(jnp.float32)
    mask = (labels != PAD_ID)
    safe = jnp.maximum(labels, 0)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, safe[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = jnp.square(lse)
    denom = jnp.maximum(jnp.sum(mask), 1)
    ce = jnp.sum(jnp.where(mask, nll, 0.0)) / denom
    z = jnp.sum(jnp.where(mask, zl, 0.0)) / denom
    total = ce + z_loss * z + aux_weight * aux_loss
    return total, {"ce": ce, "z": z, "aux": jnp.asarray(aux_loss),
                   "tokens": denom}
