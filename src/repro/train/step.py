"""train_step / serve_step builders — the functions the launcher jits.

``make_train_step(api, opt_cfg)`` returns a pure
``(params, opt_state, batch) -> (params, opt_state, metrics)``;
``make_prefill_step`` / ``make_decode_step`` wrap the serve path.  The
dry-run lowers exactly these functions for every (arch x shape) cell.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.registry import ModelApi
from . import optim
from .loss import lm_loss


def make_train_step(api: ModelApi, opt_cfg: optim.AdamWConfig, *,
                    backend: str = "chunked", remat: bool = True,
                    microbatch: int = 0) -> Callable:
    """Standard data-parallel step; optional gradient micro-batching
    (sequential accumulation) for memory-bound cells."""

    def loss_fn(params, batch):
        out = api.apply(params, {k: v for k, v in batch.items()
                                 if k != "labels"},
                        backend=backend, remat=remat)
        return lm_loss(out["logits"], batch["labels"],
                       aux_loss=out.get("aux_loss", 0.0))

    def grads_of(params, batch):
        (loss, met), grads = jax.value_and_grad(loss_fn, has_aux=True)(
            params, batch)
        return loss, met, grads

    def step(params, opt_state, batch):
        if microbatch and microbatch > 1:
            def split(x):
                b = x.shape[0]
                return x.reshape(microbatch, b // microbatch, *x.shape[1:])

            mb = jax.tree_util.tree_map(split, batch)

            def acc_fn(carry, mbatch):
                loss_a, grads_a = carry
                loss, met, grads = grads_of(params, mbatch)
                grads_a = jax.tree_util.tree_map(jnp.add, grads_a, grads)
                return (loss_a + loss, grads_a), met

            zeros = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            from repro.util import scan as _scan
            (loss, grads), mets = _scan(
                acc_fn, (jnp.float32(0.0), zeros), mb)
            loss = loss / microbatch
            grads = jax.tree_util.tree_map(lambda g: g / microbatch, grads)
            met = jax.tree_util.tree_map(lambda m: m[-1], mets)
        else:
            loss, met, grads = grads_of(params, batch)
        params, opt_state, omet = optim.update(opt_cfg, grads, opt_state,
                                               params)
        metrics = {"loss": loss, **met, **omet}
        return params, opt_state, metrics

    return step


def make_prefill_step(api: ModelApi, *, backend: str = "chunked") -> Callable:
    def step(params, batch, cache):
        return api.prefill(params, batch, cache, backend=backend)
    return step


def make_decode_step(api: ModelApi) -> Callable:
    def step(params, tokens, cache, batch_extra=None):
        if batch_extra is not None:
            return api.decode_step(params, tokens, cache,
                                   batch_extra=batch_extra)
        return api.decode_step(params, tokens, cache)
    return step
