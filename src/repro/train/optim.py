"""From-scratch optimizer stack: AdamW + cosine schedule + global-norm clip
+ (beyond-paper) error-feedback int8 gradient compression.

No optax dependency — the optimizer is three pure functions over pytrees so
it shards trivially under pjit (opt state inherits the param specs).
"""
from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    lr_min: float = 3e-5
    warmup_steps: int = 100
    total_steps: int = 10_000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    # gradient compression (int8 + error feedback) — applied to the grads
    # before the optimizer; models the paper-style "reduce bytes on the
    # wire" knob (DESIGN.md §Beyond-paper).
    compress: bool = False


class OptState(NamedTuple):
    step: jnp.ndarray
    mu: Any
    nu: Any
    err: Any  # error-feedback residual (zeros when compress=False)


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    t = jnp.clip((step - cfg.warmup_steps)
                 / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0, 1)
    cos = cfg.lr_min + 0.5 * (cfg.lr_peak - cfg.lr_min) * (1 + jnp.cos(jnp.pi * t))
    return warm * jnp.where(step < cfg.warmup_steps, cfg.lr_peak, cos)


def init(cfg: AdamWConfig, params: Any) -> OptState:
    zeros = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    err = jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params) \
        if cfg.compress else jax.tree_util.tree_map(lambda p: jnp.zeros((), jnp.float32), params)
    return OptState(step=jnp.zeros((), jnp.int32), mu=zeros,
                    nu=jax.tree_util.tree_map(jnp.copy, zeros), err=err)


def global_norm(tree: Any) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def _quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compress_grads(grads: Any, err: Any) -> Tuple[Any, Any]:
    """Int8 quantization with error feedback: g' = deq(q(g + e)),
    e' = (g + e) - g'.  On real multi-pod runs the int8 payload is what
    crosses the 'pod' axis; here the transform models the precision loss
    so convergence effects are measurable in tests."""
    def one(g, e):
        gf = g.astype(jnp.float32) + e
        q, scale = _quantize_int8(gf)
        deq = q.astype(jnp.float32) * scale
        return deq.astype(g.dtype), gf - deq

    # two passes (XLA CSE dedups the shared work under jit); avoids
    # tuple-leaf ambiguity with tuple-structured param trees (hybrid).
    new_g = jax.tree_util.tree_map(lambda g, e: one(g, e)[0], grads, err)
    new_e = jax.tree_util.tree_map(lambda g, e: one(g, e)[1], grads, err)
    return new_g, new_e


def update(cfg: AdamWConfig, grads: Any, state: OptState, params: Any
           ) -> Tuple[Any, OptState, dict]:
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-12))
    # NOTE: do NOT materialize an f32 grad tree here — the f32 cast happens
    # inside the per-leaf update, where GSPMD computes it in the (ZeRO
    # data+model-sharded) moment sharding instead of the 'model'-only param
    # sharding (an 8 GiB/chip difference for 30B-param cells).

    err = state.err
    if cfg.compress:
        grads = jax.tree_util.tree_map(
            lambda g: g.astype(jnp.float32) * scale, grads)
        scale = 1.0
        grads, err = compress_grads(grads, state.err)

    step = state.step + 1
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def one(p, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        upd = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps)
        decay = cfg.weight_decay * p.astype(jnp.float32) \
            if p.ndim >= 2 else 0.0  # no decay on norms/biases
        new_p = p.astype(jnp.float32) - lr * (upd + decay)
        return new_p.astype(p.dtype), m, v

    tm = jax.tree_util.tree_map
    new_p = tm(lambda p, g, m, v: one(p, g, m, v)[0],
               params, grads, state.mu, state.nu)
    new_m = tm(lambda p, g, m, v: one(p, g, m, v)[1],
               params, grads, state.mu, state.nu)
    new_v = tm(lambda p, g, m, v: one(p, g, m, v)[2],
               params, grads, state.mu, state.nu)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, OptState(step, new_m, new_v, err), metrics
