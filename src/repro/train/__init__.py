from .loss import lm_loss
from .optim import AdamWConfig, OptState, init, lr_schedule, update
from .step import make_decode_step, make_prefill_step, make_train_step

__all__ = ["lm_loss", "AdamWConfig", "OptState", "init", "lr_schedule",
           "update", "make_train_step", "make_prefill_step",
           "make_decode_step"]
