"""Small shared utilities.

``scan`` wraps ``jax.lax.scan`` with a process-wide UNROLL switch: XLA's
cost analysis counts a while-loop body ONCE, so roofline-counting compiles
run under ``unrolled_counting()`` which makes every repro scan fully
unroll (depth-1/2 model variants keep the unrolled op count small).
"""
from __future__ import annotations

import contextlib
import threading

import jax

_state = threading.local()


def _unroll() -> bool:
    return getattr(_state, "unroll", False)


@contextlib.contextmanager
def unrolled_counting():
    prev = getattr(_state, "unroll", False)
    _state.unroll = True
    try:
        yield
    finally:
        _state.unroll = prev


def scan(f, init, xs, length=None, unroll=None):
    """jax.lax.scan that fully unrolls under ``unrolled_counting()``."""
    if unroll is None:
        unroll = True if _unroll() else 1
    return jax.lax.scan(f, init, xs, length=length, unroll=unroll)
