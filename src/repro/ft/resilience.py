"""Fault tolerance: failure injection, checkpoint/restart, straggler
mitigation, elastic rescale — the host-side control loop a 1000-node run
needs around the pure train step.

On real hardware the failure signal is a heartbeat timeout (exactly the
paper's NodeManager -> ResourceManager heartbeat); here ``FailurePlan``
injects deterministic faults so the recovery path is unit-testable.

Straggler mitigation implements the standard coordinated-checkpoint
pattern: per-step host durations feed an EWMA; hosts slower than
``straggler_factor`` x median for ``patience`` steps are marked and the
driver requests an elastic rescale that drops them (data-parallel ranks
are a pure function of (step, live-host set) — see data.pipeline).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.checkpoint import ckpt


@dataclasses.dataclass
class FailurePlan:
    """Deterministic fault injection: fail step -> kind."""
    at_steps: Dict[int, str] = dataclasses.field(default_factory=dict)
    # kinds: "crash" (lose state, restart from ckpt),
    #        "straggle:<seconds>" (one slow step on one host)

    def check(self, step: int) -> Optional[str]:
        return self.at_steps.get(step)


class NodeFailure(RuntimeError):
    pass


@dataclasses.dataclass
class StragglerMonitor:
    n_hosts: int
    factor: float = 2.0
    patience: int = 3
    ewma: float = 0.5
    _est: Optional[np.ndarray] = None
    _strikes: Optional[np.ndarray] = None

    def observe(self, durations: Sequence[float]) -> List[int]:
        d = np.asarray(durations, np.float64)
        if self._est is None:
            self._est = d.copy()
            self._strikes = np.zeros(self.n_hosts, np.int32)
        self._est = self.ewma * d + (1 - self.ewma) * self._est
        med = np.median(self._est)
        slow = self._est > self.factor * med
        self._strikes = np.where(slow, self._strikes + 1, 0)
        return [int(i) for i in np.nonzero(
            self._strikes >= self.patience)[0]]


@dataclasses.dataclass
class TrainDriver:
    """Checkpointed, fault-tolerant training loop around a pure step fn.

    step_fn(params, opt_state, batch) -> (params, opt_state, metrics)
    batch_fn(step) -> batch   (pure; restart/elastic safe)
    """
    step_fn: Callable
    batch_fn: Callable[[int], Any]
    ckpt_dir: str
    ckpt_every: int = 50
    failure_plan: FailurePlan = dataclasses.field(default_factory=FailurePlan)
    keep_metrics: bool = True

    def run(self, params, opt_state, n_steps: int,
            start_step: int = 0) -> Tuple[Any, Any, Dict[str, Any]]:
        step = start_step
        history: List[Dict] = []
        restarts = 0
        # resume if a checkpoint exists
        latest = ckpt.latest_step(self.ckpt_dir)
        if latest is not None and latest > step:
            (params, opt_state), extra = ckpt.restore(
                self.ckpt_dir, (params, opt_state))
            step = int(extra.get("next_step", latest))
        while step < n_steps:
            fault = self.failure_plan.check(step)
            if fault == "crash":
                # lose in-memory state; restart from latest checkpoint
                self.failure_plan.at_steps.pop(step)
                restarts += 1
                latest = ckpt.latest_step(self.ckpt_dir)
                if latest is None:
                    raise NodeFailure(
                        f"crash at step {step} with no checkpoint")
                (params, opt_state), extra = ckpt.restore(
                    self.ckpt_dir, (params, opt_state))
                step = int(extra.get("next_step", latest))
                continue
            t0 = time.perf_counter()
            if fault and fault.startswith("straggle:"):
                time.sleep(float(fault.split(":")[1]))
                self.failure_plan.at_steps.pop(step)
            batch = self.batch_fn(step)
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            dt = time.perf_counter() - t0
            if self.keep_metrics:
                history.append({"step": step, "dt": dt,
                                **{k: float(np.asarray(v))
                                   for k, v in metrics.items()}})
            step += 1
            if step % self.ckpt_every == 0 or step == n_steps:
                ckpt.save(self.ckpt_dir, step, (params, opt_state),
                          extra={"next_step": step})
        return params, opt_state, {"history": history,
                                   "restarts": restarts,
                                   "final_step": step}
