from .resilience import (FailurePlan, NodeFailure, StragglerMonitor,
                         TrainDriver)

__all__ = ["FailurePlan", "NodeFailure", "StragglerMonitor", "TrainDriver"]
