"""Compatibility shims for the jax API drift between 0.4.x and >=0.5.

The LM stack (launch/dryrun, models/moe_ep, sharding/rules) and its tests
are written against the current jax surface — ``jax.set_mesh``,
``jax.shard_map(..., check_vma=...)``, ``jax.sharding.get_abstract_mesh`` —
while the baked container ships jax 0.4.37, where those spell
``with mesh:``, ``jax.experimental.shard_map.shard_map(..., check_rep=...)``
and the thread-resources physical mesh.  Importing this module (done by
``repro/__init__.py``, so any ``import repro.<x>`` suffices) installs the
new spellings onto the ``jax`` module when they are missing; on a current
jax every shim is a no-op.

No behavior is patched on new jax — only absent attributes are added — so
this cannot mask a real regression there.
"""
from __future__ import annotations

import jax


def _install() -> None:
    if not hasattr(jax, "set_mesh"):
        # jax<=0.4: a Mesh is itself a context manager (it enters the
        # thread-resources env, the ambient-mesh mechanism of that era),
        # so the context-manager use ``with jax.set_mesh(m):`` maps to
        # ``with m:`` directly.
        jax.set_mesh = lambda mesh: mesh

    if not hasattr(jax, "shard_map"):
        from jax.experimental.shard_map import shard_map as _shard_map

        def shard_map(f, mesh=None, in_specs=None, out_specs=None,
                      check_vma=None, **kw):
            if check_vma is not None:          # renamed from check_rep
                kw["check_rep"] = check_vma
            return _shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, **kw)

        jax.shard_map = shard_map

    if not hasattr(jax.sharding, "get_abstract_mesh"):
        from jax._src.mesh import thread_resources

        def get_abstract_mesh():
            m = thread_resources.env.physical_mesh
            return None if m.empty else m

        jax.sharding.get_abstract_mesh = get_abstract_mesh


_install()
