"""Fleet execution engine: chunked early-exit cohorts over the policy grid
(DESIGN.md §9).

The single vmapped ``lax.while_loop`` the runners use charges every lane for
the LONGEST trajectory in the batch, and its batched ``lax.cond`` policy
dispatch executes both branches of every policy — together the "batch wall"
that made width-6 vmap ~100x slower than serial.  The fleet layer cracks it
with three composed mechanisms:

1. **Chunked early-exit cohorts** — the grid drains through fixed-width
   cohorts of lanes advanced by K-step jitted chunks
   (``engine.make_fleet_chunk``).  Between chunks the host retires finished
   lanes, keeps their final state, and refills the lane from the pending
   queue, so no sim runs more than ``K - 1`` wasted events past its own
   finish.
2. **Bucketed admission** — a cheap calibrated step-count predictor
   (``StepPredictor``) orders the queue by expected trajectory length, so a
   cohort wave holds similar-length sims and the intra-chunk early exit
   (``jnp.all(done)``) actually fires.  Lanes are grouped by their STATIC
   policy signature (routing / traffic / placement) first: uniform branch
   fields are closed over as Python ints, letting the engine specialize its
   dispatch instead of paying for both branches under vmap.
3. **Device sharding** — with more than one visible device the lane axis
   runs under ``jax.shard_map`` over a 1-D ``"fleet"`` mesh
   (``launch.mesh``); each device drains its own slice of the cohort with
   no collectives (lanes are independent; the chunk's early exit is a
   shard-local ``jnp.all``).

Results are bit-identical to ``Experiment.run``'s serial/vmapped runners:
the chunk applies the SAME ``_step`` and freezes each lane at the first
state where ``_finished`` holds — exactly the state the serial while-loop
stops at (tests/test_fleet.py).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, Hashable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import init_fleet_carry, make_fleet_chunk, tree_select
from ..core.simmeta import SimMeta
from . import runners
from .results import Results

# the branch-selecting policy axes: uniform per cohort, closed over as
# Python ints so the engine's dispatch specializes at trace time
STATIC_FIELDS = ("routing", "traffic", "placement")


class StepPredictor:
    """Cheap step-count predictor with online calibration (DESIGN.md §9).

    Admission order only needs RELATIVE lengths, so the model is minimal: a
    size prior ``alpha * (n_tasks + n_packets)`` (step count scales with
    how many completion/activation events the workload can generate),
    refined by an EWMA over observed final step counts keyed at two
    granularities — the (scenario, static-sig) group and the individual
    grid member.  Within a fresh group every member shares the group
    estimate (ordering is a no-op); on repeated fleets — benchmark reruns,
    advisor loops — member-level observations take over and genuinely
    length-divergent sims sort into the same cohort wave.
    """

    def __init__(self, alpha: float = 3.0, ewma: float = 0.4):
        self.alpha = alpha
        self.ewma = ewma
        self._obs: Dict[Hashable, float] = {}

    def predict(self, member_key: Hashable, group_key: Hashable,
                n_tasks: int, n_packets: int) -> float:
        prior = self.alpha * (n_tasks + n_packets)
        return self._obs.get(member_key,
                             self._obs.get(group_key, prior))

    def observe(self, key: Hashable, steps: float) -> None:
        cur = self._obs.get(key)
        self._obs[key] = (steps if cur is None
                          else (1 - self.ewma) * cur + self.ewma * steps)

    def clear(self) -> None:
        self._obs.clear()


# process-wide: calibration persists across fleets in one process
_PREDICTOR = StepPredictor()


class CohortSchedule:
    """Host-side retire/refill bookkeeping for one cohort of ``width``
    lanes draining ``members`` (already in admission order).

    Lanes hold a member id or ``None`` (a PAD lane: starts — and stays —
    done, so the chunk freezes it for free).  ``step(done)`` is called at
    every chunk boundary with the device's done flags; it retires finished
    lanes and refills them from the queue, returning what the driver must
    do on-device: extract the retired lanes' states BEFORE applying the
    refill mask (a refill overwrites the lane with the t=0 state).
    """

    def __init__(self, members: Sequence[Any], width: int):
        self.width = width
        self.queue: List[Any] = list(members)
        self.lane: List[Any] = [
            self.queue.pop(0) if self.queue else None for _ in range(width)]
        self.retired: List[Tuple[int, Any]] = []

    def pad_mask(self) -> np.ndarray:
        """[W] bool: lanes with no member — force their done flag at t=0."""
        return np.array([m is None for m in self.lane])

    @property
    def active(self) -> bool:
        return any(m is not None for m in self.lane)

    def step(self, done: np.ndarray) -> Tuple[List[Tuple[int, Any]],
                                              np.ndarray]:
        """-> (retire, refill_mask) for one chunk boundary.

        ``retire`` lists ``(lane, member)`` pairs whose final state must be
        extracted now; ``refill_mask`` marks lanes reassigned to the next
        queued member (reset them to the t=0 carry).  A finished lane with
        an empty queue becomes a pad lane.
        """
        retire: List[Tuple[int, Any]] = []
        refill = np.zeros(self.width, bool)
        for i in range(self.width):
            if done[i] and self.lane[i] is not None:
                retire.append((i, self.lane[i]))
                if self.queue:
                    self.lane[i] = self.queue.pop(0)
                    refill[i] = True
                else:
                    self.lane[i] = None
        self.retired.extend(retire)
        return retire, refill


@dataclasses.dataclass
class FleetStats:
    """What the fleet actually did — surfaced for benchmarks and tests."""

    sims: int = 0        # grid cells drained
    cohorts: int = 0     # (scenario × static-sig) groups
    chunks: int = 0      # K-step chunk invocations
    refills: int = 0     # lanes recycled mid-cohort
    devices: int = 1     # fleet-mesh size (1 = no shard_map)
    width: int = 0       # lanes per cohort (after device round-up)


def _chunk_program(meta: SimMeta, sig: Tuple[int, ...], chunk_steps: int,
                   width: int, n_dev: int) -> Callable:
    """The cached jitted (and, for ``n_dev > 1``, shard_mapped) chunk."""
    key = ("fleet", meta, sig, chunk_steps, width, n_dev)

    def build() -> Callable:
        static_pol = dict(zip(STATIC_FIELDS, sig))
        chunk = make_fleet_chunk(meta, static_pol, chunk_steps)

        def counted(consts, pol, carry):
            runners.note_trace()
            return chunk(consts, pol, carry)

        fn = counted
        if n_dev > 1:
            from jax.sharding import PartitionSpec as P

            from ..launch.mesh import make_mesh
            mesh = make_mesh((n_dev,), ("fleet",))
            # consts replicated, lane axis split; each shard drains its
            # lanes independently (no collectives — the chunk's early exit
            # is a shard-local jnp.all over its own done flags)
            fn = jax.shard_map(counted, mesh=mesh,
                               in_specs=(P(), P("fleet"), P("fleet")),
                               out_specs=P("fleet"), check_vma=False)
        # donating the carry lets XLA alias it through the while loop;
        # the shared policy skips the CPU backend (jaxcheck:donation)
        return jax.jit(fn, donate_argnums=runners.donation_argnums())

    return runners.get_cached_program(key, build)


def _refill_program(meta: SimMeta, width: int) -> Callable:
    """Cached jitted refill: ``(mask, carry0, carry) -> carry`` with
    refilled lanes reset to the t=0 carry.  Eager ``tree_select`` is ~70
    per-leaf dispatches per chunk boundary — a large fraction of host time
    on fast tiers."""
    key = ("fleet-refill", meta, width)
    return runners.get_cached_program(
        key, lambda: jax.jit(tree_select))


def _init_program(meta: SimMeta, width: int) -> Callable:
    """Cached jitted cohort initializer: ``consts -> t=0 carry``.  Eager
    ``init_fleet_carry`` dispatches ~35 broadcast ops plus the endpoint
    cache per cohort (~6 ms on the small tier — comparable to a whole
    chunk); jitted it is one cached executable per (meta, width)."""
    key = ("fleet-init", meta, width)
    return runners.get_cached_program(
        key, lambda: jax.jit(lambda c: init_fleet_carry(c, meta, width)))


def _lane_policies(pol_np: Dict[str, np.ndarray],
                   sched: CohortSchedule) -> Dict[str, np.ndarray]:
    """[W]-shaped lane-varying policy rows (static fields excluded)."""
    out = {}
    for k, col in pol_np.items():
        if k in STATIC_FIELDS:
            continue
        rows = [col[m] if m is not None else col[0] for m in sched.lane]
        out[k] = np.stack(rows)
    return out


def run_fleet(exp, width: int = 32, chunk_steps: int = 32,
              devices: Optional[int] = None, return_stats: bool = False,
              predictor: Optional[StepPredictor] = None):
    """Drain an ``Experiment``'s scenario × policy grid through the fleet
    engine (DESIGN.md §9) and assemble the same ``Results`` grid
    ``Experiment.run`` returns, bit-identically.

    Parameters: ``width`` lanes per cohort (rounded up to a multiple of the
    device count); ``chunk_steps`` events per jitted chunk (K); ``devices``
    caps the fleet mesh (default: all visible devices); ``return_stats``
    additionally returns a ``FleetStats``.
    """
    predictor = predictor or _PREDICTOR
    S, P = len(exp.scenarios), len(exp.policies)
    consts, meta = exp.build()
    meta = SimMeta.coerce(meta)
    pol_np = {k: np.asarray(v) for k, v in exp.policy_arrays().items()}

    n_dev = devices if devices is not None else jax.local_device_count()
    n_dev = max(1, min(n_dev, jax.local_device_count()))

    # group the policy axis by static signature: one cohort per
    # (scenario, sig) shares one specialized chunk program
    groups: Dict[Tuple[int, ...], List[int]] = {}
    for p in range(P):
        sig = tuple(int(pol_np[f][p]) for f in STATIC_FIELDS)
        groups.setdefault(sig, []).append(p)

    stats = FleetStats(sims=S * P, devices=n_dev)
    # final [S, P, ...] state grid, allocated once and written in place at
    # retire time (one vectorized row-gather per leaf per boundary — per-sim
    # tree copies cost ~leaves × sims tiny np ops and dominated the host
    # side of small-tier fleets)
    out: Optional[List[np.ndarray]] = None
    state_cls = None

    for si in range(S):
        if S == 1:
            consts_s = consts
        else:
            from ..scenarios.sweep import slice_packed
            consts_s = slice_packed(consts, si)
        n_tasks = int(np.sum(np.asarray(consts_s.task_valid)))
        n_pkts = int(np.sum(np.asarray(consts_s.pkt_valid)))
        sname = exp.scenario_names[si]

        for sig, members in groups.items():
            gkey = (sname, sig)
            order = sorted(members, key=lambda p: predictor.predict(
                (sname, sig, exp.policy_names[p]), gkey, n_tasks, n_pkts))
            W = min(width, len(order))
            if n_dev > 1:
                W = n_dev * math.ceil(W / n_dev)
            sched = CohortSchedule(order, W)
            stats.cohorts += 1
            stats.width = max(stats.width, W)

            chunk = _chunk_program(meta, sig, chunk_steps, W, n_dev)
            carry0 = _init_program(meta, W)(consts_s)
            s0, cache0, done0 = carry0
            carry = (s0, cache0,
                     jnp.asarray(np.asarray(done0) | sched.pad_mask()))

            # hard backstop: every member can run at most max_steps events
            max_chunks = ((len(order) + W)
                          * (meta.max_steps // chunk_steps + 2))
            chunks = 0
            pol_lane = _lane_policies(pol_np, sched)
            while sched.active:
                carry = chunk(consts_s, pol_lane, carry)
                chunks += 1
                stats.chunks += 1
                if chunks > max_chunks:
                    raise RuntimeError(
                        f"fleet cohort {gkey} exceeded {max_chunks} chunks "
                        "without draining — engine not making progress")
                done = np.asarray(carry[2])
                retire, refill = sched.step(done)
                if retire:
                    host_s = [np.asarray(a) for a in carry[0]]
                    if out is None:
                        state_cls = type(carry[0])
                        out = [np.empty((S, P) + a.shape[1:], a.dtype)
                               for a in host_s]
                    lanes = np.array([l for l, _ in retire])
                    mems = np.array([m for _, m in retire])
                    for o, h in zip(out, host_s):
                        o[si, mems] = h[lanes]
                    steps_leaf = host_s[carry[0]._fields.index("steps")]
                    for lane, member in retire:
                        steps = float(steps_leaf[lane])
                        predictor.observe(
                            (sname, sig, exp.policy_names[member]), steps)
                        predictor.observe(gkey, steps)
                if refill.any():
                    stats.refills += int(refill.sum())
                    mask = jnp.asarray(refill)
                    # where refilled: back to the t=0 carry (done leaf
                    # included — a sim finished at t=0 stays frozen and
                    # retires with its s0 state, exactly like serial)
                    carry = _refill_program(meta, W)(mask, carry0, carry)
                    pol_lane = _lane_policies(pol_np, sched)

    states = state_cls(*out)   # the serial runner's [S, P, ...] grid
    if S == 1:   # Results keeps a scenario axis on consts
        consts = jax.tree_util.tree_map(lambda a: a[None], consts)
    res = Results(states=states, consts=consts, meta=meta,
                  scenario_names=exp.scenario_names,
                  policy_names=exp.policy_names)
    return (res, stats) if return_stats else res
