"""Streaming execution: ``Experiment.run_stream`` (DESIGN.md §11).

Drives the slot-recycling ring (``core.streaming``) with the fleet's
chunked cohort machinery (DESIGN.md §9): policies group by static
signature into cohorts, each lane runs the SAME arrival trace under its
own policy, and between jitted K-step chunks the host retires completed
job slots, records their sojourn, and refills the freed slots from the
trace.  Tensor shapes never change, so an arbitrarily long trace runs
through one compiled chunk program in bounded memory.

``StreamResults`` is the windowed-metrics surface: per-window p50/p99
sojourn, throughput, utilization, energy, and per-class SLO attainment
(windows with no completions are NaN, like the pad-job masking in
``Results.job_report``), plus warmup-excluded steady-state summaries.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from ..core.engine import init_fleet_carry, make_consts, make_fleet_chunk
from ..core.simmeta import SimMeta
from ..core.streaming import (RingSpec, STREAM_FIELDS, host_stream_arrays,
                              load_slot, make_refill, ring_setup,
                              stream_consts_axes)
from . import runners
from .fleet import STATIC_FIELDS, CohortSchedule, _lane_policies


@dataclasses.dataclass
class StreamStats:
    """What the streaming run actually did (conservation surface: every
    arrival is loaded exactly once and retired exactly once per lane)."""

    lanes: int = 0       # policy members across cohorts
    cohorts: int = 0     # static-signature groups
    chunks: int = 0      # K-step chunk invocations
    loads: int = 0       # slot loads (initial fill + refills), all lanes
    refills: int = 0     # slot loads AFTER the initial fill, all lanes
    retired: int = 0     # job completions recorded, all lanes
    trace_len: int = 0   # arrivals materialized below the horizon
    slots: int = 0       # ring capacity (jobs resident per lane)


def _percentile(a: np.ndarray, q: float) -> float:
    a = a[np.isfinite(a)]
    return float(np.percentile(a, q)) if a.size else float("nan")


@dataclasses.dataclass
class StreamResults:
    """Windowed streaming metrics for one scenario × P policies.

    ``jobs[pi]`` holds one row per completed job (arrays over jobs):
    ``seq`` (arrival index), ``cls`` (service-class index), ``t_arr``,
    ``t_admit``, ``t_done`` and ``sojourn = t_done - t_arr`` (arrival to
    completion, host queueing included).  ``samples[pi]`` is a ``[K, 4]``
    array of cumulative ``(time, host_energy, switch_energy, host_busy)``
    at chunk boundaries — utilization/energy windows interpolate it, so
    their resolution is the chunk cadence, not per-event."""

    scenario_name: str
    policy_names: List[str]
    classes: Tuple[Any, ...]          # arrivals.ServiceClass tuple
    horizon: float
    warmup: float
    window_s: float
    meta: SimMeta
    jobs: Dict[int, Dict[str, np.ndarray]]
    samples: Dict[int, np.ndarray]
    stats: StreamStats
    final_states: Optional[Dict[int, Any]] = None
    final_consts: Optional[Dict[int, Any]] = None
    # per-policy cumulative chaos counters at drain (DESIGN.md §13):
    # spec_launches / spec_wins / wasted_spec_work_s / degraded_time_s /
    # failover_count / failover_park_s — zero when those features are off
    chaos: Dict[int, Dict[str, float]] = dataclasses.field(
        default_factory=dict)

    @property
    def n_policies(self) -> int:
        return len(self.policy_names)

    def windows(self, policy: int = 0) -> Dict[str, np.ndarray]:
        """Per-window metrics (windows of ``window_s`` from t=0, covering
        every completion): ``t0``/``t1``, ``n_done``, ``throughput_jobs_s``,
        ``p50_sojourn_s``/``p99_sojourn_s``, ``utilization``, ``energy_j``,
        and ``slo_attainment`` as ``[n_classes, n_windows]`` — empty
        windows / empty classes are NaN."""
        j = self.jobs[policy]
        w = self.window_s
        t_hi = max(self.horizon,
                   float(j["t_done"].max()) if j["t_done"].size else 0.0)
        n_w = max(1, int(math.ceil(t_hi / w)))
        edges = np.arange(n_w + 1) * w
        idx = np.clip((j["t_done"] // w).astype(int), 0, n_w - 1)
        n_done = np.bincount(idx, minlength=n_w)[:n_w] \
            if j["t_done"].size else np.zeros(n_w, int)
        p50 = np.full(n_w, np.nan)
        p99 = np.full(n_w, np.nan)
        attain = np.full((len(self.classes), n_w), np.nan)
        for k in range(n_w):
            sel = idx == k if j["t_done"].size else np.zeros(0, bool)
            soj = j["sojourn"][sel]
            if soj.size:
                p50[k] = _percentile(soj, 50)
                p99[k] = _percentile(soj, 99)
            for ci, cl in enumerate(self.classes):
                cs = soj[j["cls"][sel] == ci]
                if cs.size:
                    attain[ci, k] = float(np.mean(cs <= cl.slo_s))
        # cumulative boundary samples -> per-window deltas (NaN before the
        # first / after the last sample of the lane's run)
        smp = self.samples[policy]
        ts, he, se, hb = smp.T
        energy = np.interp(edges, ts, he + se, left=0.0, right=(he + se)[-1])
        busy = np.interp(edges, ts, hb, left=0.0, right=hb[-1])
        util = np.diff(busy) / (int(self.meta.n_hosts) * w)
        return {
            "t0": edges[:-1], "t1": edges[1:],
            "n_done": n_done,
            "throughput_jobs_s": n_done / w,
            "p50_sojourn_s": p50, "p99_sojourn_s": p99,
            "utilization": util,
            "energy_j": np.diff(energy),
            "slo_attainment": attain,
        }

    def summary(self, policy: int = 0) -> Dict[str, Any]:
        """Warmup-excluded steady-state aggregates for one policy: jobs
        completing after ``warmup`` count; span = last completion −
        warmup."""
        j = self.jobs[policy]
        sel = j["t_done"] >= self.warmup
        soj = j["sojourn"][sel]
        span = (float(j["t_done"].max()) - self.warmup
                if sel.any() else float("nan"))
        per_class = {}
        for ci, cl in enumerate(self.classes):
            cs = soj[j["cls"][sel] == ci]
            per_class[cl.name] = {
                "n": int(cs.size),
                "slo_s": float(cl.slo_s),
                "attainment": (float(np.mean(cs <= cl.slo_s))
                               if cs.size else float("nan")),
            }
        smp = self.samples[policy]
        return {
            "policy": self.policy_names[policy],
            "jobs_done": int(sel.sum()),
            "span_s": span,
            "throughput_jobs_s": (float(sel.sum()) / span
                                  if span and span > 0 else float("nan")),
            "p50_sojourn_s": _percentile(soj, 50),
            "p99_sojourn_s": _percentile(soj, 99),
            "mean_sojourn_s": (float(soj.mean())
                               if soj.size else float("nan")),
            "energy_j": float(smp[-1, 1] + smp[-1, 2]),
            "classes": per_class,
            **self.chaos.get(policy, {}),
        }

    def rows(self) -> List[Dict[str, Any]]:
        """Flat per-(policy, window) rows — the CSV/JSON shape."""
        out = []
        for pi, pn in enumerate(self.policy_names):
            wd = self.windows(pi)
            for k in range(wd["t0"].size):
                row = {"policy": pn,
                       "t0": float(wd["t0"][k]), "t1": float(wd["t1"][k]),
                       "n_done": int(wd["n_done"][k]),
                       "throughput_jobs_s": float(
                           wd["throughput_jobs_s"][k]),
                       "p50_sojourn_s": float(wd["p50_sojourn_s"][k]),
                       "p99_sojourn_s": float(wd["p99_sojourn_s"][k]),
                       "utilization": float(wd["utilization"][k]),
                       "energy_j": float(wd["energy_j"][k])}
                for ci, cl in enumerate(self.classes):
                    row[f"slo_{cl.name}"] = float(
                        wd["slo_attainment"][ci, k])
                out.append(row)
        return out


def _stream_chunk(meta: SimMeta, sig, chunk_steps: int, width: int):
    key = ("stream", meta, sig, chunk_steps, width)

    def build():
        static_pol = dict(zip(STATIC_FIELDS, sig))
        chunk = make_fleet_chunk(meta, static_pol, chunk_steps,
                                 consts_axes=stream_consts_axes())

        def counted(consts, pol, carry):
            runners.note_trace()
            return chunk(consts, pol, carry)

        return jax.jit(counted)

    return runners.get_cached_program(key, build)


def _stream_refill(meta: SimMeta, width: int):
    key = ("stream-refill", meta, width)
    return runners.get_cached_program(key, lambda: make_refill(meta))


def _stream_init(meta: SimMeta, width: int):
    key = ("stream-init", meta, width)
    return runners.get_cached_program(
        key, lambda: jax.jit(lambda c: init_fleet_carry(c, meta, width)))


def run_stream(exp, arrivals, horizon: float, *, warmup: float = 0.0,
               window: Optional[float] = None, slots: int = 32,
               chunk_steps: int = 128, split: int = 1,
               spec: Optional[RingSpec] = None,
               max_chunks: Optional[int] = None,
               return_states: bool = False) -> StreamResults:
    """Stream an open arrival process through ONE scenario for every policy
    of ``exp`` (see ``Experiment.run_stream``).

    The trace is materialized below ``horizon`` once and shared by every
    lane; each lane consumes it at its own pace (its policy's pace).  The
    run continues PAST the horizon until every lane drains its ring — every
    arrival is accounted for, none is truncated."""
    if len(exp.scenarios) != 1:
        raise ValueError(
            f"run_stream streams one scenario per call "
            f"(got {len(exp.scenarios)}); packed scenario streaming would "
            "re-shape the job axis per scenario")
    sname, setup0 = exp.scenarios[0]
    trace = list(arrivals.events(horizon))
    if not trace:
        raise ValueError("arrival process produced no arrivals below the "
                         f"horizon ({horizon})")
    spec = spec or RingSpec.for_jobs([a.job for a in trace], slots=slots,
                                     split=split)
    for a in trace:
        spec.check(a.job)

    rs = ring_setup([a.job for a in trace[:spec.slots]], setup0.cluster,
                    spec, route_table=setup0.route_table,
                    failures=setup0.failures, ctrl=setup0.ctrl,
                    degradation=setup0.degradation,
                    spec_slots=setup0.spec_slots)
    consts0, meta = make_consts(rs)
    meta = SimMeta.coerce(meta)

    pol_np = {k: np.asarray(v) for k, v in exp.policy_arrays().items()}
    P = len(exp.policies)
    groups: Dict[Tuple[int, ...], List[int]] = {}
    for p in range(P):
        sig = tuple(int(pol_np[f][p]) for f in STATIC_FIELDS)
        groups.setdefault(sig, []).append(p)

    n_slots, T, Pk = spec.slots, spec.tasks_per_slot, spec.pkts_per_slot
    window = window if window is not None else horizon / 20.0
    classes = tuple(getattr(arrivals, "classes", ()) or ())
    n_trace = len(trace)
    if max_chunks is None:
        gens = n_trace // n_slots + 2
        max_chunks = 64 + 4 * gens * (meta.max_steps // chunk_steps + 2)

    stats = StreamStats(lanes=P, trace_len=n_trace, slots=n_slots)
    job_rows: Dict[int, List[tuple]] = {pi: [] for pi in range(P)}
    samples: Dict[int, List[tuple]] = {pi: [(0.0, 0.0, 0.0, 0.0)]
                                       for pi in range(P)}
    finals: Dict[int, Any] = {}
    finals_c: Dict[int, Any] = {}
    chaos: Dict[int, Dict[str, float]] = {}

    for sig, members in groups.items():
        W = len(members)
        # fixed lane <-> member assignment: the CohortSchedule degenerates
        # to its lane map (streaming retires SLOTS, not lanes)
        sched = CohortSchedule(members, W)
        pol_lane = {k: jnp.asarray(v)
                    for k, v in _lane_policies(pol_np, sched).items()}
        chunk = _stream_chunk(meta, sig, chunk_steps, W)
        refill = _stream_refill(meta, W)
        host = host_stream_arrays(consts0, W)
        carry = _stream_init(meta, W)(consts0)
        stats.cohorts += 1
        stats.loads += min(n_slots, n_trace) * W

        occupants: List[List[Optional[int]]] = [
            [i if i < min(n_slots, n_trace) else None
             for i in range(n_slots)] for _ in range(W)]
        ptr = [min(n_slots, n_trace)] * W
        consts_dev = consts0._replace(
            **{f: jnp.asarray(host[f]) for f in STREAM_FIELDS})

        def lane_live(li):
            return (ptr[li] < n_trace
                    or any(o is not None for o in occupants[li]))

        chunks = 0
        while any(lane_live(li) for li in range(W)):
            carry = chunk(consts_dev, pol_lane, carry)
            chunks += 1
            stats.chunks += 1
            if chunks > max_chunks:
                raise RuntimeError(
                    f"stream cohort {sig} exceeded {max_chunks} chunks "
                    "without draining — engine not making progress")
            s = carry[0]
            (done, t_arr, stalled, out_done, done_t, admit_t,
             he, se, hb) = jax.device_get(
                (carry[2], s.time, s.stalled, s.job_out_done, s.job_done_t,
                 s.job_admit_t, s.host_energy, s.switch_energy, s.host_busy))
            job_m = np.zeros((W, n_slots), bool)
            task_m = np.zeros((W, n_slots * T), bool)
            pkt_m = np.zeros((W, n_slots * Pk), bool)
            lane_m = np.zeros(W, bool)
            for li in range(W):
                pi = sched.lane[li]
                occ = occupants[li]
                n_out = host["job_n_out"][li]
                for sl in range(n_slots):
                    if occ[sl] is None:
                        continue
                    if n_out[sl] > 0 and out_done[li, sl] >= n_out[sl]:
                        a = trace[occ[sl]]
                        job_rows[pi].append(
                            (occ[sl], a.cls, a.t,
                             float(admit_t[li, sl]),
                             float(done_t[li, sl])))
                        occ[sl] = None
                        stats.retired += 1
                for sl in range(n_slots):
                    if occ[sl] is None and ptr[li] < n_trace:
                        load_slot(host, spec, li, sl, trace[ptr[li]].job)
                        occ[sl] = ptr[li]
                        ptr[li] += 1
                        job_m[li, sl] = True
                        task_m[li, sl * T:(sl + 1) * T] = True
                        pkt_m[li, sl * Pk:(sl + 1) * Pk] = True
                        lane_m[li] = True
                        stats.loads += 1
                        stats.refills += 1
                loaded = any(o is not None for o in occ)
                if stalled[li] and loaded:
                    raise RuntimeError(
                        f"stream lane {exp.policy_names[pi]!r} stalled at "
                        f"t={float(t_arr[li])} with jobs in flight")
                if done[li] and loaded and not lane_m[li]:
                    raise RuntimeError(
                        f"stream lane {exp.policy_names[pi]!r} exhausted "
                        f"its step budget ({meta.max_steps}) between "
                        "refills — raise chunk capacity or shrink jobs")
                samples[pi].append((float(t_arr[li]), float(he[li].sum()),
                                    float(se[li].sum()),
                                    float(hb[li].sum())))
            if lane_m.any():
                consts_dev = consts0._replace(
                    **{f: jnp.asarray(host[f]) for f in STREAM_FIELDS})
                carry = refill(consts_dev, carry, jnp.asarray(job_m),
                               jnp.asarray(task_m), jnp.asarray(pkt_m),
                               jnp.asarray(lane_m))
        fs = carry[0]
        (c_sl, c_sw, c_ww, c_dg, c_fo, c_fp) = jax.device_get(
            (fs.spec_launches, fs.spec_wins, fs.spec_wasted,
             fs.degraded_time, fs.ctrl_failovers, fs.ctrl_failover_park))
        for li in range(W):
            chaos[sched.lane[li]] = {
                "spec_launches": int(c_sl[li]),
                "spec_wins": int(c_sw[li]),
                "wasted_spec_work_s": float(c_ww[li]),
                "degraded_time_s": float(c_dg[li]),
                "failover_count": int(c_fo[li]),
                "failover_park_s": float(c_fp[li]),
            }
        if return_states:
            host_state = [np.asarray(leaf) for leaf in carry[0]]
            for li in range(W):
                finals[sched.lane[li]] = type(carry[0])(
                    *[leaf[li] for leaf in host_state])
                # the consts this lane's final state actually ran against
                # (its LAST ring generation) — what invariant checkers need
                finals_c[sched.lane[li]] = consts0._replace(
                    **{f: host[f][li].copy() for f in STREAM_FIELDS})

    jobs = {}
    for pi in range(P):
        rows = sorted(job_rows[pi])
        cols = (np.asarray(rows, float).reshape(len(rows), 5).T
                if rows else np.zeros((5, 0)))
        done_col = cols[4]
        jobs[pi] = {
            "seq": cols[0].astype(int), "cls": cols[1].astype(int),
            "t_arr": cols[2], "t_admit": cols[3], "t_done": done_col,
            "sojourn": done_col - cols[2],
        }
    return StreamResults(
        scenario_name=sname, policy_names=exp.policy_names,
        classes=classes, horizon=float(horizon), warmup=float(warmup),
        window_s=float(window), meta=meta, jobs=jobs,
        samples={pi: np.asarray(v, float) for pi, v in samples.items()},
        stats=stats, final_states=finals if return_states else None,
        final_consts=finals_c if return_states else None, chaos=chaos)
