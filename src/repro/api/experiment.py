"""``Experiment`` — the one public front door for running simulations
(DESIGN.md §6).

The paper's Java tool exposes a single simulation facade with pluggable
policy classes (Fig. 8); this is our equivalent.  One declarative
description::

    Experiment(scenarios="paper-fabric",
               policies=[("sdn", PolicyConfig(routing=ROUTE_SDN)),
                         ("legacy", PolicyConfig(routing=ROUTE_LEGACY))],
               seeds=range(3)).run()

covers every execution shape — a single run, a vmapped policy batch on one
fabric, and a packed heterogeneous multi-topology grid — through one
dispatch path and the shared compiled-runner cache (``repro.api.runners``),
returning a ``Results`` grid with pad-job masking built in.
"""
from __future__ import annotations

import dataclasses
from collections import OrderedDict
from typing import Any, List, Mapping, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp

from ..core import policies as policy_mod
from ..core.ctrlplane import CtrlPlaneConfig
from ..core.engine import make_consts
from ..core.failures import DegradationSchedule, FailureSchedule
from ..core.mapreduce import SimSetup
from ..core.policies import as_policy_arrays, policy_fields
from .results import Results
from . import runners

ScenarioLike = Union[str, SimSetup, Any]         # Any: scenarios.Scenario
PolicyLike = Union[None, Mapping, Any]           # Any: PolicyConfig

# Keyed consts cache (DESIGN.md §9): registry scenarios build
# deterministically from their name, so the host-side lowering
# (route-table DFS + packing — ~2.9 s for leaf-spine-xl) is paid once per
# process, not once per Experiment.  Only registry-name scenarios are
# cacheable; Scenario objects / raw SimSetups may differ run to run under
# the same name, and failure crosses mutate the setups after build.
_SETUP_CACHE: "OrderedDict[str, Tuple[str, SimSetup]]" = OrderedDict()
_CONSTS_CACHE: "OrderedDict[Tuple[str, ...], Any]" = OrderedDict()
_CACHE_MAX = 16
_CONSTS_BUILDS = 0


def consts_build_count() -> int:
    """Number of EngineConsts builds (make_consts/pack_setups) since import
    or the last ``consts_cache_clear`` — the regression hook for "one build
    per scenario set per fleet" (tests/test_fleet.py)."""
    return _CONSTS_BUILDS


def consts_cache_clear() -> None:
    """Drop cached setups/consts and zero ``consts_build_count``."""
    global _CONSTS_BUILDS
    _SETUP_CACHE.clear()
    _CONSTS_CACHE.clear()
    _CONSTS_BUILDS = 0


def _lru_put(cache: OrderedDict, key, value) -> None:
    cache[key] = value
    while len(cache) > _CACHE_MAX:
        cache.popitem(last=False)


def _build_scenario(item: ScenarioLike) -> Tuple[str, SimSetup]:
    """-> (name, SimSetup) from a registry name, Scenario, or SimSetup.

    Registry names are memoized in ``_SETUP_CACHE`` — the build is a pure
    function of the name (factories are deterministic, seeds are explicit
    defaults), so a second Experiment over the same name skips the
    host-side lowering entirely."""
    if isinstance(item, str):
        if item in _SETUP_CACHE:
            _SETUP_CACHE.move_to_end(item)
            return _SETUP_CACHE[item]
        from ..scenarios import get_scenario    # local: scenarios uses core
        sc = get_scenario(item)
        built = (sc.name, sc.build())
        _lru_put(_SETUP_CACHE, item, built)
        return built
    if isinstance(item, SimSetup):
        return "scenario", item
    if hasattr(item, "build"):                   # scenarios.Scenario
        return getattr(item, "name", "scenario"), item.build()
    raise TypeError(f"cannot interpret {type(item).__name__} as a scenario")


def _policy_label(pol) -> str:
    """Descriptive auto-name: the non-default axes, by their branch names."""
    arrs = as_policy_arrays(pol)
    parts = []
    for f in policy_fields():
        v = arrs[f.name]
        if v.ndim or int(v) == f.default:
            continue
        parts.append(f.choice_name(int(v)) if f.choices
                     else f"{f.name}={int(v)}")
    return "/".join(parts) or "default"


def _is_pair(item, *, in_sequence: bool) -> bool:
    """A ``(name, item)`` pair.  Inside a sequence ANY 2-tuple with a str
    head is a pair (legit items are never tuples, so ``("mine",
    "canonical-tree")`` names a registry scenario); at top level a
    ``(str, str)`` tuple is instead read as a sequence of two items — wrap
    a name-names-a-name pair in a list to disambiguate."""
    return (isinstance(item, tuple) and len(item) == 2
            and isinstance(item[0], str)
            and (in_sequence or not isinstance(item[1], str)))


def _normalize(items, build_one, what: str) -> List[Tuple[str, Any]]:
    """-> [(name, obj)] from one item, a sequence, or (name, item) pairs."""
    if items is None:
        items = [None] if what == "policy" else []
    elif (_is_pair(items, in_sequence=False)
          or not isinstance(items, (list, tuple))):
        items = [items]
    out = []
    for item in items:
        if _is_pair(item, in_sequence=True):
            name, obj = item[0], build_one(item[1])[1]
        else:
            name, obj = build_one(item)
        out.append((name, obj))
    if not out:
        raise ValueError(f"Experiment needs at least one {what}")
    # disambiguate duplicate auto-names
    seen: dict = {}
    named = []
    for name, obj in out:
        n = seen.get(name, 0)
        seen[name] = n + 1
        named.append((f"{name}#{n}" if n else name, obj))
    return named


class Experiment:
    """A declarative simulation experiment: scenarios × policies × seeds.

    Parameters
    ----------
    scenarios:
        One or a sequence of: a registered scenario name (``"leaf-spine"``),
        a ``scenarios.Scenario``, a raw ``SimSetup``, or a ``(name, any of
        those)`` pair.  (One ambiguity: a TOP-LEVEL ``(str, str)`` tuple is
        read as two scenario names; wrap it in a list —
        ``[("mine", "canonical-tree")]`` — to mean a named pair.)  Multiple
        scenarios are padded + renumbered into one packed batch
        (DESIGN.md §5).
    policies:
        One or a sequence of: a ``PolicyConfig``, a partial mapping of
        registered policy fields (defaults fill the gaps), or a ``(name,
        policy)`` pair.  ``None`` runs the registered defaults.
    seeds:
        Optional ints; each policy is replicated per seed (its ``seed``
        field replaced), so ``P = len(policies) * len(seeds)``.
    failures:
        Optional failure schedules (DESIGN.md §7).  One or a sequence of:
        a ``FailureSchedule``, a callable ``(SimSetup) -> FailureSchedule``
        (e.g. ``scenarios.failures.failure_injector`` — lets one spec fit
        every topology), or a ``(name, either)`` pair.  Each scenario is
        replicated per schedule, so the scenario axis becomes
        ``S = len(scenarios) * len(failures)`` — the failure-rate axis of
        ``benchmarks/failure_sweep.py``.
    ctrl:
        Optional control-plane configs (DESIGN.md §10).  One or a sequence
        of: a ``CtrlPlaneConfig`` or a ``(name, config)`` pair.  Each
        scenario is replicated per config — the install-latency axis of
        ``benchmarks/ctrl_sweep.py``.  Composes with ``failures`` (the
        cross is failures × ctrl per scenario).
    degradation:
        Optional gray-failure schedules (DESIGN.md §13).  One or a
        sequence of: a ``DegradationSchedule``, a callable
        ``(SimSetup) -> DegradationSchedule`` (e.g.
        ``scenarios.failures.degradation_injector``), or a ``(name,
        either)`` pair.  Each scenario is replicated per schedule —
        the severity axis of ``benchmarks/chaos_sweep.py``.  Composes
        with ``failures`` and ``ctrl``.
    """

    def __init__(self, scenarios: Any, policies: Any = None,
                 seeds: Optional[Sequence[int]] = None,
                 failures: Any = None, ctrl: Any = None,
                 degradation: Any = None):
        # consts are cacheable across Experiments only when every scenario
        # is a bare registry name (deterministic rebuild) and no failure /
        # ctrl / degradation cross mutates the setups afterwards
        items = (list(scenarios)
                 if isinstance(scenarios, (list, tuple))
                 and not _is_pair(scenarios, in_sequence=False)
                 else [scenarios])
        self._consts_key = (tuple(items)
                            if failures is None and ctrl is None
                            and degradation is None
                            and all(isinstance(i, str) for i in items)
                            else None)
        self.scenarios: List[Tuple[str, SimSetup]] = _normalize(
            scenarios, _build_scenario, "scenario")
        if failures is not None:
            self.scenarios = _cross_failures(self.scenarios, failures)
        if degradation is not None:
            self.scenarios = _cross_degradation(self.scenarios, degradation)
        if ctrl is not None:
            self.scenarios = _cross_ctrl(self.scenarios, ctrl)
        pols = _normalize(
            policies, lambda p: (_policy_label(p), p), "policy")
        if seeds is not None:
            seeds = list(seeds)
            if not seeds:
                raise ValueError("seeds must be non-empty when given")
            pols = [(f"{name}/s{seed}" if len(seeds) > 1 else name,
                     _with_seed(pol, seed))
                    for name, pol in pols for seed in seeds]
        self.policies: List[Tuple[str, Any]] = pols
        # the grid is immutable after __init__, so packing/stacking happens
        # once: repeated .run() calls are pack-free as well as trace-free
        self._built = None
        self._pol_arrays = None

    # -- derived views ------------------------------------------------------

    @property
    def scenario_names(self) -> List[str]:
        return [n for n, _ in self.scenarios]

    @property
    def policy_names(self) -> List[str]:
        return [n for n, _ in self.policies]

    def build(self):
        """-> (consts, SimMeta): unpacked for one scenario, packed (leading
        scenario dim) for several.  Memoized per instance, and — for
        registry-name scenario sets without failure crosses — in the
        process-wide keyed consts cache, so a fleet of Experiments over the
        same grid pays for one build total (``consts_build_count``)."""
        if self._built is None:
            key = self._consts_key
            if key is not None and key in _CONSTS_CACHE:
                _CONSTS_CACHE.move_to_end(key)
                self._built = _CONSTS_CACHE[key]
                return self._built
            global _CONSTS_BUILDS
            _CONSTS_BUILDS += 1
            if len(self.scenarios) == 1:
                self._built = make_consts(self.scenarios[0][1])
            else:
                from ..scenarios.sweep import pack_setups
                self._built = pack_setups([s for _, s in self.scenarios])
            if key is not None:
                _lru_put(_CONSTS_CACHE, key, self._built)
        return self._built

    def policy_arrays(self):
        """Registry-ordered ``[P]``-shaped policy arrays (memoized)."""
        if self._pol_arrays is None:
            stacked = [as_policy_arrays(p) for _, p in self.policies]
            self._pol_arrays = {k: jnp.stack([s[k] for s in stacked])
                                for k in stacked[0]}
        return self._pol_arrays

    # -- execution ----------------------------------------------------------

    def run(self) -> Results:
        """Execute the whole grid through the cached compiled runner."""
        S, P = len(self.scenarios), len(self.policies)
        consts, meta = self.build()
        pols = self.policy_arrays()
        if S == 1 and P == 1:
            pols = jax.tree_util.tree_map(lambda a: a[0], pols)
            states = runners.get_runner(meta, "single")(consts, pols)
            expand = lambda a: a[None, None]                  # noqa: E731
        elif S == 1:
            states = runners.get_runner(meta, "policy_batch")(consts, pols)
            expand = lambda a: a[None]                        # noqa: E731
        else:
            states = runners.get_runner(meta, "grid")(consts, pols)
            expand = None
        if expand is not None:
            states = jax.tree_util.tree_map(expand, states)
        if S == 1:   # Results keeps a scenario axis on consts
            consts = jax.tree_util.tree_map(lambda a: a[None], consts)
        return Results(states=states, consts=consts, meta=meta,
                       scenario_names=self.scenario_names,
                       policy_names=self.policy_names)

    def run_fleet(self, width: int = 32, chunk_steps: int = 32,
                  **kw) -> Results:
        """Execute the grid through the fleet engine (DESIGN.md §9):
        chunked early-exit cohorts grouped by static policy signature,
        sharded across devices when more than one is visible.  Bit-identical
        to ``run()``; strictly faster once the grid is wider than a few
        sims.  Extra keywords pass through to ``fleet.run_fleet``."""
        from .fleet import run_fleet
        return run_fleet(self, width=width, chunk_steps=chunk_steps, **kw)

    def run_stream(self, arrivals, horizon: float, *, warmup: float = 0.0,
                   window: Optional[float] = None, slots: int = 32,
                   chunk_steps: int = 128, **kw):
        """Stream an open arrival process through the experiment's (single)
        scenario for every policy (DESIGN.md §11): the job/task/packet
        tensors become a ``slots``-deep recycling ring refilled from
        ``arrivals`` (``repro.scenarios.arrivals``) at chunk boundaries, so
        an unbounded trace runs in bounded memory.  Returns a
        ``StreamResults`` with per-window p50/p99 sojourn, throughput,
        utilization, energy, and per-class SLO attainment; completions
        before ``warmup`` are excluded from ``summary()``.  A finite trace
        that fits ``slots`` reproduces ``run()`` on the equivalent
        ``streaming.ring_setup`` bitwise (tests/test_streaming.py).  Extra
        keywords pass through to ``stream.run_stream``."""
        from .stream import run_stream
        return run_stream(self, arrivals, horizon, warmup=warmup,
                          window=window, slots=slots,
                          chunk_steps=chunk_steps, **kw)


def _cross_failures(scenarios: List[Tuple[str, SimSetup]],
                    failures: Any) -> List[Tuple[str, SimSetup]]:
    """Replicate every scenario per failure schedule (names suffixed with
    the schedule label when there is more than one)."""
    if isinstance(failures, (FailureSchedule,)) or callable(failures) \
            or _is_pair(failures, in_sequence=False):
        failures = [failures]
    named = []
    for fi, item in enumerate(failures):
        if _is_pair(item, in_sequence=True):
            fname, spec = item
        else:
            fname, spec = f"f{fi}", item
        named.append((fname, spec))
    out = []
    for sname, setup in scenarios:
        for fname, spec in named:
            sched = spec(setup) if callable(spec) else spec
            if not isinstance(sched, FailureSchedule):
                raise TypeError(
                    f"cannot interpret {type(sched).__name__} as a "
                    "FailureSchedule")
            topo = setup.cluster.topo
            sched.validate(topo.n_hosts, topo.n_links)
            name = f"{sname}/{fname}" if len(named) > 1 else sname
            out.append((name, dataclasses.replace(setup, failures=sched)))
    return out


def _cross_degradation(scenarios: List[Tuple[str, SimSetup]],
                       degradation: Any) -> List[Tuple[str, SimSetup]]:
    """Replicate every scenario per degradation schedule (names suffixed
    with the schedule label when there is more than one) — mirrors
    ``_cross_failures`` for the DESIGN.md §13 gray-failure axis."""
    if isinstance(degradation, DegradationSchedule) \
            or callable(degradation) \
            or _is_pair(degradation, in_sequence=False):
        degradation = [degradation]
    named = []
    for di, item in enumerate(degradation):
        if _is_pair(item, in_sequence=True):
            dname, spec = item
        else:
            dname, spec = f"d{di}", item
        named.append((dname, spec))
    out = []
    for sname, setup in scenarios:
        for dname, spec in named:
            sched = spec(setup) if callable(spec) else spec
            if not isinstance(sched, DegradationSchedule):
                raise TypeError(
                    f"cannot interpret {type(sched).__name__} as a "
                    "DegradationSchedule")
            topo = setup.cluster.topo
            sched.validate(topo.n_hosts, topo.n_links)
            name = f"{sname}/{dname}" if len(named) > 1 else sname
            out.append((name, dataclasses.replace(setup,
                                                  degradation=sched)))
    return out


def _cross_ctrl(scenarios: List[Tuple[str, SimSetup]],
                ctrl: Any) -> List[Tuple[str, SimSetup]]:
    """Replicate every scenario per control-plane config (names suffixed
    with the config label when there is more than one) — mirrors
    ``_cross_failures`` for the DESIGN.md §10 axis."""
    if isinstance(ctrl, CtrlPlaneConfig) \
            or _is_pair(ctrl, in_sequence=False):
        ctrl = [ctrl]
    named = []
    for ci, item in enumerate(ctrl):
        if _is_pair(item, in_sequence=True):
            cname, cfg = item
        else:
            cname, cfg = f"c{ci}", item
        if not isinstance(cfg, CtrlPlaneConfig):
            raise TypeError(
                f"cannot interpret {type(cfg).__name__} as a "
                "CtrlPlaneConfig")
        named.append((cname, cfg.validate()))
    out = []
    for sname, setup in scenarios:
        for cname, cfg in named:
            name = f"{sname}/{cname}" if len(named) > 1 else sname
            out.append((name, dataclasses.replace(setup, ctrl=cfg)))
    return out


def _with_seed(pol, seed: int):
    """A copy of ``pol`` with its ``seed`` policy field replaced."""
    if pol is None:
        return policy_mod.PolicyConfig(seed=seed)
    if isinstance(pol, Mapping):
        return {**pol, "seed": seed}
    return pol.replace(seed=seed)
