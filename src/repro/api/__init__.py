"""``repro.api`` — the unified experiment front door (DESIGN.md §6).

    from repro.api import Experiment, PolicyConfig, SimMeta

* ``Experiment(scenarios=…, policies=…, seeds=…)`` declares a run grid and
  ``.run()`` executes it — single run, policy batch, or packed
  heterogeneous multi-topology sweep — through one dispatch path.
* ``SimMeta`` is the typed, frozen, hashable static description of a
  compiled program; it keys the compiled-runner cache (``runners``) so
  repeated runs with equal meta never retrace.
* ``Results`` is the one result surface (per-job reports, energy, rows)
  with pad-job masking built in.
* Policy axes are declared once in the policy-field registry
  (``repro.core.policies``); ``PolicyConfig`` and all packing/unpacking
  derive from it.

The older ``repro.core.simulate``/``simulate_batch``/``simulate_scenarios``
and ``repro.scenarios.sweep_grid`` entry points remain as thin deprecated
shims over this module, proven bit-identical by ``tests/test_api.py``.
"""
from ..core.policies import (PolicyConfig, PolicyField, as_policy_arrays,
                             policy_defaults, policy_field_names,
                             policy_fields, register_policy_field)
from ..core.simmeta import SimMeta
from .experiment import (Experiment, consts_build_count, consts_cache_clear)
from .fleet import CohortSchedule, FleetStats, StepPredictor, run_fleet
from .results import Results
from .stream import StreamResults, StreamStats, run_stream
from . import runners
from .runners import get_runner

__all__ = [
    "Experiment", "Results", "SimMeta",
    "PolicyConfig", "PolicyField", "as_policy_arrays", "policy_defaults",
    "policy_field_names", "policy_fields", "register_policy_field",
    "runners", "get_runner",
    "run_fleet", "FleetStats", "StepPredictor", "CohortSchedule",
    "run_stream", "StreamResults", "StreamStats",
    "consts_build_count", "consts_cache_clear",
]
