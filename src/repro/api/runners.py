"""Compiled-runner cache: one traced engine program per (SimMeta, batch
shape), shared by every entry point (DESIGN.md §6).

``simulate`` used to rebuild ``jax.jit(make_simulator(setup))`` on every
call, throwing the trace away each time.  Here the jitted callable is cached
under the run's hashable ``SimMeta`` plus the batch kind, so a second run
with an equal meta (and equal tensor shapes — jax.jit keys on those) reuses
the compiled program with ZERO retraces.  ``trace_count()`` exposes the
number of engine traces for tests/benchmarks to assert exactly that.

Batch kinds (all funnel into ``make_packed_simulator``'s ``run(consts,
pol)``):

==============  =============================  ==========================
kind            consts                         policies
==============  =============================  ==========================
"single"        unbatched                      unbatched dict
"policy_batch"  unbatched (broadcast)          leading policy dim [P]
"zipped"        leading replica dim [R]        leading replica dim [R]
"grid"          leading scenario dim [S]       leading policy dim [P]
==============  =============================  ==========================

"grid" nests the vmaps (scenarios outer, policies inner) so the dense
consts tensors broadcast across the policy axis instead of being
materialized P times (DESIGN.md §5).

The t=0 state is built by a separate (cached, jitted) initializer and
passed into the main program as a DONATED argument (DESIGN.md §8): XLA
aliases the init buffers straight into the while-loop carry and the final
``SimState`` outputs instead of materializing a second copy per replica.
(Buffer donation is a no-op on the CPU backend, so it is only requested
elsewhere.)
"""
from __future__ import annotations

from collections import OrderedDict
from typing import Callable, Tuple

import jax

from ..core.engine import init_state_from_consts, make_packed_simulator
from ..core.simmeta import SimMeta

KINDS = ("single", "policy_batch", "zipped", "grid")

# LRU-bounded: each entry retains a jitted callable plus its compiled XLA
# executables, and callers like roofline/advisor produce a fresh SimMeta per
# candidate schedule — without eviction a long-running process would leak
# one executable per shape ever seen.
CACHE_MAX = 64
_CACHE: "OrderedDict[Tuple, Callable]" = OrderedDict()
_TRACE_COUNT = 0


def trace_count() -> int:
    """Total engine traces since import (or the last ``cache_clear``)."""
    return _TRACE_COUNT


def cache_size() -> int:
    return len(_CACHE)


def cache_clear() -> None:
    """Drop all cached programs and reset the trace counter (tests)."""
    global _TRACE_COUNT
    _CACHE.clear()
    _TRACE_COUNT = 0


def get_cached_program(key: Tuple, builder: Callable[[], Callable]) -> Callable:
    """The shared program cache: ``builder()`` runs at most once per ``key``
    (hashable tuple), its result LRU-retained up to ``CACHE_MAX`` entries.
    ``get_runner`` and the fleet layer (``api.fleet``, DESIGN.md §9) both
    park their jitted chunk/runner programs here, so one ``cache_clear``
    resets everything tests care about."""
    if key not in _CACHE:
        _CACHE[key] = builder()
        while len(_CACHE) > CACHE_MAX:
            _CACHE.popitem(last=False)
    _CACHE.move_to_end(key)
    return _CACHE[key]


def note_trace() -> None:
    """Bump the trace counter — called at TRACE time from inside a traced
    function, so jit-cache hits don't count (see ``_build.counted``)."""
    global _TRACE_COUNT
    _TRACE_COUNT += 1


def get_runner(meta: SimMeta, kind: str) -> Callable:
    """The cached jitted ``run(consts, pols) -> SimState`` for this meta.

    The returned callable is a ``jax.jit`` wrapper: calling it with tensor
    shapes it has already seen is trace-free; new shapes (e.g. a different
    job count under the same meta) trace once and are cached by jit itself.
    """
    meta = SimMeta.coerce(meta)
    if kind not in KINDS:
        raise ValueError(f"unknown runner kind {kind!r}; one of {KINDS}")
    return get_cached_program((meta, kind), lambda: _build(meta, kind))


def donation_argnums(backend: str | None = None) -> Tuple[int, ...]:
    """The donation policy shared by every jitted engine program (here and
    ``api.fleet._chunk_program``): argument 2 — the t=0 state / chunk
    carry — is donated so XLA aliases the init buffers straight into the
    while-loop carry and final outputs, EXCEPT on the CPU backend, which
    has no donation support and would warn on every call.  Audited by the
    static analyzer (jaxcheck:donation, DESIGN.md §12)."""
    backend = backend or jax.default_backend()
    return () if backend == "cpu" else (2,)


def traced_jaxpr(meta: SimMeta, kind: str, consts, pols):
    """Static-analysis hook (DESIGN.md §12): the engine program exactly as
    ``get_runner`` would jit it, traced to a ClosedJaxpr without
    compiling, plus the number of trailing flat inputs that belong to the
    donated t=0 state argument.  Neither the program cache nor the trace
    counter is touched — ``trace_count()`` assertions stay exact."""
    meta = SimMeta.coerce(meta)
    if kind not in KINDS:
        raise ValueError(f"unknown runner kind {kind!r}; one of {KINDS}")
    fn, init = _make_fn(meta, kind, counted=False)
    s0 = jax.eval_shape(init, consts, pols)
    closed = jax.make_jaxpr(fn)(consts, pols, s0)
    return closed, len(jax.tree_util.tree_leaves(s0))


def _make_fn(meta: SimMeta, kind: str, counted: bool = True):
    """(run_fn, init_fn) for one batch kind, before jit — shared by the
    runner cache (``_build``) and the analysis hook (``traced_jaxpr``)."""
    base = make_packed_simulator(meta)

    def counted_fn(consts, pol, s0):
        # executes at TRACE time only — the compiled program has no trace
        # of it, so the counter counts traces, not runs.
        if counted:
            note_trace()
        return base(consts, pol, s0)

    def init_one(consts, pol):
        del pol  # the t=0 state depends on consts only; pol carries the
        #          batch axes the vmapped variants map over
        return init_state_from_consts(consts, meta.n_switches,
                                      meta.ctrl_slots, meta.spec_slots)

    if kind == "single":
        fn, init = counted_fn, init_one
    elif kind == "policy_batch":
        fn = jax.vmap(counted_fn, in_axes=(None, 0, 0))
        init = jax.vmap(init_one, in_axes=(None, 0))
    elif kind == "zipped":
        fn = jax.vmap(counted_fn)
        init = jax.vmap(init_one)
    else:  # grid: scenarios outer, policies inner
        def fn(consts, pols, s0):
            return jax.vmap(lambda c, s0c: jax.vmap(
                lambda p, s0p: counted_fn(c, p, s0p))(pols, s0c))(consts, s0)

        def init(consts, pols):
            return jax.vmap(lambda c: jax.vmap(
                lambda p: init_one(c, p))(pols))(consts)

    return fn, init


def _build(meta: SimMeta, kind: str) -> Callable:
    fn, init = _make_fn(meta, kind)
    run_jit = jax.jit(fn, donate_argnums=donation_argnums())
    init_jit = jax.jit(init)

    def call(consts, pols):
        return run_jit(consts, pols, init_jit(consts, pols))

    return call
