"""``Results`` — one result surface for every experiment shape
(DESIGN.md §6).

Unifies what used to be four disjoint extraction paths (``job_report`` for
single runs, ``job_report_consts`` for packed batches, ``summarize`` for
host-side numpy, ``SweepResult.rows`` for grids): states are always held as
a ``[S, P, ...]`` grid (S scenarios × P policies, both possibly 1) and every
accessor masks pad jobs via ``consts.job_valid`` before aggregating, so a
padded heterogeneous batch and a single run read identically.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, List

import jax
import numpy as np

from ..core.engine import EngineConsts, SimState
from ..core.report import energy_report, job_report_arrays
from ..core.simmeta import SimMeta


def _finite_mean(a: np.ndarray) -> float:
    """Mean over finite entries; NaN when none (e.g. a stalled replica)."""
    a = a[np.isfinite(a)]
    return float(a.mean()) if a.size else float("nan")


@dataclasses.dataclass
class Results:
    """Final states of an ``Experiment`` run.

    ``states`` leaves are ``[S, P, ...]``; ``consts`` leaves keep the
    scenario axis only (``[S, ...]``) — policy replicas share them.
    """

    states: SimState           # leaves [S, P, ...]
    consts: EngineConsts       # leaves [S, ...]
    meta: SimMeta
    scenario_names: List[str]  # [S]
    policy_names: List[str]    # [P]
    # report caches — states are final, so each grid report computes once
    _jr: dict = dataclasses.field(default=None, repr=False, compare=False)
    _er: dict = dataclasses.field(default=None, repr=False, compare=False)

    @property
    def n_scenarios(self) -> int:
        return len(self.scenario_names)

    @property
    def n_policies(self) -> int:
        return len(self.policy_names)

    def __len__(self) -> int:
        return self.n_scenarios * self.n_policies

    # -- raw state access ---------------------------------------------------

    def state(self, scenario: int = 0, policy: int = 0) -> SimState:
        """The unbatched final SimState of one (scenario, policy) cell."""
        return jax.tree_util.tree_map(
            lambda a: a[scenario, policy], self.states)

    # -- reports (pad-job masking built in) ----------------------------------

    def job_report(self) -> Dict[str, np.ndarray]:
        """Per-job metrics (paper Eqs. 6–9), every array ``[S, P, N_J]``.

        Pad jobs of a packed heterogeneous batch are NaN — aggregate with
        nan-aware reductions and the numbers match the unpadded runs."""
        if self._jr is None:
            c = self.consts
            rep = jax.vmap(lambda ci, row: jax.vmap(
                lambda s: job_report_arrays(ci.pkt_job, ci.pkt_phase,
                                            ci.task_job, ci.task_kind,
                                            ci.job_release, s))(row)
            )(c, self.states)
            valid = np.asarray(c.job_valid)[:, None, :]   # [S, 1, N_J]
            self._jr = {k: np.where(valid, np.asarray(v), np.nan)
                        for k, v in rep.items()}
        return self._jr

    def energy_report(self) -> Dict[str, np.ndarray]:
        """Energy + makespan, every array ``[S, P]``."""
        if self._er is None:
            rep = jax.vmap(jax.vmap(energy_report))(self.states)
            self._er = {k: np.asarray(v) for k, v in rep.items()}
        return self._er

    def summary(self, scenario: int = 0, policy: int = 0
                ) -> Dict[str, np.ndarray]:
        """One cell's full report as numpy (the old ``summarize`` shape)."""
        jr = {k: v[scenario, policy] for k, v in self.job_report().items()}
        er = {k: v[scenario, policy] for k, v in self.energy_report().items()}
        s = self.state(scenario, policy)
        return {**jr, **er,
                "stalled": np.asarray(s.stalled),
                "steps": np.asarray(s.steps)}

    def rows(self) -> List[Dict[str, Any]]:
        """Per-cell scalar summary, scenario-major (the old
        ``SweepResult.rows`` shape): valid-job completion/transmission
        means, energy, makespan, stall flag, and the recovery totals
        (re-executed tasks, rerouted packets, summed downtime —
        DESIGN.md §7; all zero without a failure schedule) plus the
        control-plane totals (flow-rule installs/evictions/reinstalls,
        packet install wait, controller queueing, VM migrations —
        DESIGN.md §10; all zero without a ctrl config) plus the chaos
        totals (speculative clone launches/wins/wasted work, degraded
        wall-clock, controller failovers and parked request time —
        DESIGN.md §13; all zero when those features are off)."""
        jr = self.job_report()
        er = self.energy_report()
        stalled = np.asarray(self.states.stalled)
        steps = np.asarray(self.states.steps)
        installs = np.asarray(self.states.ctrl_installs)
        evictions = np.asarray(self.states.ctrl_evictions)
        reinstalls = np.asarray(self.states.ctrl_reinstalls)
        queue_wait = np.asarray(self.states.ctrl_queue_wait)
        migrations = np.asarray(self.states.vm_migrations).sum(axis=-1)
        spec_launches = np.asarray(self.states.spec_launches)
        spec_wins = np.asarray(self.states.spec_wins)
        spec_wasted = np.asarray(self.states.spec_wasted)
        degraded = np.asarray(self.states.degraded_time)
        failovers = np.asarray(self.states.ctrl_failovers)
        failover_park = np.asarray(self.states.ctrl_failover_park)
        out = []
        for si, sn in enumerate(self.scenario_names):
            for pi, pn in enumerate(self.policy_names):
                out.append({
                    "scenario": sn,
                    "policy": pn,
                    "mean_completion_s": _finite_mean(
                        jr["completion_measured"][si, pi]),
                    "mean_transmission_s": _finite_mean(
                        jr["transmission_time"][si, pi]),
                    "energy_kwh": float(er["total_energy_j"][si, pi]) / 3.6e6,
                    "makespan_s": float(er["makespan_s"][si, pi]),
                    "stalled": bool(stalled[si, pi]),
                    "steps": int(steps[si, pi]),
                    "task_reexecs": int(np.nansum(
                        jr["task_reexecs"][si, pi])),
                    "pkt_reroutes": int(np.nansum(
                        jr["pkt_reroutes"][si, pi])),
                    "downtime_s": float(np.nansum(
                        jr["downtime_s"][si, pi])),
                    "install_wait_s": float(np.nansum(
                        jr["install_wait_s"][si, pi])),
                    "rule_installs": int(installs[si, pi]),
                    "rule_evictions": int(evictions[si, pi]),
                    "rule_reinstalls": int(reinstalls[si, pi]),
                    "ctrl_queue_wait_s": float(queue_wait[si, pi]),
                    "vm_migrations": int(migrations[si, pi]),
                    "spec_launches": int(spec_launches[si, pi]),
                    "spec_wins": int(spec_wins[si, pi]),
                    "wasted_spec_work_s": float(spec_wasted[si, pi]),
                    "degraded_time_s": float(degraded[si, pi]),
                    "failover_count": int(failovers[si, pi]),
                    "failover_park_s": float(failover_park[si, pi]),
                })
        return out
