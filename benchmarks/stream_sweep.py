"""Streaming sweep: sustained jobs/s and p99 sojourn across arrival rates
x routing, through the slot-recycling ring (DESIGN.md §11).

The finite-sweep benchmarks answer "how fast does a fixed job list
drain"; this one answers the steady-state question the streaming engine
exists for — what sustained load each routing policy holds and at what
tail latency — while also timing the ring itself (retire/refill + chunk
cadence) as wall-clock jobs/s.

The JSON report (``--json experiments/BENCH_stream.json``) is the
committed streaming perf trajectory; CI re-runs the same grid and fails
when aggregate wall-clock jobs/s regresses more than ``--max-regress``
(default 20%).

  PYTHONPATH=src python benchmarks/stream_sweep.py
  PYTHONPATH=src python benchmarks/stream_sweep.py \
      --json experiments/BENCH_stream.json
  PYTHONPATH=src python benchmarks/stream_sweep.py \
      --baseline experiments/BENCH_stream.json --max-regress 0.2
"""
import argparse
import json
import sys
import time

import jax
import numpy as np

try:
    from . import _cli            # python -m benchmarks.<name>
except ImportError:
    import _cli                   # python benchmarks/<name>.py

from repro.api import Experiment
from repro.core import PolicyConfig, ROUTE_LEGACY, ROUTE_SDN
from repro.scenarios import get_scenario
from repro.scenarios.registry import stream_arrivals

SCENARIO = "leaf-spine"
POLICIES = [
    ("sdn", PolicyConfig(routing=ROUTE_SDN, job_concurrency=4)),
    ("legacy", PolicyConfig(routing=ROUTE_LEGACY, job_concurrency=4)),
]


def run_rate(setup, rate: float, horizon: float, slots: int,
             chunk_steps: int) -> dict:
    """One open-arrival run at ``rate`` jobs/s; both routings ride as lanes
    of the same trace, so the comparison shares every arrival instant."""
    exp = Experiment(scenarios=(SCENARIO, setup), policies=POLICIES)
    arrivals = stream_arrivals(rate=rate, seed=0)
    t0 = time.perf_counter()
    res = exp.run_stream(arrivals, horizon, warmup=0.1 * horizon,
                         slots=slots, chunk_steps=chunk_steps)
    # sync before reading the clock so wall_jobs_per_s measures the
    # computation, not async dispatch (jaxcheck:naked-timer)
    jax.block_until_ready(res.jobs)
    wall = time.perf_counter() - t0
    jobs_total = sum(res.jobs[pi]["seq"].size for pi in range(res.n_policies))
    row = {
        "rate_jobs_s": rate,
        "trace_len": res.stats.trace_len,
        "refills": res.stats.refills,
        "chunks": res.stats.chunks,
        "wall_s": wall,
        "wall_jobs_per_s": jobs_total / wall,
        "policies": {},
    }
    for pi, pname in enumerate(res.policy_names):
        sm = res.summary(pi)
        row["policies"][pname] = {
            "throughput_jobs_s": sm["throughput_jobs_s"],
            "p50_sojourn_s": sm["p50_sojourn_s"],
            "p99_sojourn_s": sm["p99_sojourn_s"],
            "energy_j": sm["energy_j"],
            "slo": {k: v["attainment"] for k, v in sm["classes"].items()},
        }
    return row


def check_regression(report: dict, baseline_path: str,
                     max_regress: float) -> int:
    with open(baseline_path) as f:
        base = json.load(f)
    cur = report["aggregate_wall_jobs_per_s"]
    ref = base["aggregate_wall_jobs_per_s"]
    floor = ref * (1.0 - max_regress)
    status = "OK" if cur >= floor else "REGRESSED"
    print(f"stream gate: {cur:.1f} jobs/s vs baseline {ref:.1f} "
          f"(floor {floor:.1f}) {status}")
    if status != "OK":
        print(f"wall-clock jobs/s regression > {max_regress:.0%} "
              "(refresh the baseline in-PR if intentional)")
        return 1
    return 0


# the cold_s timer here deliberately measures wall clock INCLUDING
# compile and dispatch (run_rate syncs internally before returning)
def main(argv=None) -> int:  # jaxcheck: disable=naked-timer
    ap = argparse.ArgumentParser()
    ap.add_argument("--rates", nargs="+", type=float,
                    default=[0.05, 0.1, 0.2],
                    help="open arrival rates (jobs/s)")
    ap.add_argument("--horizon", type=float, default=1500.0,
                    help="arrival horizon (seconds of simulated time)")
    ap.add_argument("--slots", type=int, default=8,
                    help="ring capacity (jobs resident per lane)")
    ap.add_argument("--chunk-steps", type=int, default=128,
                    help="events per jitted chunk (K)")
    _cli.add_json_arg(ap)
    _cli.add_gate_args(ap, "BENCH_stream.json",
                       "allowed fractional wall-clock jobs/s drop")
    args = ap.parse_args(argv)

    setup = get_scenario(SCENARIO, n_jobs=2).build()
    # cold pass at the smallest rate compiles the chunk/refill/init
    # programs (one meta: the ring geometry is rate-independent)
    t0 = time.perf_counter()
    run_rate(setup, args.rates[0], min(args.horizon, 100.0), args.slots,
             args.chunk_steps)
    cold_s = time.perf_counter() - t0

    rows = []
    hdr = (f"{'rate':>6} {'jobs':>6} {'refills':>8} {'wall(s)':>8} "
           f"{'jobs/s(wall)':>13}  p99 sojourn (s) by policy")
    print(hdr)
    print("-" * len(hdr))
    for rate in args.rates:
        row = run_rate(setup, rate, args.horizon, args.slots,
                       args.chunk_steps)
        rows.append(row)
        p99s = "  ".join(
            f"{pn}={pv['p99_sojourn_s']:.1f}"
            for pn, pv in row["policies"].items())
        print(f"{rate:6.2f} {row['trace_len']:6d} {row['refills']:8d} "
              f"{row['wall_s']:8.2f} {row['wall_jobs_per_s']:13.1f}  {p99s}")

    wall = sum(r["wall_s"] for r in rows)
    jobs = sum(r["trace_len"] for r in rows) * len(POLICIES)
    report = {
        "benchmark": "stream_sweep",
        "backend": jax.default_backend(),
        "scenario": SCENARIO,
        "horizon_s": args.horizon,
        "slots": args.slots,
        "chunk_steps": args.chunk_steps,
        "cold_s": cold_s,
        "wall_s": wall,
        "aggregate_wall_jobs_per_s": jobs / wall,
        "rates": rows,
    }
    # sanity: the shared-trace design means both lanes retired every job
    for r in rows:
        for pv in r["policies"].values():
            assert np.isfinite(pv["p99_sojourn_s"])

    _cli.write_report(report, args.json)
    return _cli.gate(report, args, check_regression)


if __name__ == "__main__":
    sys.exit(main())
