"""Simulator scaling benchmark (beyond paper): events/sec and the vmapped
policy-sweep capability the Java original lacks (one scenario per JVM run
vs thousands of replicas per tensor program here).

Runs through the unified ``repro.api`` front door (DESIGN.md §6): the
compiled-runner cache makes the compile-once / run-many split explicit.
"""
from __future__ import annotations

import json
import time
from typing import Dict

import jax

from repro.api import Experiment, PolicyConfig, runners
from repro.core import ROUTE_LEGACY, ROUTE_SDN, paper_setup
from repro.core.engine import make_consts
from repro.core.policies import as_policy_arrays


def single_run_events_per_sec(setup) -> Dict[str, float]:
    consts, meta = make_consts(setup)
    run = runners.get_runner(meta, "single")
    pol = as_policy_arrays(PolicyConfig())
    jax.block_until_ready(consts)   # device transfer outside the timers
    t0 = time.perf_counter()
    s = run(consts, pol)
    jax.block_until_ready(s.time)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    n = 5
    for _ in range(n):
        s = run(consts, pol)
        jax.block_until_ready(s.time)
    dt = (time.perf_counter() - t0) / n
    return {"events": int(s.steps), "run_s": dt,
            "events_per_s": float(s.steps) / dt, "compile_s": compile_s}


def sweep_scaling(setup, widths=(1, 8, 32)) -> Dict[str, Dict]:
    out = {}
    for w in widths:
        pols = [PolicyConfig(routing=ROUTE_SDN if i % 2 == 0 else ROUTE_LEGACY,
                             job_concurrency=2, seed=i) for i in range(w)]
        exp = Experiment(scenarios=setup, policies=pols)
        jax.block_until_ready(exp.build()[0])
        t0 = time.perf_counter()
        res = exp.run()
        jax.block_until_ready(res.states.time)
        compile_and_run = time.perf_counter() - t0
        t0 = time.perf_counter()
        res = exp.run()
        jax.block_until_ready(res.states.time)
        run_s = time.perf_counter() - t0
        out[str(w)] = {"replicas": w, "run_s": run_s,
                       "replicas_per_s": w / run_s,
                       "first_call_s": compile_and_run}
    return out


def main(quick: bool = False) -> Dict:
    setup = paper_setup(seed=0, split=2)
    single = single_run_events_per_sec(setup)
    sweep = sweep_scaling(setup, widths=(1, 8) if quick else (1, 8, 32))
    base = sweep["1"]["run_s"]
    print(f"sim_throughput: {single['events_per_s']:.0f} events/s "
          f"({single['events']} events in {single['run_s'] * 1e3:.0f} ms)")
    for w, r in sweep.items():
        speedup = (base * int(w)) / r["run_s"]
        print(f"  vmap x{w:>3}: {r['run_s'] * 1e3:8.0f} ms "
              f"({speedup:4.1f}x vs sequential singles)")
    print(f"  engine traces this process: {runners.trace_count()} "
          f"(cached runners: {runners.cache_size()})")
    return {"single": single, "sweep": sweep,
            "engine_traces": runners.trace_count()}


if __name__ == "__main__":
    json.dump(main(), open("experiments/sim_throughput.json", "w"), indent=1)
