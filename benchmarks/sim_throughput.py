"""Simulator scaling benchmark (beyond paper): events/sec and the vmapped
policy-sweep capability the Java original lacks (one scenario per JVM run
vs thousands of replicas per tensor program here)."""
from __future__ import annotations

import json
import time
from typing import Dict

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PolicyConfig, ROUTE_LEGACY, ROUTE_SDN, make_simulator,
                        paper_setup, simulate_batch)
from repro.core.engine import make_consts


def single_run_events_per_sec(setup) -> Dict[str, float]:
    run = jax.jit(make_simulator(setup))
    pol = PolicyConfig().as_arrays()
    t0 = time.perf_counter()
    s = run(pol)
    jax.block_until_ready(s.time)
    compile_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    n = 5
    for _ in range(n):
        s = run(pol)
        jax.block_until_ready(s.time)
    dt = (time.perf_counter() - t0) / n
    return {"events": int(s.steps), "run_s": dt,
            "events_per_s": float(s.steps) / dt, "compile_s": compile_s}


def sweep_scaling(setup, widths=(1, 8, 32)) -> Dict[str, Dict]:
    out = {}
    for w in widths:
        pols = {
            "routing": jnp.asarray([ROUTE_SDN, ROUTE_LEGACY] * (w // 2)
                                   or [ROUTE_SDN])[:w],
            "traffic": jnp.zeros(w, jnp.int32),
            "placement": jnp.zeros(w, jnp.int32),
            "job_selection": jnp.zeros(w, jnp.int32),
            "job_concurrency": jnp.full(w, 2, jnp.int32),
            "seed": jnp.arange(w, dtype=jnp.int32),
        }
        t0 = time.perf_counter()
        s = simulate_batch(setup, pols)
        jax.block_until_ready(s.time)
        compile_and_run = time.perf_counter() - t0
        t0 = time.perf_counter()
        s = simulate_batch(setup, pols)
        jax.block_until_ready(s.time)
        run_s = time.perf_counter() - t0
        out[str(w)] = {"replicas": w, "run_s": run_s,
                       "replicas_per_s": w / run_s,
                       "first_call_s": compile_and_run}
    return out


def main(quick: bool = False) -> Dict:
    setup = paper_setup(seed=0, split=2)
    single = single_run_events_per_sec(setup)
    sweep = sweep_scaling(setup, widths=(1, 8) if quick else (1, 8, 32))
    base = sweep["1"]["run_s"]
    print(f"sim_throughput: {single['events_per_s']:.0f} events/s "
          f"({single['events']} events in {single['run_s'] * 1e3:.0f} ms)")
    for w, r in sweep.items():
        speedup = (base * int(w)) / r["run_s"]
        print(f"  vmap x{w:>3}: {r['run_s'] * 1e3:8.0f} ms "
              f"({speedup:4.1f}x vs sequential singles)")
    return {"single": single, "sweep": sweep}


if __name__ == "__main__":
    json.dump(main(), open("experiments/sim_throughput.json", "w"), indent=1)
