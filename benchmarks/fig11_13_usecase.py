"""Paper §5 use-case benchmarks: Figs. 11a/11b (transmission/completion),
12a/12b (mapper/reducer execution), 13 (energy) — SDN vs legacy.

Also emits the calibration grid (packet split x AM concurrency x seeds)
documented in EXPERIMENTS.md: the paper under-specifies the workload's
packet size and the application master's admission width, so we report
the SDN-vs-legacy deltas across that grid and compare the qualitative
claim (SDN wins all three metrics) plus the best-match quantitative row.
"""
from __future__ import annotations

import json
from typing import Dict, List

import numpy as np

from repro.api import Experiment
from repro.core import PolicyConfig, ROUTE_LEGACY, ROUTE_SDN, paper_setup

PAPER = {"transmission": 41.0, "completion": 24.0, "energy": 22.0}


def run_pair(seed: int, split: int, conc: int) -> Dict[str, float]:
    # one Experiment per (seed, split): both routing modes in one policy
    # batch; the compiled-runner cache reuses the trace across the grid
    # (every cell with the same packet split shares one SimMeta).
    res = Experiment(
        scenarios=paper_setup(seed=seed, split=split),
        policies=[("sdn", PolicyConfig(routing=ROUTE_SDN,
                                       job_concurrency=conc, seed=seed)),
                  ("legacy", PolicyConfig(routing=ROUTE_LEGACY,
                                          job_concurrency=conc, seed=seed))],
    ).run()
    out = {name: res.summary(0, pi)
           for pi, name in enumerate(res.policy_names)}
    for r in out.values():
        assert not bool(r["stalled"]), "simulation stalled"
    rs, rl = out["sdn"], out["legacy"]

    def delta(a, b):
        return float(100.0 * (b - a) / b)

    return {
        "seed": seed, "split": split, "conc": conc,
        "transmission": delta(np.nanmean(rs["transmission_time"]),
                              np.nanmean(rl["transmission_time"])),
        "completion": delta(np.nanmean(rs["completion_measured"]),
                            np.nanmean(rl["completion_measured"])),
        "energy": delta(float(rs["total_energy_j"]),
                        float(rl["total_energy_j"])),
        "per_job": {
            "sdn_transmission": rs["transmission_time"].tolist(),
            "legacy_transmission": rl["transmission_time"].tolist(),
            "sdn_completion": rs["completion_measured"].tolist(),
            "legacy_completion": rl["completion_measured"].tolist(),
            "sdn_map_exec": rs["map_exec_time"].tolist(),
            "legacy_map_exec": rl["map_exec_time"].tolist(),
            "sdn_reduce_exec": rs["reduce_exec_time"].tolist(),
            "legacy_reduce_exec": rl["reduce_exec_time"].tolist(),
            "sdn_energy": [float(rs["host_energy_j"]),
                           float(rs["switch_energy_j"])],
            "legacy_energy": [float(rl["host_energy_j"]),
                              float(rl["switch_energy_j"])],
        },
    }


def main(quick: bool = False) -> Dict:
    grid: List[Dict] = []
    seeds = [0] if quick else [0, 1, 2]
    splits = [2] if quick else [1, 2]
    concs = [2] if quick else [1, 2, 4]
    for seed in seeds:
        for split in splits:
            for conc in concs:
                grid.append(run_pair(seed, split, conc))
    best = max(grid, key=lambda r: r["transmission"])
    means = {k: float(np.mean([r[k] for r in grid]))
             for k in ("transmission", "completion", "energy")}
    qualitative = all(r["transmission"] > 0 and r["completion"] > 0
                      and r["energy"] > 0
                      for r in grid if r["conc"] <= 2 and r["split"] >= 2)
    report = {
        "paper_claim_pct": PAPER,
        "grid": [{k: r[k] for k in
                  ("seed", "split", "conc", "transmission", "completion",
                   "energy")} for r in grid],
        "grid_mean_pct": means,
        "best_match_pct": {k: best[k] for k in
                           ("transmission", "completion", "energy")},
        "best_match_cfg": {k: best[k] for k in ("seed", "split", "conc")},
        "qualitative_claim_reproduced": bool(qualitative),
        "fig_data": best["per_job"],
    }
    print("fig11-13 SDN-vs-legacy deltas (% improvement, paper: 41/24/22):")
    for r in report["grid"]:
        print(f"  seed={r['seed']} split={r['split']} conc={r['conc']}: "
              f"tr={r['transmission']:5.1f}% ct={r['completion']:5.1f}% "
              f"en={r['energy']:5.1f}%")
    print(f"  mean: tr={means['transmission']:.1f}% "
          f"ct={means['completion']:.1f}% en={means['energy']:.1f}%  "
          f"qualitative-claim={'OK' if qualitative else 'FAIL'}")
    return report


if __name__ == "__main__":
    json.dump(main(), open("experiments/fig11_13.json", "w"), indent=1)
