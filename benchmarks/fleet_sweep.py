"""Fleet sweep: a 10,000-simulation policy × failure-rate × seed grid as
ONE program (DESIGN.md §9).

This is the headline the fleet execution layer exists for — the paper's
"one experiment run answers a whole design question" pitch at a scale the
serial runner cannot touch: routing {legacy, sdn} × placement {least-used,
round-robin} × host-failure rate {0, 2, 5, 10 %/s·host} × hundreds of
seeds, drained in a single ``Experiment.run_fleet`` invocation through
chunked early-exit cohorts (sharded over every visible device).  Results
are bit-identical to the serial runner — proven by tests/test_fleet.py on
the same machinery, not re-proven here (a serial 10k-sim run is exactly
the wall this engine cracks).

The JSON report (``--json experiments/BENCH_fleet.json``) is the committed
fleet perf trajectory; CI re-runs a reduced grid and fails when aggregate
sims/s regresses more than ``--max-regress`` (default 20%).

  PYTHONPATH=src python benchmarks/fleet_sweep.py
  PYTHONPATH=src python benchmarks/fleet_sweep.py \
      --json experiments/BENCH_fleet.json
  PYTHONPATH=src python benchmarks/fleet_sweep.py --sims 1000 \
      --baseline experiments/BENCH_fleet.json --max-regress 0.2
"""
import argparse
import json
import sys
import time

import jax
import numpy as np

try:
    from . import _cli            # python -m benchmarks.<name>
except ImportError:
    import _cli                   # python benchmarks/<name>.py

from repro.api import Experiment
from repro.scenarios.failures import failure_injector

SCENARIO = "paper-fabric"
ROUTINGS = (("legacy", 0), ("sdn", 1))
PLACEMENTS = (("least-used", 0), ("round-robin", 1))
FAIL_RATES = (0.0, 0.02, 0.05, 0.10)


def build_grid(n_sims: int) -> Experiment:
    """policy × failure-rate × seed grid with ~n_sims cells (rounded down
    to a whole number of seeds per policy point)."""
    points = len(ROUTINGS) * len(PLACEMENTS) * len(FAIL_RATES)
    n_seeds = max(1, n_sims // points)
    pols = [(f"{rn}/{pn}/s{s}", dict(routing=r, placement=p, seed=s))
            for rn, r in ROUTINGS for pn, p in PLACEMENTS
            for s in range(n_seeds)]
    fails = [(f"host{int(rate * 100)}pct",
              failure_injector(host_rate=rate, mttr=20.0, horizon=500.0))
             for rate in FAIL_RATES]
    return Experiment(scenarios=SCENARIO, policies=pols, failures=fails)


def summarize(res) -> dict:
    """Per-(failure-rate, routing) means — the design-question readout."""
    rep = res.job_table() if hasattr(res, "job_table") else None
    del rep  # results surface varies; completion means below suffice
    comp = {}
    done_t = np.asarray(res.states.job_done_t)          # [S, P, n_jobs]
    valid = np.asarray(res.consts.job_valid)            # [S, n_jobs]
    for si, sname in enumerate(res.scenario_names):
        for rn, _ in ROUTINGS:
            sel = [pi for pi, pn in enumerate(res.policy_names)
                   if pn.startswith(rn + "/")]
            v = done_t[si][sel][:, valid[si]]
            comp[f"{sname}/{rn}"] = {
                "mean_job_done_t": float(np.nanmean(
                    np.where(np.isfinite(v), v, np.nan))),
                "finished_frac": float(np.isfinite(v).mean()),
            }
    return comp


def check_regression(report: dict, baseline_path: str,
                     max_regress: float) -> int:
    with open(baseline_path) as f:
        base = json.load(f)
    cur, ref = report["aggregate_sims_per_s"], base["aggregate_sims_per_s"]
    floor = ref * (1.0 - max_regress)
    status = "OK" if cur >= floor else "REGRESSED"
    print(f"fleet gate: {cur:.0f} sims/s vs baseline {ref:.0f} "
          f"(floor {floor:.0f}) {status}")
    if status != "OK":
        print(f"aggregate sims/s regression > {max_regress:.0%} "
              "(refresh the baseline in-PR if intentional)")
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--sims", type=int, default=10_000,
                    help="grid size (policy x failure-rate x seed cells)")
    ap.add_argument("--width", type=int, default=128,
                    help="fleet cohort width")
    ap.add_argument("--chunk-steps", type=int, default=64,
                    help="events per jitted chunk (K)")
    _cli.add_json_arg(ap)
    _cli.add_gate_args(ap, "BENCH_fleet.json",
                       "allowed fractional aggregate sims/s drop")
    args = ap.parse_args(argv)

    t0 = time.perf_counter()
    exp = build_grid(args.sims)
    n = len(exp.scenarios) * len(exp.policies)
    build_s = time.perf_counter() - t0
    print(f"grid: {len(exp.scenarios)} failure rates x "
          f"{len(exp.policies)} policies = {n} sims "
          f"(built in {build_s:.2f}s)")

    # cold run: compiles every cohort program and calibrates the step
    # predictor; the timed run below is the steady-state fleet number
    t0 = time.perf_counter()
    res, stats = exp.run_fleet(width=args.width,
                               chunk_steps=args.chunk_steps,
                               return_stats=True)
    jax.block_until_ready(res.states)
    cold_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    res, stats = exp.run_fleet(width=args.width,
                               chunk_steps=args.chunk_steps,
                               return_stats=True)
    # sync before reading the clock so the number is the computation,
    # not jax's async dispatch (jaxcheck:naked-timer)
    jax.block_until_ready(res.states)
    wall_s = time.perf_counter() - t0
    agg = n / wall_s

    print(f"cold (compile+calibrate): {cold_s:.1f}s; "
          f"timed: {n} sims in {wall_s:.1f}s = {agg:.0f} sims/s")
    print(f"cohorts={stats.cohorts} chunks={stats.chunks} "
          f"refills={stats.refills} width={stats.width} "
          f"devices={stats.devices}")

    report = {
        "benchmark": "fleet_sweep",
        "backend": jax.default_backend(),
        "scenario": SCENARIO,
        "sims": n,
        "width": args.width,
        "chunk_steps": args.chunk_steps,
        "devices": stats.devices,
        "cohorts": stats.cohorts,
        "chunks": stats.chunks,
        "refills": stats.refills,
        "build_s": build_s,
        "cold_s": cold_s,
        "wall_s": wall_s,
        "aggregate_sims_per_s": agg,
        "summary": summarize(res),
    }

    _cli.write_report(report, args.json)
    return _cli.gate(report, args, check_regression)


if __name__ == "__main__":
    sys.exit(main())
