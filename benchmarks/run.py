"""``python -m benchmarks.run`` — every paper table/figure + system benches.

Writes JSON artifacts under experiments/ and prints a summary.  Use
--full for the complete calibration grids (the default is the quick pass
used in CI / bench_output.txt).
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)
    quick = not args.full
    os.makedirs("experiments", exist_ok=True)
    results = {}
    t_all = time.time()

    from . import advisor_validation, fig11_13_usecase, roofline_table, \
        sim_throughput

    print("=" * 72)
    print("[1/4] paper use-case (Figs. 11a/11b/12/13) — SDN vs legacy")
    print("=" * 72)
    results["fig11_13"] = fig11_13_usecase.main(quick=quick)
    json.dump(results["fig11_13"], open("experiments/fig11_13.json", "w"),
              indent=1)

    print("=" * 72)
    print("[2/4] simulator throughput + vmapped policy sweeps")
    print("=" * 72)
    results["sim_throughput"] = sim_throughput.main(quick=quick)
    json.dump(results["sim_throughput"],
              open("experiments/sim_throughput.json", "w"), indent=1)

    print("=" * 72)
    print("[3/4] collective-schedule advisor validation (DES vs analytic)")
    print("=" * 72)
    results["advisor"] = advisor_validation.main(quick=quick)
    json.dump(results["advisor"],
              open("experiments/advisor_validation.json", "w"), indent=1)

    print("=" * 72)
    print("[4/4] roofline table (aggregated from dry-run artifacts)")
    print("=" * 72)
    results["roofline"] = roofline_table.main()

    print("=" * 72)
    ok = results["fig11_13"]["qualitative_claim_reproduced"]
    print(f"benchmarks done in {time.time() - t_all:.0f}s; "
          f"paper qualitative claim reproduced: {ok}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
