"""``python -m benchmarks.run`` — every paper table/figure + system benches.

One invocation regenerates every ``experiments/`` artifact: the paper
use-case figures, the system benches, and ALL the BENCH_*.json sweep
reports (scenario, failure, control-plane, fleet, engine profile,
streaming).  ``--full`` runs each sweep at its committed-baseline grid —
that is the pass that refreshes the perf-gate baselines
(``BENCH_engine.json`` / ``BENCH_fleet.json`` / ``BENCH_stream.json`` /
``BENCH_chaos.json``, whose CI gates re-run the same default grids); the default quick pass
uses the reduced CI grids and writes the gated benches to the
``*.ci.json`` artifact names, so a smoke run never clobbers a committed
baseline with a mismatched grid.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


# the suite timer is deliberate wall clock over whole child benchmarks
# (each syncs before its own timers); there is nothing here to block on
def main(argv=None):  # jaxcheck: disable=naked-timer
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true",
                    help="committed-baseline grids (refreshes BENCH_*.json "
                         "gate baselines); default is the quick CI pass")
    args = ap.parse_args(argv)
    quick = not args.full
    os.makedirs("experiments", exist_ok=True)
    results = {}
    t_all = time.time()

    from . import (advisor_validation, chaos_sweep, ctrl_sweep,
                   engine_profile, failure_sweep, fig11_13_usecase,
                   fleet_sweep, roofline_table, scenario_sweep,
                   sim_throughput, stream_sweep)

    def banner(step, title):
        print("=" * 72)
        print(f"[{step}/11] {title}")
        print("=" * 72)

    banner(1, "paper use-case (Figs. 11a/11b/12/13) — SDN vs legacy")
    results["fig11_13"] = fig11_13_usecase.main(quick=quick)
    json.dump(results["fig11_13"], open("experiments/fig11_13.json", "w"),
              indent=1)

    banner(2, "simulator throughput + vmapped policy sweeps")
    results["sim_throughput"] = sim_throughput.main(quick=quick)
    json.dump(results["sim_throughput"],
              open("experiments/sim_throughput.json", "w"), indent=1)

    banner(3, "collective-schedule advisor validation (DES vs analytic)")
    results["advisor"] = advisor_validation.main(quick=quick)
    json.dump(results["advisor"],
              open("experiments/advisor_validation.json", "w"), indent=1)

    banner(4, "roofline table (aggregated from dry-run artifacts)")
    results["roofline"] = roofline_table.main()

    # --- the post-seed sweep benches: quick = the CI bench-job grids,
    # --- full = the committed-baseline grids (each script's defaults)
    banner(5, "scenario sweep (topology x placement grid)")
    scenario_sweep.main(
        (["--scenarios", "paper-fabric", "leaf-spine"] if quick else [])
        + ["--json", "experiments/BENCH_scenario_sweep.json"])

    banner(6, "failure sweep (failure-rate x routing grid)")
    failure_sweep.main(
        (["--rates", "0", "3e-4", "--seeds", "1"] if quick else [])
        + ["--json", "experiments/BENCH_failure_sweep.json"])

    banner(7, "control-plane sweep (install-latency x routing grid)")
    ctrl_sweep.main(
        (["--latencies", "0.005", "0.05"] if quick else [])
        + ["--json", "experiments/BENCH_ctrl.json"])

    # the three GATED benches write the committed baseline path only on
    # --full (where the grid matches the CI gate); the quick pass writes
    # the .ci.json artifact names so a smoke run never clobbers a
    # baseline with a mismatched grid
    suffix = ".ci.json" if quick else ".json"

    banner(8, "fleet sweep (policy x failure-rate x seed cohorts)")
    fleet_sweep.main(
        (["--sims", "1000"] if quick else [])
        + ["--json", f"experiments/BENCH_fleet{suffix}"])

    banner(9, "engine step-kernel profile")
    engine_profile.main(
        (["--iters", "1"] if quick else ["--iters", "3"])
        + ["--json", f"experiments/BENCH_engine{suffix}"])

    banner(10, "streaming sweep (arrival rate x routing, slot ring)")
    stream_sweep.main(
        (["--horizon", "400"] if quick else [])
        + ["--json", f"experiments/BENCH_stream{suffix}"])

    banner(11, "chaos sweep (degradation severity x speculation grid)")
    chaos_sweep.main(
        (["--severities", "0.2", "0.4", "--seeds", "1"] if quick else [])
        + ["--json", f"experiments/BENCH_chaos{suffix}"])

    print("=" * 72)
    ok = results["fig11_13"]["qualitative_claim_reproduced"]
    print(f"benchmarks done in {time.time() - t_all:.0f}s; "
          f"paper qualitative claim reproduced: {ok}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
