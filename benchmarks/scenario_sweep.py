"""Scenario-diversity benchmark: topology x placement-policy grid as ONE
vmapped tensor program (paper contribution 6: "works for any topology").

Runs the paper's §5 fabric plus k-ary fat-tree, leaf-spine and
canonical-tree fabrics — each with its own workload shape — against
multiple placement policies through the unified ``repro.api.Experiment``
front door (DESIGN.md §6): padded to a common tensor shape and swept in a
single ``jit(vmap(...))`` call (DESIGN.md §5).

  PYTHONPATH=src python benchmarks/scenario_sweep.py
  PYTHONPATH=src python benchmarks/scenario_sweep.py \
      --scenarios paper-fabric fat-tree leaf-spine --seeds 2
  PYTHONPATH=src python benchmarks/scenario_sweep.py \
      --json experiments/BENCH_scenario_sweep.json
"""
import argparse
import time

import jax
import numpy as np

try:
    from . import _cli            # python -m benchmarks.<name>
except ImportError:
    import _cli                   # python benchmarks/<name>.py

from repro.api import Experiment
from repro.core import (PLACE_LEAST_USED, PLACE_RANDOM, PLACE_ROUND_ROBIN,
                        PolicyConfig)
from repro.scenarios import get_scenario, list_scenarios

PLACEMENTS = (
    ("least-used", PLACE_LEAST_USED),
    ("random", PLACE_RANDOM),
    ("round-robin", PLACE_ROUND_ROBIN),
)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", nargs="+",
                    default=["paper-fabric", "fat-tree", "leaf-spine",
                             "canonical-tree"],
                    help=f"registered scenarios ({', '.join(list_scenarios())})")
    ap.add_argument("--placements", type=int, default=2,
                    help="number of placement policies (1..3)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="workload seeds per scenario")
    ap.add_argument("--concurrency", type=int, default=2)
    _cli.add_json_arg(ap, "write a machine-readable benchmark report "
                          "(wall times, steps/s, per-scenario rows)")
    args = ap.parse_args(argv)

    t0 = time.time()
    scens = [(f"{name}/s{seed}" if args.seeds > 1 else name,
              get_scenario(name, seed=seed).build())
             for name in args.scenarios for seed in range(args.seeds)]
    pols = [(pn, PolicyConfig(placement=pid, job_concurrency=args.concurrency))
            for pn, pid in PLACEMENTS[: max(1, args.placements)]]
    exp = Experiment(scenarios=scens, policies=pols)
    jax.block_until_ready(exp.build()[0])   # consts on device, outside timers
    t_build = time.time() - t0

    t0 = time.time()
    res = exp.run()
    jax.block_until_ready(res.states.time)
    t_first = time.time() - t0       # includes the one trace + compile

    t0 = time.time()
    res = exp.run()                  # cached runner: zero retraces
    jax.block_until_ready(res.states.time)
    t_run = time.time() - t0

    n = len(res)
    total_steps = int(np.asarray(res.states.steps).sum())
    print(f"{n} simulations ({res.n_scenarios} scenarios x "
          f"{res.n_policies} placements) in one vmapped batch: "
          f"setup {t_build:.1f}s, first run {t_first:.1f}s, "
          f"cached run {t_run:.1f}s ({n / t_run:.1f} sims/s, "
          f"{total_steps / t_run:.0f} steps/s)")
    print(f"padded shape: {res.meta.n_nodes} nodes, "
          f"{res.meta.n_links} links, {res.meta.n_vms} VMs")
    rows = res.rows()
    hdr = (f"{'scenario':24} {'placement':11} {'completion(s)':>13} "
           f"{'transmit(s)':>11} {'energy(kWh)':>11} {'makespan(s)':>11}")
    print(hdr)
    print("-" * len(hdr))
    for row in rows:
        flag = "  STALLED" if row["stalled"] else ""
        print(f"{row['scenario']:24} {row['policy']:11} "
              f"{row['mean_completion_s']:13.1f} "
              f"{row['mean_transmission_s']:11.1f} "
              f"{row['energy_kwh']:11.3f} {row['makespan_s']:11.1f}{flag}")

    if args.json:
        report = {
            "benchmark": "scenario_sweep",
            "n_simulations": n,
            "n_scenarios": res.n_scenarios,
            "n_policies": res.n_policies,
            "wall_s": {"setup": t_build, "first_run": t_first,
                       "cached_run": t_run},
            "sims_per_s": n / t_run,
            "total_steps": total_steps,
            "steps_per_s": total_steps / t_run,
            "padded_meta": {"n_nodes": res.meta.n_nodes,
                            "n_links": res.meta.n_links,
                            "n_vms": res.meta.n_vms,
                            "max_steps": res.meta.max_steps},
            "rows": rows,
        }
        _cli.write_report(report, args.json)


if __name__ == "__main__":
    main()
