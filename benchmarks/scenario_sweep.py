"""Scenario-diversity benchmark: topology x placement-policy grid as ONE
vmapped tensor program (paper contribution 6: "works for any topology").

Runs the paper's §5 fabric plus k-ary fat-tree, leaf-spine and
canonical-tree fabrics — each with its own workload shape — against
multiple placement policies, padded to a common tensor shape and swept in
a single ``jit(vmap(...))`` call (DESIGN.md §5).

  PYTHONPATH=src python benchmarks/scenario_sweep.py
  PYTHONPATH=src python benchmarks/scenario_sweep.py \
      --scenarios paper-fabric fat-tree leaf-spine --seeds 2
"""
import argparse
import time

import jax

from repro.core import (PLACE_LEAST_USED, PLACE_RANDOM, PLACE_ROUND_ROBIN,
                        PolicyConfig)
from repro.scenarios import get_scenario, list_scenarios, sweep_grid

PLACEMENTS = (
    ("least-used", PLACE_LEAST_USED),
    ("random", PLACE_RANDOM),
    ("round-robin", PLACE_ROUND_ROBIN),
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", nargs="+",
                    default=["paper-fabric", "fat-tree", "leaf-spine",
                             "canonical-tree"],
                    help=f"registered scenarios ({', '.join(list_scenarios())})")
    ap.add_argument("--placements", type=int, default=2,
                    help="number of placement policies (1..3)")
    ap.add_argument("--seeds", type=int, default=1,
                    help="workload seeds per scenario")
    ap.add_argument("--concurrency", type=int, default=2)
    args = ap.parse_args()

    t0 = time.time()
    scens = [(f"{name}/s{seed}" if args.seeds > 1 else name,
              get_scenario(name, seed=seed).build())
             for name in args.scenarios for seed in range(args.seeds)]
    t_build = time.time() - t0

    pols = [(pn, PolicyConfig(placement=pid, job_concurrency=args.concurrency))
            for pn, pid in PLACEMENTS[: max(1, args.placements)]]

    t0 = time.time()
    res = sweep_grid(scens, pols)
    jax.block_until_ready(res.states.time)
    t_run = time.time() - t0

    n = len(scens) * len(pols)
    print(f"{n} simulations ({len(scens)} scenarios x {len(pols)} placements) "
          f"in one vmapped batch: setup {t_build:.1f}s, run {t_run:.1f}s "
          f"({n / t_run:.1f} sims/s)")
    print(f"padded shape: {res.meta['n_nodes']} nodes, "
          f"{res.meta['n_links']} links, {res.meta['n_vms']} VMs")
    hdr = (f"{'scenario':24} {'placement':11} {'completion(s)':>13} "
           f"{'transmit(s)':>11} {'energy(kWh)':>11} {'makespan(s)':>11}")
    print(hdr)
    print("-" * len(hdr))
    for row in res.rows():
        flag = "  STALLED" if row["stalled"] else ""
        print(f"{row['scenario']:24} {row['policy']:11} "
              f"{row['mean_completion_s']:13.1f} "
              f"{row['mean_transmission_s']:11.1f} "
              f"{row['energy_kwh']:11.3f} {row['makespan_s']:11.1f}{flag}")


if __name__ == "__main__":
    main()
