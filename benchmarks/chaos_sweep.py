"""Routing x speculation under gray failure (DESIGN.md §13).

The failure sweep (DESIGN.md §7) killed devices outright; real clusters
mostly *limp* — thermally-throttled hosts, browned-out links, a primary
controller failing over to a slower backup.  This benchmark races the
full chaos stack:

    routing (sdn / legacy)  x  speculation (off / on)
        x  degradation severity  x  seed

on the ``leaf-spine-chaos`` scenario, as ONE vmapped tensor program:
each (severity, seed) pair becomes a scenario replica via the registered
factory's ``mean_factor`` / ``seed`` overrides (the same Clos, a
different seeded ``DegradationSchedule``), the routing/speculation
policies form the policy axis.  The headline is the speculation column:
YARN-style straggler cloning onto healthy VMs should cut the makespan on
every degraded replica, at a measured ``wasted_spec_work_s`` price.
``paper-fabric-chaos`` adds controller failover on top (--scenario).

  PYTHONPATH=src python benchmarks/chaos_sweep.py
  PYTHONPATH=src python benchmarks/chaos_sweep.py \
      --severities 0.2 0.5 --seeds 2 --json experiments/BENCH_chaos.json
"""
import argparse
import json
import sys
import time

import jax

try:
    from . import _cli            # python -m benchmarks.<name>
except ImportError:
    import _cli                   # python benchmarks/<name>.py

from repro.api import Experiment
from repro.core import (PolicyConfig, ROUTE_LEGACY, ROUTE_SDN, SPEC_OFF,
                        SPEC_ON)
from repro.scenarios import get_scenario


def check_regression(report: dict, baseline_path: str,
                     max_regress: float) -> int:
    with open(baseline_path) as f:
        base = json.load(f)
    cur = report["sims_per_s"]
    ref = base["sims_per_s"]
    floor = ref * (1.0 - max_regress)
    status = "OK" if cur >= floor else "REGRESSED"
    print(f"chaos gate: {cur:.1f} sims/s vs baseline {ref:.1f} "
          f"(floor {floor:.1f}) {status}")
    if status != "OK":
        print(f"throughput regression > {max_regress:.0%} "
              "(refresh the baseline in-PR if intentional)")
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--severities", nargs="+", type=float,
                    default=[0.2, 0.4, 0.6],
                    help="mean in-window rate multipliers (lower = worse "
                    "gray failure)")
    ap.add_argument("--seeds", type=int, default=3,
                    help="degradation-trace seeds per severity")
    ap.add_argument("--scenario", default="leaf-spine-chaos",
                    help="registered chaos scenario factory "
                    "(leaf-spine-chaos / paper-fabric-chaos)")
    ap.add_argument("--spec-slots", type=int, default=2,
                    help="clone slots per job")
    ap.add_argument("--concurrency", type=int, default=2)
    _cli.add_json_arg(ap)
    _cli.add_gate_args(ap, "BENCH_chaos.json",
                       "allowed fractional sims/s drop")
    args = ap.parse_args(argv)

    t0 = time.time()
    scens = [(f"sev{sev:g}-s{seed}",
              get_scenario(args.scenario, mean_factor=sev, seed=seed,
                           spec_slots=args.spec_slots).build())
             for sev in args.severities for seed in range(args.seeds)]
    exp = Experiment(
        scenarios=scens,
        policies=[
            ("sdn", PolicyConfig(routing=ROUTE_SDN, speculation=SPEC_OFF,
                                 job_concurrency=args.concurrency)),
            ("sdn-spec", PolicyConfig(routing=ROUTE_SDN,
                                      speculation=SPEC_ON,
                                      job_concurrency=args.concurrency)),
            ("legacy", PolicyConfig(routing=ROUTE_LEGACY,
                                    speculation=SPEC_OFF,
                                    job_concurrency=args.concurrency)),
            ("legacy-spec", PolicyConfig(routing=ROUTE_LEGACY,
                                         speculation=SPEC_ON,
                                         job_concurrency=args.concurrency)),
        ],
    )
    jax.block_until_ready(exp.build()[0])   # consts on device, off the clock
    t_build = time.time() - t0

    t0 = time.time()
    res = exp.run()
    jax.block_until_ready(res.states.time)
    t_run = time.time() - t0

    n = len(res)
    print(f"{n} simulations ({res.n_scenarios} chaos traces x "
          f"{res.n_policies} policies) in one vmapped grid: "
          f"setup {t_build:.1f}s, run {t_run:.1f}s")
    rows = res.rows()
    hdr = (f"{'trace':14} {'policy':12} {'makespan(s)':>11} "
           f"{'degr(s)':>8} {'clones':>6} {'wins':>5} {'waste(s)':>9} "
           f"{'fo':>3} {'park(s)':>8}")
    print(hdr)
    print("-" * len(hdr))
    for row in rows:
        flag = "  STALLED" if row["stalled"] else ""
        print(f"{row['scenario']:14} {row['policy']:12} "
              f"{row['makespan_s']:11.2f} {row['degraded_time_s']:8.1f} "
              f"{row['spec_launches']:6d} {row['spec_wins']:5d} "
              f"{row['wasted_spec_work_s']:9.2f} {row['failover_count']:3d} "
              f"{row['failover_park_s']:8.2f}{flag}")

    # the headline: traces where cloning stragglers cuts the makespan
    by = {}
    for row in rows:
        by.setdefault(row["scenario"], {})[row["policy"]] = row
    spec_wins, deltas = [], []
    for sname, cell in by.items():
        if {"sdn", "sdn-spec"} <= cell.keys():
            d = cell["sdn"]["makespan_s"] - cell["sdn-spec"]["makespan_s"]
            deltas.append(d / max(cell["sdn"]["makespan_s"], 1e-9))
            if d > 1e-3:
                spec_wins.append(sname)
    mean_gain = sum(deltas) / len(deltas) if deltas else 0.0
    print(f"\nspeculation cuts the SDN makespan on {len(spec_wins)}/"
          f"{len(by)} traces (mean gain {mean_gain:.1%})")

    report = {
        "benchmark": "chaos_sweep",
        "n_simulations": n,
        "scenario": args.scenario,
        "severities": args.severities,
        "seeds": args.seeds,
        "spec_slots": args.spec_slots,
        "speculation_wins_at": spec_wins,
        "mean_speculation_gain": mean_gain,
        "wall_s": {"setup": t_build, "run": t_run},
        "sims_per_s": n / t_run,
        "rows": rows,
    }
    _cli.write_report(report, args.json)
    return _cli.gate(report, args, check_regression)


if __name__ == "__main__":
    sys.exit(main())
