"""SDN-vs-legacy under a REAL control plane (DESIGN.md §10).

Every earlier benchmark gave SDN routing an instant-oracle controller:
flow rules appeared at activation time for free, so SDN could only win.
This benchmark prices the control plane — flow-rule install latency, a
rate-limited controller, LRU-bounded flow tables — and asks the question
the paper's §5 comparison cannot: *when does legacy routing beat SDN?*

The grid is

    routing (sdn / sdn-proactive / legacy)  x  install latency

run as ONE vmapped tensor program through ``repro.api.Experiment``'s
``ctrl=`` axis: each latency point becomes a scenario replica (the same
fabric, a different ``CtrlPlaneConfig``), the routing/install-mode
policies form the policy axis.  Legacy forwarding never touches the
controller, so its column is flat across latencies — the crossover row
where its makespan dips below reactive SDN's is the headline result.
Proactive install pre-pins routes at admission and overlaps the install
latency with job queueing, recovering most of the gap at the cost of
blind-to-traffic route choices and table churn (``rule_reinstalls``).

  PYTHONPATH=src python benchmarks/ctrl_sweep.py
  PYTHONPATH=src python benchmarks/ctrl_sweep.py \
      --latencies 0 0.01 0.05 0.2 --json experiments/BENCH_ctrl.json
"""
import argparse
import time

import jax

try:
    from . import _cli            # python -m benchmarks.<name>
except ImportError:
    import _cli                   # python benchmarks/<name>.py

from repro.api import Experiment
from repro.core import (CtrlPlaneConfig, INSTALL_PROACTIVE, PolicyConfig,
                        ROUTE_LEGACY, ROUTE_SDN)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--latencies", nargs="+", type=float,
                    default=[0.005, 0.02, 0.05, 0.1],
                    help="per-rule install latencies (seconds)")
    ap.add_argument("--rate", type=float, default=500.0,
                    help="controller service rate (rules/second)")
    ap.add_argument("--slots", type=int, default=8,
                    help="flow-table slots per switch (LRU)")
    ap.add_argument("--scenario", default="paper-fabric",
                    help="registered scenario name to price the "
                    "controller on")
    ap.add_argument("--concurrency", type=int, default=2)
    _cli.add_json_arg(ap)
    args = ap.parse_args(argv)

    t0 = time.time()
    ctrl = [(f"lat{lat:g}",
             CtrlPlaneConfig(install_latency=lat, ctrl_rate=args.rate,
                             table_slots=args.slots))
            for lat in args.latencies]
    exp = Experiment(
        scenarios=args.scenario,
        policies=[
            ("sdn", PolicyConfig(routing=ROUTE_SDN,
                                 job_concurrency=args.concurrency)),
            ("sdn-pro", PolicyConfig(routing=ROUTE_SDN,
                                     install_mode=INSTALL_PROACTIVE,
                                     job_concurrency=args.concurrency)),
            ("legacy", PolicyConfig(routing=ROUTE_LEGACY,
                                    job_concurrency=args.concurrency)),
        ],
        ctrl=ctrl,
    )
    jax.block_until_ready(exp.build()[0])   # consts on device, off the clock
    t_build = time.time() - t0

    t0 = time.time()
    res = exp.run()
    jax.block_until_ready(res.states.time)
    t_run = time.time() - t0

    n = len(res)
    print(f"{n} simulations ({res.n_scenarios} ctrl configs x "
          f"{res.n_policies} policies) in one vmapped grid: "
          f"setup {t_build:.1f}s, run {t_run:.1f}s")
    rows = res.rows()
    hdr = (f"{'ctrl':24} {'policy':8} {'makespan(s)':>11} "
           f"{'instwait(s)':>11} {'installs':>8} {'evict':>6} "
           f"{'reinst':>6} {'qwait(s)':>9}")
    print(hdr)
    print("-" * len(hdr))
    for row in rows:
        flag = "  STALLED" if row["stalled"] else ""
        print(f"{row['scenario']:24} {row['policy']:8} "
              f"{row['makespan_s']:11.2f} {row['install_wait_s']:11.2f} "
              f"{row['rule_installs']:8d} {row['rule_evictions']:6d} "
              f"{row['rule_reinstalls']:6d} "
              f"{row['ctrl_queue_wait_s']:9.2f}{flag}")

    # the headline: latencies where the controller-free legacy path wins
    by = {}
    for row in rows:
        by.setdefault(row["scenario"], {})[row["policy"]] = row
    crossover = []
    for sname, cell in by.items():
        if {"sdn", "legacy"} <= cell.keys() \
                and cell["legacy"]["makespan_s"] < cell["sdn"]["makespan_s"]:
            crossover.append(sname)
    if crossover:
        print("\nlegacy beats reactive SDN at: " + ", ".join(crossover))
    else:
        print("\nno crossover in this latency range — SDN wins everywhere")

    if args.json:
        report = {
            "benchmark": "ctrl_sweep",
            "n_simulations": n,
            "scenario": args.scenario,
            "latencies": args.latencies,
            "ctrl_rate": args.rate,
            "table_slots": args.slots,
            "legacy_beats_sdn_at": crossover,
            "wall_s": {"setup": t_build, "run": t_run},
            "sims_per_s": n / t_run,
            "rows": rows,
        }
        _cli.write_report(report, args.json)


if __name__ == "__main__":
    main()
