"""Engine step-kernel profile: steps/s and sims/s per registry scenario
(DESIGN.md §8).

Three size tiers — small (the paper's §5 fabric), medium (a 16-host
leaf-spine Clos) and large (``leaf-spine-xl``: 128 hosts, >=1k tasks,
>=4k packets) — each run as a single compiled simulation, timed after an
explicit ``jax.block_until_ready`` so wall numbers measure compute, not
dispatch.  A small vmapped policy batch per tier reports sims/s, and the
fleet path (DESIGN.md §9) is profiled at several cohort widths
(``--widths 1,6,32``), each entry carrying ``batch_efficiency`` =
fleet sims/s ÷ serial sims/s.

The JSON report (``--json experiments/BENCH_engine.json``) is the
committed perf trajectory; CI re-runs the profile and fails when steps/s
regresses more than ``--max-regress`` against ``--baseline`` (the
baseline is refreshed in any PR that intentionally moves it).

  PYTHONPATH=src python benchmarks/engine_profile.py
  PYTHONPATH=src python benchmarks/engine_profile.py --scenarios small medium
  PYTHONPATH=src python benchmarks/engine_profile.py \
      --json experiments/BENCH_engine.json
  PYTHONPATH=src python benchmarks/engine_profile.py \
      --baseline experiments/BENCH_engine.json --max-regress 0.2
"""
import argparse
import json
import sys
import time

import jax
import numpy as np

try:
    from . import _cli            # python -m benchmarks.<name>
except ImportError:
    import _cli                   # python benchmarks/<name>.py

from repro.api import runners
from repro.core import (PLACE_LEAST_USED, PLACE_RANDOM, PLACE_ROUND_ROBIN,
                        ROUTE_LEGACY, ROUTE_SDN, PolicyConfig)
from repro.core.engine import make_consts
from repro.core.policies import as_policy_arrays
from repro.scenarios import get_scenario
from repro.scenarios.sweep import policy_arrays

# tier -> (registered scenario, default policy-batch width, fleet widths).
# All sizes come from the registry so the profile and the bit-identity
# suite exercise the same configurations.  The large tier skips the
# vmapped batch by default: under vmap the kernel's skip-when-idle conds
# become run-both-branches selects (DESIGN.md §8), so a batched xl run
# measures a different (much slower) program than the single-replica path
# the perf gate tracks.  The FLEET path (chunked early-exit cohorts,
# DESIGN.md §9) is what cracks that wall; its per-width entries carry
# ``batch_efficiency`` = fleet sims/s ÷ this tier's serial sims/s, so the
# old inversion (0.01x at width 6) and the fix (>1x) are both visible in
# the committed baseline.
TIERS = (
    ("small", "paper-fabric", 6, (1, 6, 64, 128)),
    ("medium", "leaf-spine", 6, (1, 6, 64, 128)),
    ("large", "leaf-spine-xl", 0, (2, 4, 8)),
)

# the profiled policy: SDN routing + least-used placement (both take the
# serialized branch of the kernel, so this is the worst case for the
# vectorized rewrite) under a realistic admission budget.
PROFILE_POLICY = dict(job_concurrency=4)

BATCH_POLICIES = [
    PolicyConfig(routing=r, placement=p, **PROFILE_POLICY)
    for r in (ROUTE_SDN, ROUTE_LEGACY)
    for p in (PLACE_LEAST_USED, PLACE_ROUND_ROBIN, PLACE_RANDOM)
]


def profile_scenario(name: str, iters: int, batch_width: int,
                     fleet_widths=()) -> dict:
    t0 = time.perf_counter()
    setup = get_scenario(name).build()
    consts, meta = make_consts(setup)
    pol = as_policy_arrays(PolicyConfig(**PROFILE_POLICY))
    build_s = time.perf_counter() - t0

    run = runners.get_runner(meta, "single")
    jax.block_until_ready(consts)            # consts transfer out of the timer
    t0 = time.perf_counter()
    s = jax.block_until_ready(run(consts, pol))
    compile_s = time.perf_counter() - t0

    # noise here is one-sided (GC pauses, co-tenant CPU steal only ever
    # slow a run down), so the gated number is the BEST observed run; the
    # small tiers finish in milliseconds, so rerun until the total timed
    # window is at least ~0.5 s to get a stable best
    t0 = time.perf_counter()
    s = jax.block_until_ready(run(consts, pol))
    est = max(time.perf_counter() - t0, 1e-4)
    n_timed = max(iters, min(200, int(0.5 / est) + 1))

    walls = []
    for _ in range(n_timed):
        t0 = time.perf_counter()
        s = jax.block_until_ready(run(consts, pol))
        walls.append(time.perf_counter() - t0)
    wall_s = min(walls)
    steps = int(s.steps)

    out = {
        "scenario": name,
        "n_hosts": setup.cluster.topo.n_hosts,
        "n_links": setup.cluster.topo.n_links,
        "n_jobs": setup.n_jobs,
        "n_tasks": setup.n_tasks,
        "n_packets": setup.n_packets,
        "stalled": bool(s.stalled),
        "steps": steps,
        "build_s": build_s,
        "compile_s": compile_s,
        "timed_runs": n_timed,
        "wall_s": wall_s,                       # best-of-n_timed
        "wall_mean_s": sum(walls) / n_timed,
        "steps_per_s": steps / wall_s,
        "sims_per_s": 1.0 / wall_s,
    }

    if batch_width > 0:
        cyc = [BATCH_POLICIES[i % len(BATCH_POLICIES)]
               for i in range(batch_width)]
        pols = {k: jax.numpy.asarray(v)
                for k, v in policy_arrays(cyc).items()}
        brun = runners.get_runner(meta, "policy_batch")
        sb = jax.block_until_ready(brun(consts, pols))      # compile
        t0 = time.perf_counter()
        for _ in range(iters):
            sb = jax.block_until_ready(brun(consts, pols))
        bwall = (time.perf_counter() - t0) / iters
        out["batch"] = {
            "width": batch_width,
            "wall_s": bwall,
            "sims_per_s": batch_width / bwall,
            "steps_per_s": int(np.asarray(sb.steps).sum()) / bwall,
            "batch_efficiency": (batch_width / bwall) / out["sims_per_s"],
        }

    out["fleet"] = [
        profile_fleet(name, W, iters, out["sims_per_s"])
        for W in fleet_widths]
    return out


def profile_fleet(name: str, width: int, iters: int,
                  serial_sims_per_s: float) -> dict:
    """Fleet sims/s at one cohort width: the SAME profiled policy as the
    serial measurement, replicated across seeds, so ``batch_efficiency``
    compares like with like (width-way parallelism of one workload)."""
    from repro.api import Experiment

    # slow tiers (xl) drain one wave; fast tiers use >= 2 waves so the
    # retire/refill machinery is inside the measured window
    n = width if serial_sims_per_s < 5 else max(width, min(64, 4 * width))
    exp = Experiment(scenarios=name,
                     policies=[dict(seed=i, **PROFILE_POLICY)
                               for i in range(n)])
    exp.run_fleet(width=width)                              # compile
    walls = []
    for _ in range(iters):
        t0 = time.perf_counter()
        # the retire path extracts to host numpy, but the explicit sync
        # keeps the timing honest if that ever changes (jaxcheck:naked-timer)
        jax.block_until_ready(exp.run_fleet(width=width).states)
        walls.append(time.perf_counter() - t0)
    wall = min(walls)
    return {
        "width": width,
        "sims": n,
        "wall_s": wall,
        "sims_per_s": n / wall,
        "batch_efficiency": (n / wall) / serial_sims_per_s,
    }


def check_regression(report: dict, baseline_path: str,
                     max_regress: float) -> int:
    """Exit code: 1 if any shared tier's steps/s fell > max_regress."""
    with open(baseline_path) as f:
        base = json.load(f)
    failures = []
    for tier, cur in report["tiers"].items():
        ref = base.get("tiers", {}).get(tier)
        if not ref:
            continue
        floor = ref["steps_per_s"] * (1.0 - max_regress)
        status = "OK" if cur["steps_per_s"] >= floor else "REGRESSED"
        print(f"perf gate [{tier:6}] {cur['steps_per_s']:10.0f} steps/s "
              f"vs baseline {ref['steps_per_s']:10.0f} "
              f"(floor {floor:10.0f}) {status}")
        if status != "OK":
            failures.append(tier)
    if failures:
        print(f"steps/s regression > {max_regress:.0%} on: "
              f"{', '.join(failures)} (refresh the baseline in-PR if "
              "intentional)")
        return 1
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenarios", nargs="+",
                    default=[t for t, _, _, _ in TIERS],
                    choices=[t for t, _, _, _ in TIERS],
                    help="size tiers to profile")
    ap.add_argument("--iters", type=int, default=3,
                    help="timed runs per measurement")
    ap.add_argument("--batch-width", type=int, default=None,
                    help="policy-batch width for sims/s "
                         "(0 = skip; default: per-tier)")
    ap.add_argument("--widths", default=None,
                    help="comma-separated fleet cohort widths, e.g. "
                         "1,6,32 (default: per-tier; empty string skips "
                         "the fleet section)")
    _cli.add_json_arg(ap)
    _cli.add_gate_args(ap, "BENCH_engine.json",
                       "allowed fractional steps/s drop vs --baseline")
    args = ap.parse_args(argv)

    by_tier = {t: (name, bw, fw) for t, name, bw, fw in TIERS}
    report = {"benchmark": "engine_profile",
              "backend": jax.default_backend(),
              "iters": args.iters,
              "tiers": {}}
    hdr = (f"{'tier':6} {'scenario':14} {'tasks':>6} {'pkts':>6} "
           f"{'steps':>6} {'wall(s)':>8} {'steps/s':>9} {'sims/s':>7}")
    print(hdr)
    print("-" * len(hdr))
    for tier in args.scenarios:
        name, tier_bw, tier_fw = by_tier[tier]
        bw = tier_bw if args.batch_width is None else args.batch_width
        fw = (tier_fw if args.widths is None else
              tuple(int(w) for w in args.widths.split(",") if w))
        r = profile_scenario(name, args.iters, bw, fw)
        report["tiers"][tier] = r
        sims = r.get("batch", {}).get("sims_per_s", r["sims_per_s"])
        print(f"{tier:6} {name:14} {r['n_tasks']:6d} "
              f"{r['n_packets']:6d} {r['steps']:6d} {r['wall_s']:8.3f} "
              f"{r['steps_per_s']:9.0f} {sims:7.2f}"
              + ("  STALLED" if r["stalled"] else ""))
        for fr in r["fleet"]:
            print(f"  fleet width={fr['width']:<4d} "
                  f"{fr['sims']:3d} sims in {fr['wall_s']:7.3f}s  "
                  f"{fr['sims_per_s']:8.1f} sims/s  "
                  f"batch_efficiency={fr['batch_efficiency']:.2f}x")

    _cli.write_report(report, args.json)
    return _cli.gate(report, args, check_regression)


if __name__ == "__main__":
    sys.exit(main())
