"""Shared CLI plumbing for the sweep benchmarks.

Every sweep grew the same three flags (``--json``, ``--baseline``,
``--max-regress``) and the same report-write block by copy-paste; this
module is the single copy.  Behavior is identical to the previous
inline versions — per-benchmark help strings come in as arguments.
"""
from __future__ import annotations

import json
import os


def add_json_arg(ap, help_text: str = "write the machine-readable report"):
    ap.add_argument("--json", metavar="PATH", default=None, help=help_text)


def add_gate_args(ap, baseline_name: str, regress_help: str):
    """The perf-gate pair: ``--baseline`` names the committed BENCH_*.json
    to diff against, ``--max-regress`` the allowed fractional drop."""
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help=f"committed {baseline_name} to gate against")
    ap.add_argument("--max-regress", type=float, default=0.2,
                    help=regress_help)


def write_report(report: dict, path) -> None:
    """Write the JSON report (no-op when ``path`` is falsy), creating the
    parent directory exactly like the old inline blocks did."""
    if not path:
        return
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {path}")


def gate(report: dict, args, check_regression) -> int:
    """Run the benchmark's own ``check_regression`` against ``--baseline``
    when given; 0 otherwise (the old trailing two lines of every main)."""
    if args.baseline:
        return check_regression(report, args.baseline, args.max_regress)
    return 0
