"""Aggregate experiments/dryrun/*.json into the §Roofline table."""
from __future__ import annotations

import glob
import json
import os
from typing import Dict, List

from repro.configs import ARCH_IDS, SHAPES


def load(dirpath: str = "experiments/dryrun") -> List[Dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(dirpath, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt(rows: List[Dict], mesh: str = "16x16") -> str:
    out = ["| arch | shape | dom | compute_s | memory_s | coll_s | "
           "useful | MFU-bound | HBM GiB | cnt | status |",
           "|---|---|---|---|---|---|---|---|---|---|---|"]
    index = {(r["arch"], r["shape"]): r for r in rows
             if r.get("mesh") == mesh}
    for arch in ARCH_IDS:
        for shape in SHAPES:
            r = index.get((arch, shape))
            if r is None:
                out.append(f"| {arch} | {shape} | - | | | | | | | | missing |")
            elif r.get("status") == "n/a":
                out.append(f"| {arch} | {shape} | - | | | | | | | | "
                           f"N/A ({r['reason'][:40]}...) |")
            elif r.get("status") != "ok":
                out.append(f"| {arch} | {shape} | - | | | | | | | | FAIL |")
            else:
                rf = r["roofline"]
                ext = "L2x" if r.get("depth_extrapolated") else "1x"
                out.append(
                    f"| {arch} | {shape} | {rf['dominant'][:4]} "
                    f"| {rf['compute_s']:.3f} | {rf['memory_s']:.3f} "
                    f"| {rf['collective_s']:.3f} | {rf['useful_ratio']:.2f} "
                    f"| {rf['mfu_bound'] * 100:.1f}% "
                    f"| {r['memory']['temp_gib']:.1f} | {ext} | ok |")
    return "\n".join(out)


def main(dirpath: str = "experiments/dryrun") -> Dict:
    rows = load(dirpath)
    ok = [r for r in rows if r.get("status") == "ok"]
    na = [r for r in rows if r.get("status") == "n/a"]
    fail = [r for r in rows if r.get("status") == "fail"]
    print(f"roofline_table: {len(ok)} ok / {len(na)} n/a / "
          f"{len(fail)} fail / {len(rows)} total cells")
    if ok:
        print(fmt(rows))
    return {"ok": len(ok), "na": len(na), "fail": len(fail),
            "table_md": fmt(rows)}


if __name__ == "__main__":
    main()
