"""DES-vs-analytic validation of the collective-schedule advisor (the
paper's simulator applied to the TPU pod — DESIGN.md §3)."""
from __future__ import annotations

import json
from typing import Dict

from repro.roofline import V5E, advise_allreduce, analytic_time


def main(quick: bool = False) -> Dict:
    rows = []
    meshes = [(2, 2), (4, 4)] if not quick else [(2, 2)]
    for mesh in meshes:
        n = mesh[0] * mesh[1]
        for mb in (1e6, 100e6):
            advs = advise_allreduce(mb, mesh)
            for a in advs:
                an = analytic_time(a.schedule, n, mb, V5E, mesh)
                err = abs(a.predicted_s - an) / an * 100
                rows.append({"mesh": f"{mesh[0]}x{mesh[1]}",
                             "bytes": mb, "schedule": a.schedule,
                             "des_s": a.predicted_s, "analytic_s": an,
                             "err_pct": err})
    print("advisor_validation (DES vs analytic ring formulas):")
    worst = 0.0
    for r in rows:
        worst = max(worst, r["err_pct"])
        print(f"  {r['mesh']} {r['bytes'] / 1e6:6.0f}MB "
              f"{r['schedule']:11s} des={r['des_s'] * 1e3:9.3f}ms "
              f"analytic={r['analytic_s'] * 1e3:9.3f}ms "
              f"err={r['err_pct']:.2f}%")
    print(f"  worst error: {worst:.2f}%")
    assert worst < 1.0, "DES disagrees with closed-form ring schedules"
    return {"rows": rows, "worst_err_pct": worst}


if __name__ == "__main__":
    json.dump(main(), open("experiments/advisor_validation.json", "w"),
              indent=1)
