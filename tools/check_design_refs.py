#!/usr/bin/env python3
"""Verify every ``DESIGN.md §<section>`` reference in the source tree
resolves to a real heading in DESIGN.md (run by CI and tests/test_docs.py).

A reference is any ``DESIGN.md §<token>`` occurrence in a .py file under
src/, benchmarks/, examples/, tools/ or tests/; a section resolves if some
markdown heading line in DESIGN.md contains ``§<token>`` not immediately
followed by more token characters (so §2 does not match a §20 heading).
Bare ``DESIGN.md`` mentions only require the file to exist.

Static-analyzer rule references resolve the same way: a ``jaxcheck:<id>``
token in source (e.g. ``jaxcheck:sort-in-loop``) resolves iff DESIGN.md's
rule catalog (§12) documents that exact token.  Suppression comments
(``# jaxcheck: disable=...``, with a space after the colon) are not
references and are skipped.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

REF_RE = re.compile(r"DESIGN\.md\s*§([A-Za-z0-9.-]+)")
RULE_RE = re.compile(r"jaxcheck:([a-z][a-z0-9-]*)")
SCAN_DIRS = ("src", "benchmarks", "examples", "tools", "tests")


def collect_refs(root: Path):
    """-> list of (file, lineno, section_token)."""
    refs = []
    for d in SCAN_DIRS:
        for py in sorted((root / d).rglob("*.py")):
            for i, line in enumerate(py.read_text().splitlines(), 1):
                for m in REF_RE.finditer(line):
                    refs.append((py.relative_to(root), i,
                                 m.group(1).rstrip(".")))
    return refs


def heading_sections(design_md: Path):
    """-> set of §-tokens declared by markdown headings in DESIGN.md."""
    tokens = set()
    for line in design_md.read_text().splitlines():
        if not line.lstrip().startswith("#"):
            continue
        for m in re.finditer(r"§([A-Za-z0-9.-]+)", line):
            tokens.add(m.group(1).rstrip("."))
    return tokens


def collect_rule_refs(root: Path):
    """-> list of (file, lineno, rule_id) for ``jaxcheck:<id>`` tokens."""
    refs = []
    for d in SCAN_DIRS:
        for py in sorted((root / d).rglob("*.py")):
            for i, line in enumerate(py.read_text().splitlines(), 1):
                for m in RULE_RE.finditer(line):
                    refs.append((py.relative_to(root), i, m.group(1)))
    return refs


def documented_rules(design_md: Path):
    """-> set of rule ids DESIGN.md documents as ``jaxcheck:<id>``."""
    return set(RULE_RE.findall(design_md.read_text()))


def check(root: Path) -> list[str]:
    """-> list of error strings (empty = all references resolve)."""
    design = root / "DESIGN.md"
    refs = collect_refs(root)
    if not design.exists():
        return [f"DESIGN.md missing but referenced {len(refs)} time(s)"]
    sections = heading_sections(design)
    errors = []
    for f, line, token in refs:
        if token not in sections:
            errors.append(f"{f}:{line}: DESIGN.md §{token} has no matching "
                          f"heading (have: {sorted(sections)})")
    rules = documented_rules(design)
    for f, line, rule in collect_rule_refs(root):
        if rule not in rules:
            errors.append(f"{f}:{line}: jaxcheck:{rule} is not documented "
                          f"in DESIGN.md's rule catalog "
                          f"(have: {sorted(rules)})")
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    errors = check(root)
    refs = collect_refs(root)
    if errors:
        print("\n".join(errors))
        return 1
    rule_refs = collect_rule_refs(root)
    print(f"ok: {len(refs)} DESIGN.md § reference(s) across "
          f"{len({f for f, _, _ in refs})} file(s) all resolve "
          f"({len(heading_sections(root / 'DESIGN.md'))} sections declared); "
          f"{len(rule_refs)} jaxcheck:<rule> reference(s) documented")
    return 0


if __name__ == "__main__":
    sys.exit(main())
