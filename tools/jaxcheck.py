#!/usr/bin/env python3
"""jaxcheck — static analysis over the traced engine programs and the
source tree, plus the hot-loop primitive-budget gate (DESIGN.md §12).

Two passes:

* **jaxpr**: traces every registry scenario x program kind (serial
  runner, fleet chunk per static policy signature, streaming refill) to
  a ClosedJaxpr — nothing compiles or executes — and runs the structural
  checkers (packet-axis sort/scatter in the loop body, dtype drift,
  batched-away fast-path conds, donation aliasing, carry stability).
  Per-program watched-primitive counts are diffed against the committed
  ledger ``experiments/PRIM_BUDGET.json``.
* **ast**: lints ``src/repro/{core,api,scenarios}`` and ``benchmarks/``
  for tracer-unsafe host idioms (builtin casts on traced values,
  unseeded RNG, naked benchmark timers, ...).

Exit status is nonzero iff any error-severity finding survives.

  PYTHONPATH=src python tools/jaxcheck.py \
      --json --baseline experiments/PRIM_BUDGET.json        # the CI gate
  PYTHONPATH=src python tools/jaxcheck.py --quick           # smoke run
  PYTHONPATH=src python tools/jaxcheck.py --update-baseline # refresh
  PYTHONPATH=src python tools/jaxcheck.py --seed sort-in-loop --quick
      # falsifiability: injects a doctored program, MUST exit nonzero
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys
import time
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
sys.path.insert(0, str(ROOT / "src"))

DEFAULT_BASELINE = "experiments/PRIM_BUDGET.json"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="jaxcheck",
        description="static analyzer + primitive-budget gate "
                    "(DESIGN.md §12)")
    ap.add_argument("--json", metavar="PATH", nargs="?", default=None,
                    const="experiments/jaxcheck.json",
                    help="write the machine-readable findings report "
                         "(default path when the flag is bare)")
    ap.add_argument("--baseline", metavar="PATH", default=None,
                    help=f"committed primitive-budget ledger to diff "
                         f"against (e.g. {DEFAULT_BASELINE})")
    ap.add_argument("--update-baseline", action="store_true",
                    help="rewrite --baseline (default "
                         f"{DEFAULT_BASELINE}) from the current sweep, "
                         "preserving its allowlist")
    ap.add_argument("--scenarios", nargs="+", default=None,
                    help="restrict the jaxpr sweep to these registry "
                         "scenarios (default: all)")
    ap.add_argument("--kinds", nargs="+", default=("serial", "fleet",
                                                   "refill"),
                    choices=("serial", "fleet", "refill"),
                    help="program kinds to trace")
    ap.add_argument("--max-sigs", type=int, default=None,
                    help="cap the fleet static-signature sweep (default: "
                         "every routing x traffic x placement combo)")
    ap.add_argument("--quick", action="store_true",
                    help="paper-fabric only, one fleet signature — the "
                         "fast pre-commit pass")
    ap.add_argument("--seed", metavar="RULE", default=None,
                    help="inject a doctored program violating RULE "
                         "(falsifiability check: the run must go red)")
    ap.add_argument("--no-jaxpr", action="store_true",
                    help="skip the jaxpr pass")
    ap.add_argument("--no-ast", action="store_true",
                    help="skip the AST pass")
    ap.add_argument("--list-rules", action="store_true",
                    help="print the rule catalog and exit")
    ap.add_argument("-q", "--quiet", action="store_true",
                    help="suppress per-program progress lines")
    args = ap.parse_args(argv)

    from repro.analysis import (JAXPR_RULES, RULES, analyze, clean_trace,
                                diff_ledger, doctored_trace, iter_traces,
                                lint_tree, load_ledger, refresh_ledger,
                                save_ledger, static_sigs)
    from repro.analysis.checkers import check_donation_policy
    from repro.api import runners

    if args.list_rules:
        for rid in sorted(RULES):
            kind = "jaxpr" if rid in JAXPR_RULES else "ast"
            print(f"jaxcheck:{rid:16} [{kind}] {RULES[rid]}")
        return 0

    t0 = time.perf_counter()
    findings = []
    programs = {}
    notes = []

    scenarios, sigs = args.scenarios, None
    if args.quick:
        scenarios = scenarios or ["paper-fabric"]
        sigs = static_sigs()[:1]
    elif args.max_sigs is not None:
        sigs = static_sigs()[: args.max_sigs]
    # the missing/extra-program ledger checks only make sense when the
    # sweep covers everything the ledger covers
    full_sweep = (scenarios is None and sigs is None
                  and tuple(args.kinds) == ("serial", "fleet", "refill"))

    if not args.no_jaxpr:
        progress = (lambda s: None) if args.quiet else \
            (lambda s: print(f"  {s}", flush=True))
        traces = list(iter_traces(scenarios, sigs, kinds=args.kinds,
                                  progress=progress))
        if args.seed:
            if args.seed not in ("carry-stability",):
                traces.append(doctored_trace(args.seed))
            else:
                # two same-meta programs with different carries
                a, b = clean_trace(), clean_trace(n_packets=96)
                traces += [a, b]
        findings, programs = analyze(traces)
        findings += check_donation_policy(runners.donation_argnums)

        baseline_path = args.baseline or (
            DEFAULT_BASELINE if args.update_baseline else None)
        if args.update_baseline:
            if args.seed or not full_sweep:
                print("refusing --update-baseline on a partial or seeded "
                      "sweep (drop --quick/--scenarios/--kinds/--seed)")
                return 2
            old = load_ledger(ROOT / baseline_path)
            ledger = refresh_ledger(programs, old)
            save_ledger(ledger, ROOT / baseline_path)
            print(f"wrote {baseline_path} "
                  f"({len(ledger['programs'])} programs)")
        elif baseline_path:
            baseline = load_ledger(ROOT / baseline_path)
            if baseline is None:
                print(f"no baseline at {baseline_path} — run "
                      "--update-baseline to create it")
                return 2
            # the doctored program is never in the ledger; keep its
            # findings but skip the its-not-in-the-budget noise
            budget_programs = {k: v for k, v in programs.items()
                               if not k.startswith("doctored/")}
            diff_findings, notes = diff_ledger(budget_programs, baseline,
                                               full_sweep=full_sweep)
            findings += diff_findings

    if not args.no_ast:
        findings += lint_tree(ROOT)

    wall = time.perf_counter() - t0
    errors = [f for f in findings if f.severity == "error"]
    warnings = [f for f in findings if f.severity != "error"]

    for note in notes:
        print(f"note: {note}")
    for f in findings:
        print(f.render())
    print(f"jaxcheck: {len(programs)} program(s) traced, "
          f"{len(errors)} error(s), {len(warnings)} warning(s) "
          f"in {wall:.1f}s")

    if args.json:
        report = {
            "tool": "jaxcheck",
            "programs": programs,
            "notes": notes,
            "errors": [dataclasses.asdict(f) for f in errors],
            "warnings": [dataclasses.asdict(f) for f in warnings],
            "wall_s": wall,
        }
        path = ROOT / args.json
        os.makedirs(path.parent, exist_ok=True)
        with open(path, "w") as fh:
            json.dump(report, fh, indent=1)
        print(f"wrote {args.json}")

    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
